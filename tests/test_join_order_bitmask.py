"""Differential tests: the vectorized bitmask DP (`dp_join_order`) must pick
exactly the plan of the reference oracle (`dp_join_order_ref`) — same cost,
same leaf order, same join strategies — on every query shape: star, hybrid,
path, single-star, disconnected, and randomly generated multi-star graphs."""
import numpy as np
import pytest

from repro.core.cost import CostModel
from repro.core.decomposition import decompose
from repro.core.join_order import dp_join_order, dp_join_order_ref
from repro.core.source_selection import select_sources
from repro.query.algebra import BGPQuery, Const, TriplePattern, Var


def _tree_shape(t):
    if t.kind == "leaf":
        return ("leaf", tuple(sorted(t.stars)), tuple(t.sources or []))
    return ("join", t.strategy, _tree_shape(t.left), _tree_shape(t.right))


def _assert_equivalent(q, stats, dp_backend="numpy"):
    graph = decompose(q)
    sel = select_sources(graph, stats)
    cm = CostModel()
    new = dp_join_order(graph, stats, sel, cm, q.distinct, dp_backend=dp_backend)
    ref = dp_join_order_ref(graph, stats, sel, cm, q.distinct)
    assert new.leaf_order() == ref.leaf_order(), q.name
    assert _tree_shape(new) == _tree_shape(ref), q.name
    np.testing.assert_allclose(new.cost, ref.cost, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(new.cardinality, ref.cardinality, rtol=1e-9, atol=1e-12)
    return graph


def test_bitmask_dp_matches_ref_on_workload(small_stats, workload):
    """Full generated workload: star (ST), hybrid (CD-style), path queries."""
    shapes = set()
    for q in workload:
        graph = _assert_equivalent(q, small_stats)
        shapes.add(min(len(graph.stars), 3))
    assert {1, 2} <= shapes, "workload should cover single- and multi-star queries"


def test_bitmask_dp_single_pattern(small_stats, workload):
    q0 = workload[0]
    _assert_equivalent(BGPQuery([q0.patterns[0]], distinct=True), small_stats)
    _assert_equivalent(BGPQuery([q0.patterns[0]], distinct=False), small_stats)


def test_bitmask_dp_disconnected(small_stats, workload):
    """Two independent stars (no shared variables) -> component fallback."""
    stars = [q for q in workload if q.name.startswith("ST")]
    assert len(stars) >= 2
    a, b = stars[0], stars[1]

    def rename(tp, suffix):
        def r(t):
            return Var(t.name + suffix) if isinstance(t, Var) else t
        return TriplePattern(r(tp.s), r(tp.p), r(tp.o))

    for distinct in (True, False):
        q = BGPQuery([rename(tp, "_l") for tp in a.patterns]
                     + [rename(tp, "_r") for tp in b.patterns], distinct=distinct)
        graph = _assert_equivalent(q, small_stats)
        assert len(graph.stars) >= 2


def test_bitmask_dp_random_star_graphs(tiny_stats):
    """Random chains of linked stars (3-7 meta-nodes) synthesized from the CP
    statistics (shared generator with the planner micro-benchmark); includes
    degenerate cases source selection prunes to zero sources."""
    from benchmarks.planner_bench import chain_query

    rng = np.random.default_rng(42)
    n_cases = 0
    for trial in range(40):
        n_stars = int(rng.integers(3, 8))
        q = chain_query(tiny_stats, n_stars, k_extra=int(rng.integers(0, 3)), rng=rng)
        q = BGPQuery(q.patterns, distinct=bool(rng.random() < 0.5), name=f"RG{trial}")
        _assert_equivalent(q, tiny_stats)
        n_cases += 1
    assert n_cases >= 20


def test_bitmask_dp_uses_bind_joins(small_stats, workload):
    """The DP's plan space is actually exercised: across the workload, plans
    contain joins and at least one of them is a bind join."""
    strategies = set()
    for q in workload:
        graph = decompose(q)
        sel = select_sources(graph, small_stats)
        tree = dp_join_order(graph, small_stats, sel, CostModel(), q.distinct)

        def walk(t):
            if t.kind == "leaf":
                return
            strategies.add(t.strategy)
            walk(t.left)
            walk(t.right)

        walk(tree)
    assert "bind" in strategies, f"no bind joins in the whole workload: {strategies}"
    assert strategies <= {"hash", "bind"}


def test_bitmask_dp_merges_exclusive_groups(tiny_fed):
    """Single-source federation: linked stars pinned to the same source must
    merge into one exclusive-group leaf (in both DP implementations)."""
    from repro.core.characteristic_pairs import compute_characteristic_pairs
    from repro.core.characteristic_sets import compute_characteristic_sets
    from repro.core.federation import FederatedStats

    fed, _ = tiny_fed
    table = next(s.table for s in fed.sources
                 if compute_characteristic_pairs(
                     s.table, compute_characteristic_sets(s.table), 0).n_cp)
    cs = compute_characteristic_sets(table)
    cp = compute_characteristic_pairs(table, cs, 0)
    stats = FederatedStats(cs=[cs], intra_cp=[cp])
    rng = np.random.default_rng(7)
    merged = 0
    for _ in range(10):
        r = int(rng.integers(cp.n_cp))
        pred, cs1, cs2 = int(cp.pred[r]), int(cp.cs1[r]), int(cp.cs2[r])
        pats = [TriplePattern(Var("x"), Const(int(p)), Var(f"xv{j}"))
                for j, p in enumerate(cs.preds_of(cs1)[:2]) if int(p) != pred]
        pats.append(TriplePattern(Var("x"), Const(pred), Var("y")))
        pats += [TriplePattern(Var("y"), Const(int(p)), Var(f"yv{j}"))
                 for j, p in enumerate(cs.preds_of(cs2)[:2])]
        q = BGPQuery(pats, distinct=True)
        graph = _assert_equivalent(q, stats)
        if len(graph.stars) < 2:
            continue
        sel = select_sources(graph, stats)
        tree = dp_join_order(graph, stats, sel, CostModel(), True)

        def has_merge(t):
            if t.kind == "leaf":
                return len(t.stars) > 1
            return has_merge(t.left) or has_merge(t.right)

        if has_merge(tree):
            merged += 1
    assert merged >= 1, "no exclusive-group leaf in any single-source plan"


# -- chunked + connected enumeration (the large-star path) --------------------

def test_rel_submasks_match_reference_enumeration_order():
    """The lexsort-built submask table must equal the reference order:
    popcount ascending, itertools.combinations-lex within a popcount."""
    from itertools import combinations

    from repro.core.join_order import _rel_submasks

    for s in range(2, 11):
        want = [sum(1 << j for j in sub)
                for k in range(1, s) for sub in combinations(range(s), k)]
        assert _rel_submasks(s).tolist() == want, f"s={s}"


def _assert_shaped_equivalent(shape, n_stars, seed, block_bytes=None,
                              dp_backend="numpy"):
    from repro.rdf.shapes import shaped_planning_inputs

    graph, stats, sel, q = shaped_planning_inputs(shape, n_stars, seed)
    assert len(graph.stars) == n_stars
    cm = CostModel()
    new = dp_join_order(graph, stats, sel, cm, q.distinct, block_bytes=block_bytes,
                        dp_backend=dp_backend)
    ref = dp_join_order_ref(graph, stats, sel, cm, q.distinct)
    assert new.leaf_order() == ref.leaf_order(), (shape, n_stars)
    assert _tree_shape(new) == _tree_shape(ref), (shape, n_stars)
    np.testing.assert_allclose(new.cost, ref.cost, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(new.cardinality, ref.cardinality, rtol=1e-9,
                               atol=1e-12)


def test_large_star_differential_n12():
    """Past the old 14-star fallback regime's test sizes: chunked + connected
    enumeration still returns the reference's exact plan at 12 stars."""
    _assert_shaped_equivalent("chain", 12, seed=5)
    _assert_shaped_equivalent("tree", 12, seed=17)


@pytest.mark.slow
def test_large_star_differential_n13_n14():
    """The sizes the old MAX_BITMASK_STARS fallback used to silently punt on:
    the bitmask path must match the reference oracle bit-for-bit."""
    _assert_shaped_equivalent("chain", 14, seed=3)
    _assert_shaped_equivalent("tree", 13, seed=11)


def test_chunked_tiles_identical_plans():
    """A tiny block budget forces many row/column tiles; the running
    first-strict-minimum reduction must preserve the exact plan (including
    tie-breaking) of the single-tile run and of the reference."""
    for shape, n_stars, seed in (("clique", 9, 7), ("chain", 12, 7), ("tree", 10, 7)):
        _assert_shaped_equivalent(shape, n_stars, seed, block_bytes=2048)


def test_min_tile_width_wide_member_batch_tiny_budget():
    """Regression: ``block_bytes // (_PAIR_BYTES * B)`` used to degenerate to
    1-pair tiles for wide member batches under a small budget, turning the
    sweep into a Python-level per-pair loop.  A 256-member batch under a tiny
    ``block_bytes`` must now split the member axis instead (MIN_TILE_ELEMS
    floor), plan in bounded time, and return exactly the plans of the
    default-budget sweep."""
    import time

    from repro.core.join_order import (
        MIN_TILE_ELEMS,
        _PAIR_BYTES,
        dp_join_order_batch,
    )
    from repro.rdf.shapes import shaped_planning_inputs

    graph, stats, sel, q = shaped_planning_inputs("clique", 8, seed=3)
    cm = CostModel()
    base = dp_join_order(graph, stats, sel, cm, q.distinct)   # warm memos too
    B = 256
    block_bytes = 4096
    assert block_bytes // (_PAIR_BYTES * B) < MIN_TILE_ELEMS  # floor engages
    t0 = time.perf_counter()
    trees = dp_join_order_batch([graph] * B, stats, [sel] * B, cm, q.distinct,
                                block_bytes=block_bytes)
    elapsed = time.perf_counter() - t0
    assert elapsed < 30.0, f"tiny-budget 256-member sweep took {elapsed:.1f}s"
    assert len(trees) == B
    for t in trees:
        assert _tree_shape(t) == _tree_shape(base)
        assert t.leaf_order() == base.leaf_order()
        assert t.cost == base.cost and t.cardinality == base.cardinality


def test_weighted_cost_model_with_source_less_stars():
    """Regression: ``CostModel.src_w`` used to raise ``max() arg is an empty
    sequence`` (killing both DP implementations at leaf seeding) whenever
    ``source_weight`` was configured and any star's selection was pruned to
    zero sources.  Empty selections must weigh 1.0 and plan normally."""
    from repro.rdf.shapes import shaped_planning_inputs

    graph, stats, sel, q = shaped_planning_inputs("clique", 7, seed=9)
    assert any(not s for s in sel.star_sources)       # the trigger
    cm = CostModel(source_weight={0: 2.0, 3: 0.5})
    assert cm.src_w([]) == 1.0
    new = dp_join_order(graph, stats, sel, cm, q.distinct)
    ref = dp_join_order_ref(graph, stats, sel, cm, q.distinct)
    assert new.leaf_order() == ref.leaf_order()
    assert _tree_shape(new) == _tree_shape(ref)
    np.testing.assert_allclose(new.cost, ref.cost, rtol=1e-9, atol=1e-12)


# -- dp_backend='jax': the on-device layer sweep ------------------------------

from repro.core.join_order import DP_BACKENDS  # noqa: E402 — every backend
# added there is automatically covered by the parametrized differentials


def test_dp_backend_rejects_unknown(small_stats, workload):
    graph = decompose(workload[0])
    sel = select_sources(graph, small_stats)
    with pytest.raises(ValueError, match="dp_backend"):
        dp_join_order(graph, small_stats, sel, dp_backend="tpu")


@pytest.mark.parametrize("dp_backend", DP_BACKENDS)
def test_backend_differential_workload_sample(small_stats, workload, dp_backend):
    """Both backends must return the reference oracle's exact plan on real
    workload queries (the jax path runs the Pallas kernel, interpret mode)."""
    multi = [q for q in workload if len(decompose(q).stars) >= 2]
    assert len(multi) >= 4
    for q in multi[:4]:
        _assert_equivalent(q, small_stats, dp_backend=dp_backend)


@pytest.mark.parametrize("dp_backend", DP_BACKENDS)
@pytest.mark.parametrize("shape,n_stars", [("chain", 6), ("tree", 7),
                                           ("clique", 6)])
def test_backend_differential_shapes(shape, n_stars, dp_backend):
    _assert_shaped_equivalent(shape, n_stars, seed=13, dp_backend=dp_backend)


def test_jax_backend_chain12_differential():
    """Acceptance: the jax backend matches the reference bit-for-bit at the
    12-star chain size (the tree/clique 12-star cases run in the slow tier)."""
    _assert_shaped_equivalent("chain", 12, seed=5, dp_backend="jax")


@pytest.mark.slow
def test_jax_backend_n12_tree_clique_differential():
    _assert_shaped_equivalent("tree", 12, seed=17, dp_backend="jax")
    _assert_shaped_equivalent("clique", 12, seed=7, dp_backend="jax")


def test_jax_backend_tiled_identical_plans():
    """A small block budget forces multi-tile layers through the kernel; the
    cross-tile strictly-less merge must preserve the exact plan."""
    _assert_shaped_equivalent("clique", 9, seed=7, block_bytes=2048 * 160,
                              dp_backend="jax")


@pytest.mark.parametrize("dp_backend", DP_BACKENDS)
def test_backend_batch_b8_bit_identical(dp_backend):
    """B >= 8 member-stacked sweep: every member's tree must be bit-identical
    (cost, cardinality, leaf order, strategies, sources) to the single-member
    plan, under either backend."""
    from repro.core.join_order import dp_join_order_batch
    from repro.rdf.shapes import shaped_planning_inputs

    graph, stats, sel, q = shaped_planning_inputs("tree", 8, seed=41)
    cm = CostModel()
    single = dp_join_order(graph, stats, sel, cm, q.distinct)
    trees = dp_join_order_batch([graph] * 8, stats, [sel] * 8, cm, q.distinct,
                                dp_backend=dp_backend)
    for t in trees:
        assert _tree_shape(t) == _tree_shape(single)
        assert t.leaf_order() == single.leaf_order()
        assert t.cost == single.cost and t.cardinality == single.cardinality


def test_18_star_chain_plans_through_bitmask_path():
    """Acceptance: an 18-star chain plans through the bitmask DP (no
    fallback exists anymore), tiled and untiled runs agree exactly, and the
    plan is a valid join tree over all 18 stars."""
    from repro.rdf.shapes import shaped_planning_inputs

    graph, stats, sel, q = shaped_planning_inputs("chain", 18, seed=1)
    cm = CostModel()
    tree = dp_join_order(graph, stats, sel, cm, q.distinct)
    assert sorted(tree.leaf_order()) == list(range(18))

    def check(t):
        if t.kind == "leaf":
            return set(t.stars)
        ls, rs = check(t.left), check(t.right)
        assert not (ls & rs) and set(t.stars) == ls | rs
        return set(t.stars)

    assert check(tree) == set(range(18))
    tiled = dp_join_order(graph, stats, sel, cm, q.distinct, block_bytes=1 << 20)
    assert tiled.leaf_order() == tree.leaf_order()
    assert tiled.cost == tree.cost
