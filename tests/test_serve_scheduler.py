"""Continuous-batching serving scheduler (``repro.serve``): shape-affine
deadline-driven admission, backpressure at the watermark, plan/execute
pipeline overlap, streaming completion, and per-request planning
attribution.  Scheduling is pure policy — the pipeline-vs-sync differential
pins down that it can never change answers."""
import threading
import time

import pytest

from repro.core.batch_planner import (
    AFFINITY_TIERS,
    AffinityKey,
    BatchPlanReport,
    plan_affinity,
)
from repro.engine.local import ExecutionResult, LocalEngine, naive_evaluate
from repro.serve import (
    AdmissionController,
    ArrivalQueue,
    BackpressureError,
    QueryServeEngine,
    ServeBase,
    ServeStats,
)

from benchmarks.planner_bench import object_variants, subject_variants


class FakeClock:
    """Deterministic engine clock: tests advance ``t`` by hand."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


class _Req:
    """Minimal request for controller-level tests."""

    def __init__(self, qid: int, deadline: float = 100.0):
        self.qid = qid
        self.deadline = deadline


def _key(sig, sel=None, pr=None, sh=None) -> AffinityKey:
    return AffinityKey(signature=(sig,), selection=None if sel is None else (sel,),
                       pricing=None if pr is None else (pr,),
                       shape=None if sh is None else (sh,))


# -- admission controller: deepest shared tier wins ---------------------------

def test_admission_matches_deepest_shared_tier():
    ac = AdmissionController(max_group=8)
    assert ac.add(_Req(0), _key("a", "s1", "p1", "h1"), 10.0) is None
    # one shared tier each, from deepest to shallowest — all land in group 0
    assert ac.add(_Req(1), _key("a", "s9", "p9", "h9"), 10.0) == "signature"
    assert ac.add(_Req(2), _key("b", "s1", "p8", "h8"), 10.0) == "selection"
    assert ac.add(_Req(3), _key("c", "s7", "p1", "h7"), 10.0) == "pricing"
    assert ac.add(_Req(4), _key("d", "s6", "p6", "h1"), 10.0) == "shape"
    # nothing shared: a new group
    assert ac.add(_Req(5), _key("e", "s5", "p5", "h5"), 20.0) is None
    assert len(ac) == 6
    batch, reason = ac.next_batch(now=0.0, force=True)
    assert reason == "forced"
    assert [r.qid for r in batch] == [0, 1, 2, 3, 4]
    batch2, _ = ac.next_batch(now=0.0, force=True)
    assert [r.qid for r in batch2] == [5]
    assert len(ac) == 0 and ac.next_batch(0.0, force=True) is None


def test_admission_deeper_tier_beats_shallower_group():
    """When two open groups match at different tiers the deepest wins: a
    signature match outranks a shape match regardless of group age."""
    ac = AdmissionController(max_group=8)
    ac.add(_Req(0), _key("a", "s1", "p1", "h1"), 10.0)     # old group, shape h1
    ac.add(_Req(1), _key("b", "s2", "p2", "h2"), 10.0)     # young group, sig b
    assert ac.add(_Req(2), _key("b", "s3", "p3", "h1"), 10.0) == "signature"
    batch, _ = ac.next_batch(0.0, force=True)
    assert [r.qid for r in batch] == [0]                   # group 0 is alone


def test_admission_full_group_flushes_before_deadline():
    ac = AdmissionController(max_group=2)
    ac.add(_Req(0), _key("a"), flush_at=1e9)
    assert not ac.ripe(now=0.0)
    ac.add(_Req(1), _key("a"), flush_at=1e9)
    assert ac.ripe(now=0.0)
    batch, reason = ac.next_batch(now=0.0)
    assert reason == "full" and [r.qid for r in batch] == [0, 1]


def test_admission_overflow_remainder_keeps_urgency():
    """A group larger than max_group flushes in chunks; the remainder's
    flush_at re-derives from the members left behind."""
    ac = AdmissionController(max_group=2)
    for qid, dl in enumerate((5.0, 7.0, 9.0)):
        ac.add(_Req(qid, deadline=dl), _key("a"), flush_at=dl)
    batch, reason = ac.next_batch(now=0.0)       # full: first two members
    assert reason == "full" and [r.qid for r in batch] == [0, 1]
    assert ac.next_flush_at() == 9.0             # not the flushed 5.0
    assert ac.next_batch(now=8.0) is None        # not ripe yet
    batch2, reason2 = ac.next_batch(now=9.5)
    assert reason2 == "deadline" and [r.qid for r in batch2] == [2]


def test_arrival_queue_is_fifo():
    aq = ArrivalQueue(max_group=2)
    for qid in range(3):
        aq.add(_Req(qid, deadline=50.0), None, flush_at=50.0)
    assert len(aq) == 3
    batch, reason = aq.next_batch(now=0.0)
    assert reason == "full" and [r.qid for r in batch] == [0, 1]
    assert aq.next_batch(now=0.0) is None
    batch2, reason2 = aq.next_batch(now=60.0)
    assert reason2 == "deadline" and [r.qid for r in batch2] == [2]


# -- engine: deadline-driven flush under a fake clock -------------------------

def test_deadline_flush_without_full_group(tiny_fed, tiny_stats, tiny_workload):
    fed, _ = tiny_fed
    clk = FakeClock()
    eng = QueryServeEngine(fed, tiny_stats, max_batch=64, clock=clk)
    req = eng.submit(tiny_workload[0], deadline=5.0)
    assert req.deadline == 5.0 and req.slo == 5.0
    assert eng.poll() == []                       # t=0: SLO budget not spent
    assert len(eng.queue) == 1
    clk.t = 4.9
    assert eng.poll() == []
    clk.t = 5.1
    done = eng.poll()
    assert [r.qid for r in done] == [req.qid]
    assert req.done and req.rows is not None
    assert eng.serve_stats.n_deadline_flushes == 1
    assert eng.serve_stats.n_full_flushes == 0
    assert eng.serve_stats.n_forced_flushes == 0


def test_group_flushes_at_earliest_member_deadline(tiny_fed, tiny_stats,
                                                   tiny_workload):
    """A late-arriving urgent request drags its whole affinity group forward:
    the group flushes as one batch at the earliest member deadline."""
    fed, _ = tiny_fed
    clk = FakeClock()
    eng = QueryServeEngine(fed, tiny_stats, max_batch=64, clock=clk)
    q = tiny_workload[0]
    lazy = eng.submit(q, deadline=50.0)
    urgent = eng.submit(q, deadline=2.0)
    assert urgent.affinity_tier == "signature"
    clk.t = 2.5
    done = eng.poll()
    assert {r.qid for r in done} == {lazy.qid, urgent.qid}
    assert eng.serve_stats.n_steps == 1           # one batch, one flush
    assert eng.serve_stats.n_deadline_flushes == 1


def test_full_batch_flushes_immediately(tiny_fed, tiny_stats, tiny_workload):
    fed, _ = tiny_fed
    clk = FakeClock()
    eng = QueryServeEngine(fed, tiny_stats, max_batch=2, clock=clk)
    q = tiny_workload[0]
    r0 = eng.submit(q, deadline=1e6)
    assert eng.poll() == []
    r1 = eng.submit(q, deadline=1e6)
    done = eng.poll()                             # t=0, deadlines far away
    assert {r.qid for r in done} == {r0.qid, r1.qid}
    assert eng.serve_stats.n_full_flushes == 1
    assert eng.serve_stats.n_deadline_flushes == 0


def test_engine_affinity_tiers_on_real_queries(tiny_fed, tiny_stats,
                                               tiny_workload):
    """submit() reports the tier a request joined its group at, and it is
    exactly the deepest tier where the affinity keys agree."""
    fed, _ = tiny_fed
    variants = None
    for q in tiny_workload:
        if len(q.patterns) < 2:
            continue
        ov, sv = object_variants(q, fed, 1), subject_variants(q, fed, 1)
        if ov and sv:
            variants = [q, ov[0], sv[0]]
            break
    assert variants, "workload must yield a templatable query"
    clk = FakeClock()
    eng = QueryServeEngine(fed, tiny_stats, max_batch=64, clock=clk)
    seen_keys: list = []

    def deepest_shared(kv):
        # the controller's contract: the first (deepest) tier whose key any
        # earlier request has registered
        for name, key in kv.tier_keys():
            if any(getattr(k, name) == key for k in seen_keys):
                return name
        return None

    reqs = []
    for v in [variants[0], variants[0]] + variants[1:]:
        kv = plan_affinity(v)
        want = deepest_shared(kv)
        req = eng.submit(v, deadline=100.0)
        assert req.affinity_tier == want, v.name
        assert req.affinity_tier is None or req.affinity_tier in AFFINITY_TIERS
        seen_keys.append(kv)
        reqs.append(req)
    assert reqs[0].affinity_tier is None          # founded the group
    assert reqs[1].affinity_tier == "signature"   # exact duplicate
    assert any(r.affinity_tier in ("selection", "pricing", "shape")
               for r in reqs[2:]), "a variant must share a sub-signature tier"
    n_groups = sum(1 for r in reqs if r.affinity_tier is None)
    clk.t = 200.0
    done = eng.poll()                             # one batch per group
    assert len(done) == len(reqs)
    assert eng.serve_stats.n_steps == n_groups


# -- exactly-once streaming ---------------------------------------------------

def test_poll_never_reports_a_request_twice(tiny_fed, tiny_stats,
                                            tiny_workload):
    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats, max_batch=4)
    reqs = [eng.submit(q, deadline=0.0) for q in tiny_workload]
    seen: list[int] = []
    for _ in range(50):
        seen.extend(r.qid for r in eng.poll())
        if len(seen) == len(reqs):
            break
    assert sorted(seen) == [r.qid for r in reqs]
    assert eng.poll() == []                        # drained: nothing new
    assert eng.drain() == []
    assert len(eng.finished) == len(reqs)          # cumulative history stays


def test_completed_iterator_streams_each_once(tiny_fed, tiny_stats,
                                              tiny_workload):
    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats, max_batch=4)
    reqs = [eng.submit(q, deadline=0.0) for q in tiny_workload]
    seen = [r.qid for r in eng.completed()]
    assert sorted(seen) == [r.qid for r in reqs]
    assert list(eng.completed()) == []


def test_mixed_step_and_poll_report_disjoint(tiny_fed, tiny_stats,
                                             tiny_workload):
    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats, max_batch=2)
    reqs = [eng.submit(q, deadline=0.0) for q in tiny_workload[:6]]
    a = eng.step()
    b = eng.poll()
    c = eng.drain()
    qids = [r.qid for r in a + b + c]
    assert sorted(qids) == [r.qid for r in reqs]
    assert len(set(qids)) == len(qids), "a request was reported twice"


# -- backpressure -------------------------------------------------------------

def test_backpressure_rejects_at_watermark(tiny_fed, tiny_stats,
                                           tiny_workload):
    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats, max_batch=8, queue_depth=2,
                           backpressure="reject")
    eng.submit(tiny_workload[0])
    eng.submit(tiny_workload[1])
    with pytest.raises(BackpressureError, match="watermark"):
        eng.submit(tiny_workload[2])
    assert eng.serve_stats.n_rejected == 1
    assert len(eng.queue) == 2                     # the reject queued nothing
    eng.drain()
    eng.submit(tiny_workload[2])                   # space again after drain
    assert eng.drain()[0].rows is not None
    assert eng.serve_stats.n_rejected == 1


def test_backpressure_block_requires_pipeline(tiny_fed, tiny_stats):
    fed, _ = tiny_fed
    with pytest.raises(ValueError, match="pipeline"):
        QueryServeEngine(fed, tiny_stats, backpressure="block", pipeline=False)


def test_backpressure_block_unblocks_when_worker_drains(tiny_fed, tiny_stats,
                                                        tiny_workload):
    fed, _ = tiny_fed
    with QueryServeEngine(fed, tiny_stats, max_batch=4, queue_depth=1,
                          backpressure="block", pipeline=True,
                          handoff_depth=8) as eng:
        done: list = []
        for q in tiny_workload[:4]:
            eng.submit(q, deadline=0.0)            # instantly ripe
            done.extend(eng.poll())
        done.extend(eng.drain())
        assert len(done) == 4
        assert eng.serve_stats.n_blocked >= 1, \
            "queue_depth=1 must have blocked at least one submit"
        assert eng.serve_stats.n_rejected == 0


# -- pipeline overlap ---------------------------------------------------------

def test_pipeline_results_match_synchronous(tiny_fed, tiny_stats,
                                            tiny_workload):
    """The acceptance differential: per-request rows from the pipelined
    affinity engine are byte-identical to the synchronous step() loop (and
    to the ground-truth evaluator on a sample)."""
    fed, _ = tiny_fed
    wave = []
    for q in tiny_workload:
        wave.append(q)
        if len(q.patterns) >= 2:
            wave.extend(object_variants(q, fed, 2))
    wave.extend(tiny_workload[:3])                 # exact duplicates

    sync = QueryServeEngine(fed, tiny_stats, max_batch=8)
    for q in wave:
        sync.submit(q)
    while sync.queue:
        sync.step()
    by_qid_sync = {r.qid: r for r in sync.finished}

    with QueryServeEngine(fed, tiny_stats, max_batch=8, pipeline=True,
                          default_slo_ms=1.0) as pipe:
        reqs = [pipe.submit(q) for q in wave]
        done = list(pipe.completed())
    assert sorted(r.qid for r in done) == [r.qid for r in reqs]
    for r in done:
        s = by_qid_sync[r.qid]
        assert r.query is s.query
        assert set(r.rows) == set(s.rows)
        for v in r.rows:
            assert r.rows[v].tobytes() == s.rows[v].tobytes(), (r.qid, v)
        assert r.stats_epoch == s.stats_epoch
    # spot-check against ground truth on one multi-pattern request
    probe = next(r for r in done if len(r.query.patterns) >= 2)
    want = naive_evaluate(fed, probe.query)
    proj = probe.query.effective_projection()
    n = len(next(iter(probe.rows.values()))) if probe.rows else 0
    got = set(zip(*[probe.rows[v].tolist() for v in proj])) if n else set()
    assert got == want


def test_pipeline_drain_and_counters(tiny_fed, tiny_stats, tiny_workload):
    fed, _ = tiny_fed
    with QueryServeEngine(fed, tiny_stats, max_batch=4, pipeline=True) as eng:
        reqs = [eng.submit(q) for q in tiny_workload]
        done = eng.drain()
        assert sorted(r.qid for r in done) == [r.qid for r in reqs]
        assert eng.drain() == []                   # only-new contract holds
        stats = eng.serve_stats
        assert stats.n_served == len(reqs)
        assert stats.n_planned == eng.optimizer.plan_cache.misses
        flushes = (stats.n_full_flushes + stats.n_deadline_flushes
                   + stats.n_forced_flushes)
        assert flushes == stats.n_steps >= 1


def test_step_raises_in_pipeline_mode(tiny_fed, tiny_stats):
    fed, _ = tiny_fed
    with QueryServeEngine(fed, tiny_stats, pipeline=True) as eng:
        with pytest.raises(RuntimeError, match="poll"):
            eng.step()


def test_worker_death_surfaces_at_next_call(tiny_fed, tiny_stats,
                                            tiny_workload):
    """A planner-thread exception must reach the caller as a RuntimeError on
    the next submit()/poll()/drain() — never a silent thread traceback."""
    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats, pipeline=True)
    boom = ValueError("planner exploded")

    def explode(queries):
        raise boom

    eng.optimizer.optimize_batch = explode
    eng.submit(tiny_workload[0], deadline=0.0)
    deadline = time.monotonic() + 5.0
    while eng._worker_error is None and time.monotonic() < deadline:
        time.sleep(0.005)
    assert eng._worker_error is boom
    with pytest.raises(RuntimeError, match="planner thread died") as ei:
        eng.poll()
    assert ei.value.__cause__ is boom
    with pytest.raises(RuntimeError, match="planner thread died"):
        eng.submit(tiny_workload[0])
    with pytest.raises(RuntimeError, match="planner thread died"):
        eng.drain()
    eng.close()


def test_close_is_idempotent_and_joins_worker(tiny_fed, tiny_stats,
                                              tiny_workload):
    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats, pipeline=True)
    worker = eng._worker
    assert worker.is_alive()
    eng.close()
    assert not worker.is_alive()
    eng.close()                                    # idempotent
    assert threading.active_count() >= 1


# -- per-request planning attribution (the satellite bugfix) ------------------

def test_cache_hit_not_charged_batch_planning_window(tiny_fed, tiny_stats,
                                                     tiny_workload):
    """Regression: the shared ``t_planned = t1`` stamp charged plan-cache
    hits the whole batch's planning window.  A hit is charged its own
    rebind (``optimization_ms``), clamped into the batch window."""
    fed, _ = tiny_fed
    ticks = iter(float(i) for i in range(100))
    eng = QueryServeEngine(fed, tiny_stats, clock=lambda: next(ticks))
    reqs = [eng.submit(q) for q in tiny_workload[:3]]     # clock: 0, 1, 2

    class _P:
        def __init__(self, cached, ms):
            self.cached = cached
            self.optimization_ms = ms
            self.stats_epoch = 0

    plans = [_P(cached=False, ms=900.0),     # cold: full window
             _P(cached=True, ms=50.0),       # hit: its own 50ms rebind
             _P(cached=True, ms=5000.0)]     # degenerate ms: clamped to t1
    eng.optimizer.optimize_batch = lambda queries: plans
    eng.optimizer.last_batch_report = BatchPlanReport(
        n_queries=3, cache_hits=2, n_planned=1, n_shapes=1)
    eng._plan_batch(reqs)                    # clock: t0=3, t1=4
    assert reqs[0].t_planned == 4.0
    assert reqs[1].t_planned == pytest.approx(3.0 + 50.0 * 1e-3)
    assert reqs[2].t_planned == 4.0          # min(t0 + 5s, t1) clamps
    assert reqs[1].planning_latency_s() < reqs[0].planning_latency_s()
    assert reqs[1].plan_ms == 50.0
    assert eng.serve_stats.plan_ms == pytest.approx(1000.0)
    assert eng.serve_stats.plan_cache_hits == 2
    assert eng.serve_stats.n_planned == 1


def test_planning_attribution_end_to_end(tiny_fed, tiny_stats, tiny_workload):
    """With the real planner, an in-batch duplicate's attributed planning
    never exceeds the batch window charged to a cold member."""
    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats, max_batch=8)
    q = next(q for q in tiny_workload if len(q.patterns) >= 2)
    cold = eng.submit(q, deadline=0.0)
    dup = eng.submit(q, deadline=0.0)
    eng.drain()
    assert not cold.cached and dup.cached
    assert dup.t_planned <= cold.t_planned
    assert dup.plan_ms <= cold.plan_ms
    assert dup.planning_latency_s() >= 0.0


# -- the unified surface ------------------------------------------------------

def test_query_engine_satisfies_serve_base(tiny_fed, tiny_stats):
    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats)
    assert isinstance(eng, ServeBase)
    assert isinstance(eng.serve_stats, ServeStats)


def test_run_until_done_is_deprecated_wrapper(tiny_fed, tiny_stats,
                                              tiny_workload):
    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats)
    req = eng.submit(tiny_workload[0])
    with pytest.warns(DeprecationWarning, match="drain"):
        done = eng.run_until_done()
    assert [r.qid for r in done] == [req.qid]


def test_engine_rejects_bad_modes(tiny_fed, tiny_stats):
    fed, _ = tiny_fed
    with pytest.raises(ValueError, match="admission"):
        QueryServeEngine(fed, tiny_stats, admission="lifo")
    with pytest.raises(ValueError, match="backpressure"):
        QueryServeEngine(fed, tiny_stats, backpressure="drop")
    with pytest.raises(ValueError, match="handoff_depth"):
        QueryServeEngine(fed, tiny_stats, pipeline=True, handoff_depth=0)


def test_arrival_admission_mode_still_serves(tiny_fed, tiny_stats,
                                             tiny_workload):
    """The legacy arrival-order policy stays available as the benchmark
    baseline and serves the same answers."""
    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats, max_batch=4, admission="arrival")
    reqs = [eng.submit(q, deadline=0.0) for q in tiny_workload]
    done = eng.drain()
    assert sorted(r.qid for r in done) == [r.qid for r in reqs]
    assert all(r.affinity_tier is None for r in done)


# -- ExecutionResult (the API-redesign satellite) -----------------------------

def test_execution_result_fields_and_shim(tiny_fed, tiny_stats, tiny_workload):
    from repro.core.planner import OdysseyOptimizer

    fed, _ = tiny_fed
    plan = OdysseyOptimizer(tiny_stats).optimize(tiny_workload[0])
    res = LocalEngine(fed).execute(plan)
    assert isinstance(res, ExecutionResult)
    assert res.plan is plan
    assert res.stats_epoch == plan.stats_epoch
    assert res.metrics.requests >= 1 and res.metrics.wall_ms >= 0.0
    with pytest.warns(DeprecationWarning, match="rows, metrics"):
        rows, metrics = res
    assert rows is res.rows and metrics is res.metrics
    with pytest.raises(Exception):
        res.rows = {}                              # frozen
