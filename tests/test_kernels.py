"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.join_count import join_count
from repro.kernels.seg_bitmap import NBUCKETS, seg_bitmap
from repro.kernels.sorted_intersect import sorted_intersect_weighted
from repro.kernels.summary_probe import summary_probe


@pytest.mark.parametrize("na,nb", [(256, 256), (512, 256), (256, 768), (1024, 1024)])
@pytest.mark.parametrize("overlap", [0.0, 0.3, 1.0])
def test_sorted_intersect_sweep(na, nb, overlap):
    rng = np.random.default_rng(na + nb + int(overlap * 10))
    pool = rng.choice(50_000, size=na + nb, replace=False)
    a = np.sort(pool[:na]).astype(np.int32)
    b = np.sort(rng.permutation(np.concatenate([
        rng.choice(a, size=int(overlap * min(na, nb)), replace=False) if overlap else np.empty(0, np.int32),
        pool[na: na + nb - int(overlap * min(na, nb))],
    ]))[:nb]).astype(np.int32)
    b = np.sort(np.unique(b))[:nb]
    b = np.pad(b, (0, nb - len(b)), constant_values=-2).astype(np.int32)
    aw = rng.integers(1, 5, na).astype(np.int32)
    bw = rng.integers(1, 5, nb).astype(np.int32)
    bw[b == -2] = 0
    got = sorted_intersect_weighted(jnp.asarray(a), jnp.asarray(aw), jnp.asarray(b), jnp.asarray(bw))
    want = ref.sorted_intersect_weighted_ref(jnp.asarray(a), jnp.asarray(aw), jnp.asarray(b), jnp.asarray(bw))
    assert int(got) == int(want)


def test_intersect_count_wrapper_vs_numpy():
    rng = np.random.default_rng(0)
    for trial in range(10):
        na, nb = rng.integers(1, 700, 2)
        a = np.unique(rng.choice(10_000, size=na)).astype(np.int32)
        b = np.unique(rng.choice(10_000, size=nb)).astype(np.int32)
        aw = rng.integers(1, 6, len(a)).astype(np.int32)
        bw = rng.integers(1, 6, len(b)).astype(np.int32)
        got = ops.intersect_count(a, aw, b, bw)
        common, ia, ib = np.intersect1d(a, b, assume_unique=True, return_indices=True)
        want = int((aw[ia] * bw[ib]).sum())
        assert got == want


@pytest.mark.parametrize("n,n_seg", [(256, 128), (512, 256), (1024, 128)])
def test_seg_bitmap_sweep(n, n_seg):
    rng = np.random.default_rng(n + n_seg)
    seg = np.sort(rng.integers(0, n_seg, n)).astype(np.int32)
    bucket = rng.integers(0, NBUCKETS, n).astype(np.int32)
    # pad rows with -1 segments
    pad = (-n) % 256
    seg_p = np.concatenate([seg, np.full(pad, -1, np.int32)])
    bkt_p = np.concatenate([bucket, np.zeros(pad, np.int32)])
    got = seg_bitmap(jnp.asarray(seg_p), jnp.asarray(bkt_p), n_seg)
    want = ref.seg_bitmap_ref(jnp.asarray(seg_p), jnp.asarray(bkt_p), n_seg, NBUCKETS)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_predicate_bitmaps_wrapper():
    rng = np.random.default_rng(7)
    n, n_seg = 700, 37
    seg = np.sort(rng.integers(0, n_seg, n)).astype(np.int32)
    bucket = rng.integers(0, NBUCKETS, n).astype(np.int32)
    got = ops.predicate_bitmaps(seg, bucket, n_seg)
    want = np.zeros((n_seg, NBUCKETS), bool)
    for s, b in zip(seg, bucket):
        want[s, b] = True
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("np_,nb", [(256, 256), (512, 512), (768, 256)])
def test_join_count_sweep(np_, nb):
    rng = np.random.default_rng(np_ + nb)
    build = np.sort(rng.choice(5000, size=nb, replace=False)).astype(np.int32)
    bw = rng.integers(0, 4, nb).astype(np.int32)
    probe = rng.choice(6000, size=np_).astype(np.int32)
    got = join_count(jnp.asarray(probe), jnp.asarray(build), jnp.asarray(bw))
    want = ref.join_count_ref(jnp.asarray(probe), jnp.asarray(build), jnp.asarray(bw))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_match_counts_wrapper():
    rng = np.random.default_rng(3)
    build = np.unique(rng.choice(1000, 300)).astype(np.int32)
    bw = rng.integers(1, 5, len(build)).astype(np.int32)
    probe = rng.choice(1200, 450).astype(np.int32)
    got = ops.match_counts(probe, build, bw)
    lut = dict(zip(build.tolist(), bw.tolist()))
    want = np.array([lut.get(int(p), 0) for p in probe], np.int32)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("na,nb,w", [(128, 128, 8), (256, 128, 16), (128, 256, 8)])
def test_summary_probe_sweep(na, nb, w):
    rng = np.random.default_rng(na + nb + w)
    a = rng.integers(-(2**31), 2**31 - 1, (na, w), dtype=np.int64).astype(np.int32)
    b = rng.integers(-(2**31), 2**31 - 1, (nb, w), dtype=np.int64).astype(np.int32)
    got = summary_probe(jnp.asarray(a), jnp.asarray(b))
    want = ref.summary_probe_ref(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_signature_overlap_matches_summaries(small_fed):
    """Kernel path must agree with the numpy candidate generation on real
    summary signatures (uint64 host layout)."""
    fed, _ = small_fed
    from repro.core.characteristic_sets import compute_characteristic_sets
    from repro.core.summaries import build_summary

    kinds = np.asarray(fed.dictionary.kinds, np.int8)
    auth = fed.dictionary.authority_array()
    cs_a = compute_characteristic_sets(fed.sources[7].table)
    cs_b = compute_characteristic_sets(fed.sources[3].table)
    sa = build_summary(fed.sources[7].table, cs_a, auth, src=7, entity_mask=kinds == 0)
    sb = build_summary(fed.sources[3].table, cs_b, auth, src=3, entity_mask=kinds == 0)
    if len(sa.obj_sig) == 0 or len(sb.subj_sig) == 0:
        pytest.skip("no signatures")
    pop = ops.signature_overlap(sa.obj_sig, sb.subj_sig)
    want = (sa.obj_sig[:, None, :] & sb.subj_sig[None, :, :]).any(-1)
    np.testing.assert_array_equal(pop > 0, want)


@pytest.mark.parametrize("B,R,C", [(1, 2, 3), (4, 130, 7), (8, 260, 140)])
def test_dp_layer_sweep(B, R, C):
    """dp_layer (interpret mode) vs the jnp oracle: dense candidate pricing
    plus the per-column first-strict-minimum — exact equality, including on
    injected cost ties (the DP's tie-breaking contract) and all-invalid
    columns."""
    from jax.experimental import enable_x64

    from repro.kernels.dp_layer import dp_layer

    rng = np.random.default_rng(B * 1000 + R + C)
    cost_a = rng.uniform(1, 100, (B, R, C))
    cost_b = rng.uniform(1, 100, (B, R, C))
    card_a = rng.uniform(0, 50, (B, R, C))
    n_src_b = rng.integers(1, 4, (B, R, C)).astype(np.float64)
    src_w_b = rng.uniform(0.5, 2, (B, R, C))
    bindable = rng.random((B, R, C)) < 0.5
    valid = rng.random((R, C)) < 0.6
    if C > 1:
        valid[:, -1] = False                    # an all-invalid column
    card_s = rng.uniform(0, 80, (B, C))
    cost_a[:, ::3, :] = 5.0                     # exact ties across rows
    cost_b[:, ::3, :] = 5.0
    params = (1.0, 1.0, 5.0, 20)
    got = dp_layer(cost_a, cost_b, card_a, n_src_b, src_w_b, bindable, valid,
                   card_s, params)
    with enable_x64():
        want = ref.dp_layer_ref(
            jnp.asarray(cost_a), jnp.asarray(cost_b), jnp.asarray(card_a),
            jnp.asarray(n_src_b), jnp.asarray(src_w_b), jnp.asarray(bindable),
            jnp.asarray(valid), jnp.asarray(card_s), params)
    np.testing.assert_array_equal(got[0], np.asarray(want[0]))
    np.testing.assert_array_equal(got[1], np.asarray(want[1]))
    np.testing.assert_array_equal(got[2], np.asarray(want[2]))
    if C > 1:                                   # no valid pair -> inf / BIG
        assert np.isinf(got[0][:, -1]).all()


def test_cost_jnp_twins_bitwise_equal_numpy_forms():
    """Every ``CostModel.*_jnp`` twin must reproduce its ``*_v`` numpy form
    bit for bit under x64 — the contract the on-device sweep's bit-identical
    plans rest on (``hash_join_cost_jnp`` runs inside the kernel; the others
    are pinned here so they cannot silently drift)."""
    from jax.experimental import enable_x64

    from repro.core.cost import CostModel

    rng = np.random.default_rng(23)
    cm = CostModel(intermediate_weight=1.25, transfer_weight=0.75,
                   request_cost=5.0, bind_batch=20)
    card = rng.uniform(0, 1e4, 257)
    card_l = rng.uniform(0, 1e3, 257)
    n_src = rng.integers(1, 6, 257).astype(np.float64)
    src_w = rng.uniform(0.25, 4.0, 257)
    bindable = rng.random(257) < 0.5
    with enable_x64():
        pairs = [
            (cm.leaf_cost_v(card, n_src, src_w),
             cm.leaf_cost_jnp(jnp.asarray(card), jnp.asarray(n_src),
                              jnp.asarray(src_w))),
            (cm.hash_join_cost_v(card),
             cm.hash_join_cost_jnp(jnp.asarray(card))),
            (cm.bind_join_cost_v(card_l, card, n_src, src_w),
             cm.bind_join_cost_jnp(jnp.asarray(card_l), jnp.asarray(card),
                                   jnp.asarray(n_src), jnp.asarray(src_w))),
        ]
        for want, got in pairs:
            assert np.asarray(got).dtype == np.float64
            np.testing.assert_array_equal(np.asarray(got), want)
        hj = cm.hash_join_cost_v(card)
        want_c, want_b = cm.join_candidates_v(card_l, card_l[::-1], card, hj,
                                              card_l, n_src, src_w, bindable)
        got_c, got_b = cm.join_candidates_jnp(
            jnp.asarray(card_l), jnp.asarray(card_l[::-1]), jnp.asarray(card),
            jnp.asarray(hj), jnp.asarray(card_l), jnp.asarray(n_src),
            jnp.asarray(src_w), jnp.asarray(bindable))
        np.testing.assert_array_equal(np.asarray(got_c), want_c)
        np.testing.assert_array_equal(np.asarray(got_b), want_b)


def test_popcount_identity():
    rng = np.random.default_rng(11)
    v = jnp.asarray(rng.integers(-(2**31), 2**31 - 1, 4096, dtype=np.int64).astype(np.int32))
    got = np.asarray(ref.popcount32_ref(v))
    want = np.array([bin(int(np.uint32(x))).count("1") for x in np.asarray(v)])
    np.testing.assert_array_equal(got, want)


def test_cost_params_form_bitwise_equal_numpy():
    """The traced-params fused form (``join_candidates_params_jnp``) must
    reproduce ``join_candidates_v`` bit for bit under x64, including the
    in-place ``iw * card_out`` hash term — the resident sweep's plans rest
    on it."""
    from jax.experimental import enable_x64

    from repro.core.cost import CostModel

    rng = np.random.default_rng(31)
    cm = CostModel(intermediate_weight=1.25, transfer_weight=0.75,
                   request_cost=5.0, bind_batch=20)
    card_out = rng.uniform(0, 1e4, 257)
    cost_a = rng.uniform(0, 1e3, 257)
    cost_b = rng.uniform(0, 1e3, 257)
    card_a = rng.uniform(0, 1e3, 257)
    n_src = rng.integers(0, 6, 257).astype(np.float64)
    src_w = rng.uniform(0.25, 4.0, 257)
    bindable = n_src > 0
    hj = cm.hash_join_cost_v(card_out)
    want_c, want_b = cm.join_candidates_v(cost_a, cost_b, card_out, hj,
                                          card_a, n_src, src_w, bindable)
    with enable_x64():
        params = jnp.asarray([cm.intermediate_weight, cm.transfer_weight,
                              cm.request_cost, cm.bind_batch], jnp.float64)
        got_c, got_b = CostModel.join_candidates_params_jnp(
            params, jnp.asarray(cost_a), jnp.asarray(cost_b),
            jnp.asarray(card_out), jnp.asarray(card_a), jnp.asarray(n_src),
            jnp.asarray(src_w), jnp.asarray(bindable))
    assert np.asarray(got_c).dtype == np.float64
    np.testing.assert_array_equal(np.asarray(got_c), want_c)
    np.testing.assert_array_equal(np.asarray(got_b), want_b)


def test_program_cache_one_compile_across_shapes_and_params():
    """Regression for the old ``lru_cache`` keyed on ``(params, interpret)``:
    two bucketed tile shapes under two different cost-model parameter sets
    must share ONE cached program entry (params are traced, shapes are
    specialized inside jax's own jit cache), and a parameter change must not
    add a jit specialization."""
    from repro.kernels.dp_layer import PROGRAM_CACHE, dp_layer

    PROGRAM_CACHE.clear()
    rng = np.random.default_rng(5)

    def tile(B, R, C):
        return (rng.uniform(1, 9, (B, R, C)), rng.uniform(1, 9, (B, R, C)),
                rng.uniform(0, 5, (B, R, C)),
                rng.integers(1, 3, (B, R, C)).astype(np.float64),
                rng.uniform(0.5, 2, (B, R, C)),
                rng.random((B, R, C)) < 0.5, rng.random((R, C)) < 0.7,
                rng.uniform(0, 9, (B, C)))

    p1, p2 = (1.0, 1.0, 5.0, 20), (2.0, 0.5, 7.0, 10)
    shapes = [(2, 5, 3), (2, 13, 9)]        # distinct bucketed extents
    for B, R, C in shapes:
        args = tile(B, R, C)
        for params in (p1, p2):
            dp_layer(*args, params)
    assert len(PROGRAM_CACHE) == 1          # one program entry, ever
    assert PROGRAM_CACHE.misses == 1
    assert PROGRAM_CACHE.hits == 2 * len(shapes) - 1
    assert PROGRAM_CACHE.evictions == 0
    fn = PROGRAM_CACHE._entries[("layer", True)]
    if hasattr(fn, "_cache_size"):
        # one jit specialization per bucketed shape — none per param set
        assert fn._cache_size() == len(shapes)


def test_program_cache_eviction_counter():
    from repro.kernels.dp_layer import _ProgramCache

    c = _ProgramCache(max_entries=2)
    for k in ("a", "b", "c"):
        c.get((k,), lambda: k)
    assert len(c) == 2
    assert c.evictions == 1
    assert c.misses == 3
    c.get(("c",), lambda: "c")
    assert c.hits == 1


def test_dp_sweep_resident_matches_scalar_ref():
    """The whole resident fused program (compiled XLA, one ``lax.scan``)
    vs the independent scalar oracle, on a real topology schedule with
    injected cost ties, exclusive-group seeds and source-less singletons."""
    from repro.core import join_order as jo
    from repro.kernels.dp_layer import dp_sweep_resident
    from repro.rdf.shapes import shaped_planning_inputs

    g, _, _, _ = shaped_planning_inputs("tree", 8, seed=3)
    B, n = 4, 8
    size = 1 << n
    sched = jo._dp_schedule(g, jo.DP_BLOCK_BYTES, B)
    assert sched is not None
    rng = np.random.default_rng(17)
    # small-integer stats force exact cost ties; the program must break
    # them like the scalar first-strict-minimum
    card = rng.integers(1, 5, (B, size)).astype(np.float64)
    cost0 = np.full((B, size), np.inf)
    n_src0 = np.zeros((B, size))
    src_w0 = np.ones((B, size))
    for i in range(n):
        m = 1 << i
        cost0[:, m] = rng.integers(1, 6, B)
        n_src0[:, m] = rng.integers(0, 3, B)      # some source-less leaves
        src_w0[:, m] = rng.choice([1.0, 1.5], B)
    excl_cost = np.full((B, size), np.inf)
    excl_w = np.ones((B, size))
    conn_masks = sched.layer_cols[sched.layer_cols < size]
    pick = rng.choice(conn_masks, 12, replace=False)
    excl_cost[:, pick] = rng.integers(1, 8, (B, len(pick)))
    excl_w[:, pick] = rng.choice([1.0, 2.0], (B, len(pick)))
    params = (1.0, 1.0, 5.0, 20)
    got = dp_sweep_resident(params, sched.pair_a, sched.pair_b,
                            sched.pair_seg, sched.layer_cols, card,
                            excl_cost, excl_w, cost0, n_src0, src_w0)
    want = ref.dp_sweep_ref(params, sched.pair_a, sched.pair_b,
                            sched.pair_seg, sched.layer_cols, card,
                            excl_cost, excl_w, cost0, n_src0, src_w0)
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])
    np.testing.assert_array_equal(got[2], want[2])
