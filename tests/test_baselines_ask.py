"""ASK-probe accounting in the FedX/HiBISCuS baselines: probes are memoized
per source selection (one ``optimize`` call), ``ask_count`` counts only real
probe rounds, warm mode never re-probes a known pattern signature, and the
warm cache cannot be corrupted through returned source lists."""
import pytest

from repro.baselines import FedXOptimizer, HibiscusOptimizer
from repro.query.algebra import BGPQuery, TriplePattern, Var


def _query_with_duplicate_signature(workload):
    """A workload query plus an extra pattern sharing an ASK signature with an
    existing one (same constants, different variable names)."""
    q = next(q for q in workload
             if any(isinstance(tp.s, Var) and isinstance(tp.o, Var)
                    for tp in q.patterns))
    tp = next(tp for tp in q.patterns
              if isinstance(tp.s, Var) and isinstance(tp.o, Var))
    dup = TriplePattern(Var("dup_s"), tp.p, Var("dup_o"))
    assert dup.constants() == tp.constants()
    return BGPQuery(q.patterns + [dup], distinct=q.distinct, name="dupq")


def _n_keys(q):
    return len({tp.constants() for tp in q.patterns})


def test_fedx_cold_probes_once_per_selection(tiny_fed, tiny_workload):
    """Cold mode re-probes per optimize call (FedX-Cold semantics) but within
    one selection every distinct ASK signature is probed exactly once."""
    fed, _ = tiny_fed
    opt = FedXOptimizer(fed, warm=False)
    q = _query_with_duplicate_signature(tiny_workload)
    per_call = _n_keys(q) * len(fed.sources)
    assert per_call < len(q.patterns) * len(fed.sources)  # dup really dedupes
    opt.optimize(q)
    assert opt.ask_count == per_call
    opt.optimize(q)
    assert opt.ask_count == 2 * per_call


def test_fedx_warm_never_reprobes(tiny_fed, tiny_workload):
    fed, _ = tiny_fed
    opt = FedXOptimizer(fed, warm=True)
    q = _query_with_duplicate_signature(tiny_workload)
    per_call = _n_keys(q) * len(fed.sources)
    p1 = opt.optimize(q)
    assert opt.ask_count == per_call
    p2 = opt.optimize(q)
    assert opt.ask_count == per_call          # warm: zero new probes
    assert [sq.sources for sq in p1.subqueries()] == \
        [sq.sources for sq in p2.subqueries()]


@pytest.mark.parametrize("warm", [False, True])
def test_hibiscus_counts_real_probes_only(tiny_fed, tiny_workload, warm):
    """HiBISCuS probes once per signature per selection (its FedX superclass
    pass reuses the already-probed, pruned lists) and warm mode adds zero
    probes on repeat."""
    fed, _ = tiny_fed
    opt = HibiscusOptimizer(fed, warm=warm)
    q = _query_with_duplicate_signature(tiny_workload)
    per_call = _n_keys(q) * len(fed.sources)
    opt.optimize(q)
    assert opt.ask_count == per_call
    opt.optimize(q)
    assert opt.ask_count == (per_call if warm else 2 * per_call)


def test_warm_cache_isolated_from_caller_mutation(tiny_fed, tiny_workload):
    """Returned source lists are copies: pruning/mutating them must not
    corrupt the warm ASK cache."""
    fed, _ = tiny_fed
    opt = FedXOptimizer(fed, warm=True)
    tp = next(tp for q in tiny_workload for tp in q.patterns)
    first = opt._sources_for(tp)
    first.append(10_000)
    again = opt._sources_for(tp)
    assert 10_000 not in again
    assert opt.ask_count == len(fed.sources)  # second lookup hit the cache
