"""Environment contract: tests and benches must see exactly ONE device —
the 512-fake-device flag belongs to the dry-run alone (its module sets
XLA_FLAGS before any jax import; see repro/launch/dryrun.py)."""
import os

import jax


def test_tests_see_one_device():
    assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")
    assert jax.device_count() == 1


def test_dryrun_sets_flag_first():
    """The dry-run module's first statements must pin the device count."""
    import inspect

    import repro.launch.dryrun as dr

    src = inspect.getsource(dr).splitlines()
    head = "\n".join(src[:3])
    assert "xla_force_host_platform_device_count=512" in head
