"""Group-tree algebra: parsing, normalization, compositional planning and
the differential suite against the ``naive_evaluate`` oracle.

The differentials deliberately pit two *different* evaluation structures
against each other: the planner normalizes (filter pushdown, union hoisting,
well-designed OPTIONAL pull-up) and reorders via the DP, while the oracle
evaluates the raw syntactic tree over the union of all sources."""
import numpy as np
import pytest

from repro.core.planner import OdysseyOptimizer, query_signature
from repro.engine.local import LocalEngine, naive_evaluate
from repro.query.algebra import (
    And,
    BGPQuery,
    Bgp,
    Comparison,
    Const,
    Filter,
    Join,
    LeftJoin,
    Not,
    Or,
    TriplePattern,
    Union,
    Var,
    certain_variables,
    from_algebra,
    group_variables,
    is_well_designed,
    normalize,
)


def _tp(s, p, o):
    def t(x):
        return Var(x) if isinstance(x, str) else Const(x)
    return TriplePattern(t(s), t(p), t(o))


def _engine_rows(fed, plan, q):
    rel = LocalEngine(fed).execute(plan).rows
    proj = q.effective_projection()
    n = len(next(iter(rel.values()))) if rel else 0
    return set(zip(*[rel[v].tolist() for v in proj])) if n else set()


# --------------------------------------------------------------------------
# Normalization
# --------------------------------------------------------------------------

def test_adjacent_bgps_merge_and_query_is_conjunctive():
    a = Bgp((_tp("x", 1, "y"),))
    b = Bgp((_tp("y", 2, "z"),))
    norm = normalize(Join((a, b)))
    assert isinstance(norm, Bgp) and len(norm.patterns) == 2
    q = from_algebra(Join((a, b)))
    assert q.root is not None and q.is_conjunctive()


def test_union_hoists_out_of_join_and_filter():
    star = Bgp((_tp("x", 1, "y"),))
    u = Union((Bgp((_tp("x", 2, "z"),)), Bgp((_tp("x", 3, "z"),))))
    norm = normalize(Join((star, u)))
    assert isinstance(norm, Union) and len(norm.members) == 2
    for m in norm.members:
        assert isinstance(m, Bgp) and len(m.patterns) == 2  # union-free branches

    norm2 = normalize(Filter(Comparison("!=", Var("x"), Var("z")), u))
    assert isinstance(norm2, Union)


def test_union_never_hoists_out_of_optional_arm():
    left = Bgp((_tp("x", 1, "y"),))
    arm = Union((Bgp((_tp("x", 2, "a"),)), Bgp((_tp("x", 3, "a"),))))
    norm = normalize(LeftJoin(left, arm))
    assert isinstance(norm, LeftJoin)            # the arm keeps its scope
    assert isinstance(norm.right, Union)


def test_well_designed_optional_pulls_above_the_join():
    L = Bgp((_tp("x", 1, "y"),))
    R = Bgp((_tp("x", 2, "o"),))                  # arm var o stays private
    S = Bgp((_tp("x", 3, "z"),))
    norm = normalize(Join((LeftJoin(L, R), S)))
    assert isinstance(norm, LeftJoin)
    assert norm.right == R
    assert isinstance(norm.left, Bgp) and len(norm.left.patterns) == 2


def test_non_well_designed_join_stays_in_syntactic_order():
    L = Bgp((_tp("x", 1, "y"),))
    R = Bgp((_tp("x", 2, "o"),))
    S = Bgp((_tp("o", 3, "z"),))                  # uses arm-only var o
    tree = Join((LeftJoin(L, R), S))
    assert not is_well_designed(tree)
    norm = normalize(tree)
    assert isinstance(norm, Join)                 # no pull-up
    assert is_well_designed(LeftJoin(L, R))


def test_filter_pushdown_reaches_certain_binder_only():
    a = Bgp((_tp("x", 1, "y"),))
    L = Bgp((_tp("x", 2, "z"),))
    R = Bgp((_tp("x", 3, "o"),))
    e = Comparison("=", Var("y"), Const(7))
    norm = normalize(Filter(e, Join((a, LeftJoin(L, R)))))
    # well-designed pull-up floats the OPTIONAL to the top, Bgp-merging fuses
    # a+L, and the filter then sinks through the LeftJoin into the certain
    # left block -- never above the LeftJoin, never into the arm
    assert isinstance(norm, LeftJoin) and norm.right == R
    assert isinstance(norm.left, Filter) and norm.left.expr == e
    assert isinstance(norm.left.child, Bgp)
    assert len(norm.left.child.patterns) == 2
    assert {"x", "y", "z"} == set(certain_variables(norm.left.child))
    assert "o" not in group_variables(norm.left)


def test_filter_never_sinks_into_optional_arm():
    left = Bgp((_tp("x", 1, "y"),))
    arm = Bgp((_tp("x", 2, "o"),))
    e = Comparison("=", Var("o"), Const(5))       # over the arm-only var
    norm = normalize(Filter(e, LeftJoin(left, arm)))
    assert isinstance(norm, Filter)               # stays above the LeftJoin
    assert isinstance(norm.child, LeftJoin)
    assert norm.child.right == arm                # arm untouched


def test_filter_distributes_over_union():
    u = Union((Bgp((_tp("x", 1, "y"),)), Bgp((_tp("x", 2, "y"),))))
    e = Comparison("<", Var("y"), Const(9))
    norm = normalize(Filter(e, u))
    assert isinstance(norm, Union)
    for m in norm.members:
        assert isinstance(m, Filter) and m.expr == e


# --------------------------------------------------------------------------
# Parser round-trips
# --------------------------------------------------------------------------

def _roundtrip(q, d):
    from repro.query.sparql import parse_sparql, serialize_sparql
    q2 = parse_sparql(serialize_sparql(q, d), d)
    assert q2.algebra() == q.algebra()
    assert q2.distinct == q.distinct
    assert q2.projection == q.projection
    return q2


def test_sparql_roundtrip_groups(tiny_fed):
    fed, _ = tiny_fed
    d = fed.dictionary
    p1, p2, p3 = 0, 1, 2                           # any dictionary ids work
    star = Bgp((TriplePattern(Var("x"), Const(p1), Var("y")),
                TriplePattern(Var("x"), Const(p2), Var("z"))))
    arm = Bgp((TriplePattern(Var("x"), Const(p3), Var("o")),))
    cases = [
        from_algebra(star, projection=["x", "y"]),
        from_algebra(LeftJoin(star, arm), projection=["x", "o"]),
        # nested OPTIONAL: arm of an arm
        from_algebra(LeftJoin(star, LeftJoin(
            arm, Bgp((TriplePattern(Var("o"), Const(p1), Var("w")),)))),
            distinct=True, projection=["x"]),
        from_algebra(Union((star, Bgp((TriplePattern(Var("x"), Const(p3),
                                                     Var("y")),)))),
                     projection=["x"]),
        # FILTER placement: inside a branch vs at group end
        from_algebra(Filter(And((Comparison("!=", Var("y"), Var("z")),
                                 Or((Comparison("<", Var("y"), Const(4)),
                                     Not(Comparison("=", Var("z"),
                                                    Const(2))))))), star),
                     projection=["x"]),
        from_algebra(LeftJoin(Filter(Comparison(">=", Var("y"), Const(1)),
                                     star), arm), projection=["x", "o"]),
    ]
    for q in cases:
        _roundtrip(q, d)


def test_sparql_unsupported_constructs_raise_named_errors(tiny_fed):
    from repro.query.sparql import parse_sparql
    fed, _ = tiny_fed
    d = fed.dictionary
    bodies = {
        "GRAPH": "GRAPH ?g { ?x ?p ?y }",
        "SERVICE": "SERVICE <http://ex.org/sparql> { ?x ?p ?y }",
        "MINUS": "?x ?p ?y MINUS { ?x ?q ?y }",
        "BIND": "BIND (?x = ?y)",
        "VALUES": "VALUES ?x { 1 }",
    }
    for kw, body in bodies.items():
        with pytest.raises(ValueError, match=kw):
            parse_sparql(f"SELECT * WHERE {{ {body} }}", d)
    with pytest.raises(ValueError, match="ASK"):
        parse_sparql("ASK WHERE { ?x ?p ?y }", d)


# --------------------------------------------------------------------------
# Plan cache: an OPTIONAL variant never aliases its plain-BGP entry
# --------------------------------------------------------------------------

def test_bgp_warmed_cache_misses_on_optional_variant(tiny_fed, tiny_stats,
                                                     tiny_workload):
    fed, _ = tiny_fed
    base = next(q for q in tiny_workload if len(q.patterns) >= 2)
    opt = OdysseyOptimizer(tiny_stats)
    p1 = opt.optimize(base)
    assert not p1.cached and opt.optimize(base).cached    # warm + sanity hit

    pred = base.patterns[0].p
    variant = from_algebra(
        LeftJoin(Bgp(tuple(base.patterns)),
                 Bgp((TriplePattern(Var("x"), pred, Var("opt0")),))),
        distinct=base.distinct, projection=base.projection)
    assert query_signature(variant)[0] != query_signature(base)[0]
    pv = opt.optimize(variant)
    assert not pv.cached                                  # MISS, not an alias
    assert opt.optimize(variant).cached                   # and its own entry


def _plan_shape(node):
    from repro.core.planner import (
        FilterPlanNode,
        JoinPlanNode,
        LeftJoinPlanNode,
        SubqueryNode,
        UnionPlanNode,
    )

    if isinstance(node, SubqueryNode):
        return ("sq", tuple(node.stars), tuple(node.sources),
                tuple((tp.s, tp.p, tp.o) for tp in node.patterns))
    if isinstance(node, (JoinPlanNode, LeftJoinPlanNode)):
        tag = "join" if isinstance(node, JoinPlanNode) else "leftjoin"
        return (tag, getattr(node, "strategy", None), tuple(node.join_vars),
                _plan_shape(node.left), _plan_shape(node.right))
    if isinstance(node, UnionPlanNode):
        return ("union", tuple(_plan_shape(c) for c in node.children))
    assert isinstance(node, FilterPlanNode)
    return ("filter", node.expr, _plan_shape(node.child))


def test_conjunctive_algebra_plans_identical_to_flat(tiny_fed, tiny_stats,
                                                     tiny_workload):
    """A group tree that *normalizes* to one Bgp routes through the legacy
    flat pipeline and produces the same plan as the flat query."""
    base = next(q for q in tiny_workload if len(q.patterns) >= 3)
    half = len(base.patterns) // 2
    wrapped = from_algebra(
        Join((Bgp(tuple(base.patterns[:half])),
              Bgp(tuple(base.patterns[half:])))),
        distinct=base.distinct, projection=base.projection)
    assert wrapped.root is not None and wrapped.is_conjunctive()
    flat = OdysseyOptimizer(tiny_stats).optimize(base)
    alg = OdysseyOptimizer(tiny_stats).optimize(wrapped)
    assert _plan_shape(flat.root) == _plan_shape(alg.root)
    assert flat.root.est_cardinality == alg.root.est_cardinality


def test_plain_bgp_planning_matches_reference_dp(tiny_stats, tiny_workload):
    """The bitmask DP the per-block pipeline runs stays bit-identical to the
    frozenset reference DP on every conjunctive workload query."""
    from repro.core.cost import CostModel
    from repro.core.decomposition import decompose
    from repro.core.join_order import dp_join_order, dp_join_order_ref
    from repro.core.source_selection import select_sources

    cm = CostModel()
    for q in tiny_workload:
        graph = decompose(q)
        sel = select_sources(graph, tiny_stats)
        new = dp_join_order(graph, tiny_stats, sel, cm, q.distinct)
        ref = dp_join_order_ref(graph, tiny_stats, sel, cm, q.distinct)
        assert new.leaf_order() == ref.leaf_order()
        np.testing.assert_allclose(new.cost, ref.cost, rtol=1e-9)
        np.testing.assert_allclose(new.cardinality, ref.cardinality, rtol=1e-9)


# --------------------------------------------------------------------------
# Differential suite: planner + engine vs the naive oracle
# --------------------------------------------------------------------------

def test_extended_workload_matches_oracle(tiny_fed, tiny_stats):
    from repro.rdf.generator import generate_extended_workload

    fed, gt = tiny_fed
    queries = generate_extended_workload(fed, gt, seed=17)
    assert len(queries) == 16
    fams = {q.name[:2] for q in queries}
    assert fams == {"OS", "UN", "FC"}              # all three families
    opt = OdysseyOptimizer(tiny_stats)
    nonempty = 0
    for q in queries:
        plan = opt.optimize(q)
        got = _engine_rows(fed, plan, q)
        want = naive_evaluate(fed, q)
        assert got == want, q.name
        nonempty += bool(want)
    assert nonempty == len(queries)                # families stay non-empty


def _random_tree(rng, leaves, depth):
    """Random group tree <= `depth` combinator levels over star leaves that
    share the center variable ``x``."""
    if depth == 0 or rng.random() < 0.3:
        return Bgp(tuple(leaves[int(rng.integers(len(leaves)))]))
    kind = rng.integers(4)
    if kind == 0:
        return Join((_random_tree(rng, leaves, depth - 1),
                     _random_tree(rng, leaves, depth - 1)))
    if kind == 1:
        return LeftJoin(_random_tree(rng, leaves, depth - 1),
                        _random_tree(rng, leaves, depth - 1))
    if kind == 2:
        return Union((_random_tree(rng, leaves, depth - 1),
                      _random_tree(rng, leaves, depth - 1)))
    child = _random_tree(rng, leaves, depth - 1)
    cvars = sorted(certain_variables(child))
    if len(cvars) < 2:
        return child
    a, b = rng.choice(cvars, size=2, replace=False).tolist()
    op = str(rng.choice(["=", "!=", "<", "<=", ">", ">="]))
    return Filter(Comparison(op, Var(a), Var(b)), child)


def _star_leaves(fed, gt, rng):
    """2-pattern star leaves sharing the center variable ``x``, satellite
    variables renamed per leaf so OPTIONAL arms bind private variables."""
    from repro.rdf.generator import _star_patterns

    leaves = []
    for src in [s.name for s in fed.sources]:
        for tmpl in range(len(gt.template_preds[src])):
            pats = _star_patterns(rng, fed, gt, src, tmpl, "x", 2,
                                  bind_obj=False)
            if pats is not None:
                i = len(leaves)
                ren = {f"x_v{j}": f"l{i}_v{j}" for j in range(2)}
                leaves.append([TriplePattern(
                    tp.s, tp.p,
                    Var(ren[tp.o.name]) if isinstance(tp.o, Var) else tp.o)
                    for tp in pats])
    return leaves


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
def test_random_group_trees_match_oracle(tiny_fed, tiny_stats, seed):
    """Seeded randomized differential over group trees <= 3 levels: planner
    plus engine must agree with the raw-tree oracle on every draw (the
    hypothesis twin in test_property.py explores the same space)."""
    fed, gt = tiny_fed
    rng = np.random.default_rng(100 + seed)
    leaves = _star_leaves(fed, gt, rng)
    assert len(leaves) >= 2
    for _ in range(6):
        root = _random_tree(rng, leaves, depth=int(rng.integers(1, 4)))
        q = from_algebra(root, distinct=bool(rng.random() < 0.5),
                         projection=sorted(certain_variables(root)))
        plan = OdysseyOptimizer(tiny_stats).optimize(q)
        assert _engine_rows(fed, plan, q) == naive_evaluate(fed, q)


def test_optional_answers_carry_undef(tiny_fed, tiny_stats):
    """An OS-family query must actually produce UNDEF cells somewhere across
    the workload -- otherwise the OPTIONAL arms are accidentally total and
    the family tests nothing."""
    from repro.engine.local import UNDEF
    from repro.rdf.generator import generate_extended_workload

    fed, gt = tiny_fed
    queries = [q for q in generate_extended_workload(fed, gt, seed=17)
               if q.name.startswith("OS")]
    opt = OdysseyOptimizer(tiny_stats)
    seen_undef = False
    for q in queries:
        for row in _engine_rows(fed, opt.optimize(q), q):
            if UNDEF in row:
                seen_undef = True
    assert seen_undef


def test_spmd_engine_rejects_algebra_plans(tiny_fed, tiny_stats):
    from repro.engine.distributed import DistMetrics, DistributedEngine
    from repro.rdf.generator import generate_extended_workload

    fed, gt = tiny_fed
    q = generate_extended_workload(fed, gt, n_optional=1, n_union=0,
                                   n_filtered=0, seed=17)[0]
    plan = OdysseyOptimizer(tiny_stats).optimize(q)
    # the dispatch guard fires before any mesh/device state is touched, so a
    # bare instance is enough -- no fake-device subprocess needed here
    eng = object.__new__(DistributedEngine)
    with pytest.raises(NotImplementedError, match="conjunctive"):
        eng._eval_node(plan.root, DistMetrics())
