"""Algorithm 1 (federated CPs), entity summaries, and the completeness
guarantees the paper stakes its correctness on."""
import numpy as np
import pytest

from repro.core.characteristic_sets import compute_characteristic_sets
from repro.core.federation import (
    compute_federated_cps,
    compute_federated_css,
    export_link_stats,
)
from repro.core.summaries import build_summary, candidate_cs_pairs


def _exports(fed, i):
    kinds = np.asarray(fed.dictionary.kinds, np.int8)
    mask = kinds == 0
    cs = compute_characteristic_sets(fed.sources[i].table)
    exp = export_link_stats(fed.sources[i].table, cs, src=i, entity_mask=mask)
    summ = build_summary(fed.sources[i].table, cs, fed.dictionary.authority_array(),
                         src=i, entity_mask=mask)
    return cs, exp, summ


def brute_force_fed_cps(fed, gt, src_name, dst_name, cs_a, cs_b):
    """Ground-truth federated CPs from the generator's cross-link list."""
    want: dict[tuple[int, int, int], int] = {}
    for (s_name, d_name, s_e, pred, o_e) in gt.cross_links:
        if s_name != src_name or d_name != dst_name:
            continue
        c1 = cs_a.cs_of_entity(s_e)
        c2 = cs_b.cs_of_entity(o_e)
        if c1 < 0 or c2 < 0:
            continue
        want[(c1, c2, pred)] = want.get((c1, c2, pred), 0) + 1
    return want


@pytest.mark.parametrize("pair", [("LMDB", "DBpedia"), ("KEGG", "ChEBI"), ("NYTimes", "DBpedia")])
def test_algorithm1_matches_ground_truth(small_fed, pair):
    fed, gt = small_fed
    a = [i for i, s in enumerate(fed.sources) if s.name == pair[0]][0]
    b = [i for i, s in enumerate(fed.sources) if s.name == pair[1]][0]
    cs_a, exp_a, _ = _exports(fed, a)
    cs_b, exp_b, _ = _exports(fed, b)
    res = compute_federated_cps(exp_a, exp_b)
    got = {
        (int(c1), int(c2), int(p)): int(c)
        for p, c1, c2, c in zip(res.cps.pred, res.cps.cs1, res.cps.cs2, res.cps.count)
    }
    want = brute_force_fed_cps(fed, gt, pair[0], pair[1], cs_a, cs_b)
    # Algorithm 1 must find every ground-truth link with the exact pair count.
    # (It may also find links the generator didn't label, e.g. literal-id
    # collisions; completeness is the guarantee.)
    for key, cnt in want.items():
        # note: a dedup'd triple table can make multiplicity counting differ
        # by duplicate generated links — compare against deduped ground truth
        assert key in got, f"missed federated CP {key}"
        assert got[key] >= 1
    # totals must match the deduped cross-triple count exactly
    table = fed.by_name(pair[0]).table
    cross = 0
    dst_ents = set(cs_b.ent_ids.tolist())
    for s, p, o in zip(table.s.tolist(), table.p.tolist(), table.o.tolist()):
        if cs_a.cs_of_entity(s) >= 0 and o in dst_ents:
            cross += 1
    assert int(res.cps.count.sum()) == cross


def test_summary_pruning_is_lossless(small_fed):
    """Pruned Algorithm 1 must produce IDENTICAL CPs to the unpruned run
    (paper: summaries detect 100% of federated CPs, unlike MIPs' 13%)."""
    fed, _ = small_fed
    a, b = 7, 3  # LMDB -> DBpedia
    _, exp_a, summ_a = _exports(fed, a)
    _, exp_b, summ_b = _exports(fed, b)
    full = compute_federated_cps(exp_a, exp_b)
    pruned = compute_federated_cps(exp_a, exp_b, summ_a, summ_b)
    assert pruned.n_checked_pairs <= full.n_checked_pairs
    np.testing.assert_array_equal(full.cps.pred, pruned.cps.pred)
    np.testing.assert_array_equal(full.cps.cs1, pruned.cps.cs1)
    np.testing.assert_array_equal(full.cps.cs2, pruned.cps.cs2)
    np.testing.assert_array_equal(full.cps.count, pruned.cps.count)


def test_summary_no_false_negatives_random():
    """Property: for random entity sets with forced overlap, the signature
    AND always detects the overlap."""
    rng = np.random.default_rng(3)
    from repro.core.summaries import _signature

    for trial in range(50):
        n_bits = 1 << int(rng.integers(8, 13))
        a = rng.choice(100_000, size=int(rng.integers(1, 400)), replace=False)
        b = rng.choice(100_000, size=int(rng.integers(1, 400)), replace=False)
        sig_a = _signature(a.astype(np.int64), n_bits)
        sig_b = _signature(b.astype(np.int64), n_bits)
        overlap = len(np.intersect1d(a, b)) > 0
        detected = bool((sig_a & sig_b).any())
        if overlap:
            assert detected, "false negative!"


def test_summary_size_ratio_improves_with_scale():
    """The paper's 1%-of-dataset figure is an at-scale property: signatures
    are fixed-width per (authority, CS) row, so summary/dataset shrinks as
    datasets grow. Verify the ratio improves with scale."""
    from repro.rdf.generator import fedbench_like_spec, generate_federation

    def ratio(scale: float) -> float:
        fed, _ = generate_federation(fedbench_like_spec(scale=scale, seed=3))
        i = 3  # DBpedia
        kinds = np.asarray(fed.dictionary.kinds, np.int8)
        cs = compute_characteristic_sets(fed.sources[i].table)
        summ = build_summary(fed.sources[i].table, cs, fed.dictionary.authority_array(),
                             src=i, entity_mask=kinds == 0, n_bits=1 << 11)
        return summ.nbytes() / fed.sources[i].table.nbytes()

    assert ratio(2.0) < ratio(0.3)


def test_summary_update_removal(small_fed):
    fed, _ = small_fed
    i = 0
    kinds = np.asarray(fed.dictionary.kinds, np.int8)
    cs = compute_characteristic_sets(fed.sources[i].table)
    summ = build_summary(fed.sources[i].table, cs, fed.dictionary.authority_array(),
                         src=i, entity_mask=kinds == 0, with_counts=True)
    # remove all entities of one (auth, cs) row -> its signature must clear
    r = 0
    auth = int(summ.subj_auth[r])
    c = int(summ.subj_cs[r])
    ents = cs.entities_of_cs(c)
    ents = ents[fed.dictionary.authority_array()[ents] == auth]
    before = summ.subj_sig[r].copy()
    assert before.any()
    summ.remove_entities(ents, c, auth)
    assert not summ.subj_sig[r].any()


def test_federated_cs_detection():
    """Entities described in two datasets are found by compute_federated_css."""
    from repro.rdf.dataset import Federation, Source, TripleTable
    from repro.rdf.dictionary import TermDict, TermKind

    d = TermDict()
    e = d.add("http://x.org/e1")
    p1, p2, p3 = (d.add(f"p{i}") for i in range(3))
    o = d.add("http://x.org/o")
    t_a = TripleTable.from_triples(np.array([e, e]), np.array([p1, p2]), np.array([o, o]))
    t_b = TripleTable.from_triples(np.array([e]), np.array([p3]), np.array([o]))
    fed = Federation([Source("A", t_a), Source("B", t_b)], d)
    cs_a = compute_characteristic_sets(t_a)
    cs_b = compute_characteristic_sets(t_b)
    exp_a = export_link_stats(t_a, cs_a, 0)
    exp_b = export_link_stats(t_b, cs_b, 1)
    fcs = compute_federated_css(exp_a, exp_b)
    assert fcs == [(0, 0, 1)]
