"""Distributed shard_map engine == exact local engine, via subprocess so the
fake-device XLA flag never contaminates this process (DESIGN.md dry-run rule).
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # multi-minute: subprocess + XLA compilation

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("mesh", [(2, 2), (4, 2)])
def test_distributed_matches_local(mesh):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dist_selftest", str(mesh[0]), str(mesh[1])],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-2000:]}"
    assert "queries OK" in out.stdout
