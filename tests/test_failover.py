"""Endpoint failure handling: transparent retry on transient failures;
re-plan + honest partial flag when an endpoint stays dead.  With the
versioned statistics lifecycle, exclusion is incremental (remove_source) and
the plan cache survives a replan — templated workloads hit it afterwards —
and recovery (restore/add_source) is expressible."""
import numpy as np
import pytest

from repro.core.federation import build_federated_stats
from repro.engine.local import naive_evaluate
from repro.ft.failover import FailoverSession, FlakySource, execute_with_failover
from repro.ft.resilience import RetryPolicy
from repro.rdf.dataset import Federation


def _result_set(rel, proj):
    n = len(next(iter(rel.values()))) if rel else 0
    return set(zip(*[rel[v].tolist() for v in proj])) if n else set()


def test_transient_failure_recovers_complete(small_fed, small_stats, workload):
    fed, _ = small_fed
    flaky = Federation(
        [FlakySource(s, fail_times=1) for s in fed.sources], fed.dictionary)
    q = workload[0]
    res = execute_with_failover(flaky, small_stats, q,
                                RetryPolicy(max_attempts=3, base_delay_s=0.0))
    assert not res.partial
    assert _result_set(res.rows, q.effective_projection()) == naive_evaluate(fed, q)


def test_dead_endpoint_replans_and_flags_partial(small_fed, small_stats, workload):
    fed, _ = small_fed
    # kill DBpedia (hub source) permanently
    srcs = [FlakySource(s, dead=(s.name == "DBpedia")) for s in fed.sources]
    flaky = Federation(srcs, fed.dictionary)
    survivors = Federation([s for s in fed.sources if s.name != "DBpedia"],
                           fed.dictionary)
    hit = 0
    for q in workload:
        res = execute_with_failover(flaky, small_stats, q)
        want_partial = len(naive_evaluate(survivors, q))
        got = _result_set(res.rows, q.effective_projection())
        # results == complete answer over the surviving federation
        assert got == naive_evaluate(survivors, q)
        if res.partial:
            hit += 1
            assert res.excluded == ["DBpedia"]
            # the default session salvages the pipeline's operator state on a
            # mid-query death: one salvage, zero replans
            assert res.salvages >= 1 and res.replans == 0
    assert hit > 0, "no query touched the dead endpoint?"


def test_dead_endpoint_replan_mode_still_replans(small_fed, small_stats, workload):
    """salvage=False restores the legacy exclude-and-replan loop."""
    fed, _ = small_fed
    srcs = [FlakySource(s, dead=(s.name == "DBpedia")) for s in fed.sources]
    flaky = Federation(srcs, fed.dictionary)
    survivors = Federation([s for s in fed.sources if s.name != "DBpedia"],
                           fed.dictionary)
    session = FailoverSession(flaky, small_stats, salvage=False)
    hit = 0
    for q in workload:
        res = session.execute(q)
        assert _result_set(res.rows, q.effective_projection()) == \
            naive_evaluate(survivors, q)
        if res.partial and res.replans:
            hit += 1
            assert res.salvages == 0
    assert hit > 0, "no query touched the dead endpoint?"


def test_failover_session_plan_cache_survives_replan(small_fed, small_stats, workload):
    """A shared session keeps its optimizer across queries: after the first
    replan excludes the dead endpoint, repeats of a template are plan-cache
    hits — previously impossible (each exclusion rebuilt all statistics and
    threw the optimizer away)."""
    fed, _ = small_fed
    srcs = [FlakySource(s, dead=(s.name == "DBpedia")) for s in fed.sources]
    flaky = Federation(srcs, fed.dictionary)
    survivors = Federation([s for s in fed.sources if s.name != "DBpedia"],
                           fed.dictionary)
    session = FailoverSession(flaky, small_stats)
    first = [session.execute(q) for q in workload]
    kill = next((i for i, r in enumerate(first)
                 if r.salvages >= 1 or r.replans >= 1), None)
    assert kill is not None, "no query touched the dead endpoint?"
    # once excluded, every later answer is honestly partial
    assert all(r.partial and r.excluded == ["DBpedia"] for r in first[kill:])
    epoch = session.stats.epoch
    assert epoch >= 1
    # templated repetition: same structure => plan-cache hit, zero replans.
    # Queries planned *before* the exclusion are epoch-stale: lazily evicted
    # and replanned exactly once, then they hit too (third pass).  The killed
    # query itself was *salvaged*, never replanned, so its plan is also
    # pre-exclusion stale: the boundary is kill+1.
    second = [session.execute(q) for q in workload]
    assert all(r.cache_hit and r.replans == 0 for r in second[kill + 1:])
    assert all(not r.cache_hit for r in second[:kill + 1])
    assert all(r.stats_epoch == epoch for r in second)
    third = [session.execute(q) for q in workload]
    assert all(r.cache_hit and r.replans == 0 for r in third)
    # the caller's federation must come through untouched: rebuilding the
    # live Federation must not renumber the shared Source objects' sids
    assert [s.sid for s in flaky.sources] == list(range(len(flaky.sources)))
    for q, r1, r2 in zip(workload[kill:], first[kill:], second[kill:]):
        want = naive_evaluate(survivors, q)
        proj = q.effective_projection()
        assert _result_set(r1.rows, proj) == want
        assert _result_set(r2.rows, proj) == want


def test_failover_session_execute_batch(small_fed, small_stats, workload):
    """Batched failover: one optimize_batch plans the whole workload; a dead
    endpoint costs one exclusion plus one batched replan of the remaining
    queries (not per-query rebuilds), answers match the surviving federation,
    and a repeat batch is served from the plan cache under one epoch."""
    fed, _ = small_fed
    srcs = [FlakySource(s, dead=(s.name == "DBpedia")) for s in fed.sources]
    flaky = Federation(srcs, fed.dictionary)
    survivors = Federation([s for s in fed.sources if s.name != "DBpedia"],
                           fed.dictionary)
    session = FailoverSession(flaky, small_stats)
    first = session.execute_batch(workload)
    assert len(first) == len(workload)
    assert session.excluded == ["DBpedia"]
    assert any(r.replans >= 1 for r in first), "no query touched the dead endpoint?"
    # the query that was running when the endpoint died completed on its
    # salvaged operator state instead of joining the batched replan
    assert any(r.salvages >= 1 for r in first)
    for q, r in zip(workload, first):
        assert _result_set(r.rows, q.effective_projection()) == \
            naive_evaluate(survivors, q)
    epoch = session.stats.epoch
    assert epoch >= 1
    kill = next(i for i, r in enumerate(first) if r.replans >= 1)
    second = session.execute_batch(workload)
    # one epoch for the whole repeat batch; queries replanned after the
    # exclusion are cache hits, pre-exclusion plans are epoch-stale and
    # replanned exactly once — the third batch hits throughout.  The killed
    # query was salvaged, not replanned: its plan is pre-exclusion stale too,
    # so the boundary is kill+1.
    assert {r.stats_epoch for r in second} == {epoch}
    assert all(r.cache_hit and r.replans == 0 for r in second[kill + 1:])
    assert all(not r.cache_hit for r in second[:kill + 1])
    assert all(r.partial and r.excluded == ["DBpedia"] for r in second)
    third = session.execute_batch(workload)
    assert all(r.cache_hit and r.replans == 0 for r in third)
    for q, r in zip(workload, second):
        assert _result_set(r.rows, q.effective_projection()) == \
            naive_evaluate(survivors, q)


def test_failover_session_restore_recovers_completeness(small_fed, small_stats, workload):
    """Recovery: after the endpoint comes back, restore() re-admits it via
    add_source and results are complete again (partial flag clears)."""
    fed, _ = small_fed
    srcs = [FlakySource(s, dead=(s.name == "DBpedia")) for s in fed.sources]
    flaky = Federation(srcs, fed.dictionary)
    session = FailoverSession(flaky, small_stats)
    q = next(q for q in workload
             if len(naive_evaluate(fed, q)) !=
             len(naive_evaluate(Federation([s for s in fed.sources
                                            if s.name != "DBpedia"],
                                           fed.dictionary), q)))
    res = session.execute(q)
    assert res.partial and res.excluded == ["DBpedia"]
    # the endpoint comes back
    next(s for s in srcs if s.name == "DBpedia").dead = False
    epoch = session.stats.epoch
    sid = session.restore("DBpedia")
    assert sid == len(session.fed.sources) - 1
    assert session.stats.epoch == epoch + 1
    res2 = session.execute(q)
    assert not res2.partial and not res2.excluded
    assert not res2.cache_hit                  # pre-restore plan is stale
    assert _result_set(res2.rows, q.effective_projection()) == naive_evaluate(fed, q)
    # incremental add_source == from-scratch rebuild of the restored order
    from test_stats_lifecycle import assert_stats_equal
    from repro.rdf.dataset import Source
    order = [s.name for s in session.fed.sources]
    rebuilt = build_federated_stats(Federation(
        [Source(n, fed.by_name(n).table) for n in order], fed.dictionary))
    assert_stats_equal(session.stats, rebuilt)
