"""Endpoint failure handling: transparent retry on transient failures;
re-plan + honest partial flag when an endpoint stays dead."""
import numpy as np
import pytest

from repro.core.federation import build_federated_stats
from repro.engine.local import naive_evaluate
from repro.ft.failover import FlakySource, execute_with_failover
from repro.ft.resilience import RetryPolicy
from repro.rdf.dataset import Federation


def _result_set(rel, proj):
    n = len(next(iter(rel.values()))) if rel else 0
    return set(zip(*[rel[v].tolist() for v in proj])) if n else set()


def test_transient_failure_recovers_complete(small_fed, small_stats, workload):
    fed, _ = small_fed
    flaky = Federation(
        [FlakySource(s, fail_times=1) for s in fed.sources], fed.dictionary)
    q = workload[0]
    res = execute_with_failover(flaky, small_stats, q,
                                RetryPolicy(max_attempts=3, base_delay_s=0.0))
    assert not res.partial
    assert _result_set(res.rows, q.effective_projection()) == naive_evaluate(fed, q)


def test_dead_endpoint_replans_and_flags_partial(small_fed, small_stats, workload):
    fed, _ = small_fed
    # kill DBpedia (hub source) permanently
    srcs = [FlakySource(s, dead=(s.name == "DBpedia")) for s in fed.sources]
    flaky = Federation(srcs, fed.dictionary)
    survivors = Federation([s for s in fed.sources if s.name != "DBpedia"],
                           fed.dictionary)
    hit = 0
    for q in workload:
        res = execute_with_failover(flaky, small_stats, q)
        want_partial = len(naive_evaluate(survivors, q))
        got = _result_set(res.rows, q.effective_projection())
        # results == complete answer over the surviving federation
        assert got == naive_evaluate(survivors, q)
        if res.partial:
            hit += 1
            assert res.excluded == ["DBpedia"]
            assert res.replans >= 1
    assert hit > 0, "no query touched the dead endpoint?"
