"""Per-architecture smoke tests: instantiate a REDUCED config of the same
family, run forward + one train step + one decode step on CPU, assert output
shapes and finiteness. The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import SHAPES, reduced_config
from repro.configs import ARCH_IDS, get_arch
from repro.models import model as MDL

pytestmark = pytest.mark.slow  # ~2 min: one XLA compile per architecture
from repro.train.optimizer import adamw
from repro.train.train_step import make_train_step

B, S = 2, 32


def _batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm_prefix, cfg.d_model)), jnp.float32)
    if cfg.encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_train_step(arch_id):
    cfg = reduced_config(get_arch(arch_id))
    rng = np.random.default_rng(0)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    batch = _batch(cfg, rng)

    logits, aux = jax.jit(lambda p, b: MDL.forward(cfg, p, b))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch_id}: non-finite logits"

    opt = adamw(lr=1e-3)
    step = make_train_step(cfg, opt)
    opt_state = opt.init(params)
    params2, opt_state, metrics = jax.jit(step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{arch_id}: non-finite loss"
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    diff = sum(float(jnp.abs(a - b).sum())
               for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert diff > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step(arch_id):
    cfg = reduced_config(get_arch(arch_id))
    params = MDL.init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    S_ctx = 16
    caches = MDL.init_decode_caches(cfg, B, S_ctx, jnp.float32)
    if cfg.encdec:
        rng = np.random.default_rng(1)
        frames = jnp.asarray(rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)
        caches["enc_out"] = MDL._encoder(cfg, params, frames)
    tok = jnp.zeros((B, 1), jnp.int32)
    fn = jax.jit(lambda p, c, t, pos: MDL.decode_step(cfg, p, c, t, pos))
    logits, caches = fn(params, caches, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    logits2, caches = fn(params, caches, tok, jnp.int32(1))
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch_id", ["qwen2-0.5b", "falcon-mamba-7b", "whisper-tiny"])
def test_decode_matches_forward(arch_id):
    """Greedy decode logits must match full-sequence forward logits."""
    cfg = reduced_config(get_arch(arch_id))
    params = MDL.init_params(cfg, jax.random.PRNGKey(2), jnp.float32)
    rng = np.random.default_rng(2)
    T = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    batch = {"tokens": tokens}
    if cfg.encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)
    full_logits, _ = MDL.forward(cfg, params, batch)

    caches = MDL.init_decode_caches(cfg, B, T, jnp.float32)
    if cfg.encdec:
        caches["enc_out"] = MDL._encoder(cfg, params, batch["frames"])
    outs = []
    for t in range(T):
        lg, caches = MDL.decode_step(cfg, params, caches, tokens[:, t: t + 1],
                                     jnp.int32(t))
        outs.append(lg)
    dec_logits = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits), np.asarray(full_logits),
                               rtol=2e-3, atol=2e-3)


def test_param_count_orders_of_magnitude():
    """Analytic param counts should be within ~35% of the published sizes."""
    expect = {
        "gemma3-12b": 12e9,
        "qwen1.5-32b": 32e9,
        "qwen3-14b": 14e9,
        "qwen2-0.5b": 0.5e9,
        "phi3.5-moe-42b-a6.6b": 42e9,
        "deepseek-v2-236b": 236e9,
        "falcon-mamba-7b": 7e9,
        "chameleon-34b": 34e9,
        "jamba-1.5-large-398b": 398e9,
    }
    for arch_id, want in expect.items():
        got = get_arch(arch_id).param_count()
        assert 0.6 * want < got < 1.6 * want, f"{arch_id}: {got / 1e9:.1f}B vs {want / 1e9}B"


def test_moe_active_params():
    cfg = get_arch("phi3.5-moe-42b-a6.6b")
    active = cfg.active_param_count()
    assert 4e9 < active < 9e9, f"{active / 1e9:.1f}B"
