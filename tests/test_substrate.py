"""Checkpointing, data pipeline, fault tolerance, optimizers, gradient
compression — the production substrate."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.data.loader import TokenLoader
from repro.ft.resilience import Heartbeat, RetryPolicy, StragglerMitigator
from repro.train.grad_compress import (compress_grads, decompress_grads,
                                       init_error_feedback)
from repro.train.optimizer import adafactor, adamw, apply_updates


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(8, 16)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 5, (4,)), jnp.int32)}}


def test_ckpt_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    mgr.save(10, t, extra={"step": 10, "loss": 1.5})
    restored, extra = mgr.restore(10, jax.tree.map(lambda x: x, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["loss"] == 1.5


def test_ckpt_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [3, 4]


def test_ckpt_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    path = mgr.save(5, t)
    # corrupt a leaf
    for fn in os.listdir(path):
        if fn.endswith(".npy"):
            arr = np.load(os.path.join(path, fn))
            arr_flat = arr.reshape(-1)
            arr_flat[0] += 1
            np.save(os.path.join(path, fn), arr)
            break
    with pytest.raises(IOError):
        mgr.restore(5, t)


def test_ckpt_atomic_tmp_never_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_ckpt_elastic_reshard_roundtrip(tmp_path):
    """Save, then restore with explicit (single-device) shardings — the
    elastic path's API contract."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(3, t)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    restored, _ = mgr.restore(3, t, shardings=sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_loader_deterministic_resume():
    l1 = TokenLoader(vocab=100, batch=4, seq=16, seed=3)
    l2 = TokenLoader(vocab=100, batch=4, seq=16, seed=3)
    b5 = l1.batch_at(5)
    b5b = l2.batch_at(5)  # "restart" replays identically
    np.testing.assert_array_equal(b5["tokens"], b5b["tokens"])
    np.testing.assert_array_equal(b5["labels"], b5b["labels"])


def test_loader_ranks_disjoint_streams():
    a = TokenLoader(vocab=1000, batch=4, seq=32, seed=1, dp_rank=0, dp_size=2)
    b = TokenLoader(vocab=1000, batch=4, seq=32, seed=1, dp_rank=1, dp_size=2)
    assert not np.array_equal(a.batch_at(0)["tokens"], b.batch_at(0)["tokens"])


def test_loader_learnable_structure():
    l = TokenLoader(vocab=64, batch=8, seq=128, seed=0)
    b = l.batch_at(0)
    match = (b["labels"] == (b["tokens"] * 31 + 17) % 64).mean()
    assert match > 0.3  # the markov component is present


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_retry_policy_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("endpoint down")
        return 42

    assert RetryPolicy(max_attempts=4, base_delay_s=0.001).run(flaky) == 42


def test_retry_policy_exhausts():
    with pytest.raises(RuntimeError):
        RetryPolicy(max_attempts=2, base_delay_s=0.001).run(
            lambda: (_ for _ in ()).throw(RuntimeError("x")))


def test_straggler_backup_issued():
    import time

    sm = StragglerMitigator(factor=2.0, min_samples=2)
    for _ in range(3):
        sm.run_with_backup("ep", lambda: time.sleep(0.001) or 1, lambda: 2)
    out = sm.run_with_backup("ep", lambda: time.sleep(0.08) or 1, lambda: 2)
    assert out == 2 and sm.backups_issued == 1


def test_heartbeat_detects_dead():
    hb = Heartbeat(timeout_s=0.0)
    hb.beat("n1")
    import time

    time.sleep(0.01)
    assert hb.dead() == ["n1"]


# ---------------------------------------------------------------------------
# optimizers + gradient compression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_opt", [lambda: adamw(lr=0.05), lambda: adafactor(lr=0.5)])
def test_optimizer_reduces_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 0.1 * l0


def test_grad_compression_error_feedback_converges():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    efb = init_error_feedback(g)
    total_q = jnp.zeros((64,))
    # accumulated dequantized grads + final residual == accumulated raw grads
    acc_true = jnp.zeros((64,))
    for _ in range(20):
        q, efb = compress_grads(g, efb)
        deq = decompress_grads(q)
        total_q = total_q + deq["w"]
        acc_true = acc_true + g["w"]
    # error feedback keeps the running sum faithful
    err = float(jnp.abs(total_q + efb["w"] - acc_true).max())
    assert err < 1e-3


def test_grad_compression_bytes_shrink():
    g = {"w": jnp.zeros((1024,), jnp.float32)}
    q, _ = compress_grads(g, init_error_feedback(g))
    (qw, scale) = jax.tree.leaves(q, is_leaf=lambda x: isinstance(x, tuple))[0]
    assert qw.dtype == jnp.int8 and qw.nbytes == 1024  # 4x smaller than f32
