"""Optimized paths (EXPERIMENTS.md §Perf) must match the baseline math:
chunked attention == naive softmax; chunked loss == full-logit loss;
chunked mamba scan == full associative scan; absorbed MLA decode == naive."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import PerfFlags, reduced_config

pytestmark = pytest.mark.slow  # multi-minute: decode loops + gradient checks
from repro.configs import get_arch
from repro.models import model as MDL
from repro.models.attention_chunked import chunked_gqa_attention
from repro.train.train_step import loss_fn


def _with_flags(cfg, **kw):
    return dataclasses.replace(cfg, perf=PerfFlags(**kw))


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 8), (False, 0)])
@pytest.mark.parametrize("S,H,KV", [(64, 4, 2), (128, 4, 4), (64, 8, 1)])
def test_chunked_attention_matches_naive(causal, window, S, H, KV):
    rng = np.random.default_rng(S + H + KV)
    B, hd = 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    out = chunked_gqa_attention(q, k, v, causal=causal, window=window,
                                q_chunk=32, k_chunk=16)
    # naive reference
    from repro.models.layers import NEG_INF, gqa_output, gqa_scores
    scores = gqa_scores(q, k)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.zeros((S, S))
    if causal:
        mask = jnp.where(j > i, NEG_INF, mask)
    if window:
        mask = jnp.where(i - j >= window, NEG_INF, mask)
    w = jax.nn.softmax(scores + mask, axis=-1)
    want = gqa_output(w, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_chunked_attention_in_model():
    cfg = reduced_config(get_arch("gemma3-12b"))
    cfg_opt = _with_flags(cfg, chunked_attention=True, attn_chunk=8)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)}
    base, _ = MDL.forward(cfg, params, batch)
    opt, _ = MDL.forward(cfg_opt, params, batch)
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt), rtol=1e-4, atol=1e-4)


def test_chunked_loss_matches_full():
    cfg = reduced_config(get_arch("qwen2-0.5b"))
    cfg_opt = _with_flags(cfg, chunked_loss=True, loss_chunk=8)
    params = MDL.init_params(cfg, jax.random.PRNGKey(1), jnp.float32)
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)}
    l0, (nll0, _) = loss_fn(cfg, params, batch)
    l1, (nll1, _) = loss_fn(cfg_opt, params, batch)
    np.testing.assert_allclose(float(nll0), float(nll1), rtol=1e-5)
    # gradients must match too
    g0 = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    g1 = jax.grad(lambda p: loss_fn(cfg_opt, p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_chunked_mamba_matches_full():
    cfg = reduced_config(get_arch("falcon-mamba-7b"))
    cfg_opt = _with_flags(cfg, mamba_chunk=8)
    params = MDL.init_params(cfg, jax.random.PRNGKey(2), jnp.float32)
    rng = np.random.default_rng(2)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)}
    base, _ = MDL.forward(cfg, params, batch)
    opt, _ = MDL.forward(cfg_opt, params, batch)
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt), rtol=2e-4, atol=2e-4)


def test_kv_quant_int8_decode_close_to_fp():
    """int8 KV cache: bounded quantization error on decode logits."""
    cfg = reduced_config(get_arch("gemma3-12b"))
    cfg_q = _with_flags(cfg, kv_quant_int8=True)
    params = MDL.init_params(cfg, jax.random.PRNGKey(5), jnp.float32)
    B, T = 2, 12
    rng = np.random.default_rng(5)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    c0 = MDL.init_decode_caches(cfg, B, T, jnp.float32)
    c1 = MDL.init_decode_caches(cfg_q, B, T, jnp.float32)
    assert c1["groups"]["slot_0"]["k"].dtype == jnp.int8
    errs = []
    for t in range(T):
        l0, c0 = MDL.decode_step(cfg, params, c0, tokens[:, t: t + 1], jnp.int32(t))
        l1, c1 = MDL.decode_step(cfg_q, params, c1, tokens[:, t: t + 1], jnp.int32(t))
        denom = float(jnp.abs(l0).max())
        errs.append(float(jnp.abs(l0 - l1).max()) / max(denom, 1e-6))
    assert max(errs) < 0.05, f"int8 KV error too large: {max(errs):.3f}"
    # greedy tokens unchanged
    np.testing.assert_array_equal(np.asarray(jnp.argmax(l0[:, -1], -1)),
                                  np.asarray(jnp.argmax(l1[:, -1], -1)))


def test_mla_absorbed_decode_matches_naive():
    cfg = reduced_config(get_arch("deepseek-v2-236b"))
    cfg_opt = _with_flags(cfg, mla_absorb=True)
    params = MDL.init_params(cfg, jax.random.PRNGKey(3), jnp.float32)
    B, T = 2, 8
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    c0 = MDL.init_decode_caches(cfg, B, T, jnp.float32)
    c1 = MDL.init_decode_caches(cfg_opt, B, T, jnp.float32)
    for t in range(T):
        l0, c0 = MDL.decode_step(cfg, params, c0, tokens[:, t: t + 1], jnp.int32(t))
        l1, c1 = MDL.decode_step(cfg_opt, params, c1, tokens[:, t: t + 1], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                                   rtol=2e-4, atol=2e-4)
