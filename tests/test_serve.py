"""Serving engine: continuous batching correctness (prefix-consistent greedy
decode per request, independent of co-batched traffic)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import reduced_config
from repro.configs import get_arch
from repro.models import model as MDL
from repro.serve.engine import Request, ServeEngine

pytestmark = pytest.mark.slow  # long decode loops through XLA


@pytest.fixture(scope="module")
def small_lm():
    cfg = reduced_config(get_arch("qwen2-0.5b"))
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    return cfg, params


def _reference_greedy(cfg, params, prompt, n_new):
    """Single-request greedy decode via the raw decode step."""
    caches = MDL.init_decode_caches(cfg, 1, 64, jnp.float32)
    toks = list(prompt)
    out = []
    logits = None
    for t, tok in enumerate(toks):
        logits, caches = MDL.decode_step(cfg, params, caches,
                                         jnp.asarray([[tok]], jnp.int32),
                                         jnp.int32(t))
    for i in range(n_new):
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        logits, caches = MDL.decode_step(cfg, params, caches,
                                         jnp.asarray([[nxt]], jnp.int32),
                                         jnp.int32(len(toks) + i))
    return out


def test_serve_single_request_matches_reference(small_lm):
    cfg, params = small_lm
    prompt = [5, 9, 23]
    want = _reference_greedy(cfg, params, prompt, 6)
    eng = ServeEngine(cfg, params, n_slots=2, ctx_len=64)
    eng.submit(Request(rid=0, prompt=prompt, max_new=6))
    done = eng.run_until_done()
    assert len(done) == 1
    assert done[0].out == want


def test_serve_batched_requests_independent(small_lm):
    """Co-batched requests must produce the same tokens as when run alone."""
    cfg, params = small_lm
    prompts = [[5, 9, 23], [7, 2], [40, 11, 3, 8]]
    singles = [_reference_greedy(cfg, params, p, 5) for p in prompts]
    eng = ServeEngine(cfg, params, n_slots=2, ctx_len=64)  # fewer slots than reqs
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=5))
    done = sorted(eng.run_until_done(), key=lambda r: r.rid)
    assert len(done) == 3
    for req, want in zip(done, singles):
        assert req.out == want, f"request {req.rid} diverged under batching"


def test_serve_prefill_admission_matches_reference(small_lm):
    """Prefill-seeded caches continue exactly like token-by-token decode."""
    cfg, params = small_lm
    prompts = [[5, 9, 23], [7, 2, 40, 11]]
    singles = [_reference_greedy(cfg, params, p, 5) for p in prompts]
    eng = ServeEngine(cfg, params, n_slots=2, ctx_len=64, use_prefill=True)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=5))
    done = sorted(eng.run_until_done(), key=lambda r: r.rid)
    for req, want in zip(done, singles):
        assert req.out == want, f"prefill path diverged for request {req.rid}"


def test_serve_prefill_mamba(small_lm):
    """Prefill admission works for SSM caches too (state + conv window)."""
    from repro.config.base import reduced_config
    from repro.configs import get_arch

    cfg = reduced_config(get_arch("falcon-mamba-7b"))
    params = MDL.init_params(cfg, jax.random.PRNGKey(3), jnp.float32)
    prompt = [4, 17, 9]
    want = _reference_greedy(cfg, params, prompt, 4)
    eng = ServeEngine(cfg, params, n_slots=2, ctx_len=64, use_prefill=True)
    eng.submit(Request(rid=0, prompt=prompt, max_new=4))
    done = eng.run_until_done()
    assert done[0].out == want


def test_serve_slot_reuse(small_lm):
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, n_slots=1, ctx_len=64)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[i + 1], max_new=3))
    done = eng.run_until_done()
    assert len(done) == 3
    assert all(len(r.out) == 3 for r in done)


def test_serve_retired_slot_resets_pos(small_lm):
    """Regression: `step` claims idle slots "write harmlessly at their own
    position 0", but _retire used to leave the freed slot's stale pos (up to
    ctx-1) in the vector passed to decode_step, scattering the dummy token
    into freed cache lines.  Retirement must restore the invariant."""
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, n_slots=2, ctx_len=64)
    eng.submit(Request(rid=0, prompt=[5, 9, 23], max_new=4))
    eng.submit(Request(rid=1, prompt=[7, 2], max_new=12))
    while eng.queue or eng.active:
        eng.step()
        for slot in range(eng.n_slots):
            if slot not in eng.active:
                assert int(eng.pos[slot]) == 0, \
                    f"idle slot {slot} holds stale pos {int(eng.pos[slot])}"
    assert len(eng.finished) == 2
    assert (eng.pos == 0).all()


def test_serve_run_until_done_reports_only_new(small_lm):
    """Same drain contract as ``QueryServeEngine``: each ``run_until_done``
    call reports only the requests it retired, never earlier completions."""
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, n_slots=2, ctx_len=64)
    eng.submit(Request(rid=0, prompt=[5, 9], max_new=3))
    first = eng.run_until_done()
    assert [r.rid for r in first] == [0]
    assert eng.run_until_done() == []
    eng.submit(Request(rid=1, prompt=[7], max_new=3))
    assert [r.rid for r in eng.run_until_done()] == [1]
    assert [r.rid for r in eng.finished] == [0, 1]


def test_serve_rejects_prompt_longer_than_ctx(small_lm):
    """Regression: a prompt >= ctx_len used to be admitted and run `pos` off
    the slot cache grid; it must be rejected at submit."""
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, n_slots=1, ctx_len=16)
    with pytest.raises(ValueError, match="exceeds the slot cache"):
        eng.submit(Request(rid=0, prompt=list(range(1, 21)), max_new=4))
    assert not eng.queue and not eng.active
    # the boundary case (ctx - 1 tokens) is still admitted and retires cleanly
    eng.submit(Request(rid=1, prompt=list(range(1, 16)), max_new=4))
    done = eng.run_until_done()
    assert len(done) == 1 and done[0].done and len(done[0].out) >= 1
    assert int(eng.pos.max()) <= eng.ctx


def test_serve_truncate_overlong_prompt_matches_reference(small_lm):
    """overflow='truncate' keeps the newest ctx-1 tokens; decode then matches
    the single-request reference on the truncated prompt, and the slot
    retires at the cache boundary without running past the grid."""
    cfg, params = small_lm
    prompt = list(range(1, 25))                      # 24 tokens > ctx 16
    eng = ServeEngine(cfg, params, n_slots=2, ctx_len=16, overflow="truncate")
    eng.submit(Request(rid=0, prompt=list(prompt), max_new=4))
    done = eng.run_until_done()
    assert len(done) == 1
    req = done[0]
    assert req.truncated and req.done
    assert req.prompt == prompt[-15:]                # newest ctx-1 tokens
    want = _reference_greedy(cfg, params, prompt[-15:], len(req.out))
    assert req.out == want
    assert 1 <= len(req.out) <= 4
    assert int(eng.pos.max()) <= eng.ctx


def test_serve_run_until_done_raises_on_partial_drain(small_lm):
    """Exhausting ``max_steps`` with work still pending must raise instead
    of silently returning a partial drain (the ``QueryServeEngine``
    contract)."""
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, n_slots=1, ctx_len=64)
    eng.submit(Request(rid=0, prompt=[5, 9], max_new=8))
    with pytest.raises(RuntimeError, match="remaining"):
        eng.run_until_done(max_steps=1)
    assert eng.queue or eng.active                    # work preserved
    done = eng.run_until_done()                       # finishes cleanly
    assert [r.rid for r in done] == [0]

def test_serve_deadline_orders_admission(small_lm):
    """EDF slot admission: with one slot, a later-submitted request with a
    tighter SLO budget is admitted (and finishes) before an earlier patient
    one; equal deadlines keep submission order."""
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, n_slots=1, ctx_len=64)
    eng.submit(Request(rid=0, prompt=[5], max_new=2), deadline=1e6)
    eng.submit(Request(rid=1, prompt=[9], max_new=2), deadline=0.001)
    eng.submit(Request(rid=2, prompt=[7], max_new=2), deadline=1e6)
    done = eng.drain()
    assert [r.rid for r in done] == [1, 0, 2]
    assert eng.serve_stats.n_served == 3
    assert eng.serve_stats.n_steps > 0


def test_serve_poll_and_drain_report_exactly_once(small_lm):
    """The shared streaming surface on the token engine: ``poll`` after each
    ``step`` reports each retirement exactly once; ``drain`` reports only
    what it retired itself; ``run_until_done`` warns but still works."""
    cfg, params = small_lm
    eng = ServeEngine(cfg, params, n_slots=2, ctx_len=64)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[i + 1], max_new=2))
    seen: list[int] = []
    for _ in range(50):
        eng.step()
        seen.extend(r.rid for r in eng.poll())
        if not (eng.queue or eng.active):
            break
    assert sorted(seen) == [0, 1, 2]
    assert eng.poll() == [] and eng.drain() == []
    eng.submit(Request(rid=3, prompt=[4], max_new=2))
    with pytest.warns(DeprecationWarning, match="drain"):
        done = eng.run_until_done()
    assert [r.rid for r in done] == [3]
    assert [r.rid for r in eng.finished] == sorted(seen) + [3]


def test_serve_engines_share_the_serve_base_surface(small_lm):
    from repro.serve import ServeBase, ServeStats

    cfg, params = small_lm
    eng = ServeEngine(cfg, params, n_slots=1, ctx_len=16)
    assert isinstance(eng, ServeBase)
    assert isinstance(eng.serve_stats, ServeStats)
