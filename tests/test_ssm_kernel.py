"""Chunked selective-scan Pallas kernel vs associative-scan oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # kernel sweep: one XLA compile per shape

from repro.kernels import ref
from repro.kernels.ssm_scan import ssm_scan


def _inputs(rng, B, S, D, N):
    dt = jnp.asarray(np.abs(rng.normal(0.1, 0.05, (B, S, D))), jnp.float32)
    bt = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    ct = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
    a = -jnp.asarray(np.abs(rng.normal(1.0, 0.3, (D, N))), jnp.float32)
    return dt, bt, ct, x, a


@pytest.mark.parametrize("B,S,D,N,chunk", [
    (1, 64, 256, 8, 32),
    (2, 128, 256, 16, 64),
    (2, 128, 512, 8, 64),
])
def test_ssm_scan_sweep(B, S, D, N, chunk):
    rng = np.random.default_rng(B + S + D + N)
    dt, bt, ct, x, a = _inputs(rng, B, S, D, N)
    got = ssm_scan(dt, bt, ct, x, a, chunk=chunk)
    want = ref.ssm_scan_ref(dt, bt, ct, x, a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssm_scan_carry_across_chunks():
    """State must flow across chunk boundaries (not reset per chunk)."""
    rng = np.random.default_rng(0)
    B, S, D, N = 1, 128, 256, 8
    dt, bt, ct, x, a = _inputs(rng, B, S, D, N)
    # near-unit decay so early inputs influence late outputs strongly
    dt = dt * 0.01
    got = ssm_scan(dt, bt, ct, x, a, chunk=32)
    want = ref.ssm_scan_ref(dt, bt, ct, x, a)
    np.testing.assert_allclose(np.asarray(got)[:, -1], np.asarray(want)[:, -1],
                               rtol=2e-4, atol=2e-4)
