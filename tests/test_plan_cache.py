"""Plan cache + batch planning: hits return byte-identical results with
near-zero optimization time, keys distinguish constants / DISTINCT /
structure, and ``optimize_batch`` matches per-query ``optimize``."""
import numpy as np
import pytest

from repro.core.planner import OdysseyOptimizer, query_signature
from repro.engine.local import LocalEngine, naive_evaluate
from repro.query.algebra import BGPQuery, Const, TriplePattern, Var


def _results(fed, plan, q):
    rel = LocalEngine(fed).execute(plan).rows
    proj = q.effective_projection()
    return {v: rel[v] for v in proj}


def _plan_shape(node):
    from repro.core.planner import JoinPlanNode, SubqueryNode

    if isinstance(node, SubqueryNode):
        return ("sq", tuple(node.stars), tuple(node.sources),
                tuple((tp.s, tp.p, tp.o) for tp in node.patterns))
    assert isinstance(node, JoinPlanNode)
    return ("join", node.strategy, tuple(node.join_vars),
            _plan_shape(node.left), _plan_shape(node.right))


def _sig_distinct(queries):
    out, seen = [], set()
    for q in queries:
        sig = query_signature(q)[0]
        if sig not in seen:
            seen.add(sig)
            out.append(q)
    return out


def test_cache_hit_byte_identical_and_fast(tiny_fed, tiny_stats, tiny_workload):
    fed, _ = tiny_fed
    opt = OdysseyOptimizer(tiny_stats)
    miss_ms = hit_ms = 0.0
    queries = _sig_distinct(tiny_workload)
    for q in queries:
        p1 = opt.optimize(q)
        p2 = opt.optimize(q)
        assert not p1.cached and p2.cached
        assert _plan_shape(p1.root) == _plan_shape(p2.root)
        r1 = _results(fed, p1, q)
        r2 = _results(fed, p2, q)
        assert set(r1) == set(r2)
        for v in r1:
            assert r1[v].tobytes() == r2[v].tobytes()      # byte-identical
            assert r1[v].dtype == r2[v].dtype
        miss_ms += p1.optimization_ms
        hit_ms += p2.optimization_ms
    assert opt.plan_cache.hits == len(queries)
    assert hit_ms < miss_ms / 5, (hit_ms, miss_ms)
    assert hit_ms / len(queries) < 1.0                     # near-zero per hit


def test_cache_hit_with_renamed_variables(tiny_fed, tiny_stats, tiny_workload):
    """Variable names are canonicalized away: a renamed query hits the cache
    and gets a correctly rebound plan."""
    fed, _ = tiny_fed
    opt = OdysseyOptimizer(tiny_stats)

    def rename(t):
        return Var("ren_" + t.name) if isinstance(t, Var) else t

    for q in _sig_distinct(tiny_workload):
        p1 = opt.optimize(q)
        q2 = BGPQuery([TriplePattern(rename(tp.s), rename(tp.p), rename(tp.o))
                       for tp in q.patterns], distinct=q.distinct,
                      projection=["ren_" + v for v in q.projection])
        p2 = opt.optimize(q2)
        assert not p1.cached and p2.cached
        r1 = _results(fed, p1, q)
        r2 = _results(fed, p2, q2)
        for v in r1:
            assert r1[v].tobytes() == r2["ren_" + v].tobytes()
        # correctness of the rebound plan against the oracle evaluator
        got = set(zip(*[r2[v].tolist() for v in q2.effective_projection()])) \
            if len(next(iter(r2.values()))) else set()
        assert got == naive_evaluate(fed, q2)


def test_cache_distinguishes_constants(tiny_fed, tiny_stats):
    """Two templated queries differing only in a constant id must not share a
    plan (their selectivities — and possibly sources — differ)."""
    fed, _ = tiny_fed
    src = fed.sources[0]
    # a predicate with >= 2 distinct objects
    pred = obj = None
    for p in src.table.predicates():
        objs = np.unique(src.table.o[src.table.p == p])
        if len(objs) >= 2:
            pred, obj = int(p), objs[:2].tolist()
            break
    assert pred is not None

    def q_for(o):
        return BGPQuery([TriplePattern(Var("x"), Const(pred), Const(int(o)))],
                        distinct=True, projection=["x"])

    qa, qb = q_for(obj[0]), q_for(obj[1])
    assert query_signature(qa)[0] != query_signature(qb)[0]
    opt = OdysseyOptimizer(tiny_stats)
    pa = opt.optimize(qa)
    pb = opt.optimize(qb)
    assert not pb.cached and len(opt.plan_cache) == 2
    for q, plan in ((qa, pa), (qb, pb)):
        got = {r[0] for r in zip(*[
            _results(fed, plan, q)[v].tolist() for v in q.effective_projection()])}
        assert got == {r[0] for r in naive_evaluate(fed, q)}


def test_cache_distinguishes_distinct_flag(tiny_stats, tiny_workload):
    q = next(q for q in tiny_workload if len(q.patterns) >= 2)
    qd = BGPQuery(q.patterns, distinct=True, projection=q.projection)
    qn = BGPQuery(q.patterns, distinct=False, projection=q.projection)
    assert query_signature(qd)[0] != query_signature(qn)[0]
    opt = OdysseyOptimizer(tiny_stats)
    opt.optimize(qd)
    p2 = opt.optimize(qn)
    assert not p2.cached and len(opt.plan_cache) == 2
    assert opt.optimize(qn).cached  # and the second copy hits


def test_cache_hit_isolated_from_caller_mutation(tiny_stats, tiny_workload):
    """Regression: hits used to return the cached plan's `root` tree by
    reference, so engine/caller mutation of est_cardinality/sources corrupted
    every later hit.  Both the miss plan and each hit must own their tree."""
    from repro.core.planner import JoinPlanNode, SubqueryNode

    def mutate(node):
        node.est_cardinality = -1.0
        if isinstance(node, SubqueryNode):
            node.sources.append(999)
            node.stars.append(999)
        else:
            assert isinstance(node, JoinPlanNode)
            node.join_vars.append("corrupted")
            mutate(node.left)
            mutate(node.right)

    opt = OdysseyOptimizer(tiny_stats)
    q = next(q for q in tiny_workload if len(q.patterns) >= 2)
    p1 = opt.optimize(q)
    shape = _plan_shape(p1.root)
    mutate(p1.root)                       # caller corrupts the miss plan
    p2 = opt.optimize(q)
    assert p2.cached
    assert _plan_shape(p2.root) == shape  # hit unaffected by miss mutation
    mutate(p2.root)                       # caller corrupts a hit
    p3 = opt.optimize(q)
    assert p3.cached
    assert _plan_shape(p3.root) == shape  # later hits unaffected too
    assert all(sq.est_cardinality >= 0.0 for sq in p3.subqueries())


def test_cache_lru_eviction(tiny_stats, tiny_workload):
    opt = OdysseyOptimizer(tiny_stats, plan_cache_size=2)
    distinct_qs = _sig_distinct(tiny_workload)
    assert len(distinct_qs) >= 3
    for q in distinct_qs[:3]:
        opt.optimize(q)
    assert len(opt.plan_cache) == 2
    # the oldest entry was evicted -> re-optimizing it is a miss
    assert not opt.optimize(distinct_qs[0]).cached


def test_optimize_batch_matches_per_query(tiny_fed, tiny_stats, tiny_workload):
    fed, _ = tiny_fed
    # duplicate the workload so the batch contains repeats
    batch = list(tiny_workload) + list(tiny_workload)
    plans_b = OdysseyOptimizer(tiny_stats).optimize_batch(batch)
    singles = [OdysseyOptimizer(tiny_stats, plan_cache_size=0).optimize(q)
               for q in batch]
    assert len(plans_b) == len(singles) == len(batch)
    for q, pb, ps in zip(batch, plans_b, singles):
        assert _plan_shape(pb.root) == _plan_shape(ps.root)
        rb = _results(fed, pb, q)
        rs = _results(fed, ps, q)
        for v in rb:
            assert rb[v].tobytes() == rs[v].tobytes()


def test_optimize_batch_dedupes_without_cache(tiny_fed, tiny_stats, tiny_workload):
    """Batching dedupes identical signatures even with the cache disabled."""
    fed, _ = tiny_fed
    opt = OdysseyOptimizer(tiny_stats, plan_cache_size=0)
    assert opt.plan_cache is None
    batch = [tiny_workload[0]] * 3
    plans = opt.optimize_batch(batch)
    shapes = {_plan_shape(p.root) for p in plans}
    assert len(shapes) == 1
    for p in plans:
        r = _results(fed, p, tiny_workload[0])
        r0 = _results(fed, plans[0], tiny_workload[0])
        for v in r:
            assert r[v].tobytes() == r0[v].tobytes()
