"""Bounded-buffer jnp operators vs numpy semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import operators as ops


def test_scan_pattern_wildcards():
    rng = np.random.default_rng(0)
    table = rng.integers(0, 20, (64, 3)).astype(np.int32)
    trow = np.ones(64, bool)
    trow[50:] = False
    for pattern in ([5, -1, -1], [-1, 3, -1], [-1, 3, 7], [2, 1, -1]):
        data, valid, ovf = ops.scan_pattern(jnp.asarray(table), jnp.asarray(trow),
                                            jnp.asarray(pattern, jnp.int32), 32, (0, 2))
        s, p, o = pattern
        m = trow.copy()
        if s >= 0:
            m &= table[:, 0] == s
        if p >= 0:
            m &= table[:, 1] == p
        if o >= 0:
            m &= table[:, 2] == o
        want = table[m][:, [0, 2]]
        got = np.asarray(data)[np.asarray(valid)]
        assert not bool(ovf)
        np.testing.assert_array_equal(np.sort(got, axis=0), np.sort(want[:32], axis=0))


def test_scan_pattern_overflow_flag():
    table = np.zeros((64, 3), np.int32)
    trow = np.ones(64, bool)
    _, valid, ovf = ops.scan_pattern(jnp.asarray(table), jnp.asarray(trow),
                                     jnp.asarray([-1, -1, -1], jnp.int32), 16, (0, 1))
    assert bool(ovf) and int(np.asarray(valid).sum()) == 16


@pytest.mark.parametrize("cap", [64, 256])
def test_merge_join_matches_numpy(cap):
    rng = np.random.default_rng(cap)
    L, R = 48, 56
    left = rng.integers(0, 12, (64, 2)).astype(np.int32)
    right = rng.integers(0, 12, (64, 2)).astype(np.int32)
    lvalid = np.arange(64) < L
    rvalid = np.arange(64) < R
    data, valid, ovf = ops.merge_join(jnp.asarray(left), jnp.asarray(lvalid), 0,
                                      jnp.asarray(right), jnp.asarray(rvalid), 1, cap)
    got = {tuple(r) for r in np.asarray(data)[np.asarray(valid)].tolist()}
    want = set()
    for i in range(L):
        for j in range(R):
            if left[i, 0] == right[j, 1]:
                want.add(tuple(left[i].tolist() + right[j].tolist()))
    if not bool(ovf):
        assert got == want
    else:
        assert got <= want


def test_distinct():
    rng = np.random.default_rng(5)
    rel = rng.integers(0, 4, (32, 2)).astype(np.int32)
    valid = np.arange(32) < 30
    data, v, ovf = ops.distinct(jnp.asarray(rel), jnp.asarray(valid), 32)
    got = [tuple(r) for r in np.asarray(data)[np.asarray(v)].tolist()]
    want = {tuple(r) for r in rel[:30].tolist()}
    assert len(got) == len(set(got)) == len(want)
    assert set(got) == want


def test_semi_bind():
    rel = np.array([[1, 10], [2, 20], [3, 30], [4, 40]], np.int32)
    valid = np.array([True, True, True, False])
    keys = np.array([2, 4, 9], np.int32)
    kvalid = np.array([True, True, False])
    data, v, ovf = ops.semi_bind(jnp.asarray(rel), jnp.asarray(valid),
                                 jnp.asarray(keys), jnp.asarray(kvalid), 0, 4)
    got = np.asarray(data)[np.asarray(v)]
    np.testing.assert_array_equal(got, [[2, 20]])
