"""Bounded-buffer jnp operators vs numpy semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import operators as ops


def test_scan_pattern_wildcards():
    rng = np.random.default_rng(0)
    table = rng.integers(0, 20, (64, 3)).astype(np.int32)
    trow = np.ones(64, bool)
    trow[50:] = False
    for pattern in ([5, -1, -1], [-1, 3, -1], [-1, 3, 7], [2, 1, -1]):
        data, valid, ovf = ops.scan_pattern(jnp.asarray(table), jnp.asarray(trow),
                                            jnp.asarray(pattern, jnp.int32), 32, (0, 2))
        s, p, o = pattern
        m = trow.copy()
        if s >= 0:
            m &= table[:, 0] == s
        if p >= 0:
            m &= table[:, 1] == p
        if o >= 0:
            m &= table[:, 2] == o
        want = table[m][:, [0, 2]]
        got = np.asarray(data)[np.asarray(valid)]
        assert not bool(ovf)
        np.testing.assert_array_equal(np.sort(got, axis=0), np.sort(want[:32], axis=0))


def test_scan_pattern_overflow_flag():
    table = np.zeros((64, 3), np.int32)
    trow = np.ones(64, bool)
    _, valid, ovf = ops.scan_pattern(jnp.asarray(table), jnp.asarray(trow),
                                     jnp.asarray([-1, -1, -1], jnp.int32), 16, (0, 1))
    assert bool(ovf) and int(np.asarray(valid).sum()) == 16


@pytest.mark.parametrize("cap", [64, 256])
def test_merge_join_matches_numpy(cap):
    rng = np.random.default_rng(cap)
    L, R = 48, 56
    left = rng.integers(0, 12, (64, 2)).astype(np.int32)
    right = rng.integers(0, 12, (64, 2)).astype(np.int32)
    lvalid = np.arange(64) < L
    rvalid = np.arange(64) < R
    data, valid, ovf = ops.merge_join(jnp.asarray(left), jnp.asarray(lvalid), 0,
                                      jnp.asarray(right), jnp.asarray(rvalid), 1, cap)
    got = {tuple(r) for r in np.asarray(data)[np.asarray(valid)].tolist()}
    want = set()
    for i in range(L):
        for j in range(R):
            if left[i, 0] == right[j, 1]:
                want.add(tuple(left[i].tolist() + right[j].tolist()))
    if not bool(ovf):
        assert got == want
    else:
        assert got <= want


def test_distinct():
    rng = np.random.default_rng(5)
    rel = rng.integers(0, 4, (32, 2)).astype(np.int32)
    valid = np.arange(32) < 30
    data, v, ovf = ops.distinct(jnp.asarray(rel), jnp.asarray(valid), 32)
    got = [tuple(r) for r in np.asarray(data)[np.asarray(v)].tolist()]
    want = {tuple(r) for r in rel[:30].tolist()}
    assert len(got) == len(set(got)) == len(want)
    assert set(got) == want


def test_semi_bind():
    rel = np.array([[1, 10], [2, 20], [3, 30], [4, 40]], np.int32)
    valid = np.array([True, True, True, False])
    keys = np.array([2, 4, 9], np.int32)
    kvalid = np.array([True, True, False])
    data, v, ovf = ops.semi_bind(jnp.asarray(rel), jnp.asarray(valid),
                                 jnp.asarray(keys), jnp.asarray(kvalid), 0, 4)
    got = np.asarray(data)[np.asarray(v)]
    np.testing.assert_array_equal(got, [[2, 20]])


# --------------------------------------------------------------------------
# Group-algebra operators (OPTIONAL / UNION / FILTER twins)
# --------------------------------------------------------------------------

def test_left_merge_join_matches_numpy():
    rng = np.random.default_rng(9)
    left = rng.integers(0, 16, (32, 2)).astype(np.int32)   # keys 8..15 miss
    right = rng.integers(0, 8, (32, 2)).astype(np.int32)
    lvalid = np.arange(32) < 20
    rvalid = np.arange(32) < 24
    data, valid, ovf = ops.left_merge_join(
        jnp.asarray(left), jnp.asarray(lvalid), 0,
        jnp.asarray(right), jnp.asarray(rvalid), 1, 256)
    got = sorted(tuple(r) for r in np.asarray(data)[np.asarray(valid)].tolist())
    want = []
    for i in range(20):
        matches = [j for j in range(24) if right[j, 1] == left[i, 0]]
        if matches:
            for j in matches:
                want.append(tuple(left[i].tolist() + right[j].tolist()))
        else:                                   # unmatched: UNDEF-padded row
            want.append(tuple(left[i].tolist() + [ops.UNDEF, ops.UNDEF]))
    assert not bool(ovf)
    assert got == sorted(want)
    assert any(ops.UNDEF in r for r in got)     # the pad path is exercised


def test_left_merge_join_overflow_flag():
    left = np.zeros((16, 1), np.int32)
    right = np.zeros((16, 1), np.int32)
    valid = np.ones(16, bool)
    _, v, ovf = ops.left_merge_join(jnp.asarray(left), jnp.asarray(valid), 0,
                                    jnp.asarray(right), jnp.asarray(valid), 0, 64)
    assert bool(ovf) and int(np.asarray(v).sum()) == 64  # 256 rows, cap 64


def test_align_columns_and_union_rels():
    a = np.array([[1, 2], [3, 4], [0, 0]], np.int32)
    av = np.array([True, True, False])
    b = np.array([[5], [6], [7]], np.int32)
    bv = np.array([True, False, True])
    # shared schema (x, y, z): a has (x, y), b has (y,) only
    aa, av2 = ops.align_columns(jnp.asarray(a), jnp.asarray(av), (0, 1, -1))
    bb, bv2 = ops.align_columns(jnp.asarray(b), jnp.asarray(bv), (-1, 0, -1))
    data, v, ovf = ops.union_rels(aa, av2, bb, bv2, 8)
    got = {tuple(r) for r in np.asarray(data)[np.asarray(v)].tolist()}
    U = ops.UNDEF
    assert not bool(ovf)
    assert got == {(1, 2, U), (3, 4, U), (U, 5, U), (U, 7, U)}


def test_compare_mask_two_valued_and_filter_rows():
    U = ops.UNDEF
    rel = np.array([[3, 3], [3, 5], [5, 3], [U, 3], [3, U]], np.int32)
    valid = np.ones(5, bool)
    zero = jnp.int32(0)
    jrel, jv = jnp.asarray(rel), jnp.asarray(valid)
    for op_s, fn in [("=", np.equal), ("!=", np.not_equal), ("<", np.less),
                     ("<=", np.less_equal), (">", np.greater),
                     (">=", np.greater_equal)]:
        m = ops.compare_mask(jrel, jv, ops.OP_CODES[op_s], 0, 1, zero, zero)
        want = fn(rel[:, 0], rel[:, 1]) & (rel[:, 0] != U) & (rel[:, 1] != U)
        np.testing.assert_array_equal(np.asarray(m), want)
    # UNDEF rows are false even for != (two-valued semantics)
    m = ops.compare_mask(jrel, jv, ops.OP_CODES["!="], 0, 1, zero, zero)
    assert not bool(np.asarray(m)[3]) and not bool(np.asarray(m)[4])
    # constant side + compaction
    m = ops.compare_mask(jrel, jv, ops.OP_CODES[">="], 0, -1, zero, jnp.int32(4))
    data, v, ovf = ops.filter_rows(jrel, jv, m, 5)
    got = np.asarray(data)[np.asarray(v)]
    np.testing.assert_array_equal(got, [[5, 3]])
    assert not bool(ovf)
