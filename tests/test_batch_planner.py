"""Truly batched planning (``repro.core.batch_planner``): a mixed-shape
batch must be bit-identical, per query, to the sequential ``optimize`` loop;
source selection and the DP sweep must share work across the batch; the
whole batch must be planned under a single statistics-epoch snapshot; and
exact duplicates must be flagged ``cached`` even with the plan cache off."""
import numpy as np
import pytest

from repro.core.batch_planner import BatchPlanReport, pricing_key, shape_key
from repro.core.decomposition import decompose
from repro.core.join_order import (
    DP_BACKENDS,
    dp_join_order,
    dp_join_order_batch,
    star_graph_topology,
)
from repro.core.planner import OdysseyOptimizer
from repro.core.source_selection import select_sources, select_sources_batch
from repro.engine.local import LocalEngine, naive_evaluate


# -- template instantiation ---------------------------------------------------
# Variants of a workload query that exercise the batch pipeline's sharing
# tiers: object-constant variants share a pricing key (estimates ignore
# object values), subject-constant variants share only the *shape* (their
# selections and cardinalities differ), exact copies share the signature.
# The instantiation helpers are the benchmark's own — one source of truth,
# so the equivalence tests and the CI-gated batch scenario exercise the same
# sharing tiers.
from benchmarks.planner_bench import object_variants, subject_variants


def _mixed_batch(tiny_fed, tiny_workload, size=64):
    fed, _ = tiny_fed
    base = list(tiny_workload)
    for q in tiny_workload:
        if len(q.patterns) >= 2:
            base.extend(object_variants(q, fed, 6))
            base.extend(subject_variants(q, fed, 4))
    base.extend(tiny_workload[:4])                  # exact duplicates
    batch = list(base)
    while len(batch) < size:                        # cycle to the target size
        batch.append(base[len(batch) % len(base)])
    return batch[:size]


def _plan_fingerprint(plan):
    """Everything a caller can observe about a plan, with exact floats."""
    from test_plan_cache import _plan_shape

    cards = []

    def walk(n):
        cards.append(n.est_cardinality)
        if hasattr(n, "left"):
            walk(n.left)
            walk(n.right)

    walk(plan.root)
    return (_plan_shape(plan.root), tuple(cards), plan.fallback,
            tuple(tuple(s) for s in plan.selection.star_sources))


# -- the differential: batch == loop, bitwise --------------------------------

def test_optimize_batch_matches_sequential_mixed_shapes(tiny_fed, tiny_stats,
                                                        tiny_workload):
    fed, _ = tiny_fed
    batch = _mixed_batch(tiny_fed, tiny_workload, size=64)
    shapes = {shape_key(decompose(q), q.distinct) for q in batch}
    prices = {pricing_key(decompose(q), q.distinct) for q in batch}
    assert len(shapes) >= 4, "batch must mix structural shapes"
    assert len(prices) > len(shapes), "batch must mix pricing keys per shape"

    opt_loop = OdysseyOptimizer(tiny_stats)
    opt_batch = OdysseyOptimizer(tiny_stats)
    plans_l = [opt_loop.optimize(q) for q in batch]
    plans_b = opt_batch.optimize_batch(batch)

    assert len(plans_b) == len(batch)
    for q, pl, pb in zip(batch, plans_l, plans_b):
        assert _plan_fingerprint(pl) == _plan_fingerprint(pb), q.name
        assert pl.cached == pb.cached, q.name
        assert pl.stats_epoch == pb.stats_epoch == 0
    # cache-counter parity with the loop: same hits, same entries
    assert opt_batch.plan_cache.hits == opt_loop.plan_cache.hits
    assert len(opt_batch.plan_cache) == len(opt_loop.plan_cache)
    report = opt_batch.last_batch_report
    assert isinstance(report, BatchPlanReport)
    assert report.n_queries == len(batch)
    assert report.n_planned + report.duplicates + report.cache_hits == len(batch)
    # the whole point: fewer sweeps and selections than planned queries
    assert report.n_shapes < report.n_planned
    assert report.n_priced < report.n_planned
    assert report.n_selections <= report.n_priced

    # executed results agree bytewise on a structural sample
    eng = LocalEngine(fed)
    seen = set()
    for q, pl, pb in zip(batch, plans_l, plans_b):
        key = shape_key(decompose(q), q.distinct)
        if key in seen:
            continue
        seen.add(key)
        rl = eng.execute(pl).rows
        rb = eng.execute(pb).rows
        for v in q.effective_projection():
            assert rl[v].tobytes() == rb[v].tobytes()


def test_optimize_batch_second_batch_all_cache_hits(tiny_stats, tiny_workload):
    opt = OdysseyOptimizer(tiny_stats)
    batch = list(tiny_workload)
    first = opt.optimize_batch(batch)
    assert any(not p.cached for p in first)
    second = opt.optimize_batch(batch)
    assert all(p.cached for p in second)
    assert opt.last_batch_report.n_planned == 0
    for p1, p2 in zip(first, second):
        assert _plan_fingerprint(p1) == _plan_fingerprint(p2)


# -- satellite fix: duplicates are hits even with the cache off --------------

def test_optimize_batch_cache_off_duplicates_marked_cached(tiny_stats,
                                                           tiny_workload):
    opt = OdysseyOptimizer(tiny_stats, plan_cache_size=0)
    assert opt.plan_cache is None
    q = tiny_workload[0]
    plans = opt.optimize_batch([q, q, q])
    assert [p.cached for p in plans] == [False, True, True], \
        "in-batch duplicates must be flagged like PlanCache hits"
    assert all(p.optimization_ms >= 0.0 for p in plans)
    assert opt.last_batch_report.duplicates == 2
    fps = {_plan_fingerprint(p) for p in plans}
    assert len(fps) == 1


# -- satellite: one epoch snapshot for the whole batch -----------------------

def test_optimize_batch_snapshots_epoch_once(tiny_fed, tiny_stats,
                                             tiny_workload, monkeypatch):
    """A statistics mutation landing mid-batch (after the snapshot) must not
    split the batch across epochs: every plan carries the snapshot epoch and
    every cache entry is keyed under it (so all of them go stale together)."""
    import repro.core.batch_planner as bp

    fed, _ = tiny_fed
    stats = tiny_stats.clone()              # never mutate the session fixture
    opt = OdysseyOptimizer(stats)
    epoch0 = stats.epoch

    real_select = bp.select_sources_batch
    fired = {"n": 0}

    def select_then_mutate(graphs, s, memo=None):
        out = real_select(graphs, s, memo=memo)
        if fired["n"] == 0:                 # one mid-batch refresh
            fired["n"] = 1
            stats.refresh_source(0, fed.sources[0].table)
        return out

    monkeypatch.setattr(bp, "select_sources_batch", select_then_mutate)
    batch = [q for q in tiny_workload if len(q.patterns) >= 2]
    plans = opt.optimize_batch(batch)

    assert fired["n"] == 1 and stats.epoch == epoch0 + 1
    assert {p.stats_epoch for p in plans} == {epoch0}, \
        "batch emitted plans from two epochs"
    # every entry was keyed under the snapshot => uniformly stale now: the
    # next (post-mutation) planning of any member is a miss, not a hit
    monkeypatch.setattr(bp, "select_sources_batch", real_select)
    replan = opt.optimize(batch[0])
    assert not replan.cached
    assert replan.stats_epoch == epoch0 + 1


# -- the shared layers, differentially ---------------------------------------

def test_select_sources_batch_matches_single(tiny_fed, tiny_stats,
                                             tiny_workload):
    fed, _ = tiny_fed
    batch = _mixed_batch(tiny_fed, tiny_workload, size=24)
    graphs = [decompose(q) for q in batch]
    sels_b = select_sources_batch(graphs, tiny_stats)
    for q, g, sb in zip(batch, graphs, sels_b):
        s1 = select_sources(g, tiny_stats)
        assert s1.star_sources == sb.star_sources, q.name
        assert s1.edge_pairs == sb.edge_pairs, q.name
        assert [sorted(d) for d in s1.star_cs] == [sorted(d) for d in sb.star_cs]
        for d1, d2 in zip(s1.star_cs, sb.star_cs):
            for k in d1:
                assert np.array_equal(d1[k], d2[k]), (q.name, k)


@pytest.mark.parametrize("dp_backend", DP_BACKENDS)
def test_dp_join_order_batch_matches_single(tiny_stats, tiny_workload,
                                            dp_backend):
    """Shape-group sweeps must be bit-identical (cost, cardinality, leaf
    order, strategies) to planning each member alone — under the numpy
    backend and the on-device (Pallas, interpret-mode) jax backend alike."""
    def strategies(t, out):
        out.append((t.kind, t.strategy, tuple(sorted(t.stars)),
                    t.cost, t.cardinality))
        if t.left is not None:
            strategies(t.left, out)
            strategies(t.right, out)
        return out

    groups = {}
    for q in tiny_workload:
        g = decompose(q)
        groups.setdefault((star_graph_topology(g), q.distinct), []).append((q, g))
    checked = 0
    for (_, distinct), members in groups.items():
        graphs = [g for _, g in members]
        sels = select_sources_batch(graphs, tiny_stats)
        trees = dp_join_order_batch(graphs, tiny_stats, sels, distinct=distinct,
                                    dp_backend=dp_backend)
        for (q, g), tree in zip(members, trees):
            single = dp_join_order(g, tiny_stats, select_sources(g, tiny_stats),
                                   distinct=distinct)
            assert strategies(single, []) == strategies(tree, []), q.name
            assert tree.leaf_order() == single.leaf_order(), q.name
            checked += 1
    assert checked == len(tiny_workload)


def test_dp_join_order_batch_weighted_sources(tiny_stats, tiny_workload):
    """The exclusive-group seed's per-source weight lookup (``source_weight``
    set) must keep batch == single == reference — this path is outside the
    default-cost differential tests."""
    from repro.core.cost import CostModel
    from repro.core.join_order import dp_join_order_ref

    cm = CostModel(source_weight={0: 3.0, 2: 0.4, 5: 7.5})

    def strategies(t, out):
        out.append((t.kind, t.strategy, tuple(sorted(t.stars)), t.cost,
                    t.cardinality, tuple(t.sources) if t.sources else None))
        if t.left is not None:
            strategies(t.left, out)
            strategies(t.right, out)
        return out

    groups = {}
    for q in tiny_workload:
        g = decompose(q)
        groups.setdefault((star_graph_topology(g), q.distinct), []).append((q, g))
    for (_, distinct), members in groups.items():
        graphs = [g for _, g in members]
        sels = select_sources_batch(graphs, tiny_stats)
        trees = dp_join_order_batch(graphs, tiny_stats, sels, cm, distinct)
        for (q, g), tb in zip(members, trees):
            single = dp_join_order(g, tiny_stats, select_sources(g, tiny_stats),
                                   cm, distinct)
            ref = dp_join_order_ref(g, tiny_stats, select_sources(g, tiny_stats),
                                    cm, distinct)
            assert strategies(single, []) == strategies(tb, []), q.name
            assert single.leaf_order() == ref.leaf_order(), q.name
            assert np.isclose(single.cost, ref.cost, rtol=1e-9), q.name


def test_dp_join_order_batch_rejects_mixed_topology(tiny_stats, tiny_workload):
    by_topo = {}
    for q in tiny_workload:
        g = decompose(q)
        by_topo.setdefault(star_graph_topology(g), g)
    assert len(by_topo) >= 2
    graphs = list(by_topo.values())[:2]
    sels = [select_sources(g, tiny_stats) for g in graphs]
    with pytest.raises(ValueError, match="topology"):
        dp_join_order_batch(graphs, tiny_stats, sels)


def test_optimize_batch_jax_backend_matches_numpy(tiny_fed, tiny_stats,
                                                  tiny_workload):
    """The whole batched pipeline on the jax backend: same plans, caching
    flags and batch report as the numpy-backend optimizer."""
    batch = _mixed_batch(tiny_fed, tiny_workload, size=24)
    plans_np = OdysseyOptimizer(tiny_stats).optimize_batch(batch)
    opt_jax = OdysseyOptimizer(tiny_stats, dp_backend="jax")
    plans_jx = opt_jax.optimize_batch(batch)
    for q, a, b in zip(batch, plans_np, plans_jx):
        assert _plan_fingerprint(a) == _plan_fingerprint(b), q.name
        assert a.cached == b.cached, q.name
    assert opt_jax.last_batch_report.n_planned > 0


def test_optimizer_rejects_unknown_dp_backend(tiny_stats):
    with pytest.raises(ValueError, match="dp_backend"):
        OdysseyOptimizer(tiny_stats, dp_backend="cuda")


# -- the batched serving surface ---------------------------------------------

def test_query_serve_engine_batches_and_answers(tiny_fed, tiny_stats,
                                                tiny_workload):
    from repro.serve.query import QueryServeEngine

    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats, max_batch=16)
    wave = [q for q in tiny_workload for _ in range(2)]
    for q in wave:
        eng.submit(q)
    done = eng.run_until_done()
    assert len(done) == len(wave)
    for req in done:
        want = naive_evaluate(fed, req.query)
        proj = req.query.effective_projection()
        n = len(next(iter(req.rows.values()))) if req.rows else 0
        got = set(zip(*[req.rows[v].tolist() for v in proj])) if n else set()
        assert got == want, req.query.name
    # in-wave duplicates are already hits; a repeat wave is all hits
    assert eng.serve_stats.plan_cache_hits >= len(tiny_workload)
    served = eng.serve_stats.n_served
    for q in tiny_workload:
        eng.submit(q)
    eng.run_until_done()
    assert eng.serve_stats.n_served == served + len(tiny_workload)
    assert eng.serve_stats.n_planned == eng.optimizer.plan_cache.misses


def test_query_serve_run_until_done_reports_only_new(tiny_fed, tiny_stats,
                                                     tiny_workload):
    """Regression: ``run_until_done`` used to return the cumulative
    ``finished`` list, so a second drain re-reported (and double-counted)
    requests completed by earlier calls."""
    from repro.serve.query import QueryServeEngine

    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats, max_batch=4)
    for q in tiny_workload:
        eng.submit(q)
    first = eng.run_until_done()
    assert len(first) == len(tiny_workload)
    assert eng.run_until_done() == []          # drained: nothing new
    req = eng.submit(tiny_workload[0])
    second = eng.run_until_done()
    assert [r.qid for r in second] == [req.qid], \
        "second drain must report only the newly completed request"
    # the cumulative history is still available on the attribute
    assert len(eng.finished) == len(tiny_workload) + 1


def test_query_serve_engine_jax_backend(tiny_fed, tiny_stats, tiny_workload):
    """The serve path plans whole shape groups on-device: a jax-backend
    engine must serve the same answers as the numpy one."""
    from repro.serve.query import QueryServeEngine

    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats, max_batch=8, dp_backend="jax")
    assert eng.optimizer.dp_backend == "jax"
    wave = [q for q in tiny_workload if len(q.patterns) >= 2][:4]
    for q in wave:
        eng.submit(q)
    done = eng.run_until_done()
    assert len(done) == len(wave)
    for req in done:
        want = naive_evaluate(fed, req.query)
        proj = req.query.effective_projection()
        n = len(next(iter(req.rows.values()))) if req.rows else 0
        got = set(zip(*[req.rows[v].tolist() for v in proj])) if n else set()
        assert got == want, req.query.name


def test_query_serve_run_until_done_raises_on_partial_drain(tiny_fed,
                                                            tiny_stats,
                                                            tiny_workload):
    """Regression: exhausting ``max_steps`` with requests still queued used
    to return the partial drain silently — indistinguishable from a full
    one.  It must raise, keep the leftover on the queue, and a follow-up
    call must finish the job."""
    from repro.serve.query import QueryServeEngine

    fed, _ = tiny_fed
    eng = QueryServeEngine(fed, tiny_stats, max_batch=1)
    for q in tiny_workload:
        eng.submit(q)
    assert len(tiny_workload) > 1
    with pytest.raises(RuntimeError, match="still queued"):
        eng.run_until_done(max_steps=1)
    assert len(eng.queue) == len(tiny_workload) - 1   # leftover intact
    rest = eng.run_until_done()                       # and still drainable
    assert len(rest) == len(tiny_workload) - 1
    assert not eng.queue
