"""Fast-tier smoke test for ``benchmarks/roofline_bench.py``.

The roofline table was flagged "underused" on the ROADMAP: nothing
exercised it, so a schema drift in ``results/dryrun.json`` (or in the
bench itself) could rot silently while ``benchmarks.run`` kept "passing"
by printing the not-found fallback.  This pins the contract for all three
cell states and the missing-artifact path.
"""
from __future__ import annotations

import json

from benchmarks import roofline_bench


def _fake_results():
    return {
        "qwen2-0.5b|decode_8k|single": {
            "status": "ok",
            "compute_s": 0.004, "memory_s": 0.012, "collective_s": 0.001,
            "bottleneck": "memory", "roofline_fraction": 0.41,
        },
        "odyssey-fed|fed_query|multi": {
            "status": "ok",
            "compute_s": 0.002, "memory_s": 0.001, "collective_s": 0.009,
            "bottleneck": "collective", "roofline_fraction": 0.18,
        },
        "qwen3-14b|long_500k|single": {
            "status": "skipped", "reason": "full attention is quadratic at 500k",
        },
        "phi3.5-moe|train_8k|multi": {
            "status": "error", "error": "RESOURCE_EXHAUSTED: out of memory",
        },
    }


def test_roofline_table_from_dryrun_artifact(tmp_path):
    path = tmp_path / "dryrun.json"
    path.write_text(json.dumps(_fake_results()))
    csv, text = roofline_bench.run(str(path))

    # one csv row per ok cell: (name, bottleneck term in us, roofline fraction)
    names = {row[0] for row in csv}
    assert names == {"roofline/qwen2-0.5b|decode_8k|single",
                     "roofline/odyssey-fed|fed_query|multi"}
    by_name = {row[0]: row for row in csv}
    _, us, frac = by_name["roofline/qwen2-0.5b|decode_8k|single"]
    assert us == 0.012 * 1e6              # the max term, in microseconds
    assert frac == 0.41

    # the human table carries every cell state
    assert "memory" in text and "collective" in text
    assert "skipped: full attention is quadratic at 500k" in text
    assert "ERROR RESOURCE_EXHAUSTED" in text
    assert "41.0%" in text and "18.0%" in text


def test_roofline_missing_artifact_is_graceful(tmp_path):
    csv, text = roofline_bench.run(str(tmp_path / "nope.json"))
    assert csv == []
    assert "not found" in text and "repro.launch.dryrun" in text


def test_roofline_empty_results_yields_header_only(tmp_path):
    path = tmp_path / "dryrun.json"
    path.write_text("{}")
    csv, text = roofline_bench.run(str(path))
    assert csv == []
    assert text.startswith("== Roofline")
