"""Tests for the `repro.analysis` static-analysis suite.

Three layers:

1. Fixture snippets per RPR rule: the rule fires on the bug pattern and
   stays silent on the clean / suppressed twin.  The RPR003 firing fixture
   is literally the PR 5 kernel_bench bug (bare lambda timed against a
   jitted reference), so deliberately re-introducing it anywhere in
   `benchmarks/` fails the CI lint job.
2. Framework mechanics: suppression parsing (mandatory reasons, RPR100),
   fingerprint stability under unrelated edits, baseline diff/round-trip,
   CLI exit codes and --format=json.
3. End-to-end: the committed `analysis_baseline.json` matches a fresh run
   over the real `src` + `benchmarks` tree *exactly* — any finding drift
   (new finding, or a fixed finding whose baseline line wasn't retired)
   fails here and in CI.
"""
from __future__ import annotations

import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (
    analyze_paths,
    diff_baseline,
    load_baseline,
    write_baseline,
)
from repro.analysis.cli import main as cli_main
from repro.analysis.core import all_rules

REPO_ROOT = Path(__file__).resolve().parent.parent


def run_on(tmp_path: Path, relpath: str, code: str, rules=None):
    path = tmp_path / relpath
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(code))
    return analyze_paths([str(tmp_path)], root=str(tmp_path), rules=rules)


def rule_lines(result, rule):
    return [(f.path, f.line) for f in result.findings if f.rule == rule]


# --------------------------------------------------------------------------
# RPR001 trace-host-sync
# --------------------------------------------------------------------------

def test_rpr001_fires_in_jitted_body(tmp_path):
    res = run_on(tmp_path, "mod.py", """
        import jax, numpy as np

        @jax.jit
        def step(x):
            scale = float(x[0])          # host sync on a traced value
            return x * scale

        def helper(y):
            return np.asarray(y)         # traced via call graph below

        @jax.jit
        def entry(y):
            return helper(y) + 1
    """, rules=["RPR001"])
    lines = rule_lines(res, "RPR001")
    assert ("mod.py", 6) in lines        # float() in @jax.jit body
    assert ("mod.py", 10) in lines       # np.asarray via jit reachability


def test_rpr001_scan_and_pallas_bodies_are_traced(tmp_path):
    res = run_on(tmp_path, "mod.py", """
        import jax
        from jax import lax

        def body(carry, x):
            return carry + x.item(), None        # .item() in a scanned body

        def sweep(xs):
            return lax.scan(body, 0.0, xs)
    """, rules=["RPR001"])
    assert rule_lines(res, "RPR001") == [("mod.py", 6)]


def test_rpr001_clean_twins(tmp_path):
    res = run_on(tmp_path, "mod.py", """
        import jax, numpy as np

        def host_entry(x):
            return float(np.asarray(x)[0])   # untraced host wrapper: fine

        @jax.jit
        def step(x):
            n = int(x.shape[0])              # shape math is static: fine
            return x * n

        @jax.jit
        def suppressed(x):
            # repro: ignore[RPR001] -- concrete by contract: x is weak-typed python
            return x * float(x[0])
    """, rules=["RPR001"])
    assert rule_lines(res, "RPR001") == []
    assert len(res.suppressed) == 1


# --------------------------------------------------------------------------
# RPR002 cache-aliasing
# --------------------------------------------------------------------------

def test_rpr002_fires_on_aliasing_get_and_put(tmp_path):
    res = run_on(tmp_path, "cache.py", """
        class PlanCache:
            def get(self, sig):
                entry = self._entries.get(sig)
                return entry                      # shared mutable entry

            def put(self, sig, plan):
                self._entries[sig] = plan         # caller keeps a reference

        class TileCache:
            def get(self, k):
                return self._tiles[k]             # direct store read
    """, rules=["RPR002"])
    lines = rule_lines(res, "RPR002")
    assert ("cache.py", 5) in lines
    assert ("cache.py", 8) in lines
    assert ("cache.py", 12) in lines


def test_rpr002_clean_and_suppressed_twins(tmp_path):
    res = run_on(tmp_path, "cache.py", """
        import copy

        class PlanCache:
            def get(self, sig):
                entry = self._entries.get(sig)
                return copy.deepcopy(entry)       # detached at the boundary

            def put(self, sig, plan):
                self._entries[sig] = detach(plan)

        class ProgramCache:
            def get(self, key):
                fn = self._entries.get(key)
                # repro: ignore[RPR002] -- compiled XLA callables are immutable
                return fn
    """, rules=["RPR002"])
    assert rule_lines(res, "RPR002") == []
    assert len(res.suppressed) == 1


def test_rpr002_detach_completeness_fires_on_missing_variant(tmp_path):
    res = run_on(tmp_path, "planner.py", """
        class PlanNode:
            pass

        class SubqueryNode(PlanNode):
            pass

        class LeftJoinPlanNode(PlanNode):
            pass

        def _copy_node(node):                     # LeftJoinPlanNode missing
            if isinstance(node, SubqueryNode):
                return SubqueryNode()
            raise AssertionError(node)

        def _rename_node(node, ren):              # handles both variants
            if isinstance(node, SubqueryNode):
                return SubqueryNode()
            if isinstance(node, LeftJoinPlanNode):
                return LeftJoinPlanNode()
            raise AssertionError(node)
    """, rules=["RPR002"])
    findings = [f for f in res.findings if f.rule == "RPR002"]
    assert len(findings) == 1
    assert "_copy_node" in findings[0].message
    assert "LeftJoinPlanNode" in findings[0].message


def test_rpr002_detach_completeness_clean_when_all_variants_handled(tmp_path):
    res = run_on(tmp_path, "planner.py", """
        class PlanNode:
            pass

        class SubqueryNode(PlanNode):
            pass

        class UnionPlanNode(PlanNode):
            pass

        def _copy_node(node):
            if isinstance(node, SubqueryNode):
                return SubqueryNode()
            if isinstance(node, UnionPlanNode):
                return UnionPlanNode()
            raise AssertionError(node)

        def helper_without_detach_name(node):     # not a detach helper: free
            return node
    """, rules=["RPR002"])
    assert rule_lines(res, "RPR002") == []


# --------------------------------------------------------------------------
# RPR003 bench-parity (the PR 5 kernel_bench bug, verbatim shape)
# --------------------------------------------------------------------------

PR5_BUG = """
    import jax, time
    from repro.kernels import ref
    from repro.kernels.join_count import join_count

    def _time(fn, *args, n=5):
        fn(*args)
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / n

    def run():
        t_ref = _time(jax.jit(ref.join_count_ref), 1, 2, 3)
        t_pal = _time(lambda *x: join_count(*x), 1, 2, 3)   # bare lambda!
        return t_ref, t_pal
"""


def test_rpr003_fires_on_the_pr5_bug(tmp_path):
    res = run_on(tmp_path, "benchmarks/kernel_bench.py", PR5_BUG,
                 rules=["RPR003"])
    assert rule_lines(res, "RPR003") == [("benchmarks/kernel_bench.py", 15)]


def test_rpr003_reintroducing_the_pr5_bug_fails_the_gate(tmp_path):
    """Acceptance: the deliberate bench-parity bug makes the lint gate exit 1."""
    bench = tmp_path / "benchmarks"
    bench.mkdir()
    (bench / "kernel_bench.py").write_text(textwrap.dedent(PR5_BUG))
    rc = cli_main([str(bench), "--root", str(tmp_path), "--no-baseline"])
    assert rc == 1


def test_rpr003_clean_twin_both_jitted(tmp_path):
    res = run_on(tmp_path, "benchmarks/kernel_bench.py", """
        import jax

        def run():
            jit_ref = jax.jit(reference)
            jit_pal = jax.jit(kernel)
            t_ref = _time(jit_ref, 1)
            t_pal = _time(jit_pal, 1)
            t_fac = _time(program(params), 1)    # prepared factory: no verdict
            return t_ref, t_pal, t_fac
    """, rules=["RPR003"])
    assert rule_lines(res, "RPR003") == []


def test_rpr003_ignores_non_bench_files(tmp_path):
    res = run_on(tmp_path, "src/somelib.py", PR5_BUG, rules=["RPR003"])
    assert rule_lines(res, "RPR003") == []


# --------------------------------------------------------------------------
# RPR004 recompile-hazard
# --------------------------------------------------------------------------

def test_rpr004_fires_on_loop_jit_immediate_jit_and_lru(tmp_path):
    res = run_on(tmp_path, "mod.py", """
        import functools, jax
        import jax.numpy as jnp

        def sweep(shapes):
            for n in shapes:
                fn = jax.jit(lambda x: x * n)     # fresh wrapper per pass
                fn(n)

        def once(x):
            return jax.jit(lambda y: y + 1)(x)    # build-and-discard

        @functools.lru_cache(maxsize=64)
        def build_program(params):
            return jax.jit(lambda x: jnp.dot(x, x) * params[0])
    """, rules=["RPR004"])
    lines = rule_lines(res, "RPR004")
    assert ("mod.py", 7) in lines
    assert ("mod.py", 11) in lines
    assert ("mod.py", 13) in lines       # anchored at the @lru_cache decorator


def test_rpr004_clean_twins(tmp_path):
    res = run_on(tmp_path, "mod.py", """
        import functools, jax

        jit_fn = jax.jit(lambda x: x * 2)         # bound once at module scope

        def sweep(shapes):
            for n in shapes:
                jit_fn(n)                         # reused wrapper: fine

        @functools.lru_cache(maxsize=8)
        def parse_config(text):
            return text.split(",")                # no jax in sight: fine
    """, rules=["RPR004"])
    assert rule_lines(res, "RPR004") == []


# --------------------------------------------------------------------------
# RPR005 x64-discipline
# --------------------------------------------------------------------------

def test_rpr005_fires_outside_enable_x64_in_kernels(tmp_path):
    res = run_on(tmp_path, "src/repro/kernels/k.py", """
        import jax.numpy as jnp

        def price(x):
            return jnp.asarray(x, jnp.float64)    # silently f32 without x64
    """, rules=["RPR005"])
    assert rule_lines(res, "RPR005") == [("src/repro/kernels/k.py", 5)]


def test_rpr005_clean_twins(tmp_path):
    res = run_on(tmp_path, "src/repro/kernels/k.py", """
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.experimental import enable_x64

        def lexical(x):
            with enable_x64():
                return jnp.asarray(x, jnp.float64)

        def guarded(x):
            def run():
                return jnp.asarray(x, jnp.float64)
            if jax.config.jax_enable_x64:
                return run()
            with enable_x64():
                return run()

        def host(x):
            return np.zeros(x, np.float64)        # numpy is always 64-bit
    """, rules=["RPR005"])
    assert rule_lines(res, "RPR005") == []


def test_rpr005_does_not_apply_outside_kernels(tmp_path):
    res = run_on(tmp_path, "src/repro/core/m.py", """
        import jax.numpy as jnp

        def f(x):
            return jnp.asarray(x, jnp.float64)
    """, rules=["RPR005"])
    assert rule_lines(res, "RPR005") == []


# --------------------------------------------------------------------------
# Hygiene rules + suppression mechanics
# --------------------------------------------------------------------------

def test_hygiene_rules_fire(tmp_path):
    res = run_on(tmp_path, "src/lib.py", """
        def f(x, acc=[]):
            acc.append(x)
            return acc

        def g():
            try:
                risky()
            except Exception:
                pass

        def h(n):
            assert n > 0
            return n
    """, rules=["RPR101", "RPR102", "RPR103"])
    assert rule_lines(res, "RPR101") == [("src/lib.py", 2)]
    assert rule_lines(res, "RPR102") == [("src/lib.py", 9)]
    assert rule_lines(res, "RPR103") == [("src/lib.py", 13)]


def test_broad_except_with_reraise_is_clean(tmp_path):
    res = run_on(tmp_path, "src/lib.py", """
        def g():
            try:
                risky()
            except Exception as exc:
                log(exc)
                raise
    """, rules=["RPR102"])
    assert rule_lines(res, "RPR102") == []


def test_asserts_in_tests_and_benchmarks_are_exempt(tmp_path):
    code = "def t():\n    assert 1 > 0\n"
    res_t = run_on(tmp_path, "tests/test_x.py", code, rules=["RPR103"])
    assert rule_lines(res_t, "RPR103") == []
    res_b = run_on(tmp_path, "benchmarks/b.py", code, rules=["RPR103"])
    assert rule_lines(res_b, "RPR103") == []


def test_reasonless_suppression_is_rpr100_and_does_not_silence(tmp_path):
    res = run_on(tmp_path, "src/lib.py", """
        def f(x, acc=[]):  # repro: ignore[RPR101]
            return acc
    """)
    rules = {f.rule for f in res.findings}
    assert "RPR100" in rules             # the malformed suppression itself
    assert "RPR101" in rules             # ...which silenced nothing
    assert res.suppressed == []


def test_multiline_reason_suppression_covers_next_code_line(tmp_path):
    res = run_on(tmp_path, "src/lib.py", """
        def f(x,
              # repro: ignore[RPR101] -- registry shared by design: the dict is
              # the module-level singleton every caller mutates deliberately
              acc={}):
            return acc
    """, rules=["RPR101"])
    assert rule_lines(res, "RPR101") == []
    assert len(res.suppressed) == 1


# --------------------------------------------------------------------------
# Fingerprints + baseline workflow
# --------------------------------------------------------------------------

def test_fingerprint_stable_under_unrelated_edits(tmp_path):
    code = """
        def f(x, acc=[]):
            return acc
    """
    fp1 = run_on(tmp_path, "src/a.py", code).findings[0].fingerprint
    shifted = "\n\n# a new header comment\n" + textwrap.dedent(code)
    (tmp_path / "src/a.py").write_text(shifted)
    res2 = analyze_paths([str(tmp_path)], root=str(tmp_path))
    assert [f.fingerprint for f in res2.findings] == [fp1]


def test_baseline_roundtrip_new_and_stale(tmp_path):
    res = run_on(tmp_path, "src/a.py", """
        def f(x, acc=[]):
            return acc
    """)
    bl_path = tmp_path / "baseline.json"
    write_baseline(str(bl_path), res)
    baseline = load_baseline(str(bl_path))
    new, stale = diff_baseline(res, baseline)
    assert new == [] and stale == []
    # a second finding is NEW against the old baseline
    (tmp_path / "src/a.py").write_text(
        "def f(x, acc=[]):\n    return acc\n\ndef g(y, acc2={}):\n    return acc2\n")
    res2 = analyze_paths([str(tmp_path)], root=str(tmp_path))
    new2, stale2 = diff_baseline(res2, baseline)
    assert len(new2) == 1 and stale2 == []
    # fixing the original finding leaves a STALE baseline entry
    (tmp_path / "src/a.py").write_text("def f(x, acc=None):\n    return acc\n")
    res3 = analyze_paths([str(tmp_path)], root=str(tmp_path))
    new3, stale3 = diff_baseline(res3, baseline)
    assert new3 == [] and len(stale3) == 1


def test_write_baseline_carries_reasons_forward(tmp_path):
    res = run_on(tmp_path, "src/a.py", "def f(x, acc=[]):\n    return acc\n")
    bl_path = tmp_path / "baseline.json"
    entries = write_baseline(str(bl_path), res)
    fp = next(iter(entries))
    baseline = load_baseline(str(bl_path))
    baseline[fp]["reason"] = "reviewed: harmless in this context"
    entries2 = write_baseline(str(bl_path), res, baseline)
    assert entries2[fp]["reason"] == "reviewed: harmless in this context"


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def test_cli_exit_codes_and_json(tmp_path, capsys):
    src = tmp_path / "src"
    src.mkdir()
    (src / "a.py").write_text("def f(x, acc=[]):\n    return acc\n")
    rc = cli_main([str(src), "--root", str(tmp_path), "--no-baseline",
                   "--format", "json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert payload["new"][0]["rule"] == "RPR101"
    # clean tree exits 0
    (src / "a.py").write_text("def f(x):\n    return x\n")
    assert cli_main([str(src), "--root", str(tmp_path), "--no-baseline"]) == 0
    capsys.readouterr()
    # unknown rule id is a usage error
    assert cli_main([str(src), "--rules", "RPR999"]) == 2


def test_cli_baseline_gate(tmp_path, capsys):
    src = tmp_path / "src"
    src.mkdir()
    (src / "a.py").write_text("def f(x, acc=[]):\n    return acc\n")
    bl = tmp_path / "bl.json"
    assert cli_main([str(src), "--root", str(tmp_path), "--baseline", str(bl),
                     "--write-baseline"]) == 0
    assert cli_main([str(src), "--root", str(tmp_path),
                     "--baseline", str(bl)]) == 0
    # fixing the finding without retiring the baseline entry is loud
    (src / "a.py").write_text("def f(x):\n    return x\n")
    assert cli_main([str(src), "--root", str(tmp_path),
                     "--baseline", str(bl)]) == 1
    out = capsys.readouterr().out
    assert "STALE" in out


def test_every_rpr_rule_is_registered():
    ids = set(all_rules())
    for required in ("RPR001", "RPR002", "RPR003", "RPR004", "RPR005",
                     "RPR101", "RPR102", "RPR103"):
        assert required in ids


# --------------------------------------------------------------------------
# End-to-end over the real tree: the committed baseline matches exactly
# --------------------------------------------------------------------------

def test_e2e_committed_baseline_matches_real_tree_exactly():
    result = analyze_paths([str(REPO_ROOT / "src"), str(REPO_ROOT / "benchmarks")],
                           root=str(REPO_ROOT))
    baseline = load_baseline(str(REPO_ROOT / "analysis_baseline.json"))
    new, stale = diff_baseline(result, baseline)
    assert not new, "unbaselined findings (fix or re-baseline):\n" + \
        "\n".join(f.render() for f in new)
    assert not stale, "stale baseline entries (retire with --write-baseline):\n" + \
        "\n".join(stale)
    # the grandfathered set is exactly the committed one — drift in either
    # direction (new finding, silently fixed finding) fails loudly
    assert {f.fingerprint for f in result.findings} == set(baseline)
