import numpy as np
import pytest

from repro.core.federation import build_federated_stats
from repro.rdf.generator import (
    fedbench_like_spec,
    generate_federation,
    generate_workload,
)


@pytest.fixture(scope="session")
def small_fed():
    fed, gt = generate_federation(fedbench_like_spec(scale=0.2, seed=11))
    return fed, gt


@pytest.fixture(scope="session")
def small_stats(small_fed):
    fed, _ = small_fed
    return build_federated_stats(fed)


@pytest.fixture(scope="session")
def workload(small_fed):
    fed, gt = small_fed
    return generate_workload(fed, gt, n_star=8, n_hybrid=8, n_path=4, seed=5)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
