import numpy as np
import pytest

from repro.core.federation import build_federated_stats
from repro.rdf.generator import (
    fedbench_like_spec,
    generate_federation,
    generate_workload,
)


@pytest.fixture(scope="session")
def small_fed():
    fed, gt = generate_federation(fedbench_like_spec(scale=0.2, seed=11))
    return fed, gt


@pytest.fixture(scope="session")
def small_stats(small_fed):
    fed, _ = small_fed
    return build_federated_stats(fed)


@pytest.fixture(scope="session")
def workload(small_fed):
    fed, gt = small_fed
    return generate_workload(fed, gt, n_star=8, n_hybrid=8, n_path=4, seed=5)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


# -- smaller-scale federation for the fast tier ------------------------------
# A fraction of small_fed's size: fast-tier tests (planner differentials,
# plan-cache behavior) get a full 9-source federation without paying
# small_fed's generation/statistics cost.

@pytest.fixture(scope="session")
def tiny_fed():
    fed, gt = generate_federation(fedbench_like_spec(scale=0.06, seed=3))
    return fed, gt


@pytest.fixture(scope="session")
def tiny_stats(tiny_fed):
    fed, _ = tiny_fed
    return build_federated_stats(fed)


@pytest.fixture(scope="session")
def tiny_workload(tiny_fed):
    fed, gt = tiny_fed
    return generate_workload(fed, gt, n_star=4, n_hybrid=4, n_path=2, seed=9)
