"""Pallas flash-attention kernel vs naive softmax oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


def _naive(q, k, v, causal, window):
    S = q.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q, k)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.zeros((S, S))
    if causal:
        mask = jnp.where(j > i, -1e30, mask)
    if window:
        mask = mask + jnp.where(i - j >= window, -1e30, 0.0)
    return jax.nn.softmax(s + mask, -1) @ v


def test_flash_gqa_wrapper_matches_model_attention():
    from repro.kernels import ops
    from repro.models.layers import NEG_INF, gqa_output, gqa_scores

    rng = np.random.default_rng(1)
    B, S, H, KV, hd = 2, 256, 4, 2, 128
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    out = ops.flash_attention_gqa(q, k, v)
    s = gqa_scores(q, k)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    w = jax.nn.softmax(s + jnp.where(j > i, NEG_INF, 0.0), -1)
    want = gqa_output(w, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("S,hd,bq,bk", [(256, 128, 128, 128), (512, 128, 128, 256),
                                        (256, 256, 128, 128)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, hd, bq, bk, causal, window, dtype):
    rng = np.random.default_rng(S + hd + int(causal))
    BH = 2
    q = jnp.asarray(rng.normal(size=(BH, S, hd)), dtype) * hd ** -0.5
    k = jnp.asarray(rng.normal(size=(BH, S, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(BH, S, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk)
    want = _naive(q.astype(jnp.float32), k.astype(jnp.float32),
                  v.astype(jnp.float32), causal, window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want),
                               rtol=tol, atol=tol)
