"""Formulas (1)-(4): exactness of DISTINCT counts, accuracy of estimates."""
import numpy as np
import pytest

from repro.core.cardinality import (
    linked_star_cardinality_distinct,
    linked_star_cardinality_estimate,
    star_cardinality_distinct,
    star_cardinality_estimate,
)
from repro.core.decomposition import decompose
from repro.engine.local import naive_evaluate
from repro.query.algebra import BGPQuery, Const, Var
from repro.rdf.generator import generate_workload


def _pure_star_queries(fed, gt, workload):
    for q in workload:
        g = decompose(q)
        if (len(g.stars) == 1 and q.distinct
                and not any(isinstance(tp.o, Const) for tp in q.patterns)):
            yield q, g


def test_formula1_exact(small_fed, small_stats, workload):
    fed, gt = small_fed
    checked = 0
    for q, g in _pure_star_queries(fed, gt, workload):
        preds = [tp.p.tid for tp in q.patterns]
        got = sum(star_cardinality_distinct(cs, preds) for cs in small_stats.cs)
        var = g.stars[0].subject.name
        want = len(naive_evaluate(fed, BGPQuery(q.patterns, True, [var])))
        assert got == want, q.name
        checked += 1
    assert checked >= 1


def test_formula2_estimate_geq_distinct(small_fed, small_stats, workload):
    """Non-DISTINCT estimates must be >= the exact DISTINCT count and close
    to the true multiset size (paper example: 145,417 est vs 149,440 true)."""
    fed, gt = small_fed
    rel_errors = []
    for q, g in _pure_star_queries(fed, gt, workload):
        preds = [tp.p.tid for tp in q.patterns]
        distinct = sum(star_cardinality_distinct(cs, preds) for cs in small_stats.cs)
        est = sum(star_cardinality_estimate(cs, preds) for cs in small_stats.cs)
        assert est >= distinct - 1e-6
        # ground truth multiset size: evaluate star with all object vars kept
        var = g.stars[0].subject.name
        proj = sorted(q.variables())
        true = len(naive_evaluate(fed, BGPQuery(q.patterns, True, proj)))
        if true:
            rel_errors.append(abs(est - true) / true)
    assert rel_errors and float(np.median(rel_errors)) < 0.35


def test_formula3_exact(small_fed, small_stats, workload):
    fed, gt = small_fed
    checked = 0
    for q in workload:
        g = decompose(q)
        if len(g.stars) != 2 or not q.distinct:
            continue
        if any(isinstance(tp.o, Const) for tp in q.patterns):
            continue
        real_edges = [e for e in g.edges if not e.generic]
        if len(real_edges) != 1:
            continue
        e = real_edges[0]
        p1 = [p for p in g.stars[e.src].bound_preds() if p != e.pred]
        p2 = g.stars[e.dst].bound_preds()
        got = 0
        n = len(fed.sources)
        for a in range(n):
            for b in range(n):
                cp = small_stats.cp_between(a, b)
                if cp is None:
                    continue
                got += linked_star_cardinality_distinct(
                    cp, small_stats.cs[a], small_stats.cs[b], p1, p2, e.pred)
        sv = g.stars[e.src].subject.name
        ov = g.stars[e.dst].subject.name
        want = len(naive_evaluate(fed, BGPQuery(q.patterns, True, [sv, ov])))
        assert got == want, q.name
        checked += 1
    assert checked >= 1


def test_formula4_estimate(small_fed, small_stats, workload):
    fed, gt = small_fed
    errors = []
    for q in workload:
        g = decompose(q)
        real_edges = [e for e in g.edges if not e.generic]
        if len(g.stars) != 2 or len(real_edges) != 1:
            continue
        if any(isinstance(tp.o, Const) for tp in q.patterns):
            continue
        e = real_edges[0]
        p1 = [p for p in g.stars[e.src].bound_preds() if p != e.pred]
        p2 = g.stars[e.dst].bound_preds()
        est = 0.0
        n = len(fed.sources)
        for a in range(n):
            for b in range(n):
                cp = small_stats.cp_between(a, b)
                if cp is None:
                    continue
                est += linked_star_cardinality_estimate(
                    cp, small_stats.cs[a], small_stats.cs[b], p1 + [e.pred], p2, e.pred)
        proj = sorted(q.variables())
        true = len(naive_evaluate(fed, BGPQuery(q.patterns, True, proj)))
        if true:
            errors.append(abs(est - true) / true)
    assert errors and float(np.median(errors)) < 0.5
