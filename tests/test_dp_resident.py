"""Differential coverage for the compiled (non-interpret) jax DP path: the
device-resident fused sweep must return plans bit-identical to the numpy
sweep and to ``dp_join_order_ref`` on every topology family, including the
n=12 / B>=8 sizes the backend is benchmarked at, and must fall back to the
tiled per-layer kernel when a topology's schedule exceeds the budget."""
import numpy as np
import pytest

from repro.core.cost import CostModel
from repro.core.join_order import (
    DP_SWEEP_COUNTERS,
    dp_join_order,
    dp_join_order_batch,
    dp_join_order_ref,
)
from repro.core.source_selection import SourceSelection
from repro.rdf.shapes import shaped_planning_inputs


def _assert_same_tree(a, b, path=""):
    assert a.kind == b.kind, path
    assert a.stars == b.stars, path
    assert a.cardinality == b.cardinality, path
    assert a.cost == b.cost, path
    assert a.sources == b.sources, path
    assert a.strategy == b.strategy, path
    if a.kind == "join":
        _assert_same_tree(a.left, b.left, path + "L")
        _assert_same_tree(a.right, b.right, path + "R")


def _vary_sources(sel, b):
    """Member-specific source trims (same topology, different numbers /
    exclusive groups per member)."""
    ss = []
    for i, srcs in enumerate(sel.star_sources):
        keep = srcs
        if len(srcs) > 1 and (i + b) % 3 == 0:
            keep = srcs[:1] if b % 2 else srcs[1:]
        ss.append(list(keep))
    return SourceSelection(star_sources=ss, star_cs=sel.star_cs,
                           edge_pairs=sel.edge_pairs)


@pytest.mark.parametrize("shape,n", [
    ("chain", 4), ("chain", 8), ("chain", 12),
    ("tree", 4), ("tree", 8), ("tree", 12),
    ("clique", 4), ("clique", 8), ("clique", 10),
])
def test_resident_bit_identical_to_numpy(shape, n):
    g, stats, sel, q = shaped_planning_inputs(shape, n, seed=n)
    cm = CostModel()
    before = DP_SWEEP_COUNTERS["resident"]
    t_np = dp_join_order(g, stats, sel, cm, q.distinct, dp_backend="numpy")
    t_jx = dp_join_order(g, stats, sel, cm, q.distinct, dp_backend="jax")
    _assert_same_tree(t_np, t_jx)
    assert DP_SWEEP_COUNTERS["resident"] == before + 1   # resident, not tiled


@pytest.mark.slow
def test_resident_bit_identical_to_numpy_clique12():
    g, stats, sel, q = shaped_planning_inputs("clique", 12, seed=12)
    cm = CostModel()
    t_np = dp_join_order(g, stats, sel, cm, q.distinct, dp_backend="numpy")
    t_jx = dp_join_order(g, stats, sel, cm, q.distinct, dp_backend="jax")
    _assert_same_tree(t_np, t_jx)


@pytest.mark.parametrize("shape", ["chain", "tree", "clique"])
def test_resident_bit_identical_to_reference_oracle(shape):
    """Small-n grid against the frozenset reference, with per-source
    weights active so the exclusive-group w_lut path is exercised."""
    cm = CostModel(source_weight={0: 1.5, 1: 0.8, 2: 2.0})
    for n in (3, 5, 7):
        for seed in (1, 2):
            g, stats, sel, q = shaped_planning_inputs(shape, n, seed=seed)
            t_ref = dp_join_order_ref(g, stats, sel, cost_model=cm,
                                      distinct=q.distinct)
            t_jx = dp_join_order(g, stats, sel, cm, q.distinct,
                                 dp_backend="jax")
            _assert_same_tree(t_ref, t_jx)


def test_resident_b8_stack_bit_identical_members():
    """B=8 member stack at n=12 with member-specific source selections:
    every member's tree must match both the numpy batch and its own
    single-member plan, under default and weighted cost models."""
    g, stats, sel, q = shaped_planning_inputs("tree", 12, seed=41)
    sels = [_vary_sources(sel, b) for b in range(8)]
    graphs = [g] * 8
    for cm in (CostModel(), CostModel(source_weight={0: 1.3, 1: 0.7})):
        t_np = dp_join_order_batch(graphs, stats, sels, cm, q.distinct,
                                   dp_backend="numpy")
        t_jx = dp_join_order_batch(graphs, stats, sels, cm, q.distinct,
                                   dp_backend="jax")
        for a, b in zip(t_np, t_jx):
            _assert_same_tree(a, b)
        for b_i in (0, 3, 7):
            single = dp_join_order(g, stats, sels[b_i], cm, q.distinct,
                                   dp_backend="numpy")
            _assert_same_tree(single, t_jx[b_i])


def test_oversized_schedule_falls_back_to_tiled():
    """A tiny block budget must route the jax backend through the tiled
    per-layer kernel (resident state would not fit) — with identical
    plans."""
    g, stats, sel, q = shaped_planning_inputs("clique", 9, seed=7)
    cm = CostModel()
    before = dict(DP_SWEEP_COUNTERS)
    t_np = dp_join_order(g, stats, sel, cm, q.distinct,
                         block_bytes=2048 * 160, dp_backend="numpy")
    t_jx = dp_join_order(g, stats, sel, cm, q.distinct,
                         block_bytes=2048 * 160, dp_backend="jax")
    _assert_same_tree(t_np, t_jx)
    assert DP_SWEEP_COUNTERS["tiled"] == before["tiled"] + 1
    assert DP_SWEEP_COUNTERS["resident"] == before["resident"]


def test_batch_report_surfaces_resident_counters(tiny_stats, tiny_workload):
    """``optimize_batch`` under ``dp_backend='jax'`` reports how its DP
    sweeps ran (``dp_resident`` / ``dp_tiled``) on the batch report."""
    from repro.core.planner import OdysseyOptimizer

    opt = OdysseyOptimizer(tiny_stats, plan_cache_size=0, dp_backend="jax")
    opt.optimize_batch(tiny_workload[:6])
    report = opt.last_batch_report
    assert report is not None
    assert report.dp_resident + report.dp_tiled > 0
