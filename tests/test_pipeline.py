"""Operator-pipeline execution (repro.engine.pipeline): bit-identity with
the recursive evaluator, schedule invariance, mid-query salvage (no shipped
tuple is ever recomputed), tuple routing to alternate sources, and the
deterministic fault/latency injection behind all of it."""
import numpy as np
import pytest

from repro.core.planner import OdysseyOptimizer, SubqueryNode, _detach_plan
from repro.engine.local import LocalEngine, naive_evaluate
from repro.engine.pipeline import VirtualClock, compile_plan
from repro.ft.failover import EndpointDown, FlakySource
from repro.ft.resilience import RetryPolicy
from repro.query.algebra import certain_variables, from_algebra
from repro.rdf.dataset import Federation
from repro.rdf.generator import generate_extended_workload, generate_workload


def _assert_identical(a, b):
    """Bit-identity: same columns, same values, same row order, same logical
    metrics (NTT / requests / intermediate rows — what the paper counts)."""
    assert set(a.rows) == set(b.rows)
    for v in a.rows:
        assert np.array_equal(a.rows[v], b.rows[v]), v
    assert a.metrics.transferred_tuples == b.metrics.transferred_tuples
    assert a.metrics.requests == b.metrics.requests
    assert a.metrics.intermediate_rows == b.metrics.intermediate_rows


def _result_set(rel, proj):
    n = len(next(iter(rel.values()))) if rel else 0
    return set(zip(*[rel[v].tolist() for v in proj])) if n else set()


# --------------------------------------------------------------------------
# bit-identity differentials
# --------------------------------------------------------------------------

def test_pipeline_bit_identical_flat_and_algebra(tiny_fed, tiny_stats,
                                                 tiny_workload):
    """The default engine path (pipeline) returns exactly the recursive
    evaluator's rows, row order and metric totals — flat BGPs and the full
    OPTIONAL/UNION/FILTER extended workload."""
    fed, gt = tiny_fed
    eng = LocalEngine(fed)
    assert eng.use_pipeline
    opt = OdysseyOptimizer(tiny_stats)
    queries = list(tiny_workload) + generate_extended_workload(fed, gt, seed=17)
    for q in queries:
        plan = opt.optimize(q)
        res_p = eng.execute(plan)
        res_r = eng.execute_recursive(plan)
        _assert_identical(res_p, res_r)
        # the recursive oracle records no cardinality samples; the pipeline
        # logs one per dispatch
        assert res_r.card_log == ()
        assert len(res_p.card_log) >= 1


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_pipeline_bit_identical_random_group_trees(tiny_fed, tiny_stats, seed):
    """Seeded random group trees (the PR 8 differential space): pipeline ==
    recursive on every draw."""
    from test_algebra import _random_tree, _star_leaves

    fed, gt = tiny_fed
    rng = np.random.default_rng(300 + seed)
    leaves = _star_leaves(fed, gt, rng)
    eng = LocalEngine(fed)
    opt = OdysseyOptimizer(tiny_stats)
    for _ in range(5):
        root = _random_tree(rng, leaves, depth=int(rng.integers(1, 4)))
        q = from_algebra(root, distinct=bool(rng.random() < 0.5),
                         projection=sorted(certain_variables(root)))
        plan = opt.optimize(q)
        _assert_identical(eng.execute(plan), eng.execute_recursive(plan))


def test_pipeline_schedule_invariance(tiny_fed, tiny_stats, tiny_workload):
    """The symmetric-hash joins make the answer independent of the scan
    dispatch order: random and adaptive schedules reproduce the static
    (legacy-order) rows and logical metrics exactly."""
    fed, _ = tiny_fed
    opt = OdysseyOptimizer(tiny_stats)
    for q in tiny_workload:
        plan = opt.optimize(q)
        ref = compile_plan(plan, fed).run()
        orders = set()
        for i in range(3):
            exec_ = compile_plan(plan, fed, policy="random",
                                 rng=np.random.default_rng(i))
            orders.add(tuple(pos for _, pos in exec_.scan_order()))
            _assert_identical(exec_.run(), ref)
        _assert_identical(compile_plan(plan, fed, policy="adaptive").run(), ref)
        if len(ref.plan.subqueries()) > 1:
            assert len(orders) >= 1    # shuffles drawn; answers identical

    with pytest.raises(ValueError, match="policy"):
        compile_plan(plan, fed, policy="fastest")


def test_card_log_accounts_for_every_shipped_tuple(tiny_fed, tiny_stats,
                                                   tiny_workload):
    """Every dispatch logs observed-vs-estimated cardinality; the scan-kind
    observations sum exactly to NTT, and unbound single-star scans carry the
    planner's per-source estimate (``SubqueryNode.est_source_cards``)."""
    fed, _ = tiny_fed
    names = {s.name for s in fed.sources}
    opt = OdysseyOptimizer(tiny_stats)
    eng = LocalEngine(fed)
    saw_scan = False
    for q in tiny_workload:
        plan = opt.optimize(q)
        res = eng.execute(plan)
        scans = [ob for ob in res.card_log if ob.kind.startswith("scan")]
        assert sum(ob.obs for ob in scans) == res.metrics.transferred_tuples
        assert len(scans) == res.metrics.requests
        for ob in scans:
            assert ob.source in names
            if ob.kind == "scan":                  # unbound single-star
                saw_scan = True
                assert ob.est is not None and ob.est >= 0.0
                assert ob.star is not None
    assert saw_scan


# --------------------------------------------------------------------------
# mid-query salvage
# --------------------------------------------------------------------------

def _flaky(fed):
    srcs = [FlakySource(s) for s in fed.sources]
    return Federation(srcs, fed.dictionary), {s.name: s for s in srcs}


def test_salvage_never_recomputes_shipped_tuples(tiny_fed, tiny_stats,
                                                 tiny_workload):
    """Kill the *last*-scheduled endpoint mid-query: everything shipped
    before the death is replayed from operator state — per-channel physical
    scan/tuple counters of completed endpoints do not move, no scan key is
    ever executed twice, and the salvaged answer matches the surviving
    federation."""
    fed, gt = tiny_fed
    opt = OdysseyOptimizer(tiny_stats)
    # tiny_workload alone schedules mostly single-endpoint queries; add
    # cross-source hybrids/paths and the algebra families so several queries
    # genuinely have shipped state to salvage
    queries = (list(tiny_workload)
               + generate_workload(fed, gt, n_star=0, n_hybrid=6, n_path=6,
                                   seed=33)
               + generate_extended_workload(fed, gt, seed=17))
    exercised = strict = 0
    for q in queries:
        plan = opt.optimize(q)
        flaky, by_name = _flaky(fed)
        exec_ = compile_plan(_detach_plan(plan), flaky, honor_faults=True)
        order = [flaky.sources[pos].name for _, pos in exec_.scan_order()]
        first_idx: dict = {}
        for i, nm in enumerate(order):
            first_idx.setdefault(nm, i)
        late = [nm for nm, i in first_idx.items() if i > 0]
        if not late:
            continue                   # single-endpoint schedule: no salvage
        # die at the latest-starting endpoint: maximal shipped state to keep
        victim = max(late, key=lambda nm: first_idx[nm])
        vi = first_idx[victim]
        # endpoints whose *every* unbound scan completed before the death
        completed = {nm for nm in first_idx if nm != victim
                     and all(i < vi for i, n2 in enumerate(order) if n2 == nm)}
        # bound (bind-join) subqueries dispatch at finalize — after the death
        # point — so their endpoints legitimately do new work on the re-run
        bound_names = {flaky.sources[pos].name for op in exec_.subquery_ops
                       if op.bound for pos in op.slots}
        by_name[victim].dead = True
        with pytest.raises(EndpointDown):
            exec_.run()
        done = {ch.name: (ch.physical_scans, ch.physical_tuples)
                for ch in exec_.channels.values()}
        routed = set(exec_.drop_source(victim))
        res = exec_.run()
        exercised += 1
        assert exec_.salvages == 1
        for ch in exec_.channels.values():
            # no scan key ever executes twice: re-derivation is pure replay
            assert ch.physical_scans == len(ch._scans)
            if (ch.name in completed and ch.name in done
                    and ch.name not in routed and ch.name not in bound_names):
                # fully-shipped survivors: *exactly* zero new physical traffic
                assert (ch.physical_scans, ch.physical_tuples) == done[ch.name]
                strict += 1
        survivors = Federation([s for s in fed.sources if s.name != victim],
                               fed.dictionary)
        proj = q.effective_projection()
        assert _result_set(res.rows, proj) == naive_evaluate(survivors, q)
    assert exercised >= 2, "workload never scheduled two distinct endpoints"
    assert strict >= 1, "no fully-shipped survivor channel was ever checked"


def test_salvage_reroutes_to_alternate_relevant_source(tiny_fed, tiny_stats,
                                                       tiny_workload):
    """Tuple routing: when the plan dispatched a star to one endpoint but the
    SourceSelection retains another relevant one, a death re-routes the star
    there instead of dropping it — and the re-routed pipeline reproduces the
    recursive evaluation of the re-routed plan exactly."""
    fed, _ = tiny_fed
    opt = OdysseyOptimizer(tiny_stats)
    exercised = 0
    for q in tiny_workload:
        plan = _detach_plan(opt.optimize(q))
        leaf = next((n for n in plan.subqueries() if len(n.stars) == 1), None)
        if leaf is None:
            continue
        # the synthetic federation selects one source per star; model the
        # paper's replicated-data case by registering an alternate relevant
        # source on the selection (exactly what a duplicate-aware selection
        # retains) without putting it on the plan's dispatch list
        keep = leaf.sources[0]
        if any(keep in n.sources for n in plan.subqueries() if n is not leaf):
            continue           # the death must hit exactly this one subquery
        sel_star = plan.selection.star_sources[leaf.stars[0]]
        alt = next(i for i in range(len(fed.sources)) if i not in sel_star)
        sel_star.append(alt)
        alts = sorted(a for a in sel_star if a != keep)
        leaf.sources = [keep]
        leaf.est_source_cards = (leaf.est_source_cards or [0.0])[:1]
        flaky, by_name = _flaky(fed)
        exec_ = compile_plan(plan, flaky, honor_faults=True)
        victim = fed.sources[keep].name
        by_name[victim].dead = True
        with pytest.raises(EndpointDown):
            exec_.run()
        routed = exec_.drop_source(victim)
        assert routed, "selection retained alternates; none routed in"
        assert set(routed) == {fed.sources[a].name for a in alts}
        assert exec_.rerouted == [(victim, nm) for nm in routed]
        res = exec_.run()
        # reference: the same plan with the leaf re-pointed at the alternates,
        # evaluated recursively (dead endpoint untouched on either path)
        ref_plan = _detach_plan(plan)
        ref_leaf = next(n for n in ref_plan.subqueries()
                        if n.stars == leaf.stars)
        ref_leaf.sources = list(alts)
        ref = LocalEngine(flaky, use_pipeline=False).execute(ref_plan)
        _assert_identical(res, ref)
        exercised += 1
    assert exercised >= 1, "no multi-source single-star leaf in the workload"


def test_mid_scan_death_after_n_tuples(tiny_fed, tiny_stats, tiny_workload):
    """``die_after_tuples`` kills the endpoint *during* execution — after it
    already served tuples — which is exactly the state the salvage keeps:
    the crossing scan is lost, completed scans stay shipped, and the salvaged
    run matches the surviving federation."""
    fed, _ = tiny_fed
    opt = OdysseyOptimizer(tiny_stats)
    exercised = 0
    for q in tiny_workload:
        plan = opt.optimize(q)
        flaky, by_name = _flaky(fed)
        probe = compile_plan(plan, flaky, honor_faults=True)
        order = [flaky.sources[pos].name for _, pos in probe.scan_order()]
        victim = order[0]
        by_name[victim].die_after_tuples = 0     # die on the first real scan
        exec_ = compile_plan(_detach_plan(plan), flaky, honor_faults=True)
        try:
            exec_.run()
        except EndpointDown:
            pass
        else:
            continue                              # victim served only empties
        assert by_name[victim].dead               # the death is sticky
        assert by_name[victim].tuples_served > 0  # it died *mid*-stream
        exec_.drop_source(victim)
        res = exec_.run()
        survivors = Federation([s for s in fed.sources if s.name != victim],
                               fed.dictionary)
        assert _result_set(res.rows, q.effective_projection()) == \
            naive_evaluate(survivors, q)
        exercised += 1
    assert exercised >= 2, "no endpoint ever served a non-empty first scan"


# --------------------------------------------------------------------------
# deterministic latency + adaptive routing + injectable retry clock
# --------------------------------------------------------------------------

def test_virtual_clock_charges_exactly_per_physical_scan(tiny_fed, tiny_stats,
                                                         tiny_workload):
    """Latency is deterministic: each physical (memo-missing) scan advances
    the virtual clock by its endpoint's ``latency_s``, memo hits are free."""
    fed, _ = tiny_fed
    lat = {s.name: 0.01 * (i + 1) for i, s in enumerate(fed.sources)}
    flaky = Federation([FlakySource(s, latency_s=lat[s.name])
                        for s in fed.sources], fed.dictionary)
    plan = OdysseyOptimizer(tiny_stats).optimize(tiny_workload[0])
    clock = VirtualClock()
    exec_ = compile_plan(plan, flaky, honor_faults=True, clock=clock)
    res = exec_.run()
    want = sum(ch.physical_scans * lat[ch.name]
               for ch in exec_.channels.values())
    assert clock.t == pytest.approx(want)
    assert exec_.physical_scans > 0
    # a second run is pure replay: the clock must not move
    t1 = clock.t
    _assert_identical(exec_.run(), res)
    assert clock.t == t1


def test_adaptive_policy_wins_first_answer_on_replicated_star():
    """``adaptive`` dispatches fast endpoints first.  On a star whose data
    both endpoints serve, degrading the statically-first endpoint makes the
    static schedule wait its full latency for a first answer while the
    adaptive one answers from the fast replica — same rows either way."""
    from repro.core.federation import build_federated_stats
    from repro.query.algebra import BGPQuery, Const, TriplePattern, Var
    from repro.rdf.dataset import Source, TripleTable
    from repro.rdf.dictionary import TermDict

    d = TermDict()
    p = d.add("http://x.org/p")
    t_a = TripleTable.from_triples(
        np.array([d.add(f"http://a.org/s{i}") for i in range(6)]),
        np.full(6, p), np.array([d.add(f"http://a.org/o{i}") for i in range(6)]))
    t_b = TripleTable.from_triples(
        np.array([d.add(f"http://b.org/s{i}") for i in range(4)]),
        np.full(4, p), np.array([d.add(f"http://b.org/o{i}") for i in range(4)]))
    fed = Federation([Source("A", t_a), Source("B", t_b)], d)
    stats = build_federated_stats(fed)
    q = BGPQuery(patterns=[TriplePattern(Var("x"), Const(p), Var("y"))],
                 projection=["x", "y"])
    plan = OdysseyOptimizer(stats).optimize(q)
    leaf = plan.subqueries()[0]
    assert sorted(leaf.sources) == [0, 1]      # genuinely replicated star
    slow = leaf.sources[0]                     # degrade the static head
    lat = [0.0, 0.0]
    lat[slow] = 0.5
    lat[1 - slow] = 0.001
    results = {}
    for policy in ("static", "adaptive"):
        clock = VirtualClock()
        flaky = Federation([FlakySource(s, latency_s=lat[s.sid])
                            for s in fed.sources], fed.dictionary)
        exec_ = compile_plan(plan, flaky, honor_faults=True,
                             policy=policy, clock=clock)
        order = [pos for _, pos in exec_.scan_order()]
        if policy == "adaptive":
            assert order[-1] == slow           # slow endpoint deferred
        else:
            assert order[0] == slow
        res = exec_.run()
        results[policy] = (res, exec_.first_answer_t)
    _assert_identical(results["adaptive"][0], results["static"][0])
    assert results["adaptive"][1] == pytest.approx(0.001)
    assert results["static"][1] == pytest.approx(0.5)


def test_retry_policy_sleep_is_injectable():
    """Backoff retries charge an injectable clock instead of wall-clock
    sleeping — fault tests and benchmarks stay deterministic and instant."""
    clock = VirtualClock()
    pol = RetryPolicy(max_attempts=3, base_delay_s=1.0, backoff=2.0,
                      sleep=clock.advance)
    calls = []

    def flaky_fn():
        calls.append(1)
        if len(calls) < 3:
            raise EndpointDown("transient")
        return 7

    assert pol.run(flaky_fn) == 7
    assert clock.t == pytest.approx(1.0 + 2.0)    # two backoff sleeps


def test_recursive_path_still_available(tiny_fed, tiny_stats, tiny_workload):
    """``use_pipeline=False`` pins the legacy recursive evaluator (the
    differential oracle): same rows, no cardinality log."""
    fed, _ = tiny_fed
    plan = OdysseyOptimizer(tiny_stats).optimize(tiny_workload[0])
    eng = LocalEngine(fed, use_pipeline=False)
    res = eng.execute(plan)
    assert res.card_log == ()
    _assert_identical(res, LocalEngine(fed).execute(plan))
