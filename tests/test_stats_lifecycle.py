"""Versioned statistics lifecycle: incremental per-source mutators are
bit-identical to a from-scratch rebuild, every mutation bumps the epoch, the
plan cache never serves a pre-mutation plan, and the selection/graph state a
cached plan hands out is detached from the cache."""
import numpy as np
import pytest

from repro.core.decomposition import decompose
from repro.core.federation import build_federated_stats
from repro.core.planner import OdysseyOptimizer
from repro.core.source_selection import select_sources
from repro.rdf.dataset import Federation, Source, TripleTable


def _refed(fed, keep=None, tables=None):
    """Federation over fresh Source wrappers (never renumber a fixture's
    shared Source objects in place)."""
    sources = fed.sources if keep is None else [fed.sources[i] for i in keep]
    out = []
    for s in sources:
        table = s.table if tables is None else tables.get(s.name, s.table)
        out.append(Source(s.name, table))
    return Federation(out, fed.dictionary)


def _arrays_equal(a, b, fields):
    for f in fields:
        x, y = getattr(a, f), getattr(b, f)
        if x is None or y is None:
            assert x is None and y is None, f
        else:
            np.testing.assert_array_equal(x, y, err_msg=f)


def assert_stats_equal(got, want):
    """Bit-identity of every CS/CP statistic, export, summary and pruning
    counter between two FederatedStats."""
    assert got.n_sources == want.n_sources
    for g, w in zip(got.cs, want.cs):
        _arrays_equal(g, w, ("cs_count", "indptr", "pred_ids", "pred_occ",
                             "ent_ids", "ent_cs"))
    for g, w in zip(got.intra_cp, want.intra_cp):
        assert (g.src1, g.src2) == (w.src1, w.src2)
        _arrays_equal(g, w, ("pred", "cs1", "cs2", "count"))
    assert set(got.fed_cp) == set(want.fed_cp)
    for k in want.fed_cp:
        g, w = got.fed_cp[k], want.fed_cp[k]
        assert (g.src1, g.src2) == (w.src1, w.src2) == k
        _arrays_equal(g, w, ("pred", "cs1", "cs2", "count"))
    assert got.fed_cs == want.fed_cs
    for g, w in zip(got.exports, want.exports):
        assert g.src == w.src and g.n_cs == w.n_cs
        _arrays_equal(g, w, ("subj_indptr", "subj_ents", "obj_cs", "obj_pred",
                             "obj_indptr", "obj_ents", "obj_mult"))
    assert len(got.summaries) == len(want.summaries)
    for g, w in zip(got.summaries, want.summaries):
        assert g.src == w.src and g.n_bits == w.n_bits
        _arrays_equal(g, w, ("subj_auth", "subj_cs", "subj_sig", "obj_auth",
                             "obj_cs", "obj_pred", "obj_sig", "subj_counts"))
    assert got.pruning_checked == want.pruning_checked
    assert got.pruning_possible == want.pruning_possible


def _plan_shape(node):
    from repro.core.planner import JoinPlanNode, SubqueryNode

    if isinstance(node, SubqueryNode):
        return ("sq", tuple(node.stars), tuple(node.sources),
                tuple((tp.s, tp.p, tp.o) for tp in node.patterns))
    assert isinstance(node, JoinPlanNode)
    return ("join", node.strategy, tuple(node.join_vars),
            _plan_shape(node.left), _plan_shape(node.right))


# --------------------------------------------------------------------------
# Differential: incremental mutators == from-scratch rebuild
# --------------------------------------------------------------------------

@pytest.mark.parametrize("sid", [0, 4, 8])
def test_remove_source_matches_rebuild(tiny_fed, tiny_stats, sid):
    fed, _ = tiny_fed
    keep = [i for i in range(len(fed.sources)) if i != sid]
    got = tiny_stats.clone()
    epoch0 = got.epoch
    got.remove_source(sid)
    assert got.epoch == epoch0 + 1
    want = build_federated_stats(_refed(fed, keep))
    assert_stats_equal(got, want)


def test_add_source_matches_rebuild(tiny_fed, tiny_stats):
    fed, _ = tiny_fed
    sub = build_federated_stats(_refed(fed, keep=list(range(len(fed.sources) - 1))))
    epoch0 = sub.epoch
    sid = sub.add_source(fed.sources[-1].table)
    assert sid == len(fed.sources) - 1
    assert sub.epoch == epoch0 + 1
    # the full build (== the session fixture) is the oracle
    assert_stats_equal(sub, tiny_stats)


def test_remove_then_add_roundtrip(tiny_fed, tiny_stats):
    fed, _ = tiny_fed
    got = tiny_stats.clone()
    got.remove_source(len(fed.sources) - 1)   # last source: no renumbering
    got.add_source(fed.sources[-1].table)
    assert got.epoch == tiny_stats.epoch + 2
    assert_stats_equal(got, tiny_stats)


def _shrunk(table: TripleTable) -> TripleTable:
    keep = np.ones(len(table.s), bool)
    keep[::3] = False                          # drop every third triple
    return TripleTable.from_triples(table.s[keep], table.p[keep], table.o[keep])


def test_refresh_source_matches_rebuild(tiny_fed, tiny_stats):
    fed, _ = tiny_fed
    sid = 3
    new_table = _shrunk(fed.sources[sid].table)
    got = tiny_stats.clone()
    got.refresh_source(sid, new_table)
    assert got.epoch == tiny_stats.epoch + 1
    want = build_federated_stats(
        _refed(fed, tables={fed.sources[sid].name: new_table}))
    assert_stats_equal(got, want)


def test_refresh_source_identity_is_noop_but_bumps_epoch(tiny_fed, tiny_stats):
    fed, _ = tiny_fed
    got = tiny_stats.clone()
    got.refresh_source(2, fed.sources[2].table)
    assert got.epoch == tiny_stats.epoch + 1
    assert_stats_equal(got, tiny_stats)


def test_clone_isolates_mutation(tiny_fed, tiny_stats):
    base = tiny_stats.clone()
    fork = base.clone()
    fork.remove_source(0)
    assert base.n_sources == tiny_stats.n_sources
    assert base.epoch == tiny_stats.epoch
    assert_stats_equal(base, tiny_stats)       # src tags/keys untouched


def test_invalidate_caches_clears_memos_and_bumps_epoch(tiny_stats, tiny_workload):
    stats = tiny_stats.clone()
    opt = OdysseyOptimizer(stats)
    q = tiny_workload[0]
    opt.optimize(q)                            # warms formula memos
    assert any(c._card_cache for c in stats.cs) or \
        any(c._card_cache for c in stats.intra_cp)
    epoch = stats.epoch
    stats.invalidate_caches()
    assert stats.epoch == epoch + 1
    assert all(not c._card_cache and not c._pred_index for c in stats.cs)
    assert all(not c._card_cache for c in stats.intra_cp)
    assert all(not c._card_cache for c in stats.fed_cp.values())
    assert not opt.optimize(q).cached          # epoch bump => stale plan


def test_lifecycle_requires_dictionary():
    from repro.core.characteristic_pairs import CPStats
    from repro.core.characteristic_sets import compute_characteristic_sets
    from repro.core.federation import FederatedStats

    t = TripleTable.from_triples(np.array([1, 1]), np.array([2, 3]), np.array([4, 5]))
    e = np.zeros(0, np.int32)

    def mk():
        return FederatedStats(cs=[compute_characteristic_sets(t)],
                              intra_cp=[CPStats(e, e.copy(), e.copy(),
                                                np.zeros(0, np.int64))])

    # add/refresh rebuild local stats from the dictionary => must refuse
    with pytest.raises(ValueError, match="lifecycle"):
        mk().add_source(t)
    with pytest.raises(ValueError, match="lifecycle"):
        mk().refresh_source(0, t)
    # removal is pure bookkeeping: works on directly-constructed stats too
    stats = mk()
    stats.remove_source(0)
    assert stats.n_sources == 0 and stats.epoch == 1


# --------------------------------------------------------------------------
# Epoch-keyed plan cache
# --------------------------------------------------------------------------

def test_cached_plan_not_served_across_refresh(tiny_fed, tiny_stats, tiny_workload):
    fed, _ = tiny_fed
    stats = tiny_stats.clone()
    opt = OdysseyOptimizer(stats)
    q = next(q for q in tiny_workload if len(q.patterns) >= 2)
    p1 = opt.optimize(q)
    assert opt.optimize(q).cached
    stats.refresh_source(1, fed.sources[1].table)
    p3 = opt.optimize(q)
    assert not p3.cached                       # epoch bump => lazy miss
    assert opt.plan_cache.stale_evictions >= 1
    assert _plan_shape(p3.root) == _plan_shape(p1.root)  # identity refresh
    assert opt.optimize(q).cached              # re-warmed under the new epoch


def test_cached_plan_not_served_across_remove(tiny_fed, tiny_stats, tiny_workload):
    fed, _ = tiny_fed
    stats = tiny_stats.clone()
    opt = OdysseyOptimizer(stats)
    plans = [opt.optimize(q) for q in tiny_workload]
    assert all(not p.cached for p in plans[:1])
    sid = len(fed.sources) - 1
    stats.remove_source(sid)
    # every replan equals a from-scratch optimizer over the rebuilt stats
    want = OdysseyOptimizer(build_federated_stats(
        _refed(fed, keep=list(range(sid)))))
    for q in tiny_workload:
        p = opt.optimize(q)
        assert not p.cached
        assert _plan_shape(p.root) == _plan_shape(want.optimize(q).root)
    # and the cache serves them again under the new epoch
    assert all(opt.optimize(q).cached for q in tiny_workload)


def test_epoch_zero_stats_unaffected(tiny_stats, tiny_workload):
    """Legacy behavior: without mutations the epoch never moves and hits flow."""
    opt = OdysseyOptimizer(tiny_stats)
    q = tiny_workload[0]
    opt.optimize(q)
    assert opt.optimize(q).cached
    assert opt.plan_cache.stale_evictions == 0


# --------------------------------------------------------------------------
# Regression: cached plans must not share selection/graph with callers
# --------------------------------------------------------------------------

def test_cache_hit_isolated_from_selection_mutation(tiny_stats, tiny_workload):
    opt = OdysseyOptimizer(tiny_stats)
    q = next(q for q in tiny_workload if len(q.patterns) >= 2)
    p1 = opt.optimize(q)
    want_sources = [list(s) for s in p1.selection.star_sources]
    # failover-style source exclusion mutates the selection in place
    for lst in p1.selection.star_sources:
        lst.clear()
    for d in p1.selection.star_cs:
        d.clear()
    p1.selection.edge_pairs.clear()
    p1.graph.stars.clear()
    p2 = opt.optimize(q)
    assert p2.cached
    assert [list(s) for s in p2.selection.star_sources] == want_sources
    assert len(p2.graph.stars) == len(want_sources)
    # a hit's mutation must not leak into later hits either
    p2.selection.star_sources[0].append(999)
    p3 = opt.optimize(q)
    assert p3.cached
    assert [list(s) for s in p3.selection.star_sources] == want_sources
    # and each hit's per-query memo starts empty (documented lifetime)
    assert p2.selection._memo is not p3.selection._memo
    assert not p3.selection._memo


def test_selection_memo_not_shared_across_hits(tiny_stats, tiny_workload):
    opt = OdysseyOptimizer(tiny_stats)
    q = tiny_workload[0]
    opt.optimize(q)
    p2 = opt.optimize(q)
    p2.selection._memo["poison"] = -1.0
    p3 = opt.optimize(q)
    assert "poison" not in p3.selection._memo


# --------------------------------------------------------------------------
# Regression: select_sources keeps star_cs/edge_pairs consistent
# --------------------------------------------------------------------------

def test_star_cs_consistent_with_star_sources(tiny_stats, tiny_workload):
    pruned_something = False
    for q in tiny_workload:
        graph = decompose(q)
        sel = select_sources(graph, tiny_stats)
        for si in range(len(graph.stars)):
            assert set(sel.star_cs[si]) == set(sel.star_sources[si]), \
                "star_cs retains sources the CP fixpoint eliminated"
            if len(sel.star_cs[si]) < tiny_stats.n_sources:
                pruned_something = True
        for ei, pairs in sel.edge_pairs.items():
            e = graph.edges[ei]
            for a, b in pairs:
                assert a in sel.star_sources[e.src]
                assert b in sel.star_sources[e.dst]
    assert pruned_something, "workload never exercised pruning?"
