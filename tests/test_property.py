"""Hypothesis property tests on the system's core invariants.

``hypothesis`` is an optional dependency locally (the CI fast tier installs
it); without it this module skips instead of breaking collection."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.characteristic_sets import compute_characteristic_sets
from repro.core.cardinality import star_cardinality_distinct, star_cardinality_estimate
from repro.core.summaries import _signature
from repro.rdf.dataset import TripleTable
from repro.stats.reduce import reduce_cs


@st.composite
def triple_tables(draw, max_subj=40, max_pred=10, max_rows=300):
    n = draw(st.integers(1, max_rows))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    s = rng.integers(0, max_subj, n).astype(np.int32)
    p = rng.integers(0, max_pred, n).astype(np.int32)
    o = rng.integers(100, 160, n).astype(np.int32)
    return TripleTable.from_triples(s, p, o)


@given(triple_tables())
@settings(max_examples=40, deadline=None)
def test_cs_partition_invariants(table):
    """CSs partition the subjects; occurrences sum to the triple count."""
    cs = compute_characteristic_sets(table)
    assert int(cs.cs_count.sum()) == len(table.subjects())
    assert int(cs.pred_occ.sum()) == table.n_triples
    # every CS's predicate list is sorted & unique
    for c in range(cs.n_cs):
        preds = cs.preds_of(c)
        assert np.all(np.diff(preds) > 0)
        # occurrences >= count (every entity has >= 1 triple per predicate)
        assert np.all(cs.occ_of(c) >= cs.cs_count[c])


@given(triple_tables(), st.integers(0, 9), st.integers(0, 9))
@settings(max_examples=40, deadline=None)
def test_formula1_exact_against_bruteforce(table, p1, p2):
    """Formula (1) == brute-force count of subjects having all predicates."""
    cs = compute_characteristic_sets(table)
    preds = sorted({p1, p2})
    got = star_cardinality_distinct(cs, preds)
    want = 0
    for e in table.subjects():
        have = set(table.p[table.scan(int(e), None, None)].tolist())
        if set(preds) <= have:
            want += 1
    assert got == want


@given(triple_tables(), st.integers(0, 9))
@settings(max_examples=30, deadline=None)
def test_formula2_upper_bounds_formula1(table, p1):
    cs = compute_characteristic_sets(table)
    d = star_cardinality_distinct(cs, [p1])
    e = star_cardinality_estimate(cs, [p1])
    assert e >= d - 1e-6


@given(triple_tables(), st.integers(2, 12))
@settings(max_examples=25, deadline=None)
def test_reduce_cs_never_loses_relevance(table, max_cs):
    """The §3.3 reduction must keep every query answerable (no false
    negatives): any predicate set relevant before stays relevant after."""
    cs = compute_characteristic_sets(table)
    red = reduce_cs(cs, max_cs)
    assert int(red.cs_count.sum()) == int(cs.cs_count.sum())
    for c in range(cs.n_cs):
        preds = cs.preds_of(c).tolist()
        assert len(red.relevant_cs(preds)) > 0
        # formula-1 value may only grow (conservative merge)
        assert (star_cardinality_distinct(red, preds)
                >= star_cardinality_distinct(cs, preds))


@given(st.lists(st.integers(0, 10_000), min_size=1, max_size=200, unique=True),
       st.lists(st.integers(0, 10_000), min_size=1, max_size=200, unique=True),
       st.sampled_from([256, 1024, 4096]))
@settings(max_examples=60, deadline=None)
def test_signature_no_false_negatives(a, b, n_bits):
    """Bitset summaries may over-approximate but never miss an overlap."""
    a = np.asarray(a, np.int64)
    b = np.asarray(b, np.int64)
    sig_a = _signature(a, n_bits)
    sig_b = _signature(b, n_bits)
    if len(np.intersect1d(a, b)):
        assert bool((sig_a & sig_b).any())


@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_loader_restart_equivalence(seed, step):
    """Checkpoint/restart: batch_at(step) after 'restart' is identical."""
    from repro.data.loader import TokenLoader

    a = TokenLoader(vocab=97, batch=2, seq=16, seed=seed % 1000)
    b = TokenLoader(vocab=97, batch=2, seq=16, seed=seed % 1000)
    x = a.batch_at(step)
    _ = b.batch_at(0)  # consumed some other batch first
    y = b.batch_at(step)
    np.testing.assert_array_equal(x["tokens"], y["tokens"])


@given(triple_tables(max_subj=20, max_pred=6, max_rows=120))
@settings(max_examples=20, deadline=None)
def test_dp_plan_cost_not_worse_than_left_deep(table):
    """The DP optimizer's plan cost is <= a naive left-deep ordering's cost
    under the same cost model (optimality on its own model)."""
    from repro.core.cost import CostModel
    from repro.core.decomposition import decompose
    from repro.core.federation import FederatedStats, export_link_stats
    from repro.core.characteristic_pairs import compute_characteristic_pairs
    from repro.core.join_order import (JoinTree, dp_join_order,
                                       star_cardinality)
    from repro.core.source_selection import select_sources
    from repro.query.algebra import BGPQuery, Const, TriplePattern, Var

    cs = compute_characteristic_sets(table)
    cp = compute_characteristic_pairs(table, cs, 0)
    stats = FederatedStats(cs=[cs], intra_cp=[cp])
    preds = np.unique(table.p)
    if len(preds) < 2:
        return
    q = BGPQuery([
        TriplePattern(Var("x"), Const(int(preds[0])), Var("y")),
        TriplePattern(Var("y"), Const(int(preds[1 % len(preds)])), Var("z")),
    ], distinct=True)
    graph = decompose(q)
    sel = select_sources(graph, stats)
    if any(len(s) == 0 for s in sel.star_sources):
        return
    cm = CostModel()
    tree = dp_join_order(graph, stats, sel, cm, True)
    # left-deep: leaves in star order, hash joins
    cards = [star_cardinality(s, stats, sel, True) for s in graph.stars]
    left_cost = sum(cm.leaf_cost(c, sel.star_sources[i])
                    for i, c in enumerate(cards))
    left_cost += cm.hash_join_cost(tree.cardinality)
    assert tree.cost <= left_cost + 1e-6


@st.composite
def star_graph_queries(draw, max_stars=6):
    """Random star-graph BGP: a chain of star subjects linked object->subject,
    each star fleshed out with extra predicates, over a random triple table
    whose objects overlap its subjects (so CPs exist)."""
    n_stars = draw(st.integers(1, max_stars))
    seed = draw(st.integers(0, 2**31 - 1))
    k_extra = draw(st.integers(0, 2))
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 200))
    s = rng.integers(0, 30, n).astype(np.int32)
    p = rng.integers(0, 8, n).astype(np.int32)
    # half the objects are entities (joinable), half literals
    o = np.where(rng.random(n) < 0.5, rng.integers(0, 30, n),
                 rng.integers(100, 140, n)).astype(np.int32)
    table = TripleTable.from_triples(s, p, o)
    from repro.query.algebra import BGPQuery, Const, TriplePattern, Var

    preds = table.predicates()
    pats = []
    for i in range(n_stars):
        if i < n_stars - 1:
            link = int(preds[rng.integers(len(preds))])
            pats.append(TriplePattern(Var(f"x{i}"), Const(link), Var(f"x{i + 1}")))
        for j in range(k_extra):
            q = int(preds[rng.integers(len(preds))])
            pats.append(TriplePattern(Var(f"x{i}"), Const(q), Var(f"x{i}_v{j}")))
    if not pats:
        pats.append(TriplePattern(Var("x0"), Const(int(preds[0])), Var("y")))
    distinct = bool(rng.random() < 0.5)
    return table, BGPQuery(pats, distinct=distinct)


@st.composite
def large_shaped_cases(draw):
    """Random chain/tree star graphs at 16-18 meta-nodes — past anything the
    reference DP can verify in test time, so the properties below are
    invariants rather than differentials."""
    shape = draw(st.sampled_from(["chain", "tree"]))
    n_stars = draw(st.integers(16, 18))
    seed = draw(st.integers(0, 2**31 - 1))
    return shape, n_stars, seed


@given(large_shaped_cases())
@settings(max_examples=6, deadline=None)
def test_large_star_dp_plan_validity(case):
    """16-18-star chains/trees: the plan is a join tree whose leaves
    partition the full star set, with costs monotone along every path (a
    join is never cheaper than the subplan it extends)."""
    from repro.core.cost import CostModel
    from repro.core.join_order import dp_join_order
    from repro.rdf.shapes import shaped_planning_inputs

    shape, n_stars, seed = case
    graph, stats, sel, q = shaped_planning_inputs(shape, n_stars, seed)
    tree = dp_join_order(graph, stats, sel, CostModel(), q.distinct)
    assert sorted(tree.leaf_order()) == list(range(n_stars))

    def walk(t):
        if t.kind == "leaf":
            assert t.cost >= 0.0
            return set(t.stars)
        ls, rs = walk(t.left), walk(t.right)
        assert not (ls & rs), "overlapping leaf sets"
        assert set(t.stars) == ls | rs, "join stars != union of children"
        # both strategies keep the left subplan's cost as a summand
        assert t.cost >= t.left.cost - 1e-9
        return set(t.stars)

    assert walk(tree) == set(range(n_stars))


@given(large_shaped_cases())
@settings(max_examples=6, deadline=None)
def test_large_star_dp_not_worse_than_left_deep(case):
    """The exact DP's cost is <= the greedy left-deep hash-join plan in node
    order (which is in the DP's search space: chain/tree prefixes are always
    connected by construction)."""
    from repro.core.cost import CostModel
    from repro.core.join_order import (_subset_cardinalities, dp_join_order,
                                       edge_selectivity, star_cardinality)
    from repro.rdf.shapes import shaped_planning_inputs

    shape, n_stars, seed = case
    graph, stats, sel, q = shaped_planning_inputs(shape, n_stars, seed)
    cm = CostModel()
    tree = dp_join_order(graph, stats, sel, cm, q.distinct)

    cards = [max(star_cardinality(st, stats, sel, q.distinct), 0.0)
             for st in graph.stars]
    sels = [edge_selectivity(e, graph, stats, sel, q.distinct)
            for e in graph.edges]
    pmasks = np.array([(1 << k) - 1 for k in range(2, n_stars + 1)], np.int64)
    pcards = _subset_cardinalities(graph, cards, sels, pmasks)
    # fold exactly like the DP costs its hash joins: (left + leaf) + join
    ld = cm.leaf_cost(cards[0], sel.star_sources[0])
    for k in range(1, n_stars):
        ld = (ld + cm.leaf_cost(cards[k], sel.star_sources[k]))
        ld = ld + cm.hash_join_cost(pcards[k - 1])
    assert tree.cost <= ld * (1 + 1e-9) + 1e-9


@given(star_graph_queries())
@settings(max_examples=25, deadline=None)
def test_bitmask_dp_equals_reference_on_random_star_graphs(case):
    """Property: the vectorized bitmask DP picks exactly the reference DP's
    plan (cost, leaf order, join strategies) on arbitrary star graphs."""
    from repro.core.characteristic_pairs import compute_characteristic_pairs
    from repro.core.cost import CostModel
    from repro.core.decomposition import decompose
    from repro.core.federation import FederatedStats
    from repro.core.join_order import dp_join_order, dp_join_order_ref
    from repro.core.source_selection import select_sources

    table, q = case
    cs = compute_characteristic_sets(table)
    cp = compute_characteristic_pairs(table, cs, 0)
    stats = FederatedStats(cs=[cs], intra_cp=[cp])
    graph = decompose(q)
    sel = select_sources(graph, stats)
    cm = CostModel()
    new = dp_join_order(graph, stats, sel, cm, q.distinct)
    ref = dp_join_order_ref(graph, stats, sel, cm, q.distinct)
    assert new.leaf_order() == ref.leaf_order()
    np.testing.assert_allclose(new.cost, ref.cost, rtol=1e-9, atol=1e-12)
    np.testing.assert_allclose(new.cardinality, ref.cardinality, rtol=1e-9, atol=1e-12)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_random_group_trees_match_oracle(tiny_fed, tiny_stats, seed):
    """Property: on random OPTIONAL/UNION/FILTER group trees (<= 3 combinator
    levels) the normalized, DP-reordered plan executes bit-identical to the
    raw-tree ``naive_evaluate`` oracle.  Seeded twin (always on):
    tests/test_algebra.py::test_random_group_trees_match_oracle."""
    from test_algebra import _engine_rows, _random_tree, _star_leaves

    from repro.core.planner import OdysseyOptimizer
    from repro.engine.local import naive_evaluate
    from repro.query.algebra import certain_variables, from_algebra

    fed, gt = tiny_fed
    rng = np.random.default_rng(seed)
    leaves = _star_leaves(fed, gt, rng)
    root = _random_tree(rng, leaves, depth=int(rng.integers(1, 4)))
    q = from_algebra(root, distinct=bool(rng.random() < 0.5),
                     projection=sorted(certain_variables(root)))
    plan = OdysseyOptimizer(tiny_stats).optimize(q)
    assert _engine_rows(fed, plan, q) == naive_evaluate(fed, q)
