"""Integration: the training launcher checkpoints, restarts bit-exact, and
its loss improves on the structured stream."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import main as train_main

pytestmark = pytest.mark.slow  # full train/checkpoint/restart cycles


def test_train_restart_bit_exact(tmp_path):
    """Run 6 steps straight vs 3 steps + restart + 3 steps: identical loss
    trajectory (resumable loader + checkpointed params/optimizer)."""
    common = ["--arch", "qwen2-0.5b", "--reduced", "--d-model", "64",
              "--layers", "2", "--batch", "2", "--seq", "32",
              "--log-every", "100"]
    straight = train_main(common + ["--steps", "6",
                                    "--ckpt-dir", str(tmp_path / "a"),
                                    "--ckpt-every", "100"])
    train_main(common + ["--steps", "3", "--ckpt-dir", str(tmp_path / "b"),
                         "--ckpt-every", "3"])
    resumed = train_main(common + ["--steps", "6",
                                   "--ckpt-dir", str(tmp_path / "b"),
                                   "--ckpt-every", "100"])
    np.testing.assert_allclose(straight["losses"][3:], resumed["losses"],
                               rtol=1e-5)


def test_train_with_compression_improves(tmp_path):
    out = train_main(["--arch", "qwen2-0.5b", "--reduced", "--d-model", "64",
                      "--layers", "2", "--batch", "4", "--seq", "64",
                      "--steps", "30", "--compress-grads", "--log-every", "100"])
    assert out["last"] < out["first"]


def test_train_microbatched_matches_monolithic():
    """Gradient accumulation over microbatches == one big batch (same data)."""
    import dataclasses

    from repro.config.base import reduced_config
    from repro.configs import get_arch
    from repro.data.loader import TokenLoader
    from repro.models import model as MDL
    from repro.train.optimizer import adamw
    from repro.train.train_step import make_train_step

    cfg = reduced_config(get_arch("qwen2-0.5b"), n_layers=2)
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    loader = TokenLoader(vocab=cfg.vocab, batch=4, seq=32, seed=1)
    batch = {k: jnp.asarray(v) for k, v in loader.batch_at(0).items()}

    opt = adamw(lr=1e-3)
    s1 = make_train_step(cfg, opt, microbatches=1)
    s2 = make_train_step(cfg, opt, microbatches=2)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["nll"]), float(m2["nll"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=1e-5)
