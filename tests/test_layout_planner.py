"""The cost-based layout planner must independently re-derive the §Perf
winners (its estimates are the napkin math; the dry-run measured the same
ordering)."""
import pytest

from repro.config.base import SHAPES
from repro.configs import get_arch
from repro.launch.plan_shardings import plan_layout


def test_planner_picks_seq_parallel_for_tp_prefill():
    best, ranked = plan_layout(get_arch("qwen3-14b"), SHAPES["prefill_32k"])
    assert best.choice.tp_mode == "seq_parallel"
    assert best.choice.attention == "chunked"


def test_planner_picks_chunked_attention_for_long_prefill():
    best, ranked = plan_layout(get_arch("chameleon-34b"), SHAPES["prefill_32k"])
    assert best.choice.attention == "chunked"
    # naive attention at 32k must be flagged infeasible (can't fit a chip)
    naive_plans = [p for p in ranked if p.choice.attention == "naive"]
    assert any(not p.feasible for p in naive_plans)


def test_planner_picks_chunked_scan_for_ssm_train():
    best, _ = plan_layout(get_arch("falcon-mamba-7b"), SHAPES["train_4k"])
    assert best.choice.mamba == "chunked"


def test_planner_chunked_loss_for_big_vocab_train():
    best, _ = plan_layout(get_arch("gemma3-12b"), SHAPES["train_4k"])
    assert best.choice.loss == "chunked"


def test_planner_orderings_consistent_with_dryrun():
    """For qwen3 prefill the planner's collective estimate must drop by >10x
    between allreduce and seq_parallel — the direction the dry-run measured
    (566.8s -> 0.17s)."""
    _, ranked = plan_layout(get_arch("qwen3-14b"), SHAPES["prefill_32k"])
    ar = [p for p in ranked if p.choice.tp_mode == "allreduce"
          and p.choice.attention == "chunked"][0]
    sp = [p for p in ranked if p.choice.tp_mode == "seq_parallel"
          and p.choice.attention == "chunked"][0]
    assert ar.collective_s / max(sp.collective_s, 1e-12) > 10


def test_flags_roundtrip():
    from repro.config.base import SHAPES

    best, _ = plan_layout(get_arch("deepseek-v2-236b"), SHAPES["decode_32k"])
    flags = best.choice.to_flags(SHAPES["decode_32k"])
    assert flags.mla_absorb
    assert not flags.seq_parallel  # decode: no seq to shard
