"""End-to-end: every optimizer's plan over the full workload returns the
complete result set (the paper's non-negotiable guarantee), and Odyssey's
plan metrics beat the heuristic baselines in aggregate."""
import numpy as np
import pytest

from repro.baselines import FedXOptimizer, HibiscusOptimizer, VoidDPOptimizer
from repro.core.planner import OdysseyOptimizer
from repro.engine.local import LocalEngine, naive_evaluate


def _result_set(rel, proj):
    n = len(next(iter(rel.values()))) if rel else 0
    return set(zip(*[rel[v].tolist() for v in proj])) if n else set()


@pytest.fixture(scope="module")
def engines(small_fed, small_stats):
    from repro.baselines.hybrids import FedXOdyssey, OdysseyFedX

    fed, _ = small_fed
    return {
        "odyssey": OdysseyOptimizer(small_stats),
        "fedx": FedXOptimizer(fed),
        "fedx_warm": FedXOptimizer(fed, warm=True),
        "void_dp": VoidDPOptimizer(fed),
        "splendid": VoidDPOptimizer(fed, use_ask=True),
        "hibiscus": HibiscusOptimizer(fed),
        "odyssey_fedx": OdysseyFedX(small_stats),
        "fedx_odyssey": FedXOdyssey(small_stats, fed),
    }


def test_all_optimizers_complete_results(small_fed, workload, engines):
    fed, _ = small_fed
    eng = LocalEngine(fed)
    for q in workload:
        want = naive_evaluate(fed, q)
        for name, opt in engines.items():
            plan = opt.optimize(q)
            res = eng.execute(plan)
            rel, m = res.rows, res.metrics
            got = _result_set(rel, q.effective_projection())
            assert got == want, f"{name} incomplete/incorrect on {q.name}"


def test_odyssey_plan_quality(small_fed, workload, engines):
    """Aggregate NSS / NSQ / NTT: Odyssey <= FedX and <= VOID-DP (paper
    Figs. 5, 6, 8 directionally)."""
    fed, _ = small_fed
    eng = LocalEngine(fed)
    agg = {k: dict(ntt=0, nsq=0, nss=0) for k in engines}
    for q in workload:
        for name, opt in engines.items():
            plan = opt.optimize(q)
            res = eng.execute(plan)
            rel, m = res.rows, res.metrics
            agg[name]["ntt"] += m.transferred_tuples
            agg[name]["nsq"] += plan.n_subqueries
            agg[name]["nss"] += plan.n_selected_sources
    assert agg["odyssey"]["nss"] <= agg["fedx"]["nss"]
    assert agg["odyssey"]["nss"] <= agg["void_dp"]["nss"]
    assert agg["odyssey"]["nsq"] <= agg["fedx"]["nsq"]
    assert agg["odyssey"]["nsq"] <= agg["void_dp"]["nsq"]
    assert agg["odyssey"]["ntt"] <= agg["fedx"]["ntt"]
    assert agg["odyssey"]["ntt"] <= agg["void_dp"]["ntt"]


def test_source_selection_no_false_negatives(small_fed, small_stats, workload):
    """Executing ONLY on Odyssey-selected sources must still give the
    complete answer (paper: "it will not miss any relevant sources")."""
    fed, _ = small_fed
    opt = OdysseyOptimizer(small_stats)
    eng = LocalEngine(fed)
    for q in workload:
        plan = opt.optimize(q)
        rel = eng.execute(plan).rows
        got = _result_set(rel, q.effective_projection())
        want = naive_evaluate(fed, q)
        assert want <= got and got == want


def test_distinct_and_projection(small_fed, small_stats, workload):
    fed, _ = small_fed
    opt = OdysseyOptimizer(small_stats)
    eng = LocalEngine(fed)
    for q in workload:
        if not q.distinct:
            continue
        plan = opt.optimize(q)
        rel = eng.execute(plan).rows
        proj = q.effective_projection()
        assert set(rel.keys()) == set(proj)
        rows = list(zip(*[rel[v].tolist() for v in proj])) if rel and len(rel[proj[0]]) else []
        assert len(rows) == len(set(rows)), "DISTINCT produced duplicates"
