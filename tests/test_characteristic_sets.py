"""CS statistics vs brute force (paper §3.1, Listing 1.1 semantics)."""
import numpy as np
import pytest

from repro.core.characteristic_sets import compute_characteristic_sets
from repro.rdf.dataset import TripleTable
from repro.stats.reduce import reduce_cs


def brute_force_cs(table: TripleTable):
    """entity -> (pred set, {pred: triple count})"""
    per_ent: dict[int, dict[int, int]] = {}
    for s, p in zip(table.s.tolist(), table.p.tolist()):
        per_ent.setdefault(s, {}).setdefault(p, 0)
        per_ent[s][p] += 1
    groups: dict[frozenset, dict] = {}
    for e, pc in per_ent.items():
        key = frozenset(pc)
        g = groups.setdefault(key, {"count": 0, "occ": {}})
        g["count"] += 1
        for p, c in pc.items():
            g["occ"][p] = g["occ"].get(p, 0) + c
    return groups


def random_table(rng, n=500, n_subj=60, n_pred=12):
    s = rng.integers(0, n_subj, n).astype(np.int32)
    p = rng.integers(0, n_pred, n).astype(np.int32)
    o = rng.integers(1000, 1100, n).astype(np.int32)
    return TripleTable.from_triples(s, p, o)


def test_cs_matches_brute_force(rng):
    for seed in range(5):
        table = random_table(np.random.default_rng(seed))
        cs = compute_characteristic_sets(table)
        want = brute_force_cs(table)
        assert cs.n_cs == len(want)
        got = {}
        for c in range(cs.n_cs):
            key = frozenset(cs.preds_of(c).tolist())
            got[key] = {
                "count": int(cs.cs_count[c]),
                "occ": dict(zip(cs.preds_of(c).tolist(), cs.occ_of(c).tolist())),
            }
        for key, g in want.items():
            assert key in got
            assert got[key]["count"] == g["count"]
            assert got[key]["occ"] == g["occ"]


def test_cs_totals(small_fed):
    fed, _ = small_fed
    for src in fed.sources:
        cs = compute_characteristic_sets(src.table)
        assert int(cs.cs_count.sum()) == len(src.table.subjects())
        assert int(cs.pred_occ.sum()) == src.table.n_triples
        # every entity maps to a CS that contains exactly its predicates
        ent = int(src.table.s[0])
        c = cs.cs_of_entity(ent)
        ent_preds = set(src.table.p[src.table.scan(ent, None, None)].tolist())
        assert set(cs.preds_of(c).tolist()) == ent_preds


def test_relevant_cs_superset_semantics(rng):
    table = random_table(rng, n=800, n_subj=100, n_pred=10)
    cs = compute_characteristic_sets(table)
    preds = [3, 7]
    rel = cs.relevant_cs(preds)
    for c in range(cs.n_cs):
        has = set(preds) <= set(cs.preds_of(c).tolist())
        assert (c in rel) == has


def test_reduce_cs_conservative(rng):
    table = random_table(np.random.default_rng(42), n=2000, n_subj=300, n_pred=14)
    cs = compute_characteristic_sets(table)
    if cs.n_cs < 8:
        pytest.skip("not enough CSs")
    red = reduce_cs(cs, max_cs=max(4, cs.n_cs // 3))
    assert red.n_cs <= cs.n_cs
    assert int(red.cs_count.sum()) == int(cs.cs_count.sum())
    assert int(red.pred_occ.sum()) == int(cs.pred_occ.sum())
    # no-false-negative: any pred set relevant before stays relevant after
    for c in range(cs.n_cs):
        preds = cs.preds_of(c).tolist()
        assert len(red.relevant_cs(preds)) > 0, "reduction lost a relevant CS"
