"""Cardinality feedback (repro.stats.feedback): observed execution closes
the statistics loop — scans whose observed cardinality drifts past the
threshold trigger ``refresh_source`` through the versioned lifecycle, the
epoch bump retires exactly the stale cached plans, and subsequent plans
estimate the refreshed source accurately."""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.cost import estimation_error
from repro.core.federation import build_federated_stats
from repro.core.planner import OdysseyOptimizer, query_signature
from repro.engine.pipeline import CardObservation
from repro.rdf.dataset import Federation, Source, TripleTable
from repro.rdf.generator import (
    fedbench_like_spec,
    generate_federation,
    generate_workload,
)
from repro.serve.query import QueryServeEngine
from repro.stats.feedback import CardinalityFeedback


def _scan(source, est, obs):
    return CardObservation(kind="scan", source=source, star=0, est=est, obs=obs)


def _result(*observations):
    return SimpleNamespace(card_log=tuple(observations))


# --------------------------------------------------------------------------
# units
# --------------------------------------------------------------------------

def test_estimation_error_is_symmetric_log_qerror():
    assert estimation_error(0, 0) == 0.0
    assert estimation_error(1, 3) == pytest.approx(1.0)       # off by 2x
    assert estimation_error(1, 7) == pytest.approx(2.0)       # off by 4x
    # symmetric: over- and under-estimation by the same factor score equally
    assert estimation_error(3, 1) == estimation_error(1, 3)
    assert estimation_error(0, 100) == pytest.approx(np.log2(101))


def test_feedback_threshold_and_min_observations():
    fb = CardinalityFeedback(stats=None, fed=None, threshold_x=4.0,
                             min_observations=3)
    # est=1 obs=7 -> error 2.0 == log2(4): exactly at the threshold
    fb.observe_result(_result(_scan("A", 1.0, 7), _scan("A", 1.0, 7)))
    assert fb.dirty_sources() == []            # two samples < min_observations
    fb.observe_result(_result(_scan("A", 1.0, 7)))
    assert fb.dirty_sources() == ["A"]
    assert fb.mean_error("A") == pytest.approx(2.0)
    # an accurate source never goes dirty, whatever its sample count
    for _ in range(5):
        fb.observe_result(_result(_scan("B", 10.0, 11)))
    assert fb.dirty_sources() == ["A"]
    assert fb.n_observations == 8


def test_feedback_scores_only_unbound_scan_samples():
    fb = CardinalityFeedback(stats=None, fed=None, threshold_x=2.0,
                             min_observations=1)
    fb.observe_result(_result(
        CardObservation(kind="scan_bound", source="A", star=0, est=1.0, obs=99),
        CardObservation(kind="scan_merged", source="A", star=None, est=1.0, obs=99),
        CardObservation(kind="join", source=None, star=None, est=4.0, obs=99),
        CardObservation(kind="scan", source="A", star=0, est=None, obs=99),
    ))
    # bound/merged estimates measure a different quantity; operator kinds
    # have no source; an estimate-free scan cannot be scored
    assert fb.dirty_sources() == []
    assert fb.n_observations == 0


def test_feedback_rejects_degenerate_threshold():
    with pytest.raises(ValueError, match="threshold_x"):
        CardinalityFeedback(stats=None, fed=None, threshold_x=1.0)


def test_apply_pending_refreshes_and_clears(tiny_fed, tiny_stats):
    fed, _ = tiny_fed
    stats = tiny_stats.clone()
    name = fed.sources[0].name
    fb = CardinalityFeedback(stats, fed, threshold_x=2.0, min_observations=2)
    fb.observe_result(_result(_scan(name, 1.0, 50), _scan(name, 1.0, 50)))
    assert fb.dirty_sources() == [name]
    epoch = stats.epoch
    assert fb.apply_pending() == [name]
    assert stats.epoch == epoch + 1            # one bump per refreshed source
    assert fb.refreshes == [name]
    assert fb.dirty_sources() == []            # drift evidence cleared
    assert fb.apply_pending() == []            # idempotent until new evidence
    assert stats.epoch == epoch + 1
    # a source excluded mid-flight (not in the federation) is dropped quietly
    fb.observe_result(_result(_scan("no-such-endpoint", 1.0, 50),
                              _scan("no-such-endpoint", 1.0, 50)))
    assert fb.apply_pending() == []


# --------------------------------------------------------------------------
# the serve-loop integration: drift -> refresh -> epoch -> better plans
# --------------------------------------------------------------------------

def _truncated(table: TripleTable, frac: float, seed: int) -> TripleTable:
    rng = np.random.default_rng(seed)
    keep = np.sort(rng.choice(len(table), size=max(1, int(len(table) * frac)),
                              replace=False))
    return TripleTable.from_triples(table.s[keep], table.p[keep], table.o[keep])


def test_serve_feedback_refreshes_drifted_source_through_epoch_lifecycle():
    """End to end: statistics built from a stale (10%) snapshot of the hub
    source drift against live execution; the serve loop's feedback marks the
    source dirty, the next planning batch refreshes exactly that source,
    the epoch bump retires exactly the stale cached plans (each template
    replans once, then hits again), and the refreshed statistics estimate
    the source accurately."""
    fed, gt = generate_federation(fedbench_like_spec(scale=0.06, seed=3))
    victim = max(fed.sources, key=lambda s: s.table.n_triples).name
    stale_fed = Federation(
        [Source(s.name, _truncated(s.table, 0.1, 7) if s.name == victim
                else s.table) for s in fed.sources], fed.dictionary)
    stats = build_federated_stats(stale_fed)
    fb = CardinalityFeedback(stats, fed, threshold_x=4.0, min_observations=3)
    eng = QueryServeEngine(fed, stats, feedback=fb)
    # no path queries: their variable-predicate plans never enter the plan
    # cache, which would muddy the evicts-exactly-stale-entries assertions
    queries = generate_workload(fed, gt, n_star=8, n_hybrid=6, n_path=0,
                                seed=21)

    # the drift the serve loop should discover, measured offline against a
    # detached clone of the stale statistics (the serve loop clears its own
    # evidence when it refreshes, so measure the "before" independently)
    from repro.engine.local import LocalEngine
    probe = OdysseyOptimizer(stats.clone(), plan_cache_size=0)
    probe_eng = LocalEngine(fed)
    pre = [estimation_error(ob.est, ob.obs)
           for q in queries for ob in probe_eng.execute(probe.optimize(q)).card_log
           if ob.kind == "scan" and ob.source == victim and ob.est is not None]
    pre_error = float(np.mean(pre))
    assert pre_error >= fb.threshold           # the snapshot is genuinely stale

    def round_():
        for q in queries:
            eng.submit(q)
        done = eng.drain()
        return sorted(done, key=lambda r: r.qid)

    r1 = round_()
    assert fb.refreshes == []                  # min_observations not reached
    rounds = [r1]
    # affinity admission may split a drain into several plan/execute batches,
    # so the refresh lands mid-drain as soon as the evidence completes —
    # iterate to convergence (bounded) instead of pinning batch boundaries
    for _ in range(3):
        rounds.append(round_())
        if fb.refreshes:
            break
    assert fb.refreshes == [victim]            # exactly the drifted source
    assert eng.serve_stats.n_stats_refreshes == 1
    assert stats.epoch == 1                    # one refresh == one epoch bump
    # settle: two more rounds — stale templates replan exactly once under the
    # new epoch, then everything is a cache hit again with no further refresh
    rounds.append(round_())
    settle = round_()
    assert all(r.cached and r.stats_epoch == 1 for r in settle)
    assert fb.refreshes == [victim]
    assert fb.dirty_sources() == []
    # evicts *exactly* the stale entries: each distinct template once
    assert eng.optimizer.plan_cache.stale_evictions == \
        len({query_signature(q)[0] for q in queries})
    # the refreshed statistics estimate the drifted source accurately now
    # (mean_error holds only post-refresh evidence — the refresh cleared the
    # stale-epoch samples)
    post_error = fb.mean_error(victim)
    assert post_error < fb.threshold
    assert post_error < pre_error / 2
    # the stale snapshot had broken the selection's no-false-negative
    # guarantee (a pruned-away source really held answers); the refresh can
    # only *restore* completeness — post-refresh answers are a superset, and
    # two fully post-refresh rounds agree exactly
    def result_set(rel, proj):
        n = len(next(iter(rel.values()))) if rel else 0
        return set(zip(*[rel[v].tolist() for v in proj])) if n else set()

    grew = False
    for a, b in zip(r1, settle):
        proj = a.query.effective_projection()
        before, after = result_set(a.rows, proj), result_set(b.rows, proj)
        assert before <= after
        grew = grew or (before < after)
    assert grew, "the stale statistics never cost an answer? weak scenario"
    for a, b in zip(rounds[-1], settle):
        for v in a.rows:
            assert np.array_equal(a.rows[v], b.rows[v])
