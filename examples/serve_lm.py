"""Serve a small model with batched requests (continuous-batching-lite).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import reduced_config
from repro.configs import get_arch
from repro.models import model as MDL
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = reduced_config(get_arch("qwen2-0.5b"))
    params = MDL.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    eng = ServeEngine(cfg, params, n_slots=4, ctx_len=64, use_prefill=True)

    rng = np.random.default_rng(0)
    n_req = 10
    t0 = time.perf_counter()
    for i in range(n_req):
        prompt = rng.integers(1, cfg.vocab, rng.integers(2, 6)).tolist()
        eng.submit(Request(rid=i, prompt=prompt, max_new=8))
    done = eng.run_until_done()
    dt = time.perf_counter() - t0

    total_tokens = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.1f} tok/s on CPU)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        lat = (r.t_done - r.t_submit) * 1e3
        print(f"  req {r.rid}: prompt={r.prompt} -> {r.out} ({lat:.0f} ms)")
    assert len(done) == n_req
    print("SERVING OK")


if __name__ == "__main__":
    main()
