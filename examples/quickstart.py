"""Quickstart: the Odyssey pipeline end to end on a synthetic federation.

    PYTHONPATH=src python examples/quickstart.py

1. Generate a FedBench-like federation (9 sources).
2. Compute CS/CP statistics + entity summaries + federated CPs (Algorithm 1).
3. Parse a SPARQL query, optimize it with Odyssey, execute it, and compare
   plan metrics against a FedX-style heuristic baseline.
"""
import numpy as np

from repro.baselines import FedXOptimizer
from repro.core.federation import build_federated_stats
from repro.core.planner import JoinPlanNode, OdysseyOptimizer, SubqueryNode
from repro.engine.local import LocalEngine, naive_evaluate
from repro.query.sparql import parse_sparql
from repro.rdf.generator import fedbench_like_spec, generate_federation, generate_workload


def show_plan(node, fed, depth=0):
    pad = "  " * depth
    if isinstance(node, SubqueryNode):
        srcs = ",".join(fed.sources[s].name for s in node.sources)
        print(f"{pad}Subquery(stars={node.stars}, sources=[{srcs}], "
              f"{len(node.patterns)} patterns, est={node.est_cardinality:.0f})")
    else:
        assert isinstance(node, JoinPlanNode)
        print(f"{pad}{node.strategy.upper()}-JOIN on {node.join_vars}")
        show_plan(node.left, fed, depth + 1)
        show_plan(node.right, fed, depth + 1)


def main():
    print("== generating federation ==")
    fed, gt = generate_federation(fedbench_like_spec(scale=0.5))
    print(f"{len(fed)} sources, {fed.total_triples():,} triples")

    print("\n== computing Odyssey statistics (CS/CP + summaries + Alg.1) ==")
    stats = build_federated_stats(fed)
    for i, src in enumerate(fed.sources):
        print(f"  {src.name:10} {stats.cs[i].n_cs:4} CSs, "
              f"{stats.intra_cp[i].n_cp:6} CPs")
    n_fcp = sum(v.n_cp for v in stats.fed_cp.values())
    print(f"  federated CPs across sources: {n_fcp} "
          f"(summary pruning: {stats.pruning_checked}/{stats.pruning_possible} "
          "exact checks)")

    # a hybrid query via the SPARQL parser (Listing 1.4 analog)
    lmdb_pred = [t for t in fed.dictionary.terms if t == "owl:sameAs"][0]
    query_text = """
    SELECT DISTINCT ?x ?y WHERE {
      ?x owl:sameAs ?y .
      ?x lmdb:sequel ?s .
      ?y rdf:type ?t .
    }"""
    q = parse_sparql(query_text, fed.dictionary)
    print(f"\n== query ==\n{query_text}")

    engine = LocalEngine(fed)
    for name, opt in (("Odyssey", OdysseyOptimizer(stats)),
                      ("FedX", FedXOptimizer(fed))):
        plan = opt.optimize(q)
        res = engine.execute(plan)
        rel, m = res.rows, res.metrics
        n = len(next(iter(rel.values()))) if rel else 0
        print(f"\n-- {name} --")
        show_plan(plan.root, fed)
        print(f"answers={n}  OT={plan.optimization_ms:.1f}ms  "
              f"NSS={plan.n_selected_sources}  NSQ={plan.n_subqueries}  "
              f"NTT={m.transferred_tuples}  requests={m.requests}")

    want = naive_evaluate(fed, q)
    print(f"\ngold-standard answers: {len(want)} (both engines must match)")


if __name__ == "__main__":
    main()
