"""Federated statistics deep-dive: Algorithm 1, summaries, and completeness.

    PYTHONPATH=src python examples/federated_demo.py

Reproduces the paper's §3.2 narrative on synthetic LMDB/DBpedia: computes the
link exports, runs ComputeFedCPs with and without summary pruning, verifies
they agree (the no-false-negative guarantee), and uses the federated CPs for
a cross-dataset cardinality estimate (formula 3/4 analog of Table 1).
"""
import numpy as np

from repro.core.characteristic_sets import compute_characteristic_sets
from repro.core.cardinality import (linked_star_cardinality_distinct,
                                    linked_star_cardinality_estimate)
from repro.core.federation import compute_federated_cps, export_link_stats
from repro.core.summaries import build_summary
from repro.engine.local import naive_evaluate
from repro.query.algebra import BGPQuery, Const, TriplePattern, Var
from repro.rdf.generator import fedbench_like_spec, generate_federation


def main():
    fed, gt = generate_federation(fedbench_like_spec(scale=0.5))
    lmdb = fed.by_name("LMDB")
    dbp = fed.by_name("DBpedia")
    kinds = np.asarray(fed.dictionary.kinds, np.int8)
    auth = fed.dictionary.authority_array()

    print("== per-source statistics ==")
    cs_l = compute_characteristic_sets(lmdb.table)
    cs_d = compute_characteristic_sets(dbp.table)
    print(f"LMDB:    {lmdb.table.n_triples:,} triples, {cs_l.n_cs} CSs")
    print(f"DBpedia: {dbp.table.n_triples:,} triples, {cs_d.n_cs} CSs")

    exp_l = export_link_stats(lmdb.table, cs_l, lmdb.sid, entity_mask=kinds == 0)
    exp_d = export_link_stats(dbp.table, cs_d, dbp.sid, entity_mask=kinds == 0)
    summ_l = build_summary(lmdb.table, cs_l, auth, lmdb.sid, entity_mask=kinds == 0)
    summ_d = build_summary(dbp.table, cs_d, auth, dbp.sid, entity_mask=kinds == 0)
    print(f"\nexports: LMDB {exp_l.nbytes() / 1024:.0f} KB, "
          f"DBpedia {exp_d.nbytes() / 1024:.0f} KB")
    print(f"summaries: LMDB {summ_l.nbytes() / 1024:.0f} KB, "
          f"DBpedia {summ_d.nbytes() / 1024:.0f} KB")

    print("\n== Algorithm 1: federated CPs LMDB -> DBpedia ==")
    full = compute_federated_cps(exp_l, exp_d)
    pruned = compute_federated_cps(exp_l, exp_d, summ_l, summ_d)
    print(f"without summaries: {full.n_checked_pairs} exact intersections")
    print(f"with summaries:    {pruned.n_checked_pairs} exact intersections "
          f"({full.n_checked_pairs / max(1, pruned.n_checked_pairs):.1f}x pruning)")
    same = (np.array_equal(full.cps.count, pruned.cps.count)
            and np.array_equal(full.cps.pred, pruned.cps.pred))
    print(f"identical federated CPs: {same}  (paper: summaries find 100%)")
    print(f"federated CPs found: {pruned.cps.n_cp}, "
          f"entity pairs: {int(pruned.cps.count.sum()):,}")

    # Table-1-style cardinality check on a cross-dataset query
    same_as = fed.dictionary.id_of("owl:sameAs")
    rdf_type = fed.dictionary.id_of("rdf:type")
    # find an LMDB predicate co-occurring with sameAs
    lmdb_preds = [int(p) for p in cs_l.pred_ids if int(p) != same_as]
    best = None
    for c in range(cs_l.n_cs):
        preds = set(cs_l.preds_of(c).tolist())
        if same_as in preds:
            others = [p for p in preds if p != same_as and p != rdf_type]
            if others:
                best = others[0]
                break
    if best is None:
        print("no co-occurring predicate found")
        return
    q = BGPQuery([
        TriplePattern(Var("x"), Const(same_as), Var("y")),
        TriplePattern(Var("x"), Const(best), Var("v")),
        TriplePattern(Var("y"), Const(rdf_type), Var("t")),
    ], distinct=True, projection=["x", "y"])
    exact = linked_star_cardinality_distinct(
        pruned.cps, cs_l, cs_d, [best], [rdf_type], same_as)
    est = linked_star_cardinality_estimate(
        pruned.cps, cs_l, cs_d, [best, same_as], [rdf_type], same_as)
    true = len(naive_evaluate(fed, q))
    print(f"\ncross-dataset query cardinality: formula(3)={exact} "
          f"formula(4)={est:.0f} true={true}")
    print("formula (3) exactness:", "EXACT" if exact == true else "MISMATCH")


if __name__ == "__main__":
    main()
