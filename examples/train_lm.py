"""End-to-end training driver: train a ~100M-class LM for a few hundred steps.

    # quick CPU sanity run (~20M params, 60 steps):
    PYTHONPATH=src python examples/train_lm.py

    # the full ~100M x 300-step run (hours on CPU; the production path):
    PYTHONPATH=src python examples/train_lm.py --full

Demonstrates the full substrate: config registry, resumable data pipeline,
AdamW, checkpoint/restart (kill it mid-run and re-run: it resumes), and loss
that actually goes down on the structured synthetic stream.
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args, extra = ap.parse_known_args()
    if args.full:
        # qwen2-0.5b reduced to ~110M params
        argv = ["--arch", "qwen2-0.5b", "--reduced", "--d-model", "512",
                "--layers", "12", "--steps", "300", "--batch", "8",
                "--seq", "512", "--ckpt-dir", args.ckpt_dir,
                "--ckpt-every", "50", "--chunked-loss"]
    else:
        argv = ["--arch", "qwen2-0.5b", "--reduced", "--d-model", "256",
                "--layers", "4", "--steps", "60", "--batch", "8",
                "--seq", "128", "--ckpt-dir", args.ckpt_dir,
                "--ckpt-every", "30"]
    result = train_main(argv + extra)
    ok = result["last"] < result["first"]
    print("TRAINING", "OK: loss improved" if ok else "FAILED: no improvement")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
