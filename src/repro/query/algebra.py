"""SPARQL group-graph-pattern algebra.

The conjunctive core is unchanged (``Var``/``Const``/``TriplePattern`` and
``BGPQuery``); on top of it sits a recursive *group tree* covering the
non-conjunctive constructs the Odyssey evaluation queries use:

``Bgp``        a conjunctive block of triple patterns (the DP-planned unit)
``Join``       conjunction of arbitrary sub-groups (``{ G1 . G2 }``)
``LeftJoin``   OPTIONAL (``G1 OPTIONAL { G2 }``); child order is semantic
``Union``      UNION of alternatives (n-ary, flattened)
``Filter``     FILTER over a group, with a small expression language
               (comparisons over term ids, ``&&``/``||``/``!``)

``BGPQuery`` stays the single query type: ``root is None`` means the query is
the degenerate one-node case ``Bgp(patterns)`` and every pre-existing call
site keeps working mechanically; a non-``None`` ``root`` carries the full
tree while ``patterns`` always holds the tree's triple patterns flattened in
tree order (so ``variables()``/``len()`` and structure-agnostic consumers
keep their meaning).

``normalize`` rewrites a tree into the planner's canonical form (see
``docs/algebra.md``):

1. *Union hoisting* — UNION distributes out of Join / Filter / LeftJoin-left
   so each branch becomes an independent (mostly conjunctive) plan problem.
2. *Well-designed OPTIONAL pull-up* — ``Join(LeftJoin(L, R), S)`` is
   rewritten to ``LeftJoin(Join(L, S), R)`` when ``vars(R) ∩ vars(S) ⊆
   vars(L)`` (the well-designedness condition of Pérez et al., applied per
   arm as in arXiv 1810.09780), maximizing the conjunctive core handed to
   the star-decomposition + DP pipeline.  Non-well-designed arms are left
   in place — correctness first, reordering only where licensed.
3. *Filter pushdown* — every conjunct is pushed into the deepest group that
   certainly binds its variables (never into an OPTIONAL arm, always into
   all UNION branches), so FILTER evaluates as early as its variables are
   bound.

Filter semantics are deliberately two-valued over term ids: a comparison
involving an unbound variable (UNDEF) is *false*, ``!`` is plain negation.
The engine and the ``naive_evaluate`` oracle share one evaluator, so plans
and oracle agree by construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Term:
    pass


@dataclass(frozen=True)
class Var(Term):
    name: str  # without leading '?'

    def __repr__(self) -> str:
        return f"?{self.name}"


@dataclass(frozen=True)
class Const(Term):
    tid: int  # term-dictionary id

    def __repr__(self) -> str:
        return f"<{self.tid}>"


@dataclass(frozen=True)
class TriplePattern:
    s: Term
    p: Term
    o: Term

    def variables(self) -> frozenset[str]:
        return frozenset(t.name for t in (self.s, self.p, self.o) if isinstance(t, Var))

    def constants(self) -> tuple[int | None, int | None, int | None]:
        """(s, p, o) with None where unbound — the engine's scan signature."""
        return tuple(t.tid if isinstance(t, Const) else None for t in (self.s, self.p, self.o))  # type: ignore[return-value]

    @property
    def has_var_predicate(self) -> bool:
        return isinstance(self.p, Var)


# --------------------------------------------------------------------------
# Filter expressions
# --------------------------------------------------------------------------

COMPARISON_OPS = ("=", "!=", "<", "<=", ">", ">=")


@dataclass(frozen=True)
class Expr:
    pass


@dataclass(frozen=True)
class Comparison(Expr):
    op: str                       # one of COMPARISON_OPS
    lhs: Term
    rhs: Term

    def __post_init__(self):
        if self.op not in COMPARISON_OPS:
            raise ValueError(f"unknown comparison operator {self.op!r}")

    def __repr__(self) -> str:
        return f"({self.lhs!r} {self.op} {self.rhs!r})"


@dataclass(frozen=True)
class And(Expr):
    parts: tuple[Expr, ...]


@dataclass(frozen=True)
class Or(Expr):
    parts: tuple[Expr, ...]


@dataclass(frozen=True)
class Not(Expr):
    part: Expr


def expr_variables(expr: Expr) -> frozenset[str]:
    if isinstance(expr, Comparison):
        return frozenset(t.name for t in (expr.lhs, expr.rhs) if isinstance(t, Var))
    if isinstance(expr, (And, Or)):
        out: frozenset[str] = frozenset()
        for p in expr.parts:
            out |= expr_variables(p)
        return out
    assert isinstance(expr, Not)
    return expr_variables(expr.part)


def conjuncts(expr: Expr) -> list[Expr]:
    """Flatten nested ``And`` into its conjunct list (pushdown unit)."""
    if isinstance(expr, And):
        out: list[Expr] = []
        for p in expr.parts:
            out.extend(conjuncts(p))
        return out
    return [expr]


# --------------------------------------------------------------------------
# Group tree
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class GroupNode:
    pass


@dataclass(frozen=True)
class Bgp(GroupNode):
    patterns: tuple[TriplePattern, ...]


@dataclass(frozen=True)
class Join(GroupNode):
    children: tuple[GroupNode, ...]


@dataclass(frozen=True)
class LeftJoin(GroupNode):
    left: GroupNode
    right: GroupNode


@dataclass(frozen=True)
class Union(GroupNode):
    members: tuple[GroupNode, ...]


@dataclass(frozen=True)
class Filter(GroupNode):
    expr: Expr
    child: GroupNode


def group_triples(node: GroupNode) -> list[TriplePattern]:
    """All triple patterns of the tree, flattened in tree order."""
    if isinstance(node, Bgp):
        return list(node.patterns)
    if isinstance(node, Join):
        return [tp for c in node.children for tp in group_triples(c)]
    if isinstance(node, LeftJoin):
        return group_triples(node.left) + group_triples(node.right)
    if isinstance(node, Union):
        return [tp for m in node.members for tp in group_triples(m)]
    assert isinstance(node, Filter)
    return group_triples(node.child)


def group_variables(node: GroupNode) -> frozenset[str]:
    """Variables that *may* be bound by the group (pattern variables)."""
    out: frozenset[str] = frozenset()
    for tp in group_triples(node):
        out |= tp.variables()
    return out


def certain_variables(node: GroupNode) -> frozenset[str]:
    """Variables bound in *every* solution of the group: all pattern vars of
    a Bgp, the union across Join children, only the left side of a LeftJoin
    (the OPTIONAL arm may stay unmatched), the intersection across Union
    members, and the child's for Filter.  This is the safety condition for
    filter pushdown and the well-designedness check."""
    if isinstance(node, Bgp):
        out: frozenset[str] = frozenset()
        for tp in node.patterns:
            out |= tp.variables()
        return out
    if isinstance(node, Join):
        out = frozenset()
        for c in node.children:
            out |= certain_variables(c)
        return out
    if isinstance(node, LeftJoin):
        return certain_variables(node.left)
    if isinstance(node, Union):
        if not node.members:
            return frozenset()
        out = certain_variables(node.members[0])
        for m in node.members[1:]:
            out &= certain_variables(m)
        return out
    assert isinstance(node, Filter)
    return certain_variables(node.child)


def _all_vars(node: GroupNode) -> frozenset[str]:
    """Pattern vars plus filter-expression vars — occurrence in the
    well-designedness sense."""
    if isinstance(node, Filter):
        return _all_vars(node.child) | expr_variables(node.expr)
    if isinstance(node, Join):
        out: frozenset[str] = frozenset()
        for c in node.children:
            out |= _all_vars(c)
        return out
    if isinstance(node, LeftJoin):
        return _all_vars(node.left) | _all_vars(node.right)
    if isinstance(node, Union):
        out = frozenset()
        for m in node.members:
            out |= _all_vars(m)
        return out
    assert isinstance(node, Bgp)
    return group_variables(node)


def is_well_designed(root: GroupNode) -> bool:
    """Pérez et al.'s condition: for every ``LeftJoin(l, r)`` occurrence,
    each variable of ``r`` that also occurs *outside* the LeftJoin must
    occur in ``l``.  Well-designed trees license the OPTIONAL reordering
    ``normalize`` performs (arXiv 1810.09780)."""

    ok = True

    def walk(node: GroupNode, outside: frozenset[str]) -> None:
        nonlocal ok
        if not ok:
            return
        if isinstance(node, LeftJoin):
            lv, rv = _all_vars(node.left), _all_vars(node.right)
            if not (rv & outside) <= lv:
                ok = False
                return
            walk(node.left, outside | rv)
            walk(node.right, outside | lv)
        elif isinstance(node, Join):
            for i, c in enumerate(node.children):
                sib = frozenset()
                for j, d in enumerate(node.children):
                    if j != i:
                        sib |= _all_vars(d)
                walk(c, outside | sib)
        elif isinstance(node, Union):
            for m in node.members:
                walk(m, outside)
        elif isinstance(node, Filter):
            walk(node.child, outside | expr_variables(node.expr))

    walk(root, frozenset())
    return ok


# --------------------------------------------------------------------------
# Normalization
# --------------------------------------------------------------------------


def normalize(node: GroupNode) -> GroupNode:
    """Canonical planning form: unions hoisted, well-designed OPTIONAL arms
    pulled above maximal conjunctive cores, filters pushed to the deepest
    group that certainly binds their variables.  Semantics-preserving under
    the two-valued filter semantics shared by engine and oracle."""
    structured = _structure(node)
    return _push_filters(structured)


def _structure(node: GroupNode) -> GroupNode:
    if isinstance(node, Bgp):
        return node
    if isinstance(node, Filter):
        child = _structure(node.child)
        if isinstance(child, Union):       # FILTER distributes over UNION
            return Union(tuple(_structure(Filter(node.expr, m))
                               for m in child.members))
        return Filter(node.expr, child)
    if isinstance(node, Union):
        members: list[GroupNode] = []
        for m in node.members:
            sm = _structure(m)
            if isinstance(sm, Union):
                members.extend(sm.members)
            else:
                members.append(sm)
        if len(members) == 1:
            return members[0]
        return Union(tuple(members))
    if isinstance(node, LeftJoin):
        left = _structure(node.left)
        right = _structure(node.right)
        if isinstance(left, Union):        # OPTIONAL applies per branch
            return Union(tuple(_structure(LeftJoin(m, right))
                               for m in left.members))
        return LeftJoin(left, right)
    assert isinstance(node, Join)
    if not node.children:
        return Bgp(())
    children: list[GroupNode] = []
    filters: list[Expr] = []
    for c in node.children:
        sc = _structure(c)
        # lift filters whose vars the child itself binds; pushdown re-places
        # them at the deepest binder after restructuring
        while isinstance(sc, Filter) and \
                expr_variables(sc.expr) <= group_variables(sc.child):
            filters.append(sc.expr)
            sc = sc.child
        if isinstance(sc, Join):
            children.extend(sc.children)
        else:
            children.append(sc)
    # hoist the first UNION child: Join(..., Union(A, B), ...) ->
    # Union(Join(..., A, ...), Join(..., B, ...)), recursively
    for i, c in enumerate(children):
        if isinstance(c, Union):
            branches = []
            for m in c.members:
                j: GroupNode = Join(tuple(children[:i] + [m] + children[i + 1:]))
                for e in filters:
                    j = Filter(e, j)
                branches.append(_structure(j))
            return Union(tuple(branches))
    # pull well-designed OPTIONAL arms above the join so the conjunctive
    # core is maximal: Join(LeftJoin(L, R), S) -> LeftJoin(Join(L, S), R)
    # when vars(R) ∩ vars(S) ⊆ vars(L)
    arms: list[GroupNode] = []
    changed = True
    while changed:
        changed = False
        for i, c in enumerate(children):
            if not isinstance(c, LeftJoin):
                continue
            sib: frozenset[str] = frozenset()
            for j, d in enumerate(children):
                if j != i:
                    sib |= _all_vars(d)
            for e in filters:
                sib |= expr_variables(e)
            if (_all_vars(c.right) & sib) <= _all_vars(c.left):
                children[i] = c.left
                arms.append(c.right)
                changed = True
                break
    # merge every Bgp child into one conjunctive block (at the position of
    # the first), in child order
    bgp_pats: list[TriplePattern] = []
    merged: list[GroupNode] = []
    bgp_at = -1
    for c in children:
        if isinstance(c, Bgp):
            if bgp_at < 0:
                bgp_at = len(merged)
                merged.append(c)           # placeholder, replaced below
            bgp_pats.extend(c.patterns)
        else:
            merged.append(c)
    if bgp_at >= 0:
        merged[bgp_at] = Bgp(tuple(bgp_pats))
    out: GroupNode = merged[0] if len(merged) == 1 else Join(tuple(merged))
    for arm in arms:
        out = LeftJoin(out, arm)
    for e in filters:
        out = Filter(e, out)
    if isinstance(out, (LeftJoin, Filter)):
        return _structure(out)             # arms/filters may enable more
    return out


def _push_filters(node: GroupNode) -> GroupNode:
    exprs: list[Expr] = []
    while isinstance(node, Filter):
        exprs.extend(conjuncts(node.expr))
        node = node.child
    if isinstance(node, Join):
        node = Join(tuple(_push_filters(c) for c in node.children))
    elif isinstance(node, LeftJoin):
        node = LeftJoin(_push_filters(node.left), _push_filters(node.right))
    elif isinstance(node, Union):
        node = Union(tuple(_push_filters(m) for m in node.members))
    for e in exprs:
        node = _place_filter(e, node)
    return node


def _place_filter(expr: Expr, node: GroupNode) -> GroupNode:
    """Push one conjunct into the deepest group that certainly binds its
    variables.  Never descends into an OPTIONAL arm (that would turn filtered
    rows into unmatched-left survivors); always distributes over UNION."""
    vs = expr_variables(expr)
    if isinstance(node, Union):
        return Union(tuple(_place_filter(expr, m) for m in node.members))
    if isinstance(node, Join):
        for i, c in enumerate(node.children):
            if vs <= certain_variables(c):
                kids = list(node.children)
                kids[i] = _place_filter(expr, c)
                return Join(tuple(kids))
        return Filter(expr, node)
    if isinstance(node, LeftJoin):
        if vs <= certain_variables(node.left):
            return LeftJoin(_place_filter(expr, node.left), node.right)
        return Filter(expr, node)
    if isinstance(node, Filter):
        return Filter(node.expr, _place_filter(expr, node.child))
    return Filter(expr, node)


# --------------------------------------------------------------------------
# Query
# --------------------------------------------------------------------------


@dataclass
class BGPQuery:
    patterns: list[TriplePattern]
    distinct: bool = False
    projection: list[str] = field(default_factory=list)  # empty => all vars
    name: str = ""
    # full group tree; None == the degenerate one-node case Bgp(patterns).
    # When set, `patterns` holds the tree's triples flattened in tree order.
    root: GroupNode | None = None

    def variables(self) -> frozenset[str]:
        out: frozenset[str] = frozenset()
        for tp in self.patterns:
            out |= tp.variables()
        return out

    def effective_projection(self) -> list[str]:
        return self.projection if self.projection else sorted(self.variables())

    def __len__(self) -> int:
        return len(self.patterns)

    def algebra(self) -> GroupNode:
        """The group tree (``Bgp(patterns)`` for the degenerate case)."""
        return self.root if self.root is not None else Bgp(tuple(self.patterns))

    def is_conjunctive(self) -> bool:
        """True iff the query is a plain BGP — the planner's fast path, kept
        bit-identical to the pre-algebra pipeline."""
        return self.root is None or isinstance(normalize(self.root), Bgp)


def from_algebra(root: GroupNode, distinct: bool = False,
                 projection: list[str] | None = None,
                 name: str = "") -> BGPQuery:
    """Build a query from a group tree; ``patterns`` is the flattened triple
    list so structure-agnostic consumers (variable sets, NSS metrics,
    baselines on conjunctive queries) keep working."""
    if isinstance(root, Bgp):
        return BGPQuery(list(root.patterns), distinct=distinct,
                        projection=list(projection or []), name=name)
    return BGPQuery(group_triples(root), distinct=distinct,
                    projection=list(projection or []), name=name, root=root)
