"""Minimal SPARQL BGP algebra: variables, triple patterns, conjunctive queries."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Term:
    pass


@dataclass(frozen=True)
class Var(Term):
    name: str  # without leading '?'

    def __repr__(self) -> str:
        return f"?{self.name}"


@dataclass(frozen=True)
class Const(Term):
    tid: int  # term-dictionary id

    def __repr__(self) -> str:
        return f"<{self.tid}>"


@dataclass(frozen=True)
class TriplePattern:
    s: Term
    p: Term
    o: Term

    def variables(self) -> frozenset[str]:
        return frozenset(t.name for t in (self.s, self.p, self.o) if isinstance(t, Var))

    def constants(self) -> tuple[int | None, int | None, int | None]:
        """(s, p, o) with None where unbound — the engine's scan signature."""
        return tuple(t.tid if isinstance(t, Const) else None for t in (self.s, self.p, self.o))  # type: ignore[return-value]

    @property
    def has_var_predicate(self) -> bool:
        return isinstance(self.p, Var)


@dataclass
class BGPQuery:
    patterns: list[TriplePattern]
    distinct: bool = False
    projection: list[str] = field(default_factory=list)  # empty => all vars
    name: str = ""

    def variables(self) -> frozenset[str]:
        out: frozenset[str] = frozenset()
        for tp in self.patterns:
            out |= tp.variables()
        return out

    def effective_projection(self) -> list[str]:
        return self.projection if self.projection else sorted(self.variables())

    def __len__(self) -> int:
        return len(self.patterns)
