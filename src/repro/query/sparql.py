"""A tiny SPARQL-subset parser: SELECT [DISTINCT] ?v ... WHERE { BGP }.

Supports triple patterns over prefixed names / full IRIs / variables, '.'
separators, and string literals. This keeps examples/readme snippets runnable
without external dependencies; the optimizer itself consumes ``BGPQuery``.
"""
from __future__ import annotations

import re

from repro.query.algebra import BGPQuery, Const, TriplePattern, Var
from repro.rdf.dictionary import TermDict, TermKind

_TOKEN = re.compile(r"\?[A-Za-z_][\w]*|<[^>]*>|\"[^\"]*\"|[A-Za-z_][\w.\-]*:[\w.\-]*|[{}.]|SELECT|DISTINCT|WHERE", re.I)


def parse_sparql(text: str, dictionary: TermDict) -> BGPQuery:
    tokens = _TOKEN.findall(text)
    i = 0

    def expect(tok: str) -> None:
        nonlocal i
        if i >= len(tokens) or tokens[i].upper() != tok.upper():
            raise ValueError(f"expected {tok!r} at token {i}: {tokens[max(0, i - 2): i + 3]}")
        i += 1

    expect("SELECT")
    distinct = False
    if i < len(tokens) and tokens[i].upper() == "DISTINCT":
        distinct = True
        i += 1
    projection: list[str] = []
    while i < len(tokens) and tokens[i].startswith("?"):
        projection.append(tokens[i][1:])
        i += 1
    expect("WHERE")
    expect("{")
    patterns: list[TriplePattern] = []
    terms: list = []
    while i < len(tokens) and tokens[i] != "}":
        tok = tokens[i]
        i += 1
        if tok == ".":
            continue
        if tok.startswith("?"):
            terms.append(Var(tok[1:]))
        elif tok.startswith("<"):
            terms.append(Const(dictionary.add(tok[1:-1], TermKind.IRI)))
        elif tok.startswith('"'):
            terms.append(Const(dictionary.add(tok[1:-1], TermKind.LITERAL)))
        else:  # prefixed name
            terms.append(Const(dictionary.add(tok, TermKind.IRI)))
        if len(terms) == 3:
            patterns.append(TriplePattern(*terms))
            terms = []
    if terms:
        raise ValueError("dangling terms in BGP")
    return BGPQuery(patterns=patterns, distinct=distinct, projection=projection)
