"""A small SPARQL-subset parser and serializer.

    SELECT [DISTINCT] (?v ... | *) WHERE { group }

A group may contain triple patterns over prefixed names / full IRIs /
variables / string literals, '.' separators, nested groups in braces,
``OPTIONAL { ... }``, ``{ ... } UNION { ... }`` chains, and
``FILTER (expr)`` with comparisons (``= != < <= > >=``) over variables and
terms composed with ``&& || !`` and parentheses.  ``serialize_sparql`` is
the inverse: ``parse_sparql(serialize_sparql(q, d), d)`` reconstructs the
same group tree (term ids resolve through the same dictionary).

Recognized-but-unsupported SPARQL constructs (GRAPH, SERVICE, MINUS, BIND,
VALUES, EXISTS, ASK, CONSTRUCT, DESCRIBE) raise a ``ValueError`` naming the
construct, never a bare ``KeyError``.  The optimizer itself consumes
``BGPQuery``; this module keeps examples and round-trip tests runnable
without external dependencies.
"""
from __future__ import annotations

import re

from repro.query.algebra import (
    And,
    BGPQuery,
    Bgp,
    Comparison,
    Const,
    Expr,
    Filter,
    GroupNode,
    Join,
    LeftJoin,
    Not,
    Or,
    Term,
    TriplePattern,
    Union,
    Var,
    from_algebra,
)
from repro.rdf.dictionary import TermDict, TermKind

_TOKEN = re.compile(
    r"\?[A-Za-z_][\w]*"          # variables
    r"|<[^>\s]*>"                # full IRIs (no whitespace => '<' stays an op)
    r"|\"[^\"]*\""               # string literals
    r"|[A-Za-z_][\w.\-]*:[\w.\-]*"  # prefixed names
    r"|&&|\|\||!=|<=|>=|[{}().!=<>*]"  # operators / punctuation
    r"|[A-Za-z_][\w]*",          # bare keywords (SELECT, OPTIONAL, ...)
)

_UNSUPPORTED = {"GRAPH", "SERVICE", "MINUS", "BIND", "VALUES", "EXISTS",
                "NOT", "ASK", "CONSTRUCT", "DESCRIBE"}


class _Parser:
    def __init__(self, tokens: list[str], dictionary: TermDict):
        self.toks = tokens
        self.i = 0
        self.d = dictionary

    def peek(self) -> str | None:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> str:
        if self.i >= len(self.toks):
            raise ValueError("unexpected end of query")
        tok = self.toks[self.i]
        self.i += 1
        return tok

    def expect(self, tok: str) -> None:
        got = self.peek()
        if got is None or got.upper() != tok.upper():
            raise ValueError(f"expected {tok!r} at token {self.i}: "
                             f"{self.toks[max(0, self.i - 2): self.i + 3]}")
        self.i += 1

    def _check_supported(self, tok: str) -> None:
        if tok.upper() in _UNSUPPORTED:
            raise ValueError(
                f"unsupported SPARQL construct '{tok.upper()}' — this subset "
                "covers BGPs, OPTIONAL, UNION and FILTER")

    # -- terms --------------------------------------------------------------
    def term(self, tok: str) -> Term:
        if tok.startswith("?"):
            return Var(tok[1:])
        if tok.startswith("<"):
            return Const(self.d.add(tok[1:-1], TermKind.IRI))
        if tok.startswith('"'):
            return Const(self.d.add(tok[1:-1], TermKind.LITERAL))
        if ":" in tok:  # prefixed name
            return Const(self.d.add(tok, TermKind.IRI))
        self._check_supported(tok)
        raise ValueError(f"expected a term, got {tok!r}")

    # -- filter expressions -------------------------------------------------
    def expr(self) -> Expr:
        return self._or_expr()

    def _or_expr(self) -> Expr:
        parts = [self._and_expr()]
        while self.peek() == "||":
            self.next()
            parts.append(self._and_expr())
        return parts[0] if len(parts) == 1 else Or(tuple(parts))

    def _and_expr(self) -> Expr:
        parts = [self._unary_expr()]
        while self.peek() == "&&":
            self.next()
            parts.append(self._unary_expr())
        return parts[0] if len(parts) == 1 else And(tuple(parts))

    def _unary_expr(self) -> Expr:
        tok = self.peek()
        if tok == "!":
            self.next()
            return Not(self._unary_expr())
        if tok == "(":
            self.next()
            e = self._or_expr()
            self.expect(")")
            return e
        lhs = self.term(self.next())
        op = self.next()
        if op == "=" or op == "!=" or op in ("<", "<=", ">", ">="):
            rhs = self.term(self.next())
            return Comparison(op, lhs, rhs)
        raise ValueError(f"expected a comparison operator, got {op!r}")

    # -- groups -------------------------------------------------------------
    def group(self) -> GroupNode:
        """Parse one ``{ ... }`` group (the opening brace is consumed by the
        caller)."""
        elements: list[GroupNode] = []
        filters: list[Expr] = []
        acc: list[Term] = []
        pats: list[TriplePattern] = []

        def flush_bgp() -> None:
            if acc:
                raise ValueError("dangling terms in BGP")
            if pats:
                elements.append(Bgp(tuple(pats)))
                pats.clear()

        while True:
            tok = self.peek()
            if tok is None:
                raise ValueError("unterminated group: missing '}'")
            up = tok.upper()
            if tok == "}":
                self.next()
                break
            if tok == ".":
                self.next()
                continue
            if up == "OPTIONAL":
                self.next()
                self.expect("{")
                arm = self.group()
                flush_bgp()
                if not elements:
                    base: GroupNode = Bgp(())
                elif len(elements) == 1:
                    base = elements.pop()
                else:
                    base = Join(tuple(elements))
                elements.clear()
                elements.append(LeftJoin(base, arm))
                continue
            if up == "FILTER":
                self.next()
                self.expect("(")
                filters.append(self.expr())
                self.expect(")")
                continue
            if tok == "{":
                self.next()
                g = self.group()
                while self.peek() is not None and self.peek().upper() == "UNION":
                    self.next()
                    self.expect("{")
                    g2 = self.group()
                    if isinstance(g, Union):
                        g = Union(g.members + (g2,))
                    else:
                        g = Union((g, g2))
                flush_bgp()
                elements.append(g)
                continue
            self._check_supported(tok)
            acc.append(self.term(self.next()))
            if len(acc) == 3:
                pats.append(TriplePattern(*acc))
                acc.clear()
        flush_bgp()
        if not elements:
            node: GroupNode = Bgp(())
        elif len(elements) == 1:
            node = elements[0]
        else:
            node = Join(tuple(elements))
        for e in filters:
            node = Filter(e, node)
        return node


def parse_sparql(text: str, dictionary: TermDict) -> BGPQuery:
    p = _Parser(_TOKEN.findall(text), dictionary)
    p.expect("SELECT")
    distinct = False
    if p.peek() is not None and p.peek().upper() == "DISTINCT":
        distinct = True
        p.next()
    projection: list[str] = []
    if p.peek() == "*":
        p.next()
    else:
        while p.peek() is not None and p.peek().startswith("?"):
            projection.append(p.next()[1:])
    p.expect("WHERE")
    p.expect("{")
    root = p.group()
    return from_algebra(root, distinct=distinct, projection=projection)


# --------------------------------------------------------------------------
# Serialization (the parser's inverse)
# --------------------------------------------------------------------------


def _ser_term(t: Term, d: TermDict) -> str:
    if isinstance(t, Var):
        return f"?{t.name}"
    assert isinstance(t, Const)
    text = d.term_of(t.tid)
    if d.kinds[t.tid] == int(TermKind.LITERAL):
        return f'"{text}"'
    if "://" in text or " " in text:
        return f"<{text}>"
    return text if ":" in text else f"<{text}>"


def _ser_expr(e: Expr, d: TermDict) -> str:
    if isinstance(e, Comparison):
        return f"{_ser_term(e.lhs, d)} {e.op} {_ser_term(e.rhs, d)}"
    if isinstance(e, And):
        return " && ".join(f"({_ser_expr(p, d)})" for p in e.parts)
    if isinstance(e, Or):
        return " || ".join(f"({_ser_expr(p, d)})" for p in e.parts)
    assert isinstance(e, Not)
    return f"!({_ser_expr(e.part, d)})"


def _ser_group(node: GroupNode, d: TermDict) -> str:
    """Serialize a group node to the *contents* of a braced group."""
    if isinstance(node, Bgp):
        return " . ".join(
            f"{_ser_term(tp.s, d)} {_ser_term(tp.p, d)} {_ser_term(tp.o, d)}"
            for tp in node.patterns)
    if isinstance(node, Join):
        return " ".join(f"{{ {_ser_group(c, d)} }}" for c in node.children)
    if isinstance(node, LeftJoin):
        left = _ser_group(node.left, d)
        # Filter must stay braced too: an unbraced trailing FILTER would
        # re-parse with the whole group (incl. the OPTIONAL) as its scope
        if isinstance(node.left, (Union, Join, Filter)):
            left = f"{{ {left} }}"
        return f"{left} OPTIONAL {{ {_ser_group(node.right, d)} }}"
    if isinstance(node, Union):
        return " UNION ".join(f"{{ {_ser_group(m, d)} }}" for m in node.members)
    assert isinstance(node, Filter)
    return f"{_ser_group(node.child, d)} FILTER ({_ser_expr(node.expr, d)})"


def serialize_sparql(query: BGPQuery, dictionary: TermDict) -> str:
    proj = " ".join(f"?{v}" for v in query.projection) if query.projection else "*"
    head = "SELECT DISTINCT" if query.distinct else "SELECT"
    return f"{head} {proj} WHERE {{ {_ser_group(query.algebra(), dictionary)} }}"
