from repro.query.algebra import Term, Var, Const, TriplePattern, BGPQuery
from repro.query.sparql import parse_sparql

__all__ = ["Term", "Var", "Const", "TriplePattern", "BGPQuery", "parse_sparql"]
