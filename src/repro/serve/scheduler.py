"""Shape-affine, deadline-driven admission and the background planning stage
of the continuous-batching query engine.

``AdmissionController`` groups queued requests by *plan-sharing affinity*:
each request's ``AffinityKey`` (``repro.core.batch_planner.plan_affinity``)
is matched against open groups tier by tier — exact signature, then
selection key, then pricing key, then DP shape key — and the request joins
the first (deepest) group it shares a tier with.  Grouping is purely a
batch-formation heuristic: ``optimize_batch`` re-derives the exact sharing
inside every batch, so membership can never change a plan, only how much of
the planning pipeline a batch amortizes.

Flushing is deadline-driven, not size-driven: a group becomes ripe when the
*earliest* member's admission deadline (``t_submit + slo``) expires, or
immediately when it accumulates a full batch.  ``next_batch(force=True)``
(the drain path) flushes the most urgent group regardless.

``PlannerWorker`` is the host-side planning stage of the two-stage pipeline:
it pulls ripe batches off the controller, runs ``optimize_batch``, and
pushes planned batches into the engine's bounded handoff queue — so planning
of batch *k+1* overlaps the caller's execution of batch *k*.  A worker that
dies records its exception on the engine, where it is re-raised to the
caller at the next ``submit``/``poll``/``drain``; it is never swallowed.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

from repro.core.batch_planner import AFFINITY_TIERS, AffinityKey


@dataclass
class _Group:
    """One open affinity group: members in arrival order, the earliest
    member's admission deadline, and the tier keys registered for it."""

    gid: int
    members: list = field(default_factory=list)
    flush_at: float = float("inf")
    keys: "list[tuple[int, tuple]]" = field(default_factory=list)


class AdmissionController:
    """Deadline-driven, affinity-grouped admission queue (module docstring).

    Not thread-safe on its own — the engine serializes access under its
    condition lock.
    """

    def __init__(self, max_group: int):
        if max_group < 1:
            raise ValueError(f"max_group must be >= 1, got {max_group}")
        self.max_group = max_group
        self._groups: "dict[int, _Group]" = {}     # insertion == creation order
        # tier index -> key -> gid (first-writer wins; cleaned up on close)
        self._tiers: "list[dict[tuple, int]]" = [{} for _ in AFFINITY_TIERS]
        self._next_gid = 0
        self._n = 0

    def __len__(self) -> int:
        return self._n

    def requests(self) -> list:
        """Every queued request (group creation order, members in arrival
        order within a group)."""
        return [r for g in self._groups.values() for r in g.members]

    def add(self, req, key: AffinityKey, flush_at: float) -> "str | None":
        """Queue ``req``; returns the tier name it matched an open group at
        (``'signature'`` > ``'selection'`` > ``'pricing'`` > ``'shape'``),
        or ``None`` when it founded a new group."""
        matched: "str | None" = None
        group: "_Group | None" = None
        for ti, (name, k) in enumerate(key.tier_keys()):
            gid = self._tiers[ti].get(k)
            if gid is not None:
                group, matched = self._groups[gid], name
                break
        if group is None:
            group = _Group(gid=self._next_gid)
            self._next_gid += 1
            self._groups[group.gid] = group
        group.members.append(req)
        group.flush_at = min(group.flush_at, flush_at)
        # register this member's keys at every still-unclaimed tier, so a
        # later request matching *it* (not the founder) still finds the group
        for ti, (name, k) in enumerate(key.tier_keys()):
            if k not in self._tiers[ti]:
                self._tiers[ti][k] = group.gid
                group.keys.append((ti, k))
        self._n += 1
        return matched

    def next_flush_at(self) -> "float | None":
        if not self._groups:
            return None
        return min(g.flush_at for g in self._groups.values())

    def ripe(self, now: float) -> bool:
        return any(len(g.members) >= self.max_group or g.flush_at <= now
                   for g in self._groups.values())

    def next_batch(self, now: float,
                   force: bool = False) -> "tuple[list, str] | None":
        """Flush the most urgent group: full groups first (creation order),
        then the earliest expired deadline; under ``force``, the earliest
        deadline regardless.  Returns ``(members, reason)`` with ``reason``
        in ``('full', 'deadline', 'forced')``, or ``None`` when nothing is
        ripe."""
        chosen: "_Group | None" = None
        reason = ""
        for g in self._groups.values():
            if len(g.members) >= self.max_group:
                chosen, reason = g, "full"
                break
        if chosen is None:
            expired = [g for g in self._groups.values() if g.flush_at <= now]
            if expired:
                chosen = min(expired, key=lambda g: g.flush_at)
                reason = "deadline"
            elif force and self._groups:
                chosen = min(self._groups.values(), key=lambda g: g.flush_at)
                reason = "forced"
        if chosen is None:
            return None
        batch = chosen.members[:self.max_group]
        del chosen.members[:len(batch)]
        self._n -= len(batch)
        if chosen.members:
            # overflow remainder keeps the group (and its registrations);
            # its urgency re-derives from the members left behind
            chosen.flush_at = min(r.deadline for r in chosen.members)
        else:
            for ti, k in chosen.keys:
                if self._tiers[ti].get(k) == chosen.gid:
                    del self._tiers[ti][k]
            del self._groups[chosen.gid]
        return batch, reason


class ArrivalQueue:
    """Legacy arrival-order admission with the same interface: one FIFO, a
    batch is the first ``max_group`` requests, ripe when full or when the
    head-of-line deadline expires.  This is the drain-loop policy the
    affinity controller replaces; kept as the benchmark baseline
    (``admission='arrival'``)."""

    def __init__(self, max_group: int):
        self.max_group = max_group
        self._fifo: list = []

    def __len__(self) -> int:
        return len(self._fifo)

    def requests(self) -> list:
        return list(self._fifo)

    def add(self, req, key, flush_at: float) -> None:
        self._fifo.append(req)
        return None

    def next_flush_at(self) -> "float | None":
        return self._fifo[0].deadline if self._fifo else None

    def ripe(self, now: float) -> bool:
        return (len(self._fifo) >= self.max_group
                or (bool(self._fifo) and self._fifo[0].deadline <= now))

    def next_batch(self, now: float,
                   force: bool = False) -> "tuple[list, str] | None":
        if not self._fifo:
            return None
        if len(self._fifo) >= self.max_group:
            reason = "full"
        elif self._fifo[0].deadline <= now:
            reason = "deadline"
        elif force:
            reason = "forced"
        else:
            return None
        batch = self._fifo[:self.max_group]
        del self._fifo[:len(batch)]
        return batch, reason


class PlannerWorker(threading.Thread):
    """Background planning stage (module docstring): admission -> plan ->
    bounded handoff.  One worker per engine; the optimizer is touched by
    this thread only, so the plan cache needs no locking."""

    # worker liveness poll while waiting on a flush deadline or a full
    # handoff queue; real-time bound even under a simulated engine clock
    _WAIT_S = 0.02

    def __init__(self, engine):
        super().__init__(name="query-serve-planner", daemon=True)
        self.engine = engine

    def run(self) -> None:
        eng = self.engine
        try:
            while True:
                with eng._cond:
                    got = None
                    while got is None:
                        if eng._stopping and not len(eng._admission):
                            return
                        now = eng._clock()
                        force = eng._force_flush or eng._stopping
                        got = eng._admission.next_batch(now, force=force)
                        if got is None:
                            eng._cond.wait(self._WAIT_S)
                    batch, reason = got
                    eng._note_flush(reason)
                    eng._cond.notify_all()     # submit() may unblock now
                eng._plan_batch(batch)         # outside the lock: the overlap
                with eng._cond:
                    while (len(eng._handoff) >= eng.handoff_depth
                           and not eng._stopping):
                        eng._cond.wait(self._WAIT_S)
                    eng._handoff.append(batch)
                    eng._cond.notify_all()
        except BaseException as e:  # repro: ignore[RPR102] -- worker death
            # must reach the caller, not a thread traceback: the exception is
            # recorded here and re-raised by the engine on the next submit()/
            # poll()/drain() (tested: test_serve_scheduler.py worker-death)
            with eng._cond:
                eng._worker_error = e
                eng._cond.notify_all()
