"""Continuous-batching federated-query serving: shape-affine deadline-driven
admission, a two-stage plan/execute pipeline, and streaming completion.

``QueryServeEngine`` is the query-side sibling of the token-serving
``ServeEngine`` and shares its surface (``repro.serve.base.ServeBase``):
``submit(query, deadline=None)`` enqueues under a per-request latency SLO,
``poll()`` streams completions out as they finish, ``drain()`` runs the
queue dry.  Three layers turn that surface into throughput:

1. **Shape-affine admission** (``repro.serve.scheduler``): queued requests
   are grouped by plan-sharing affinity key — exact signature > selection
   key > pricing key > DP shape key, the exact tiering
   ``repro.core.batch_planner`` exploits — and a group is flushed when its
   earliest member's deadline budget expires or it fills a batch.
   Deadline-driven, not size-driven: a lone request never waits past its
   SLO for batch-mates that are not coming, and a templated burst lands in
   *one* ``optimize_batch`` call instead of arrival-order fragments.
2. **Plan/execute overlap** (``pipeline=True``): a background planner
   thread runs host-side ``optimize_batch`` for batch *k+1* while the
   caller executes batch *k*, handing planned batches over a bounded queue.
   Past the admission watermark ``submit`` rejects or blocks
   (``queue_depth``/``backpressure``); a dead worker re-raises at the next
   call, never silently.
3. **Batched planning** underneath is unchanged: plan-cache hits and exact
   duplicates rebound per request, the rest share one source-selection pass
   and one DP sweep per shape (``dp_backend='jax'`` routes shape groups
   through the device-resident ``repro.kernels.dp_layer`` program).

Scheduling never changes answers: per-request plans and rows are
bit-identical to the synchronous arrival-order ``step()`` loop
(differentially tested), because ``optimize_batch`` is bit-identical to the
sequential ``optimize`` loop regardless of how batches are cut.

See docs/serving.md for the admission policy, SLO semantics and the
migration notes.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass

from repro.core.batch_planner import plan_affinity
from repro.core.cost import CostModel
from repro.core.federation import FederatedStats
from repro.core.planner import OdysseyOptimizer, PhysicalPlan
from repro.engine.local import ExecutionMetrics, LocalEngine
from repro.query.algebra import BGPQuery
from repro.rdf.dataset import Federation
from repro.serve.base import (
    BackpressureError,
    ServeStats,
    warn_run_until_done,
)
from repro.serve.scheduler import AdmissionController, ArrivalQueue, PlannerWorker


@dataclass
class QueryRequest:
    qid: int
    query: BGPQuery
    plan: PhysicalPlan | None = None
    rows: dict | None = None
    metrics: ExecutionMetrics | None = None
    done: bool = False
    cached: bool = False               # plan served from the plan cache
    stats_epoch: int = 0               # epoch the plan was emitted under
    slo: float = 0.0                   # admission deadline budget (seconds)
    deadline: float = 0.0              # absolute flush-by time (t_submit + slo)
    affinity_tier: str | None = None   # deepest tier shared with its group
    plan_ms: float = 0.0               # this request's own planning cost
    t_submit: float = 0.0
    t_planned: float = 0.0
    t_done: float = 0.0

    def planning_latency_s(self) -> float:
        """Submission-to-plan latency as this request experienced it: queue
        wait plus its *own* planning attribution — a cache hit is charged
        its rebind, not the batch's whole planning window."""
        return max(0.0, self.t_planned - self.t_submit)


class QueryServeEngine:
    """Continuous batching for federated queries (module docstring).

    Modes:

    - ``admission='affinity'`` (default): shape-affine deadline-driven
      admission; ``'arrival'`` keeps the legacy arrival-order FIFO.
    - ``pipeline=False`` (default): synchronous — ``step()``/``poll()``/
      ``drain()`` plan and execute in the caller's thread.  ``True`` starts
      the background planner thread; use ``poll()``/``drain()`` (``step()``
      would race the worker and raises).
    - ``queue_depth``: admission watermark (requests waiting for planning);
      past it ``submit`` raises ``BackpressureError`` when
      ``backpressure='reject'`` or waits when ``'block'`` (pipeline mode
      only — in synchronous mode nothing drains the queue concurrently, so
      blocking would deadlock).

    ``deadline`` on ``submit`` is a per-request SLO budget in seconds; it
    bounds how long admission may hold the request waiting for batch-mates
    (``default_slo_ms`` when absent).  Planning and execution latency come
    on top; the serving benchmark measures the end-to-end distribution.
    """

    def __init__(self, fed: Federation, stats: FederatedStats,
                 max_batch: int = 64, plan_cache_size: int = 1024,
                 cost_model: CostModel | None = None, engine=None,
                 dp_backend: str = "numpy",
                 admission: str = "affinity",
                 default_slo_ms: float = 25.0,
                 queue_depth: int | None = None,
                 backpressure: str = "reject",
                 pipeline: bool = False,
                 handoff_depth: int = 2,
                 feedback=None,
                 clock=time.perf_counter):
        if admission not in ("affinity", "arrival"):
            raise ValueError(f"admission must be 'affinity' or 'arrival', "
                             f"got {admission!r}")
        if backpressure not in ("reject", "block"):
            raise ValueError(f"backpressure must be 'reject' or 'block', "
                             f"got {backpressure!r}")
        if backpressure == "block" and not pipeline:
            raise ValueError(
                "backpressure='block' requires pipeline=True: in synchronous "
                "mode nothing drains the admission queue while submit waits, "
                "so a blocked submit could never resume")
        if handoff_depth < 1:
            raise ValueError(f"handoff_depth must be >= 1, got {handoff_depth}")
        self.optimizer = OdysseyOptimizer(stats, cost_model=cost_model,
                                          plan_cache_size=plan_cache_size,
                                          dp_backend=dp_backend)
        self.engine = engine if engine is not None else LocalEngine(fed)
        # optional repro.stats.feedback.CardinalityFeedback: executions feed
        # observed cardinalities in (_execute_batch, any thread), and drifted
        # sources are refreshed at the top of the next planning batch
        # (_plan_batch — the only code that touches the optimizer/statistics)
        self.feedback = feedback
        self.max_batch = max_batch
        self.admission = admission
        self.default_slo = default_slo_ms * 1e-3
        self.queue_depth = queue_depth
        self.backpressure = backpressure
        self.pipeline = pipeline
        self.handoff_depth = handoff_depth
        self.finished: list[QueryRequest] = []
        self.serve_stats = ServeStats()
        self._clock = clock
        self._cond = threading.Condition()
        self._admission = (AdmissionController(max_batch)
                           if admission == "affinity"
                           else ArrivalQueue(max_batch))
        self._handoff: deque = deque()       # planned batches awaiting execution
        self._unpolled: list[QueryRequest] = []
        self._n_pending = 0                  # submitted and not yet finished
        self._next_qid = 0
        self._force_flush = False
        self._stopping = False
        self._worker_error: BaseException | None = None
        self._worker: PlannerWorker | None = None
        if pipeline:
            self._worker = PlannerWorker(self)
            self._worker.start()

    # -- introspection -------------------------------------------------------
    @property
    def queue(self) -> "list[QueryRequest]":
        """Requests still waiting for planning, in submission order (planned
        or in-flight requests are no longer on the queue)."""
        return sorted(self._admission.requests(), key=lambda r: r.qid)

    def _raise_worker_error(self) -> None:
        if self._worker_error is not None:
            err = self._worker_error
            raise RuntimeError(
                "the background planner thread died; the engine cannot make "
                "progress (original exception chained)") from err

    def _note_flush(self, reason: str) -> None:
        """Stats for one flushed batch — called with the lock held."""
        if reason == "full":
            self.serve_stats.n_full_flushes += 1
        elif reason == "deadline":
            self.serve_stats.n_deadline_flushes += 1
        else:
            self.serve_stats.n_forced_flushes += 1

    # -- admission -----------------------------------------------------------
    def submit(self, query: BGPQuery,
               deadline: "float | None" = None) -> QueryRequest:
        """Enqueue one query under a latency SLO of ``deadline`` seconds
        (``default_slo_ms`` when ``None``).  Raises ``BackpressureError``
        (or blocks, per ``backpressure``) at the queue-depth watermark."""
        key = plan_affinity(query) if self.admission == "affinity" else None
        with self._cond:
            self._raise_worker_error()
            if self.queue_depth is not None \
                    and len(self._admission) >= self.queue_depth:
                if self.backpressure == "reject":
                    self.serve_stats.n_rejected += 1
                    raise BackpressureError(
                        f"admission queue at its watermark "
                        f"({len(self._admission)} >= {self.queue_depth}); "
                        f"retry after draining or raise queue_depth")
                self.serve_stats.n_blocked += 1
                while len(self._admission) >= self.queue_depth:
                    self._cond.wait(0.02)
                    self._raise_worker_error()
            now = self._clock()
            slo = self.default_slo if deadline is None else float(deadline)
            req = QueryRequest(qid=self._next_qid, query=query, slo=slo,
                               deadline=now + slo, t_submit=now)
            self._next_qid += 1
            req.affinity_tier = self._admission.add(req, key, req.deadline)
            self._n_pending += 1
            self._cond.notify_all()
        return req

    # -- the two pipeline stages --------------------------------------------
    def _plan_batch(self, batch: "list[QueryRequest]") -> None:
        """Plan one admitted batch through ``optimize_batch`` and stamp
        per-request attribution.  In pipeline mode this runs on the worker
        thread (the only thread that touches the optimizer)."""
        if self.feedback is not None:
            # planner thread == the only safe place to mutate the statistics;
            # each refresh bumps the epoch, so the plan cache retires exactly
            # the entries priced under the drifted source
            applied = self.feedback.apply_pending()
            if applied:
                with self._cond:
                    self.serve_stats.n_stats_refreshes += len(applied)
        t0 = self._clock()
        plans = self.optimizer.optimize_batch([r.query for r in batch])
        t1 = self._clock()
        report = self.optimizer.last_batch_report
        for req, plan in zip(batch, plans):
            req.plan = plan
            req.cached = plan.cached
            req.stats_epoch = plan.stats_epoch
            req.plan_ms = plan.optimization_ms
            # per-request attribution: a plan-cache hit (or in-batch
            # duplicate) was ready after its own ~50us rebind — charging it
            # the whole batch's planning window (the old shared `t1` stamp)
            # made hits look as slow as cold plans in the latency bench
            if plan.cached:
                req.t_planned = min(t0 + plan.optimization_ms * 1e-3, t1)
            else:
                req.t_planned = t1
        with self._cond:
            self.serve_stats.plan_ms += (t1 - t0) * 1e3
            self.serve_stats.plan_cache_hits += (report.cache_hits
                                                 + report.duplicates)
            self.serve_stats.n_planned += report.n_planned
            self.serve_stats.n_shapes += report.n_shapes

    def _execute_batch(self, batch: "list[QueryRequest]") -> None:
        """Execute one planned batch in the caller's thread; completions
        land on ``finished`` and the unpolled buffer."""
        t0 = self._clock()
        for req in batch:
            res = self.engine.execute(req.plan)
            req.rows, req.metrics = res.rows, res.metrics
            if self.feedback is not None:
                self.feedback.observe_result(res)   # thread-safe
            req.done = True
            req.t_done = self._clock()
        with self._cond:
            self.serve_stats.exec_ms += (self._clock() - t0) * 1e3
            self.serve_stats.n_served += len(batch)
            self.serve_stats.n_steps += 1
            self.finished.extend(batch)
            self._unpolled.extend(batch)
            self._n_pending -= len(batch)
            self._cond.notify_all()

    def _take_unpolled(self) -> "list[QueryRequest]":
        with self._cond:
            out, self._unpolled = self._unpolled, []
        return out

    # -- synchronous quantum -------------------------------------------------
    def step(self) -> "list[QueryRequest]":
        """Synchronously flush the most urgent batch (deadline expired or
        not), plan it, execute it.  Returns the newly completed requests
        (anything finished since the last report, exactly once)."""
        if self.pipeline:
            raise RuntimeError(
                "step() is the synchronous scheduling quantum; with "
                "pipeline=True the planner thread owns batch formation — "
                "use poll()/drain()")
        self._raise_worker_error()
        with self._cond:
            got = self._admission.next_batch(self._clock(), force=True)
            if got is not None:
                self._note_flush(got[1])
        if got is not None:
            batch, _ = got
            self._plan_batch(batch)
            self._execute_batch(batch)
        return self._take_unpolled()

    # -- streaming completion ------------------------------------------------
    def poll(self) -> "list[QueryRequest]":
        """Non-blocking streaming completion: service whatever is ripe
        (synchronous mode) or already planned (pipeline mode), then return
        the requests that finished since the last report — each exactly
        once."""
        self._raise_worker_error()
        if self.pipeline:
            while True:
                with self._cond:
                    if not self._handoff:
                        break
                    batch = self._handoff.popleft()
                    self._cond.notify_all()    # handoff slot freed
                self._execute_batch(batch)
            self._raise_worker_error()
        else:
            while True:
                with self._cond:
                    got = self._admission.next_batch(self._clock(), force=False)
                    if got is not None:
                        self._note_flush(got[1])
                if got is None:
                    break
                batch, _ = got
                self._plan_batch(batch)
                self._execute_batch(batch)
        return self._take_unpolled()

    def completed(self):
        """Iterator form of ``poll``: yields requests as they complete until
        everything submitted so far has been reported."""
        while True:
            with self._cond:
                pending = self._n_pending or self._unpolled
            if not pending:
                return
            yield from self.poll()

    # -- drain ---------------------------------------------------------------
    def drain(self, max_steps: int = 10_000) -> "list[QueryRequest]":
        """Run until everything submitted has completed; returns only the
        requests completed by *this* call (cumulative history stays on
        ``self.finished``).  Raises ``RuntimeError`` if ``max_steps``
        batches are exhausted with requests still queued — a partial drain
        must not be mistakable for a full one (the leftover stays on
        ``self.queue``; callers can inspect it and drain again)."""
        done: "list[QueryRequest]" = []
        steps = 0
        if not self.pipeline:
            while self._n_pending and steps < max_steps:
                done.extend(self.step())
                steps += 1
        else:
            with self._cond:
                self._force_flush = True
                self._cond.notify_all()
            try:
                while steps < max_steps:
                    with self._cond:
                        if not self._n_pending:
                            break
                        self._raise_worker_error()
                        if not self._handoff:
                            self._cond.wait(0.02)
                            continue
                        batch = self._handoff.popleft()
                        self._cond.notify_all()
                    self._execute_batch(batch)
                    steps += 1
                done.extend(self._take_unpolled())
            finally:
                with self._cond:
                    self._force_flush = False
        if self._n_pending:
            raise RuntimeError(
                f"drain gave up after {max_steps} steps with "
                f"{self._n_pending} request(s) still queued ({len(done)} "
                f"completed this call; the leftover stays on .queue)")
        return done

    def run_until_done(self, max_steps: int = 10_000) -> "list[QueryRequest]":
        """Deprecated: thin wrapper over ``drain`` (same return value, same
        partial-drain ``RuntimeError`` contract)."""
        warn_run_until_done(type(self).__name__)
        return self.drain(max_steps=max_steps)

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Stop the background planner thread (no-op in synchronous mode).
        Queued-but-unplanned requests stay queued; a later ``close`` is
        idempotent."""
        if self._worker is None:
            return
        with self._cond:
            self._stopping = True
            self._cond.notify_all()
        self._worker.join(timeout=10.0)
        self._worker = None

    def __enter__(self) -> "QueryServeEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
