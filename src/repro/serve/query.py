"""Batched federated-query serving: micro-batching admission over the
truly batched planner.

``QueryServeEngine`` is the query-side sibling of the token-serving
``ServeEngine``: requests accumulate in an admission queue, and every
``step()`` drains up to ``max_batch`` of them through **one**
``OdysseyOptimizer.optimize_batch`` call — plan-cache hits and exact
duplicates rebound per request, the rest sharing a single source-selection
pass and one DP sweep per structural shape (``repro.core.batch_planner``) —
then executes the plans.  The host-side scheduler stays tiny; the batched
planning pipeline is where the sharing happens, exactly as the jitted decode
step is for tokens.

A structurally repetitive stream (the FedBench/templated-workload serving
case) therefore pays per *shape*, not per query, for planning — and on top
of that, warm steady-state traffic is absorbed by the optimizer's epoch-
keyed plan cache across steps.  ``dp_backend='jax'`` routes every shape
group's DP sweep through the device-resident ``repro.kernels.dp_layer``
sweep program (plans stay bit-identical; see docs/planner.md "On-device
DP sweep").
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.cost import CostModel
from repro.core.federation import FederatedStats
from repro.core.planner import OdysseyOptimizer, PhysicalPlan
from repro.engine.local import ExecutionMetrics, LocalEngine
from repro.query.algebra import BGPQuery
from repro.rdf.dataset import Federation


@dataclass
class QueryRequest:
    qid: int
    query: BGPQuery
    plan: PhysicalPlan | None = None
    rows: dict | None = None
    metrics: ExecutionMetrics | None = None
    done: bool = False
    cached: bool = False               # plan served from the plan cache
    stats_epoch: int = 0               # epoch the plan was emitted under
    t_submit: float = 0.0
    t_planned: float = 0.0
    t_done: float = 0.0


@dataclass
class ServeStats:
    """Cumulative serving counters (across all steps)."""

    n_served: int = 0
    n_steps: int = 0
    plan_cache_hits: int = 0           # incl. in-batch exact duplicates
    n_planned: int = 0                 # requests that ran the full pipeline
    n_shapes: int = 0                  # shape groups swept (summed over steps)
    plan_ms: float = 0.0
    exec_ms: float = 0.0


class QueryServeEngine:
    """Continuous micro-batching for federated queries: ``submit`` enqueues,
    ``step`` plans one admission batch through the batched planner and
    executes it, ``run_until_done`` drains the queue."""

    def __init__(self, fed: Federation, stats: FederatedStats,
                 max_batch: int = 64, plan_cache_size: int = 1024,
                 cost_model: CostModel | None = None, engine=None,
                 dp_backend: str = "numpy"):
        self.optimizer = OdysseyOptimizer(stats, cost_model=cost_model,
                                          plan_cache_size=plan_cache_size,
                                          dp_backend=dp_backend)
        self.engine = engine if engine is not None else LocalEngine(fed)
        self.max_batch = max_batch
        self.queue: list[QueryRequest] = []
        self.finished: list[QueryRequest] = []
        self.serve_stats = ServeStats()
        self._next_qid = 0

    def submit(self, query: BGPQuery) -> QueryRequest:
        req = QueryRequest(qid=self._next_qid, query=query,
                           t_submit=time.perf_counter())
        self._next_qid += 1
        self.queue.append(req)
        return req

    def step(self) -> "list[QueryRequest]":
        """Admit up to ``max_batch`` queued requests, plan them as one batch,
        execute the plans.  Returns the requests completed by this step."""
        if not self.queue:
            return []
        admitted = self.queue[:self.max_batch]
        del self.queue[:len(admitted)]

        t0 = time.perf_counter()
        plans = self.optimizer.optimize_batch([r.query for r in admitted])
        t1 = time.perf_counter()
        report = self.optimizer.last_batch_report
        self.serve_stats.plan_ms += (t1 - t0) * 1e3
        self.serve_stats.plan_cache_hits += report.cache_hits + report.duplicates
        self.serve_stats.n_planned += report.n_planned
        self.serve_stats.n_shapes += report.n_shapes

        # planning finished for every admitted request at t1: stamp before
        # execution starts, so (t_planned - t_submit) is planning latency and
        # never includes queue-mates' execution time
        for req, plan in zip(admitted, plans):
            req.plan = plan
            req.cached = plan.cached
            req.stats_epoch = plan.stats_epoch
            req.t_planned = t1
        for req in admitted:
            req.rows, req.metrics = self.engine.execute(req.plan)
            req.done = True
            req.t_done = time.perf_counter()
            self.finished.append(req)
        self.serve_stats.exec_ms += (time.perf_counter() - t1) * 1e3
        self.serve_stats.n_served += len(admitted)
        self.serve_stats.n_steps += 1
        return admitted

    def run_until_done(self, max_steps: int = 10_000) -> "list[QueryRequest]":
        """Drain the queue; returns only the requests completed by *this*
        call (the cumulative history stays on ``self.finished`` — returning
        it here would let a second call re-report, and double-count,
        requests finished earlier).

        Raises ``RuntimeError`` if ``max_steps`` is exhausted with requests
        still queued — a partial drain must not be mistakable for a full
        one (the undrained requests stay on ``self.queue``; callers can
        inspect them and call again)."""
        done: "list[QueryRequest]" = []
        steps = 0
        while self.queue and steps < max_steps:
            done.extend(self.step())
            steps += 1
        if self.queue:
            raise RuntimeError(
                f"run_until_done gave up after {max_steps} steps with "
                f"{len(self.queue)} request(s) still queued ({len(done)} "
                f"completed this call; the leftover stays on .queue)")
        return done
