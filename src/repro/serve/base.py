"""The unified serving surface shared by the token engine and the query
engine.

Both engines expose the same four verbs over the same stats shape
(``ServeBase``):

- ``submit(item, deadline=None)`` — enqueue one request.  ``deadline`` is a
  per-request SLO *budget in seconds* (relative to submission); ``None``
  takes the engine's default latency target.  The admission layer may hold a
  request up to its deadline waiting for batch-mates; past a configured
  queue-depth watermark, ``submit`` rejects (``BackpressureError``) or
  blocks, with counters on ``ServeStats``.
- ``step()`` — synchronously advance the engine by one scheduling quantum
  (one admitted batch for queries, one decode token for the LM).
- ``poll()`` — streaming completion: return the requests that finished since
  the last ``step()``/``poll()``/``drain()`` report.  A request is reported
  exactly once across all three verbs; the cumulative history stays on
  ``.finished``.
- ``drain(max_steps=...)`` — run until everything submitted has completed
  and return the requests completed by this call.  Exhausting ``max_steps``
  with work still pending raises ``RuntimeError`` (a partial drain must not
  be mistakable for a full one); the leftover stays queued.

``run_until_done`` survives as a thin deprecated wrapper over ``drain`` with
the identical partial-drain contract.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Protocol, runtime_checkable


class BackpressureError(RuntimeError):
    """``submit`` rejected: the admission queue is at its watermark."""


@dataclass
class ServeStats:
    """Cumulative serving counters — one shape for every engine.  The token
    engine leaves the planner fields at zero; the query engine fills them
    from ``BatchPlanReport``."""

    n_served: int = 0                  # requests completed
    n_steps: int = 0                   # scheduling quanta executed
    n_rejected: int = 0                # submits rejected at the watermark
    n_blocked: int = 0                 # submits that waited at the watermark
    n_deadline_flushes: int = 0        # batches flushed by an expiring SLO
    n_full_flushes: int = 0            # batches flushed by a full group
    n_forced_flushes: int = 0          # batches flushed by step()/drain()
    plan_cache_hits: int = 0           # incl. in-batch exact duplicates
    n_planned: int = 0                 # requests that ran the full pipeline
    n_shapes: int = 0                  # shape groups swept (summed over steps)
    n_stats_refreshes: int = 0         # feedback-triggered refresh_source calls
    plan_ms: float = 0.0
    exec_ms: float = 0.0


@runtime_checkable
class ServeBase(Protocol):
    """Structural protocol of a serving engine (see the module docstring).
    ``ServeEngine`` and ``QueryServeEngine`` both satisfy it."""

    serve_stats: ServeStats

    def submit(self, item, deadline: "float | None" = None): ...

    def step(self): ...

    def poll(self) -> list: ...

    def drain(self, max_steps: int = 10_000) -> list: ...


def warn_run_until_done(cls_name: str) -> None:
    """The shared deprecation notice behind both engines' wrappers."""
    warnings.warn(
        f"{cls_name}.run_until_done is deprecated; call drain() "
        "(same semantics, including the partial-drain RuntimeError)",
        DeprecationWarning, stacklevel=3)
