from repro.serve.base import BackpressureError, ServeBase, ServeStats
from repro.serve.engine import Request, ServeEngine
from repro.serve.query import QueryRequest, QueryServeEngine
from repro.serve.scheduler import AdmissionController, ArrivalQueue

__all__ = [
    "AdmissionController",
    "ArrivalQueue",
    "BackpressureError",
    "QueryRequest",
    "QueryServeEngine",
    "Request",
    "ServeBase",
    "ServeEngine",
    "ServeStats",
]
