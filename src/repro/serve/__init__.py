from repro.serve.engine import ServeEngine
from repro.serve.query import QueryServeEngine

__all__ = ["ServeEngine", "QueryServeEngine"]
