"""Batched serving engine: continuous-batching-lite over the decode step.

Requests join/leave a fixed slot grid (B slots × S_ctx cache); each engine
step decodes one token for every active slot. Slot admission, greedy sampling,
EOS retirement and per-request accounting live host-side; the device step is
the jitted ``decode_step`` of the arch. This mirrors production TPU serving:
a static-shaped device program + a tiny host scheduler.

The engine exposes the shared serving surface (``repro.serve.base``):
``submit(req, deadline=None)`` — the deadline budget orders slot admission
(earliest absolute deadline first; FIFO among equals) — plus ``step()``,
``poll()``, ``drain()``, and ``serve_stats``.  ``run_until_done`` is a
deprecated wrapper over ``drain()``.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ArchConfig
from repro.models import model as MDL
from repro.serve.base import ServeStats, warn_run_until_done


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    slot: int = -1
    done: bool = False
    truncated: bool = False          # prompt clamped to the slot cache
    deadline: float = 0.0            # absolute admission priority (t_submit + slo)
    t_submit: float = 0.0
    t_done: float = 0.0


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, n_slots: int = 4,
                 ctx_len: int = 128, eos: int | None = None,
                 use_prefill: bool = False, overflow: str = "reject",
                 default_slo_ms: float = 60_000.0):
        if overflow not in ("reject", "truncate"):
            raise ValueError(f"overflow must be 'reject' or 'truncate', got {overflow!r}")
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.ctx = ctx_len
        self.eos = eos
        self.overflow = overflow
        self.default_slo = default_slo_ms * 1e-3
        self.serve_stats = ServeStats()
        self._reported = 0               # finished[: _reported] already returned
        # prefill admission: run the whole prompt in one full-seq pass and
        # seed the slot's cache (decoder-only archs)
        self.use_prefill = use_prefill and not cfg.encdec
        self.caches = MDL.init_decode_caches(cfg, n_slots, ctx_len, jnp.float32)
        self.pos = np.zeros(n_slots, np.int32)           # next write index
        self.active: dict[int, Request] = {}             # slot -> request
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._step = jax.jit(
            lambda p, c, t, pos: MDL.decode_step(cfg, p, c, t, pos))
        self._prefill = jax.jit(
            lambda p, t: MDL.prefill_with_caches(cfg, p, t, ctx_len))

    # -- host scheduler ------------------------------------------------------
    def submit(self, req: Request, deadline: "float | None" = None) -> None:
        """Enqueue one request.  ``deadline`` is the request's SLO budget in
        seconds; slot admission picks the earliest absolute deadline first
        (FIFO among requests sharing the default)."""
        # the slot cache holds positions 0..ctx-1 and the decode loop retires
        # a slot at pos == ctx-1, so a prompt may occupy at most ctx-1 lines
        # (leaving >= 1 decode step); anything longer would run `pos` off the
        # cache grid and scatter out of bounds
        limit = self.ctx - 1
        if len(req.prompt) > limit:
            if self.overflow == "reject":
                raise ValueError(
                    f"prompt of {len(req.prompt)} tokens exceeds the slot cache "
                    f"(ctx_len={self.ctx}, max prompt {limit}); shorten it or "
                    f"construct the engine with overflow='truncate'")
            req.prompt = req.prompt[-limit:]    # keep the newest context
            req.truncated = True
        req.t_submit = time.perf_counter()
        slo = self.default_slo if deadline is None else float(deadline)
        req.deadline = req.t_submit + slo
        self.queue.append(req)

    def _place_slot(self, slot: int, pre_caches) -> None:
        """Copy a B=1 prefill cache into one slot of the batched caches.
        Leaves under 'groups' carry a leading scan-group dim: batch is axis 1
        there, axis 0 elsewhere."""
        def place(path, c_all, c_pre):
            in_groups = any(str(getattr(k, "key", k)) == "groups" for k in path)
            if in_groups:
                return c_all.at[:, slot].set(c_pre[:, 0].astype(c_all.dtype))
            return c_all.at[slot].set(c_pre[0].astype(c_all.dtype))

        self.caches = jax.tree_util.tree_map_with_path(place, self.caches,
                                                       pre_caches)

    def _admit(self) -> None:
        free = [s for s in range(self.n_slots) if s not in self.active]
        while free and self.queue:
            slot = free.pop(0)
            # earliest-deadline-first; ties keep submission order (stable min)
            nxt = min(range(len(self.queue)),
                      key=lambda i: (self.queue[i].deadline, i))
            req = self.queue.pop(nxt)
            req.slot = slot
            self.active[slot] = req
            self.pos[slot] = 0
            if self.use_prefill and len(req.prompt) > 1:
                toks = jnp.asarray([req.prompt], jnp.int32)
                logits, pre = self._prefill(self.params, toks)
                self._place_slot(slot, pre)
                self.pos[slot] = len(req.prompt)
                tok = int(jnp.argmax(logits[0, -1]))
                req.out.append(tok)
                if (len(req.out) >= req.max_new
                        or (self.eos is not None and tok == self.eos)):
                    self._retire(slot, req)
                    free.insert(0, slot)

    def step(self) -> None:
        """Advance every active slot by one token."""
        self._admit()
        if not self.active:
            return
        self.serve_stats.n_steps += 1
        toks = np.zeros((self.n_slots, 1), np.int32)
        for slot, req in self.active.items():
            consumed = int(self.pos[slot])
            if consumed < len(req.prompt):
                toks[slot, 0] = req.prompt[consumed]
            else:
                toks[slot, 0] = req.out[-1] if req.out else 0
        # per-slot position vector: slots progress independently (idle slots
        # write harmlessly at their own position 0 and are never read)
        logits, self.caches = self._step(self.params, self.caches,
                                         jnp.asarray(toks),
                                         jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for slot, req in list(self.active.items()):
            self.pos[slot] += 1
            if self.pos[slot] >= len(req.prompt):
                tok = int(nxt[slot])
                req.out.append(tok)
                if (len(req.out) >= req.max_new
                        or (self.eos is not None and tok == self.eos)
                        or self.pos[slot] >= self.ctx - 1):
                    self._retire(slot, req)
            elif self.pos[slot] >= self.ctx - 1:
                # prompt longer than the slot cache: retire before `pos` runs
                # off the grid (defense in depth — ``submit`` clamps/rejects)
                req.truncated = True
                self._retire(slot, req)

    def _retire(self, slot: int, req: Request) -> None:
        req.done = True
        req.t_done = time.perf_counter()
        self.serve_stats.n_served += 1
        self.finished.append(req)
        del self.active[slot]
        # reset the slot's position: `step` passes the whole `pos` vector to
        # decode_step, so a freed slot with a stale pos (up to ctx-1) would
        # scatter its dummy token into freed cache lines instead of holding
        # the stated "idle slots write at their own position 0" invariant
        self.pos[slot] = 0

    def _take_new(self) -> list[Request]:
        """Completions not yet reported by ``poll``/``drain`` — each request
        is reported exactly once across both."""
        out = self.finished[self._reported:]
        self._reported = len(self.finished)
        return out

    def poll(self) -> list[Request]:
        """Streaming completion: the requests retired since the last
        ``poll()``/``drain()`` report.  Purely a report — ``step()`` is the
        scheduling quantum (the query engine's ``poll`` also services ripe
        work; here the caller drives the decode loop)."""
        return self._take_new()

    def drain(self, max_steps: int = 10_000) -> list[Request]:
        """Drain queue + active slots; returns only the requests retired by
        *this* call (``self.finished`` keeps the cumulative history — the
        sibling ``QueryServeEngine`` contract, so repeated drains never
        re-report earlier completions).

        Raises ``RuntimeError`` if ``max_steps`` is exhausted with work
        still pending — a partial drain must not be mistakable for a full
        one (undrained requests stay on ``self.queue``/``self.active``)."""
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        if self.queue or self.active:
            raise RuntimeError(
                f"drain gave up after {max_steps} steps with "
                f"{len(self.queue)} queued and {len(self.active)} active "
                f"request(s) remaining (finished stay on .finished)")
        return self._take_new()

    def run_until_done(self, max_steps: int = 10_000) -> list[Request]:
        """Deprecated: thin wrapper over ``drain`` (same return value, same
        partial-drain ``RuntimeError`` contract)."""
        warn_run_until_done(type(self).__name__)
        return self.drain(max_steps=max_steps)
