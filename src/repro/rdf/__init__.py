from repro.rdf.dictionary import TermDict, TermKind
from repro.rdf.dataset import TripleTable, Source, Federation
from repro.rdf.generator import FederationSpec, SourceSpec, generate_federation, fedbench_like_spec

__all__ = [
    "TermDict",
    "TermKind",
    "TripleTable",
    "Source",
    "Federation",
    "FederationSpec",
    "SourceSpec",
    "generate_federation",
    "fedbench_like_spec",
]
