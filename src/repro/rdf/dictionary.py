"""Term dictionary: RDF terms (IRIs / literals) <-> dense int32 ids.

The federation shares one dictionary — equivalent to identifying entities by a
collision-free hash of their IRI, which is what Odyssey's summaries rely on.
Each term records its *authority* (scheme+host for IRIs, datatype for
literals); the entity summaries of §3.3 partition by authority instead of a
radix tree over full IRIs (DESIGN.md deviation D2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import IntEnum

import numpy as np


class TermKind(IntEnum):
    IRI = 0
    LITERAL = 1


@dataclass
class TermDict:
    terms: list[str] = field(default_factory=list)
    kinds: list[int] = field(default_factory=list)
    authorities: list[int] = field(default_factory=list)  # authority id per term
    _index: dict[str, int] = field(default_factory=dict)
    _auth_index: dict[str, int] = field(default_factory=dict)
    _auth_names: list[str] = field(default_factory=list)

    def authority_id(self, authority: str) -> int:
        aid = self._auth_index.get(authority)
        if aid is None:
            aid = len(self._auth_names)
            self._auth_index[authority] = aid
            self._auth_names.append(authority)
        return aid

    def add(self, term: str, kind: TermKind = TermKind.IRI, authority: str | None = None) -> int:
        tid = self._index.get(term)
        if tid is not None:
            return tid
        if authority is None:
            authority = _authority_of(term, kind)
        tid = len(self.terms)
        self.terms.append(term)
        self.kinds.append(int(kind))
        self.authorities.append(self.authority_id(authority))
        self._index[term] = tid
        return tid

    def id_of(self, term: str) -> int:
        return self._index[term]

    def term_of(self, tid: int) -> str:
        return self.terms[tid]

    def __len__(self) -> int:
        return len(self.terms)

    def authority_array(self) -> np.ndarray:
        return np.asarray(self.authorities, dtype=np.int32)

    @property
    def n_authorities(self) -> int:
        return len(self._auth_names)


def _authority_of(term: str, kind: TermKind) -> str:
    if kind == TermKind.LITERAL:
        return "literal:plain"
    # IRI: scheme://host
    if "://" in term:
        scheme, rest = term.split("://", 1)
        return scheme + "://" + rest.split("/", 1)[0]
    if ":" in term:  # prefixed form like dbr:Gary_Goetzman
        return term.split(":", 1)[0] + ":"
    return "urn:"
