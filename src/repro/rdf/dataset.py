"""Columnar triple tables and federation containers.

A ``TripleTable`` is a set of (s, p, o) int32 triples stored sorted by
(s, p, o) with a per-predicate secondary index sorted by (p, o, s). This gives
O(log n) pattern scans for the access paths SPARQL BGP evaluation needs:
  (s ? ?), (s p ?), (? p ?), (? p o), (s p o), (? ? o)
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.rdf.dictionary import TermDict


@dataclass
class TripleTable:
    s: np.ndarray  # int32, sorted lexicographically by (s, p, o)
    p: np.ndarray
    o: np.ndarray
    # secondary order: permutation sorting by (p, o, s)
    pos_perm: np.ndarray = field(default=None)  # type: ignore[assignment]

    @staticmethod
    def from_triples(s: np.ndarray, p: np.ndarray, o: np.ndarray, dedup: bool = True) -> "TripleTable":
        s = np.asarray(s, dtype=np.int32)
        p = np.asarray(p, dtype=np.int32)
        o = np.asarray(o, dtype=np.int32)
        order = np.lexsort((o, p, s))
        s, p, o = s[order], p[order], o[order]
        if dedup and len(s):
            keep = np.ones(len(s), dtype=bool)
            keep[1:] = (s[1:] != s[:-1]) | (p[1:] != p[:-1]) | (o[1:] != o[:-1])
            s, p, o = s[keep], p[keep], o[keep]
        t = TripleTable(s=s, p=p, o=o)
        t.pos_perm = np.lexsort((t.s, t.o, t.p)).astype(np.int32)
        return t

    def __len__(self) -> int:
        return len(self.s)

    @property
    def n_triples(self) -> int:
        return len(self.s)

    def predicates(self) -> np.ndarray:
        return np.unique(self.p)

    def subjects(self) -> np.ndarray:
        return np.unique(self.s)

    def objects(self) -> np.ndarray:
        return np.unique(self.o)

    # -- pattern scans ------------------------------------------------------
    def scan(self, s: int | None, p: int | None, o: int | None) -> np.ndarray:
        """Return row indices (into the canonical (s,p,o) order) matching the
        pattern; ``None`` means unbound."""
        n = len(self.s)
        if n == 0:
            return np.empty(0, dtype=np.int64)
        if s is not None:
            lo, hi = np.searchsorted(self.s, [s, s + 1])
            idx = np.arange(lo, hi)
            if p is not None:
                sub = self.p[lo:hi]
                l2, h2 = np.searchsorted(sub, [p, p + 1])
                idx = idx[l2:h2]
                if o is not None:
                    sub_o = self.o[idx]
                    idx = idx[sub_o == o]
            elif o is not None:
                idx = idx[self.o[idx] == o]
            return idx
        if p is not None:
            # use (p, o, s) order
            ps = self.p[self.pos_perm]
            lo, hi = np.searchsorted(ps, [p, p + 1])
            sel = self.pos_perm[lo:hi]
            if o is not None:
                os_ = self.o[sel]
                l2, h2 = np.searchsorted(os_, [o, o + 1])
                sel = sel[l2:h2]
            return sel.astype(np.int64)
        if o is not None:
            return np.nonzero(self.o == o)[0]
        return np.arange(n)

    def count(self, s: int | None, p: int | None, o: int | None) -> int:
        return len(self.scan(s, p, o))

    def nbytes(self) -> int:
        return int(self.s.nbytes + self.p.nbytes + self.o.nbytes)


@dataclass
class Source:
    """One federation member ("SPARQL endpoint")."""

    name: str
    table: TripleTable
    sid: int = 0

    def ask(self, s: int | None, p: int | None, o: int | None) -> bool:
        """FedX-style ASK probe (DESIGN.md D4: O(log n) local lookup)."""
        return self.table.count(s, p, o) > 0


@dataclass
class Federation:
    sources: list[Source]
    dictionary: TermDict

    def __post_init__(self) -> None:
        for i, src in enumerate(self.sources):
            src.sid = i

    def __len__(self) -> int:
        return len(self.sources)

    def by_name(self, name: str) -> Source:
        for s in self.sources:
            if s.name == name:
                return s
        raise KeyError(name)

    def total_triples(self) -> int:
        return sum(s.table.n_triples for s in self.sources)
