"""Synthetic RDF federation generator (FedBench stand-in, DESIGN.md §6).

The real FedBench datasets are not available offline, so we synthesize a
federation with the same *structure*: each source has a population of
characteristic-set templates (Zipf-distributed entity counts), predicates drawn
from shared + source-local pools, per-(entity, predicate) triple multiplicities
> 1 (so DISTINCT vs non-DISTINCT estimation differs), and *link predicates*
whose objects are entities of another source — the federated joins Odyssey's
federated CPs capture.

The generator also emits LD/CD/LS-style query workloads (star + hybrid shapes,
2–7 triple patterns) that are guaranteed to have non-empty answers, plus ground
truth needed by tests (entity -> template assignment, cross-source link lists).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.query.algebra import BGPQuery, Const, TriplePattern, Var
from repro.rdf.dataset import Federation, Source, TripleTable
from repro.rdf.dictionary import TermDict, TermKind

SHARED_PREDS = ["rdf:type", "rdfs:label", "foaf:name", "owl:sameAs", "skos:subject"]


@dataclass
class LinkSpec:
    pred: str            # predicate IRI (prefixed)
    target: str          # target source name
    density: float = 0.3  # fraction of templates that carry this link


@dataclass
class SourceSpec:
    name: str
    n_entities: int = 1000
    n_templates: int = 12
    n_local_preds: int = 20
    template_size: tuple[int, int] = (3, 7)
    multiplicity_p: float = 0.35   # P(extra triple per (e, pred)), geometric
    zipf_a: float = 1.4
    links: list[LinkSpec] = field(default_factory=list)
    n_classes: int = 6             # rdf:type object pool
    literal_pool: int = 64         # distinct literals per (source, pred)
    authority: str | None = None   # shared namespaces weaken authority-only
                                   # pruning (HiBISCuS), as in real FedBench


@dataclass
class FederationSpec:
    sources: list[SourceSpec]
    seed: int = 0


@dataclass
class GroundTruth:
    """Ground truth for tests: per-source entity/template structure."""

    entity_template: dict[str, dict[int, int]]           # source -> entity id -> template idx
    template_preds: dict[str, list[list[int]]]           # source -> template idx -> pred ids
    template_entities: dict[str, list[np.ndarray]]       # source -> template idx -> entity ids
    cross_links: list[tuple[str, str, int, int, int]]    # (src, dst, s_ent, pred, o_ent)
    link_specs: dict[str, list[LinkSpec]]


def fedbench_like_spec(scale: float = 1.0, seed: int = 7) -> FederationSpec:
    """Nine sources with relative sizes/CS-counts shaped like FedBench Table 2."""

    def n(x: int) -> int:
        return max(16, int(x * scale))

    bio = "http://bio2rdf.org"  # shared namespace across the life-science trio
    chebi = SourceSpec("ChEBI", n_entities=n(1200), n_templates=14, n_local_preds=16,
                       authority=bio)
    kegg = SourceSpec("KEGG", n_entities=n(500), n_templates=6, n_local_preds=12,
                      authority=bio, links=[LinkSpec("kegg:compound", "ChEBI", 0.4)])
    drugbank = SourceSpec("Drugbank", n_entities=n(700), n_templates=20, n_local_preds=30,
                          authority=bio,
                          links=[LinkSpec("drugbank:target", "KEGG", 0.3),
                                 LinkSpec("owl:sameAs", "DBpedia", 0.25)])
    dbpedia = SourceSpec("DBpedia", n_entities=n(4000), n_templates=40, n_local_preds=60,
                         links=[LinkSpec("dbo:director", "DBpedia", 0.25),
                                LinkSpec("dbo:producer", "DBpedia", 0.2)])
    geonames = SourceSpec("Geonames", n_entities=n(3000), n_templates=8, n_local_preds=14,
                          links=[LinkSpec("gn:parentFeature", "Geonames", 0.5)])
    jamendo = SourceSpec("Jamendo", n_entities=n(600), n_templates=5, n_local_preds=12,
                         links=[LinkSpec("foaf:based_near", "Geonames", 0.4)])
    swdf = SourceSpec("SWDF", n_entities=n(300), n_templates=16, n_local_preds=26,
                      links=[LinkSpec("owl:sameAs", "DBpedia", 0.3)])
    lmdb = SourceSpec("LMDB", n_entities=n(1500), n_templates=18, n_local_preds=24,
                      links=[LinkSpec("owl:sameAs", "DBpedia", 0.35),
                             LinkSpec("lmdb:sequel", "LMDB", 0.15)])
    nytimes = SourceSpec("NYTimes", n_entities=n(400), n_templates=6, n_local_preds=12,
                         links=[LinkSpec("owl:sameAs", "DBpedia", 0.5),
                                LinkSpec("nyt:mentions", "Geonames", 0.3)])
    return FederationSpec(
        sources=[chebi, kegg, drugbank, dbpedia, geonames, jamendo, swdf, lmdb, nytimes],
        seed=seed,
    )


def generate_federation(spec: FederationSpec) -> tuple[Federation, GroundTruth]:
    rng = np.random.default_rng(spec.seed)
    d = TermDict()
    shared_pred_ids = [d.add(p, TermKind.IRI) for p in SHARED_PREDS]

    # --- allocate entity id pools per source (IRIs with per-source authority)
    entity_ids: dict[str, np.ndarray] = {}
    for ss in spec.sources:
        auth = ss.authority or f"http://{ss.name.lower()}.org"
        ids = np.array(
            [d.add(f"{auth}/{ss.name.lower()}/e{i}", TermKind.IRI, authority=auth)
             for i in range(ss.n_entities)],
            dtype=np.int32,
        )
        entity_ids[ss.name] = ids

    gt = GroundTruth({}, {}, {}, [], {ss.name: list(ss.links) for ss in spec.sources})
    sources: list[Source] = []

    for ss in spec.sources:
        local_preds = [d.add(f"{ss.name.lower()}:p{i}", TermKind.IRI) for i in range(ss.n_local_preds)]
        link_pred_ids = {lk.pred: d.add(lk.pred, TermKind.IRI) for lk in ss.links}
        class_ids = [d.add(f"{ss.name.lower()}:Class{i}", TermKind.IRI) for i in range(ss.n_classes)]
        rdf_type = shared_pred_ids[0]

        # --- build templates -------------------------------------------------
        templates: list[list[int]] = []
        template_link: list[list[tuple[int, str]]] = []  # per template: (pred id, target source)
        for t in range(ss.n_templates):
            size = int(rng.integers(ss.template_size[0], ss.template_size[1] + 1))
            pool = local_preds + shared_pred_ids[:3]  # type/label/name always possible
            preds = list(rng.choice(pool, size=min(size, len(pool)), replace=False))
            if rdf_type not in preds:
                preds.append(rdf_type)
            links_here: list[tuple[int, str]] = []
            for lk in ss.links:
                if rng.random() < lk.density:
                    pid = link_pred_ids[lk.pred]
                    if pid not in preds:
                        preds.append(pid)
                    links_here.append((pid, lk.target))
            templates.append(sorted(set(int(p) for p in preds)))
            template_link.append(links_here)

        # --- assign entities to templates (Zipf weights) ----------------------
        w = 1.0 / np.arange(1, ss.n_templates + 1) ** ss.zipf_a
        w /= w.sum()
        assign = rng.choice(ss.n_templates, size=ss.n_entities, p=w)
        ents = entity_ids[ss.name]
        tmpl_entities = [ents[assign == t] for t in range(ss.n_templates)]

        # --- literal pools ---------------------------------------------------
        lit_pool: dict[int, np.ndarray] = {}

        def literals_for(pred: int) -> np.ndarray:
            if pred not in lit_pool:
                lit_pool[pred] = np.array(
                    [d.add(f"lit:{ss.name}:{pred}:{i}", TermKind.LITERAL) for i in range(ss.literal_pool)],
                    dtype=np.int32,
                )
            return lit_pool[pred]

        # --- emit triples ----------------------------------------------------
        S: list[np.ndarray] = []
        P: list[np.ndarray] = []
        O: list[np.ndarray] = []
        for t, preds in enumerate(templates):
            es = tmpl_entities[t]
            if len(es) == 0:
                continue
            link_map = dict(template_link[t])
            for pred in preds:
                # multiplicity per entity: 1 + Geometric(p)
                mult = 1 + rng.geometric(1.0 - ss.multiplicity_p, size=len(es)) - 1
                mult = np.clip(mult, 1, 4)
                subs = np.repeat(es, mult)
                k = len(subs)
                if pred == rdf_type:
                    objs = rng.choice(class_ids, size=k)
                elif pred in link_map:
                    target = link_map[pred]
                    objs = rng.choice(entity_ids[target], size=k)
                    if target != ss.name:
                        for s_e, o_e in zip(subs.tolist(), objs.tolist()):
                            gt.cross_links.append((ss.name, target, s_e, pred, o_e))
                else:
                    objs = rng.choice(literals_for(pred), size=k)
                S.append(subs)
                P.append(np.full(k, pred, dtype=np.int32))
                O.append(np.asarray(objs, dtype=np.int32))

        table = TripleTable.from_triples(np.concatenate(S), np.concatenate(P), np.concatenate(O))
        sources.append(Source(name=ss.name, table=table))
        gt.entity_template[ss.name] = {int(e): int(t) for e, t in zip(ents.tolist(), assign.tolist())}
        gt.template_preds[ss.name] = templates
        gt.template_entities[ss.name] = tmpl_entities

    return Federation(sources=sources, dictionary=d), gt


# --------------------------------------------------------------------------
# Query workload generation (LD/CD/LS-style)
# --------------------------------------------------------------------------

def _star_patterns(rng: np.random.Generator, fed: Federation, gt: GroundTruth,
                   src: str, tmpl: int, var: str, k: int,
                   bind_obj: bool) -> "list[TriplePattern] | None":
    """A k-pattern star over one template's predicates (subject ``?var``),
    optionally grounding one object so the star has a bound constant.
    Non-empty by construction: every template entity matches."""
    preds = gt.template_preds[src][tmpl]
    ents = gt.template_entities[src][tmpl]
    if len(ents) == 0 or len(preds) < k:
        return None
    chosen = rng.choice(preds, size=k, replace=False)
    table = fed.by_name(src).table
    pats = []
    for j, pred in enumerate(chosen.tolist()):
        if bind_obj and j == 0:
            e = int(rng.choice(ents))
            rows = table.scan(e, int(pred), None)
            if len(rows) == 0:
                return None
            obj = int(table.o[rows[0]])
            pats.append(TriplePattern(Var(var), Const(int(pred)), Const(obj)))
        else:
            pats.append(TriplePattern(Var(var), Const(int(pred)), Var(f"{var}_v{j}")))
    return pats


def generate_workload(
    fed: Federation,
    gt: GroundTruth,
    n_star: int = 10,
    n_hybrid: int = 10,
    n_path: int = 5,
    seed: int = 13,
) -> list[BGPQuery]:
    """Star, hybrid (two linked stars) and path-ish queries with non-empty answers."""
    rng = np.random.default_rng(seed)
    queries: list[BGPQuery] = []

    def star_patterns(src: str, tmpl: int, var: str, k: int, bind_obj: bool) -> list[TriplePattern] | None:
        return _star_patterns(rng, fed, gt, src, tmpl, var, k, bind_obj)

    src_names = [s.name for s in fed.sources]

    made = 0
    attempts = 0
    while made < n_star and attempts < 200:
        attempts += 1
        src = str(rng.choice(src_names))
        tmpl = int(rng.integers(len(gt.template_preds[src])))
        k = int(rng.integers(2, 5))
        pats = star_patterns(src, tmpl, "x", k, bind_obj=bool(rng.random() < 0.4))
        if pats is None:
            continue
        queries.append(BGPQuery(pats, distinct=bool(rng.random() < 0.5), projection=["x"], name=f"ST{made + 1}"))
        made += 1

    # hybrid: star(x) -- link pred --> star(y)
    links = gt.cross_links
    made = 0
    attempts = 0
    while made < n_hybrid and attempts < 400 and links:
        attempts += 1
        (src, dst, s_e, pred, o_e) = links[int(rng.integers(len(links)))]
        t1 = gt.entity_template[src][s_e]
        t2 = gt.entity_template[dst][o_e]
        k1 = int(rng.integers(1, 4))
        k2 = int(rng.integers(1, 4))
        p1 = star_patterns(src, t1, "x", k1, bind_obj=False)
        p2 = star_patterns(dst, t2, "y", k2, bind_obj=False)
        if p1 is None or p2 is None:
            continue
        bridge = TriplePattern(Var("x"), Const(int(pred)), Var("y"))
        queries.append(
            BGPQuery(p1 + [bridge] + p2, distinct=bool(rng.random() < 0.5), projection=["x", "y"],
                     name=f"HY{made + 1}")
        )
        made += 1

    # path: x --p--> y --q--> z (chains through intra-source links)
    made = 0
    attempts = 0
    while made < n_path and attempts < 400 and links:
        attempts += 1
        (src, dst, s_e, pred, o_e) = links[int(rng.integers(len(links)))]
        t2 = gt.entity_template[dst][o_e]
        preds2 = gt.template_preds[dst][t2]
        if not preds2:
            continue
        q = int(rng.choice(preds2))
        pats = [
            TriplePattern(Var("x"), Const(int(pred)), Var("y")),
            TriplePattern(Var("y"), Const(q), Var("z")),
        ]
        queries.append(BGPQuery(pats, distinct=True, projection=["x", "z"], name=f"PA{made + 1}"))
        made += 1

    return queries


# --------------------------------------------------------------------------
# Extended (group-algebra) workload: OPTIONAL / UNION / FILTER families
# --------------------------------------------------------------------------

def generate_extended_workload(
    fed: Federation,
    gt: GroundTruth,
    n_optional: int = 6,
    n_union: int = 6,
    n_filtered: int = 4,
    seed: int = 17,
) -> list[BGPQuery]:
    """Seeded group-tree queries over the synthetic federation, three families:

    * **OS** (optional-star): a template star plus 1–2 OPTIONAL arms whose
      predicates come from *other* templates of the same source, so some
      answers carry UNDEF — the arms are genuinely partial.
    * **UN** (union-of-templates): one star shape instantiated over two
      different (source, template) pairs, branches sharing variable names.
    * **FC** (filtered-chain): a cross-source chain or a star with a
      ``!=`` FILTER over distinct object variables (always satisfiable —
      distinct literal pools — so answers stay non-empty).

    Every query carries a non-degenerate group tree (``query.root`` is set);
    the conjunctive parts reuse the template machinery of
    ``generate_workload`` so answers are non-empty by construction."""
    from repro.query.algebra import (
        Bgp,
        Comparison,
        Filter,
        LeftJoin,
        Union,
        from_algebra,
    )

    rng = np.random.default_rng(seed)
    queries: list[BGPQuery] = []
    src_names = [s.name for s in fed.sources]

    # -- OS: star + 1-2 OPTIONAL arms ---------------------------------------
    made = 0
    attempts = 0
    while made < n_optional and attempts < 400:
        attempts += 1
        src = str(rng.choice(src_names))
        tmpl = int(rng.integers(len(gt.template_preds[src])))
        base = _star_patterns(rng, fed, gt, src, tmpl, "x",
                              int(rng.integers(2, 4)), bind_obj=False)
        if base is None:
            continue
        here = set(gt.template_preds[src][tmpl])
        elsewhere = sorted({p for t in gt.template_preds[src] for p in t} - here)
        if not elsewhere:
            continue
        n_arms = int(rng.integers(1, 3))
        arm_preds = rng.choice(elsewhere, size=min(n_arms, len(elsewhere)),
                               replace=False)
        node = Bgp(tuple(base))
        opt_vars = []
        for a, pred in enumerate(arm_preds.tolist()):
            ov = f"o{a}"
            node = LeftJoin(node, Bgp((TriplePattern(Var("x"), Const(int(pred)),
                                                     Var(ov)),)))
            opt_vars.append(ov)
        queries.append(from_algebra(node, distinct=bool(rng.random() < 0.5),
                                    projection=["x", *opt_vars],
                                    name=f"OS{made + 1}"))
        made += 1

    # -- UN: the same star shape over two templates -------------------------
    made = 0
    attempts = 0
    while made < n_union and attempts < 400:
        attempts += 1
        src_a = str(rng.choice(src_names))
        src_b = str(rng.choice(src_names))
        t_a = int(rng.integers(len(gt.template_preds[src_a])))
        t_b = int(rng.integers(len(gt.template_preds[src_b])))
        if (src_a, t_a) == (src_b, t_b):
            continue
        k = int(rng.integers(2, 4))
        b_a = _star_patterns(rng, fed, gt, src_a, t_a, "x", k, bind_obj=False)
        b_b = _star_patterns(rng, fed, gt, src_b, t_b, "x", k, bind_obj=False)
        if b_a is None or b_b is None:
            continue
        node = Union((Bgp(tuple(b_a)), Bgp(tuple(b_b))))
        queries.append(from_algebra(node, distinct=bool(rng.random() < 0.5),
                                    projection=["x"], name=f"UN{made + 1}"))
        made += 1

    # -- FC: chain/star with a != filter over distinct object variables -----
    links = gt.cross_links
    made = 0
    attempts = 0
    while made < n_filtered and attempts < 400:
        attempts += 1
        if links and rng.random() < 0.5:
            (src, dst, s_e, pred, o_e) = links[int(rng.integers(len(links)))]
            t2 = gt.entity_template[dst][o_e]
            preds2 = gt.template_preds[dst][t2]
            if not preds2:
                continue
            q = int(rng.choice(preds2))
            pats = [TriplePattern(Var("x"), Const(int(pred)), Var("y")),
                    TriplePattern(Var("y"), Const(q), Var("z"))]
            expr = Comparison("!=", Var("x"), Var("z"))
            proj = ["x", "z"]
        else:
            src = str(rng.choice(src_names))
            tmpl = int(rng.integers(len(gt.template_preds[src])))
            pats = _star_patterns(rng, fed, gt, src, tmpl, "x", 3,
                                  bind_obj=False)
            if pats is None:
                continue
            # distinct per-predicate literal pools: != always satisfiable
            expr = Comparison("!=", Var("x_v1"), Var("x_v2"))
            proj = ["x"]
        node = Filter(expr, Bgp(tuple(pats)))
        queries.append(from_algebra(node, distinct=bool(rng.random() < 0.5),
                                    projection=proj, name=f"FC{made + 1}"))
        made += 1

    return queries
