"""Synthetic star-graph planning workloads (chains / trees / cliques).

Large-star planner tests and benchmarks need queries with a *controlled*
star-graph shape at sizes (14-20 meta-nodes) the FedBench-like workload
generator never produces.  Cases are built over a small random triple table:
every star ``i`` owns one ``(x_i, p, x_i_v)`` pattern, so decomposition
yields exactly one star per node in node order, and each shape edge
``(a, b)`` adds an object->subject link pattern ``(x_a, p, x_b)``.  Chains
and trees keep every prefix ``{x_0..x_k}`` connected (tree parents are
always earlier nodes), which the left-deep-bound property tests rely on.
"""
from __future__ import annotations

import numpy as np

from repro.query.algebra import BGPQuery, Const, TriplePattern, Var
from repro.rdf.dataset import TripleTable

SHAPES = ("chain", "tree", "clique")


def shape_edges(shape: str, n_stars: int, rng) -> list[tuple[int, int]]:
    if shape == "chain":
        return [(i, i + 1) for i in range(n_stars - 1)]
    if shape == "tree":
        return [(int(rng.integers(0, i)), i) for i in range(1, n_stars)]
    if shape == "clique":
        return [(a, b) for a in range(n_stars) for b in range(a + 1, n_stars)]
    raise ValueError(f"unknown star-graph shape {shape!r}")


def shaped_case(shape: str, n_stars: int, seed: int, n_preds: int = 6,
                n_rows: int = 400, distinct: bool = True):
    """``(TripleTable, BGPQuery)`` decomposing into exactly ``n_stars`` stars
    (star index == node index) linked in the requested shape."""
    rng = np.random.default_rng(seed)
    s = rng.integers(0, 40, n_rows).astype(np.int32)
    p = rng.integers(0, n_preds, n_rows).astype(np.int32)
    # half the objects are entities (joinable), half literals
    o = np.where(rng.random(n_rows) < 0.5, rng.integers(0, 40, n_rows),
                 rng.integers(100, 140, n_rows)).astype(np.int32)
    table = TripleTable.from_triples(s, p, o)
    preds = table.predicates()

    def pred() -> Const:
        return Const(int(preds[rng.integers(len(preds))]))

    pats = [TriplePattern(Var(f"x{i}"), pred(), Var(f"x{i}_v"))
            for i in range(n_stars)]
    for a, b in shape_edges(shape, n_stars, rng):
        pats.append(TriplePattern(Var(f"x{a}"), pred(), Var(f"x{b}")))
    return table, BGPQuery(pats, distinct=distinct, name=f"{shape}{n_stars}")


def shaped_planning_inputs(shape: str, n_stars: int, seed: int, **kw):
    """``(graph, stats, sel, query)`` ready for ``dp_join_order``."""
    from repro.core.characteristic_pairs import compute_characteristic_pairs
    from repro.core.characteristic_sets import compute_characteristic_sets
    from repro.core.decomposition import decompose
    from repro.core.federation import FederatedStats
    from repro.core.source_selection import select_sources

    table, q = shaped_case(shape, n_stars, seed, **kw)
    cs = compute_characteristic_sets(table)
    cp = compute_characteristic_pairs(table, cs, 0)
    stats = FederatedStats(cs=[cs], intra_cp=[cp])
    graph = decompose(q)
    sel = select_sources(graph, stats)
    return graph, stats, sel, q
