"""Training step factory: loss, grads (remat'd scan inside the model),
optional microbatch gradient accumulation, optional int8 gradient compression
with error feedback, optimizer update. Built for jit with explicit
in/out_shardings by the launcher and the dry-run."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig
from repro.models import model as MDL
from repro.train import grad_compress as GC
from repro.train.optimizer import Optimizer, apply_updates


def loss_fn(cfg: ArchConfig, params, batch, aux_weight: float = 0.01):
    labels = batch["labels"]
    if cfg.perf.chunked_loss:
        # never materialize the (B, S, V) logits: scan sequence chunks and
        # matmul against the head inside the (checkpointed) chunk body
        x, aux = MDL.forward_hidden(cfg, params, batch)
        head = MDL.lm_head(cfg, params)
        B, S, D = x.shape
        c = min(cfg.perf.loss_chunk, S)
        nc = S // c

        def body(acc, i):
            xb = jax.lax.dynamic_slice_in_dim(x, i * c, c, 1)
            lb = jax.lax.dynamic_slice_in_dim(labels, i * c, c, 1)
            lg = (xb @ head).astype(jnp.float32)
            logz = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, lb[..., None], axis=-1)[..., 0]
            return acc + (logz - gold).sum(), None

        acc, _ = jax.lax.scan(jax.checkpoint(body), jnp.zeros((), jnp.float32),
                              jnp.arange(nc))
        nll = acc / (B * nc * c)
        return nll + aux_weight * aux, (nll, aux)
    logits, aux = MDL.forward(cfg, params, batch)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold).mean()
    return nll + aux_weight * aux, (nll, aux)


def make_train_step(cfg: ArchConfig, optimizer: Optimizer, microbatches: int = 1,
                    compress: bool = False):
    """Returns train_step(params, opt_state, batch [, error_fb]) ->
    (params, opt_state, metrics [, error_fb])."""

    grad_fn = jax.grad(lambda p, b: loss_fn(cfg, p, b)[0], has_aux=False)

    def value_grad(params, batch):
        (loss, (nll, aux)), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, params=p, batch=batch), has_aux=True)(params)
        return loss, nll, aux, grads

    def split_micro(batch, i):
        return jax.tree.map(
            lambda x: jax.lax.dynamic_slice_in_dim(
                x, i * (x.shape[0] // microbatches), x.shape[0] // microbatches, 0),
            batch)

    def train_step(params, opt_state, batch, error_fb=None):
        if microbatches == 1:
            loss, nll, aux, grads = value_grad(params, batch)
        else:
            def body(carry, i):
                acc = carry
                mb = split_micro(batch, i)
                loss, nll, aux, grads = value_grad(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return acc, (loss, nll, aux)

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            acc, (losses, nlls, auxs) = jax.lax.scan(
                body, zeros, jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, acc)
            loss, nll, aux = losses.mean(), nlls.mean(), auxs.mean()

        if compress:
            assert error_fb is not None
            qtree, error_fb = GC.compress_grads(grads, error_fb)
            grads = GC.decompress_grads(qtree)

        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "nll": nll, "moe_aux": aux, "grad_norm": gnorm}
        if compress:
            return params, opt_state, metrics, error_fb
        return params, opt_state, metrics

    return train_step
