from repro.train.optimizer import adamw, adafactor, make_optimizer
from repro.train.train_step import make_train_step, loss_fn

__all__ = ["adamw", "adafactor", "make_optimizer", "make_train_step", "loss_fn"]
