"""Optimizers (no external deps): AdamW and factored Adafactor.

Adafactor's factored second moment keeps optimizer state ≈ O(rows + cols)
instead of O(params) — the default for the ≥200B MoE archs so the multi-pod
memory budget closes (DESIGN.md). States inherit the parameter shardings.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable     # (grads, state, params) -> (updates, state)
    name: str = ""


def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v2 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m2 / (1 - b1 ** t)
            vhat = v2 / (1 - b2 ** t)
            u = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (-lr * u).astype(p.dtype), m2.astype(state_dtype), v2.astype(state_dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update, "adamw")


def adafactor(lr: float = 1e-2, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second-moment Adafactor (no momentum)."""

    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def zeros(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(zeros, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        rho = 1.0 - t ** (-decay)

        def upd(g, v, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(g.shape):
                vr = rho * v["vr"] + (1 - rho) * g2.mean(axis=-1)
                vc = rho * v["vc"] + (1 - rho) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]) / jnp.maximum(
                    vr.mean(axis=-1)[..., None, None], eps)
                u = g32 * jax.lax.rsqrt(denom + eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": rho * v["v"] + (1 - rho) * g2}
                u = g32 * jax.lax.rsqrt(nv["v"] + eps)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return (-lr * u).astype(p.dtype), nv

        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_v = treedef.flatten_up_to(state["v"])
        leaves_p = treedef.flatten_up_to(params)
        out = [upd(g, v, p) for g, v, p in zip(leaves_g, leaves_v, leaves_p)]
        updates = treedef.unflatten([o[0] for o in out])
        v = treedef.unflatten([o[1] for o in out])
        return updates, {"v": v, "step": step}

    return Optimizer(init, update, "adafactor")


def make_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(name)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
