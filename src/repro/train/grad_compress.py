"""Gradient compression with error feedback (distributed-optimization trick).

Quantizes gradients to int8 (per-leaf max-abs scaling) before the data-
parallel all-reduce; the quantization residual is carried to the next step
(error feedback), which keeps SGD-style convergence. On the mesh this shrinks
the DP all-reduce bytes 2×(bf16)/4×(fp32) — directly attacks the collective
roofline term of gradient synchronization.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, error_fb):
    """Returns (quantized pytree of (q, scale) pairs, new residuals)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        deq = dequantize_int8(q, s)
        return (q, s), g32 - deq

    leaves, treedef = jax.tree.flatten(grads)
    eleaves = treedef.flatten_up_to(error_fb)
    pairs = [one(g, e) for g, e in zip(leaves, eleaves)]
    qtree = treedef.unflatten([p[0] for p in pairs])
    etree = treedef.unflatten([p[1] for p in pairs])
    return qtree, etree


def decompress_grads(qtree):
    def is_pair(x):
        return isinstance(x, tuple) and len(x) == 2
    return jax.tree.map(lambda qs: dequantize_int8(*qs), qtree, is_leaf=is_pair)
