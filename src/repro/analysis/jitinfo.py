"""Lightweight jit-boundary inference: "is this function body traced?"

Purely syntactic, per module.  A function (or lambda) is considered
*traced* when it is

- decorated with something jit-like (``@jax.jit``, ``@jit``,
  ``@partial(jax.jit, ...)``, ``@functools.partial(jax.jit, ...)``),
- passed to a jit-like call (``jax.jit(f)``, possibly through one level of
  ``functools.partial``),
- passed to a tracing combinator (``pl.pallas_call``, ``lax.scan``,
  ``lax.while_loop``, ``lax.fori_loop``, ``lax.cond``, ``lax.switch``,
  ``lax.map``, ``lax.associative_scan``, ``jax.vmap``, ``jax.grad``,
  ``jax.checkpoint``, ``jax.remat``),
- defined lexically inside a traced function, or
- called (by name, including ``self.<name>``) from a traced function in the
  same module — propagated to a fixpoint, so helper chains under a jitted
  entry point are covered.

False negatives are accepted by design (cross-module reachability is out of
scope — the CI gate catches the classes of bug this repo actually hits,
inside the modules that hit them); false positives are kept near zero so
the suite stays adoptable without suppression sprawl.
"""
from __future__ import annotations

import ast

_JIT_NAMES = {"jit"}
_COMBINATORS = {
    "pallas_call", "scan", "while_loop", "fori_loop", "cond", "switch",
    "map", "associative_scan", "vmap", "grad", "value_and_grad",
    "checkpoint", "remat",
}
_PARTIAL_NAMES = {"partial"}


def _terminal_name(node: ast.AST) -> str | None:
    """`jax.jit` -> 'jit', `pl.pallas_call` -> 'pallas_call', `jit` -> 'jit'."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _is_jit_like(node: ast.AST) -> bool:
    """Does this expression evaluate to a jit transform?  Covers ``jax.jit``
    and ``partial(jax.jit, ...)``."""
    name = _terminal_name(node)
    if name in _JIT_NAMES:
        return True
    if isinstance(node, ast.Call) and _terminal_name(node.func) in _PARTIAL_NAMES:
        return bool(node.args) and _is_jit_like(node.args[0])
    return False


def _unwrap_partial(node: ast.AST) -> ast.AST:
    """``functools.partial(f, ...)`` -> ``f`` (one level)."""
    if (isinstance(node, ast.Call)
            and _terminal_name(node.func) in _PARTIAL_NAMES and node.args):
        return node.args[0]
    return node


class JitInfo:
    """Traced-function inference for one module AST."""

    def __init__(self, tree: ast.Module):
        self.tree = tree
        # every function-ish node, by id, plus name -> nodes for call-graph
        self._funcs: dict[int, ast.AST] = {}
        self._by_name: dict[str, list[ast.AST]] = {}
        self._enclosing: dict[int, ast.AST] = {}   # func node -> nearest func
        self._traced: set[int] = set()
        self._collect()
        self._seed_roots()
        self._propagate()

    # -- public ------------------------------------------------------------

    def is_traced(self, func_node: ast.AST) -> bool:
        return id(func_node) in self._traced

    def traced_functions(self) -> list[ast.AST]:
        return [n for n in self._funcs.values() if id(n) in self._traced]

    def function_nodes(self) -> list[ast.AST]:
        return list(self._funcs.values())

    # -- construction ------------------------------------------------------

    def _collect(self) -> None:
        stack: list[ast.AST] = []

        def visit(node: ast.AST) -> None:
            is_func = isinstance(
                node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda))
            if is_func:
                self._funcs[id(node)] = node
                if stack:
                    self._enclosing[id(node)] = stack[-1]
                name = getattr(node, "name", None)
                if name:
                    self._by_name.setdefault(name, []).append(node)
                stack.append(node)
            for child in ast.iter_child_nodes(node):
                visit(child)
            if is_func:
                stack.pop()

        visit(self.tree)

    def _mark_callable_expr(self, expr: ast.AST) -> None:
        """Mark the function a callable-expression refers to, if resolvable."""
        expr = _unwrap_partial(expr)
        if isinstance(expr, ast.Lambda):
            self._traced.add(id(expr))
        elif isinstance(expr, ast.Name):
            for fn in self._by_name.get(expr.id, []):
                self._traced.add(id(fn))
        elif isinstance(expr, ast.Attribute):
            # self._helper / mod.fn: match by terminal name if defined here
            for fn in self._by_name.get(expr.attr, []):
                self._traced.add(id(fn))

    def _seed_roots(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if _is_jit_like(dec) or (
                            isinstance(dec, ast.Call) and _is_jit_like(dec.func)):
                        self._traced.add(id(node))
            elif isinstance(node, ast.Call):
                name = _terminal_name(node.func)
                if _is_jit_like(node.func):
                    if node.args:
                        self._mark_callable_expr(node.args[0])
                elif name in _COMBINATORS:
                    for arg in node.args:
                        if isinstance(_unwrap_partial(arg),
                                      (ast.Lambda, ast.Name, ast.Attribute)):
                            self._mark_callable_expr(arg)

    def _propagate(self) -> None:
        changed = True
        while changed:
            changed = False
            # lexical nesting: a def inside a traced function is traced
            for fid, node in self._funcs.items():
                if fid in self._traced:
                    continue
                enc = self._enclosing.get(fid)
                if enc is not None and id(enc) in self._traced:
                    self._traced.add(fid)
                    changed = True
            # same-module call graph: traced body calls name -> name traced
            for node in list(self.traced_functions()):
                for call in ast.walk(node):
                    if not isinstance(call, ast.Call):
                        continue
                    name = _terminal_name(call.func)
                    if name in _JIT_NAMES or name in _COMBINATORS:
                        continue      # already handled as roots
                    for fn in self._by_name.get(name or "", []):
                        if id(fn) not in self._traced:
                            self._traced.add(id(fn))
                            changed = True
