"""Committed baseline of grandfathered findings.

The baseline is a JSON map ``fingerprint -> entry`` checked in at the repo
root (``analysis_baseline.json``).  A finding in the baseline does not fail
the build; a finding *not* in it does, and so does a baseline entry whose
finding has disappeared (the fix should retire its baseline line in the
same commit — finding-drift fails loudly in both directions).

Refresh with ``python -m repro.analysis ... --write-baseline`` after
reviewing the diff; hand-edit the ``reason`` fields to record *why* each
grandfathered finding is acceptable.
"""
from __future__ import annotations

import json
from typing import TYPE_CHECKING

if TYPE_CHECKING:                       # pragma: no cover
    from repro.analysis.core import AnalysisResult, Finding

SCHEMA_VERSION = 1


def load_baseline(path: str) -> dict[str, dict]:
    try:
        with open(path, encoding="utf-8") as fh:
            payload = json.load(fh)
    except FileNotFoundError:
        return {}
    if payload.get("schema") != SCHEMA_VERSION:
        raise ValueError(f"{path}: unsupported baseline schema "
                         f"{payload.get('schema')!r} (want {SCHEMA_VERSION})")
    return payload["findings"]


def write_baseline(path: str, result: "AnalysisResult",
                   previous: "dict[str, dict] | None" = None) -> dict[str, dict]:
    """Serialize the current findings as the new baseline, carrying forward
    hand-written reasons from ``previous`` where the fingerprint survives."""
    previous = previous or {}
    entries: dict[str, dict] = {}
    for f in sorted(result.findings, key=lambda f: (f.path, f.line, f.rule)):
        old = previous.get(f.fingerprint, {})
        entries[f.fingerprint] = {
            "rule": f.rule,
            "path": f.path,
            "line": f.line,
            "message": f.message,
            "reason": old.get("reason", "grandfathered (review + justify or fix)"),
        }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"schema": SCHEMA_VERSION, "findings": entries}, fh,
                  indent=1, sort_keys=True)
        fh.write("\n")
    return entries


def diff_baseline(result: "AnalysisResult", baseline: dict[str, dict]
                  ) -> "tuple[list[Finding], list[str]]":
    """Returns ``(new_findings, stale_fingerprints)``."""
    current = {f.fingerprint for f in result.findings}
    new = [f for f in result.findings if f.fingerprint not in baseline]
    stale = sorted(fp for fp in baseline if fp not in current)
    return new, stale
