"""`repro.analysis`: a jax-aware static-analysis suite for this repo.

Mechanizes the bug classes the PR 1-6 bugfix tail kept rediscovering by
hand (see docs/analysis.md for the rule catalog):

- RPR001 trace-host-sync   host coercions on traced values in jitted code
- RPR002 cache-aliasing    caches handing out / storing shared mutable state
- RPR003 bench-parity      benchmark timers comparing jitted vs bare callables
- RPR004 recompile-hazard  per-call jit wrapping, lru_cache over programs
- RPR005 x64-discipline    jax float64 escaping ``enable_x64`` in kernels
- RPR1xx generic hygiene   mutable defaults, broad excepts, library asserts

Run it as ``PYTHONPATH=src python -m repro.analysis src benchmarks``; inline
suppressions are ``# repro: ignore[RPR001] -- reason`` (reason mandatory)
and grandfathered findings live in the committed ``analysis_baseline.json``.
"""
from repro.analysis.core import (  # noqa: F401
    AnalysisResult,
    FileContext,
    Finding,
    Rule,
    all_rules,
    analyze_paths,
    get_rule,
    register,
)
from repro.analysis.baseline import diff_baseline, load_baseline, write_baseline  # noqa: F401
