"""Rule registry, file contexts and the analysis runner.

The framework is deliberately dependency-free (``ast`` + stdlib only): it
must run in the CI ``lint`` job before any test tier, and it must never
import jax — rules reason about jax *syntactically* (see ``jitinfo``), so
a broken kernel module cannot take the analyzer down with it.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import os
from collections import Counter
from typing import Callable, Iterable, Iterator

from repro.analysis.jitinfo import JitInfo
from repro.analysis.suppress import Suppression, parse_suppressions


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: a rule firing at a source location."""

    rule: str
    path: str                       # posix path relative to the analysis root
    line: int                       # 1-based
    col: int                        # 0-based
    message: str
    fingerprint: str = ""           # stable id; filled in by the runner

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col + 1}: {self.rule} {self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class FileContext:
    """Everything a rule needs about one source file, computed once."""

    def __init__(self, path: str, source: str, tree: ast.Module):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = tree
        self._jit: JitInfo | None = None
        self._suppressions: dict[int, Suppression] | None = None
        self._parents: dict[int, ast.AST] | None = None

    @property
    def jit(self) -> JitInfo:
        if self._jit is None:
            self._jit = JitInfo(self.tree)
        return self._jit

    @property
    def suppressions(self) -> dict[int, Suppression]:
        if self._suppressions is None:
            self._suppressions = parse_suppressions(self.source)
        return self._suppressions

    def parent(self, node: ast.AST) -> "ast.AST | None":
        if self._parents is None:
            self._parents = {}
            for outer in ast.walk(self.tree):
                for child in ast.iter_child_nodes(outer):
                    self._parents[id(child)] = outer
        return self._parents.get(id(node))

    def ancestors(self, node: ast.AST) -> Iterator[ast.AST]:
        cur = self.parent(node)
        while cur is not None:
            yield cur
            cur = self.parent(cur)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def finding(self, rule: "Rule", node: ast.AST, message: str) -> Finding:
        return Finding(rule=rule.rule_id, path=self.path,
                       line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0), message=message)


class Rule:
    """Base class: one bug class, one ``check`` pass over a file."""

    rule_id = "RPR000"
    name = "abstract-rule"
    description = ""

    def applies(self, ctx: FileContext) -> bool:
        return True

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        raise NotImplementedError


_REGISTRY: dict[str, Rule] = {}


def register(cls: "type[Rule]") -> "type[Rule]":
    inst = cls()
    if inst.rule_id in _REGISTRY:
        raise ValueError(f"duplicate rule id {inst.rule_id}")
    _REGISTRY[inst.rule_id] = inst
    return cls


def all_rules() -> dict[str, Rule]:
    # import for side effect: rule modules self-register on first use
    from repro.analysis import rules as _rules  # noqa: F401

    return dict(sorted(_REGISTRY.items()))


def get_rule(rule_id: str) -> Rule:
    return all_rules()[rule_id]


# --------------------------------------------------------------------------
# Runner
# --------------------------------------------------------------------------

@dataclasses.dataclass
class AnalysisResult:
    findings: list[Finding]            # active (not suppressed)
    suppressed: list[Finding]          # silenced by a valid inline suppression
    files: int = 0

    @property
    def by_rule(self) -> "Counter[str]":
        return Counter(f.rule for f in self.findings)


def iter_py_files(paths: Iterable[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames
                                 if d != "__pycache__" and not d.startswith("."))
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def _relpath(path: str, root: str) -> str:
    try:
        rel = os.path.relpath(path, root)
    except ValueError:          # different drive (windows) — keep as-is
        rel = path
    return rel.replace(os.sep, "/")


def _fingerprints(findings: list[Finding], ctxs: dict[str, FileContext]) -> list[Finding]:
    """Stable ids: hash of (rule, path, normalized line text, occurrence
    index among identical triples).  Line *numbers* are deliberately not
    hashed, so unrelated edits above a grandfathered finding do not churn
    the baseline; editing the finding's own line does invalidate it."""
    seen: Counter[tuple] = Counter()
    out = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule)):
        ctx = ctxs.get(f.path)
        text = ctx.line_text(f.line).strip() if ctx else ""
        key = (f.rule, f.path, text)
        occ = seen[key]
        seen[key] += 1
        digest = hashlib.sha256(
            "|".join((f.rule, f.path, text, str(occ))).encode()).hexdigest()[:16]
        out.append(dataclasses.replace(f, fingerprint=digest))
    return out


def analyze_paths(paths: Iterable[str], *, root: str = ".",
                  rules: "Iterable[str] | None" = None,
                  file_filter: "Callable[[str], bool] | None" = None) -> AnalysisResult:
    """Run every (selected) rule over every ``.py`` file under ``paths``.

    ``root`` anchors the relative paths baked into finding fingerprints —
    CI and the e2e tests must agree on it (the repo root).
    """
    registry = all_rules()
    if rules is not None:
        registry = {r: registry[r] for r in rules}
    active: list[Finding] = []
    suppressed: list[Finding] = []
    ctxs: dict[str, FileContext] = {}
    n_files = 0
    for path in iter_py_files(paths):
        if file_filter is not None and not file_filter(path):
            continue
        n_files += 1
        rel = _relpath(path, root)
        with open(path, encoding="utf-8") as fh:
            source = fh.read()
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as exc:
            active.append(Finding(rule="RPR900", path=rel,
                                  line=exc.lineno or 1, col=(exc.offset or 1) - 1,
                                  message=f"syntax error: {exc.msg}"))
            continue
        ctx = FileContext(rel, source, tree)
        ctxs[rel] = ctx
        raw: list[Finding] = []
        for rule in registry.values():
            if rule.applies(ctx):
                raw.extend(rule.check(ctx))
        # malformed suppression comments are findings themselves (RPR100):
        # a reason is mandatory, and a reasonless ignore must not silence
        for sup in sorted(ctx.suppressions.values(),
                          key=lambda s: s.comment_line):
            if not sup.valid:
                raw.append(Finding(rule="RPR100", path=rel,
                                   line=sup.comment_line, col=0,
                                   message=sup.error or "malformed suppression"))
        for f in raw:
            sup = _matching_suppression(ctx, f)
            (suppressed if sup else active).append(f)
    return AnalysisResult(findings=_fingerprints(active, ctxs),
                          suppressed=suppressed, files=n_files)


def _matching_suppression(ctx: FileContext, finding: Finding) -> "Suppression | None":
    """A valid ``# repro: ignore[RULE] -- reason`` silences findings of that
    rule on the line it covers (its own line for trailing comments, the next
    code line for comment-only lines — see ``suppress.parse_suppressions``)."""
    if finding.rule == "RPR100":
        return None                       # malformed suppressions are not silencable
    sup = ctx.suppressions.get(finding.line)
    if sup is not None and sup.valid and finding.rule in sup.rules:
        return sup
    return None
