"""CLI: ``PYTHONPATH=src python -m repro.analysis src benchmarks``.

Exit status: 0 == clean (every finding fixed, suppressed with a reason, or
reason-baselined), 1 == new findings or baseline drift, 2 == usage error.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.baseline import diff_baseline, load_baseline, write_baseline
from repro.analysis.core import all_rules, analyze_paths

DEFAULT_BASELINE = "analysis_baseline.json"


def main(argv: "list[str] | None" = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jax-aware static analysis for this repo "
                    "(rule catalog: docs/analysis.md)")
    ap.add_argument("paths", nargs="*", default=["src", "benchmarks"],
                    help="files/directories to analyze (default: src benchmarks)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline file (default: {DEFAULT_BASELINE}; "
                         "missing file == empty baseline)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite --baseline from the current findings "
                         "(carries forward existing reasons) and exit 0")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--root", default=".",
                    help="root that finding paths/fingerprints are relative "
                         "to (default: cwd; CI runs from the repo root)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, rule in all_rules().items():
            print(f"{rid}  {rule.name:22} {rule.description}")
        return 0

    rules = None
    if args.rules:
        known = all_rules()
        rules = [r.strip().upper() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in known]
        if unknown:
            print(f"unknown rule id(s): {', '.join(unknown)}", file=sys.stderr)
            return 2

    try:
        result = analyze_paths(args.paths, root=args.root, rules=rules)
    except OSError as exc:
        print(f"repro.analysis: {exc}", file=sys.stderr)
        return 2

    if args.write_baseline:
        previous = {} if args.no_baseline else load_baseline(args.baseline)
        entries = write_baseline(args.baseline, result, previous)
        print(f"wrote {args.baseline}: {len(entries)} grandfathered finding(s) "
              f"across {result.files} file(s)")
        return 0

    baseline = {} if args.no_baseline else load_baseline(args.baseline)
    new, stale = diff_baseline(result, baseline)
    n_baselined = len(result.findings) - len(new)

    if args.format == "json":
        payload = {
            "files": result.files,
            "new": [f.to_dict() for f in new],
            "baselined": n_baselined,
            "suppressed": len(result.suppressed),
            "stale_baseline": stale,
        }
        print(json.dumps(payload, indent=1))
    else:
        for f in new:
            print(f.render())
        for fp in stale:
            entry = baseline[fp]
            print(f"{entry['path']}:{entry['line']}: STALE baseline entry "
                  f"{fp} ({entry['rule']}) — the finding is gone; retire it "
                  f"with --write-baseline")
        summary = (f"repro.analysis: {result.files} file(s), "
                   f"{len(new)} new finding(s), {n_baselined} baselined, "
                   f"{len(result.suppressed)} suppressed, "
                   f"{len(stale)} stale baseline entr(y/ies)")
        print(summary, file=sys.stderr if (new or stale) else sys.stdout)
    return 1 if (new or stale) else 0


if __name__ == "__main__":              # pragma: no cover
    sys.exit(main())
