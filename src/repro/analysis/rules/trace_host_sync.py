"""RPR001 trace-host-sync: host coercions on traced values in jitted code.

The bug class: ``float(x)`` / ``int(x)`` / ``bool(x)`` / ``x.item()`` /
``np.asarray(x)`` inside a jitted (or Pallas/``lax.scan``-traced) body
either raises ``TracerConversionError`` at trace time or — worse, on
concrete sub-paths — silently forces a device->host sync per call, which is
exactly the per-layer round-trip that made ``dp_backend='jax'`` lose to
numpy before PR 6 went device-resident.

Shape arithmetic is *static* under trace, so coercions whose argument only
touches ``.shape`` / ``.ndim`` / ``len(...)`` / constants are not flagged.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import FileContext, Finding, Rule, register

_COERCIONS = {"float", "int", "bool", "complex"}
_SYNC_METHODS = {"item", "tolist"}
_NP_BASES = {"np", "numpy", "onp"}
_NP_SYNCS = {"asarray", "array", "copy"}
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize"}


def _is_static_expr(node: ast.AST) -> bool:
    """True when every leaf of the expression is trace-time static."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
            return True
        if isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name) \
                and sub.func.id == "len":
            return True
    return all(isinstance(s, (ast.Constant, ast.UnaryOp, ast.BinOp, ast.unaryop,
                              ast.operator, ast.Load)) for s in ast.walk(node))


@register
class TraceHostSync(Rule):
    rule_id = "RPR001"
    name = "trace-host-sync"
    description = ("host coercion (float/int/bool/.item()/np.asarray) on a "
                   "traced value inside a jitted body")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        seen: set[int] = set()
        for fn in ctx.jit.traced_functions():
            fn_name = getattr(fn, "name", "<lambda>")
            for node in ast.walk(fn):
                if id(node) in seen or not isinstance(node, ast.Call):
                    continue
                seen.add(id(node))
                hit = self._classify(node)
                if hit:
                    yield ctx.finding(self, node,
                                      f"{hit} inside traced `{fn_name}` forces a "
                                      "host sync (or fails to trace); keep the "
                                      "value on device or hoist the coercion "
                                      "outside the jit boundary")

    def _classify(self, call: ast.Call) -> str | None:
        func = call.func
        if isinstance(func, ast.Name) and func.id in _COERCIONS:
            if len(call.args) == 1 and not _is_static_expr(call.args[0]):
                return f"`{func.id}(...)`"
        if isinstance(func, ast.Attribute):
            if func.attr in _SYNC_METHODS:
                return f"`.{func.attr}()`"
            if (func.attr in _NP_SYNCS and isinstance(func.value, ast.Name)
                    and func.value.id in _NP_BASES):
                return f"`{func.value.id}.{func.attr}(...)`"
        if isinstance(func, ast.Attribute) and func.attr == "device_get":
            return "`jax.device_get(...)`"
        return None
