"""Rule modules self-register on import (see ``core.all_rules``)."""
from repro.analysis.rules import (  # noqa: F401
    bench_parity,
    cache_aliasing,
    hygiene,
    recompile_hazard,
    trace_host_sync,
    x64_discipline,
)
