"""RPR003 bench-parity: benchmark timers comparing jitted vs bare callables.

The bug class (PR 5): ``kernel_bench`` timed ``jax.jit(ref...)`` against a
*bare* ``lambda`` over the Pallas entry — charging the Pallas side Python
dispatch + retrace overhead on every call that the jitted reference never
paid, skewing every kernel ratio.  Both sides of a timed comparison must
cross the same dispatch boundary.

Detection (benchmark files only): within one function, collect the callable
argument of every timing call (a call to ``_time``/``timeit``/``*_time*``
whose first argument is callable-shaped).  If at least one timed callable
is jit-wrapped (its expression — or the expression its name was assigned
from — mentions ``jit(``), then any *bare* timed callable in the same
function is flagged: a bare ``lambda``, a bare local ``def``, or a name /
attribute with no jit in sight.  Calls (``prog(params)``) are assumed to
return prepared device callables and are not flagged.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import FileContext, Finding, Rule, register

_TIMER_HINT = "time"


def _is_bench_file(path: str) -> bool:
    parts = path.replace("\\", "/").split("/")
    return "benchmarks" in parts or parts[-1].endswith("_bench.py")


def _mentions_jit(node: ast.AST) -> bool:
    return "jit(" in ast.unparse(node).replace(" ", "")


@register
class BenchParity(Rule):
    rule_id = "RPR003"
    name = "bench-parity"
    description = ("timing loop compares a jit-wrapped callable against a "
                   "bare one (dispatch/trace overhead skews the ratio)")

    def applies(self, ctx: FileContext) -> bool:
        return _is_bench_file(ctx.path)

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for fn in ctx.jit.function_nodes():
            if isinstance(fn, ast.Lambda):
                continue
            # only inspect top-level function scopes (methods included)
            yield from self._check_scope(ctx, fn)

    def _check_scope(self, ctx, fn) -> Iterable[Finding]:
        timed: list[ast.AST] = []
        assigns: dict[str, ast.AST] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and isinstance(node.value, ast.AST):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        assigns[tgt.id] = node.value
            if isinstance(node, ast.Call):
                name = None
                if isinstance(node.func, ast.Name):
                    name = node.func.id
                elif isinstance(node.func, ast.Attribute):
                    name = node.func.attr
                if name and _TIMER_HINT in name.lower() and node.args:
                    cand = node.args[0]
                    if isinstance(cand, (ast.Lambda, ast.Name, ast.Attribute,
                                         ast.Call)):
                        timed.append(cand)
        if len(timed) < 2:
            return

        def is_jitted(arg: ast.AST) -> bool:
            if _mentions_jit(arg):
                return True
            if isinstance(arg, ast.Name) and arg.id in assigns:
                return _mentions_jit(assigns[arg.id])
            return False

        if not any(is_jitted(a) for a in timed):
            return
        for arg in timed:
            if is_jitted(arg):
                continue
            if isinstance(arg, ast.Call):
                continue          # assume a prepared/jitted callable factory
            if isinstance(arg, ast.Name) and arg.id not in assigns:
                continue          # unknown origin (import/global): no verdict
            kind = ("bare lambda" if isinstance(arg, ast.Lambda) else
                    f"bare `{ast.unparse(arg)}`")
            yield ctx.finding(
                self, arg,
                f"{kind} timed against a jit-wrapped rival in the same "
                "function; wrap both sides in `jax.jit` (PR 5's "
                "kernel_bench dispatch skew)")
