"""RPR004 recompile-hazard: program caches that silently recompile per call.

The bug class (PR 6): ``dp_layer``'s ``lru_cache(maxsize=64)`` keyed the
compiled per-tile program on the cost-model *values* — which the trace does
not depend on at all — so a parameter sweep compiled (and at >64 sets,
evicted) one program per tuple.  The sibling shapes of the same bug:

- ``jax.jit(lambda ...)`` (or a freshly ``def``-ed local) created inside a
  loop: each iteration builds a new function object, so jax's jit cache —
  keyed on function identity — can never hit, and every call retraces.
- ``jax.jit(lambda ...)(...)`` immediate invocation: the wrapper is thrown
  away after one call, guaranteeing a retrace next time the line runs.
- ``functools.lru_cache`` over a function that builds jax programs/arrays:
  the cache keys on argument equality, not on what the trace depends on
  (and unhashable array arguments raise ``TypeError`` at first call).
  Key program caches structurally instead — see ``dp_layer._ProgramCache``.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import FileContext, Finding, Rule, register
from repro.analysis.jitinfo import _is_jit_like

_JAX_MARKERS = ("jnp.", "jax.", "pallas", "pl.")


@register
class RecompileHazard(Rule):
    rule_id = "RPR004"
    name = "recompile-hazard"
    description = ("per-call jit wrapping or value-keyed caching of compiled "
                   "programs (every call/entry recompiles)")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        local_defs = {getattr(n, "name", None)
                      for n in ctx.jit.function_nodes()}
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.For, ast.While)):
                yield from self._check_loop(ctx, node, local_defs)
            elif isinstance(node, ast.Call):
                yield from self._check_immediate(ctx, node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_lru(ctx, node)

    def _jit_wrap_of_fresh_fn(self, call: ast.AST, local_defs) -> bool:
        return (isinstance(call, ast.Call) and _is_jit_like(call.func)
                and bool(call.args)
                and (isinstance(call.args[0], ast.Lambda)
                     or (isinstance(call.args[0], ast.Name)
                         and call.args[0].id in local_defs)))

    def _check_loop(self, ctx, loop, local_defs) -> Iterable[Finding]:
        for node in ast.walk(loop):
            if node is loop:
                continue
            if self._jit_wrap_of_fresh_fn(node, local_defs):
                target = ("a lambda" if isinstance(node.args[0], ast.Lambda)
                          else f"local `{node.args[0].id}`")
                yield ctx.finding(
                    self, node,
                    f"`jax.jit` wraps {target} inside a loop: a fresh "
                    "function object per iteration defeats jax's "
                    "identity-keyed jit cache (retrace every pass) — hoist "
                    "the jitted wrapper out of the loop")

    def _check_immediate(self, ctx, call) -> Iterable[Finding]:
        if isinstance(call.func, ast.Call) \
                and self._jit_wrap_of_fresh_fn(call.func, set()):
            yield ctx.finding(
                self, call,
                "`jax.jit(lambda ...)(...)` builds and discards the jitted "
                "wrapper in one expression: every execution retraces — bind "
                "the jitted callable once and reuse it")

    def _check_lru(self, ctx, fn) -> Iterable[Finding]:
        for dec in fn.decorator_list:
            base = dec.func if isinstance(dec, ast.Call) else dec
            name = base.attr if isinstance(base, ast.Attribute) else (
                base.id if isinstance(base, ast.Name) else None)
            if name not in ("lru_cache", "cache"):
                continue
            body_src = "".join(ast.unparse(stmt) for stmt in fn.body)
            if any(m in body_src for m in _JAX_MARKERS):
                yield ctx.finding(
                    self, dec,
                    f"`{name}` over `{fn.name}`, which builds jax programs/"
                    "arrays: the cache keys on argument *values*, not on "
                    "what the trace depends on (PR 6's `_ProgramCache` bug; "
                    "unhashable array args raise TypeError) — key "
                    "structurally on the trace-relevant parts")
