"""Generic hygiene rules: the non-jax bug surface that still bit this repo.

- RPR101 mutable-default-arg: ``def f(x, acc=[])`` — the default is shared
  across calls; one caller's mutation leaks into the next.
- RPR102 broad-except: ``except:`` / ``except Exception:`` without a
  re-raise swallows everything, including the bit-identity assertion errors
  the differential tests exist to surface.  Deliberate record-and-continue
  boundaries (the dry-run sweep, resilience wrappers) suppress with the
  boundary contract as the reason.
- RPR103 assert-in-library: ``assert`` in ``src/`` vanishes under
  ``python -O`` — shape/contract checks that matter must raise.  (Asserts
  in tests and benchmarks are the point, and are not flagged.)
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import FileContext, Finding, Rule, register

_MUTABLE_CALLS = {"list", "dict", "set", "OrderedDict", "defaultdict", "Counter"}


@register
class MutableDefaultArg(Rule):
    rule_id = "RPR101"
    name = "mutable-default-arg"
    description = "mutable default argument shared across calls"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for fn in ast.walk(ctx.tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.Lambda)):
                continue
            for default in list(fn.args.defaults) + [
                    d for d in fn.args.kw_defaults if d is not None]:
                if self._is_mutable(default):
                    name = getattr(fn, "name", "<lambda>")
                    yield ctx.finding(
                        self, default,
                        f"mutable default in `{name}`: one call's mutation "
                        "leaks into the next — default to None and build "
                        "inside the body")

    def _is_mutable(self, node: ast.AST) -> bool:
        if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            base = node.func
            name = base.attr if isinstance(base, ast.Attribute) else (
                base.id if isinstance(base, ast.Name) else None)
            return name in _MUTABLE_CALLS
        return False


@register
class BroadExcept(Rule):
    rule_id = "RPR102"
    name = "broad-except"
    description = "bare/broad except without re-raise swallows real failures"

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            broad = node.type is None or (
                isinstance(node.type, ast.Name)
                and node.type.id in ("Exception", "BaseException"))
            if not broad:
                continue
            if any(isinstance(sub, ast.Raise) for sub in ast.walk(node)):
                continue
            what = "bare `except:`" if node.type is None else \
                f"`except {node.type.id}:`"
            yield ctx.finding(
                self, node,
                f"{what} without re-raise swallows everything (including "
                "differential-test assertion errors) — narrow the exception "
                "set, or suppress citing the record-and-continue boundary")


@register
class AssertInLibrary(Rule):
    rule_id = "RPR103"
    name = "assert-in-library"
    description = "assert in library code vanishes under python -O"

    def applies(self, ctx: FileContext) -> bool:
        path = ctx.path.replace("\\", "/")
        parts = path.split("/")
        in_src = "src" in parts or "/repro/" in f"/{path}"
        is_test = any(p.startswith("test") or p == "tests" for p in parts)
        return in_src and not is_test

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Assert):
                yield ctx.finding(
                    self, node,
                    "`assert` in library code is stripped under `python -O` "
                    "— raise an explicit exception for contract checks that "
                    "must hold in production")
