"""RPR005 x64-discipline: jax float64 escaping ``enable_x64`` in kernels.

The bug class: the DP prices in float64 to stay bit-identical to the numpy
sweep, but jax silently *downcasts to float32* when ``enable_x64`` is off —
no error, just plans that stop matching the oracle on tie-breaks.  Every
``jnp.float64`` (or ``dtype="float64"`` handed to a jnp/jax call) in
``src/repro/kernels/`` must therefore sit under an ``enable_x64`` context:

- lexically inside a ``with enable_x64():`` block, or
- inside a function that *contains* such a block or the
  ``if jax.config.jax_enable_x64: ...`` guard pattern (the ``run()``
  closure idiom in ``dp_layer.py``), or any enclosing function that does.

Host-side ``np.float64`` is exempt — numpy is always 64-bit.  A function
whose *callers* hold the context by documented contract can't be proven
safe syntactically: suppress with that contract as the reason.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import FileContext, Finding, Rule, register

_JNP_BASES = {"jnp", "jax"}


def _is_kernels_file(path: str) -> bool:
    return "kernels" in path.replace("\\", "/").split("/")[:-1]


def _has_x64_guard(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.With):
            for item in node.items:
                if "enable_x64" in ast.unparse(item.context_expr):
                    return True
        if isinstance(node, ast.If) and "jax_enable_x64" in ast.unparse(node.test):
            return True
    return False


@register
class X64Discipline(Rule):
    rule_id = "RPR005"
    name = "x64-discipline"
    description = ("jax float64 dtype used outside an enable_x64 context in "
                   "kernel code (silent downcast to float32 breaks "
                   "bit-identity)")

    def applies(self, ctx: FileContext) -> bool:
        return _is_kernels_file(ctx.path)

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for node in ast.walk(ctx.tree):
            ref = self._f64_ref(node)
            if ref is None:
                continue
            if self._guarded(ctx, node):
                continue
            yield ctx.finding(
                self, node,
                f"{ref} outside an `enable_x64` context: jax silently "
                "downcasts to float32 and plans drift off the numpy oracle "
                "on tie-breaks — enter `enable_x64` (or suppress citing the "
                "caller's documented context)")

    def _f64_ref(self, node: ast.AST) -> str | None:
        if isinstance(node, ast.Attribute) and node.attr == "float64" \
                and isinstance(node.value, ast.Name) \
                and node.value.id in _JNP_BASES:
            return f"`{node.value.id}.float64`"
        if isinstance(node, ast.Constant) and node.value == "float64":
            return "`\"float64\"` dtype literal"
        return None

    def _guarded(self, ctx: FileContext, node: ast.AST) -> bool:
        for anc in ctx.ancestors(node):
            if isinstance(anc, ast.With):
                for item in anc.items:
                    if "enable_x64" in ast.unparse(item.context_expr):
                        return True
            if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)) and _has_x64_guard(anc):
                return True
        return False
