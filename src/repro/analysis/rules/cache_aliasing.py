"""RPR002 cache-aliasing: caches handing out (or storing) shared mutable state.

The bug class (PR 2/3): ``PlanCache`` hits returned the *stored* plan tree;
callers mutated ``est_cardinality`` / ``sources`` / ``selection.star_sources``
in place — exactly what failover-style source exclusion does — and silently
corrupted every later hit.  The fix pattern is to detach/deep-copy at the
cache boundary (store pristine, hand out fresh).

Detection: inside a class whose name contains ``Cache`` (or ``Memo``), a
``get``/``put``-shaped method that

- returns a value read straight out of a ``self.<store>`` container
  (``return self._entries[k]`` / ``x = self._entries.get(k); ...; return x``)
  without routing it through a call (``detach``/``deepcopy``/constructor), or
- stores a bare caller-owned parameter into ``self.<store>`` without a
  wrapping call.

Handing out genuinely immutable entries (compiled callables, tuples) is
fine — suppress with a reason stating the immutability contract.

A second check guards *detach completeness* (PR 8): modules that define a
``PlanNode``-style class hierarchy next to copy/rename detach helpers
(``_copy_node`` / ``_rename_node`` / ``detach``) must reference every
subclass by name inside each helper.  When a new plan-node variant (say
``LeftJoinPlanNode``) is added but the detach helper's dispatch chain is
not extended, cache hits hand out trees whose new nodes alias the stored
entry — the same corruption, one level down.
"""
from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.core import FileContext, Finding, Rule, register

_GET_NAMES = {"get", "lookup", "fetch", "hit"}
_PUT_NAMES = {"put", "set", "store", "add", "insert"}

# detach-helper shapes: functions whose job is a per-variant deep copy of a
# node tree; every node subclass must appear in each of them
_DETACH_HELPER_NAMES = {"_copy_node", "_rename_node"}
_NODE_BASE_SUFFIX = "PlanNode"


def _is_self_store_read(node: ast.AST) -> bool:
    """``self.<attr>[k]`` or ``self.<attr>.get(k)``."""
    if isinstance(node, ast.Subscript):
        return _is_self_attr(node.value)
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
            and node.func.attr in ("get", "setdefault", "pop"):
        return _is_self_attr(node.func.value)
    return False


def _is_self_attr(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name) and node.value.id == "self")


@register
class CacheAliasing(Rule):
    rule_id = "RPR002"
    name = "cache-aliasing"
    description = ("cache get/put hands out or stores a shared mutable object "
                   "without detach/deepcopy at the boundary")

    def check(self, ctx: FileContext) -> Iterable[Finding]:
        for cls in ast.walk(ctx.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            if "Cache" not in cls.name and "Memo" not in cls.name:
                continue
            for meth in cls.body:
                if not isinstance(meth, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if meth.name in _GET_NAMES:
                    yield from self._check_get(ctx, cls, meth)
                elif meth.name in _PUT_NAMES:
                    yield from self._check_put(ctx, cls, meth)
        yield from self._check_detach_completeness(ctx)

    def _check_get(self, ctx, cls, meth) -> Iterable[Finding]:
        tainted: set[str] = set()
        for node in ast.walk(meth):
            if isinstance(node, ast.Assign) and _is_self_store_read(node.value):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        tainted.add(tgt.id)
            elif isinstance(node, ast.Assign):
                # reassignment from anything else cleanses the name
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        tainted.discard(tgt.id)
            elif isinstance(node, ast.Return) and node.value is not None:
                val = node.value
                direct = _is_self_store_read(val)
                aliased = isinstance(val, ast.Name) and val.id in tainted
                if direct or aliased:
                    yield ctx.finding(
                        self, node,
                        f"`{cls.name}.{meth.name}` returns the stored entry "
                        "itself; a caller mutating it corrupts every later "
                        "hit — detach/deep-copy at the boundary (or suppress "
                        "with the immutability contract as the reason)")

    def _check_detach_completeness(self, ctx) -> Iterable[Finding]:
        """Every ``*PlanNode`` subclass defined in a module must be referenced
        by name inside each of the module's detach helpers (``_copy_node`` /
        ``_rename_node``) — an unhandled variant aliases the cached tree."""
        base_names = {
            cls.name for cls in ctx.tree.body
            if isinstance(cls, ast.ClassDef) and cls.name.endswith(_NODE_BASE_SUFFIX)
            and not any(isinstance(b, ast.Name) and
                        b.id.endswith(_NODE_BASE_SUFFIX) for b in cls.bases)
        }
        subclasses = [
            cls.name for cls in ctx.tree.body
            if isinstance(cls, ast.ClassDef)
            and any(isinstance(b, ast.Name) and b.id in base_names
                    for b in cls.bases)
        ]
        if not subclasses:
            return
        for fn in ctx.tree.body:
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if fn.name not in _DETACH_HELPER_NAMES:
                continue
            referenced = {n.id for n in ast.walk(fn) if isinstance(n, ast.Name)}
            for missing in subclasses:
                if missing not in referenced:
                    yield ctx.finding(
                        self, fn,
                        f"detach helper `{fn.name}` does not handle plan-node "
                        f"variant `{missing}`; a cached tree containing one "
                        "would be handed out aliased — extend the dispatch "
                        "chain")

    def _check_put(self, ctx, cls, meth) -> Iterable[Finding]:
        params = {a.arg for a in meth.args.args[1:]}    # skip self
        for node in ast.walk(meth):
            if not isinstance(node, ast.Assign):
                continue
            for tgt in node.targets:
                if isinstance(tgt, ast.Subscript) and _is_self_attr(tgt.value) \
                        and isinstance(node.value, ast.Name) \
                        and node.value.id in params:
                    yield ctx.finding(
                        self, node,
                        f"`{cls.name}.{meth.name}` stores caller-owned "
                        f"`{node.value.id}` directly; the caller keeps a "
                        "reference and can mutate the cached entry — store a "
                        "detached copy")
