"""Inline suppression comments.

Syntax (the reason is mandatory — a silence with no recorded justification
is exactly the kind of unreviewable precedent this suite exists to kill):

    x = float(y)  # repro: ignore[RPR001] -- host value by contract, see docstring
    # repro: ignore[RPR002, RPR004] -- compiled callables are immutable;
    # continuation comment lines may elaborate before the code line
    entry = cache.get(sig)

A trailing comment covers its own line; a comment-only line covers the next
non-comment, non-blank line (so a multi-line reason can elaborate in the
comment lines between).  Malformed suppressions (no rule list, empty
reason) never silence anything — the runner turns them into ``RPR100``
findings instead.
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize

_PATTERN = re.compile(
    r"#\s*repro:\s*ignore"
    r"(?:\[(?P<rules>[^\]]*)\])?"
    r"(?:\s*(?:--|:)\s*(?P<reason>.*))?\s*$")


@dataclasses.dataclass(frozen=True)
class Suppression:
    rules: tuple[str, ...]
    reason: str
    comment_line: int     # where the ignore comment itself sits
    valid: bool
    error: str = ""


def parse_suppressions(source: str) -> dict[int, Suppression]:
    """Map *covered* line number -> suppression.

    The key is the line a suppression silences: the comment's own line for a
    trailing comment, the next non-comment non-blank line for a comment-only
    line.  ``comment_line`` keeps the comment's location for RPR100 reports.
    """
    out: dict[int, Suppression] = {}
    lines = source.splitlines()
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return out
    for tok in tokens:
        if tok.type != tokenize.COMMENT or "repro:" not in tok.string:
            continue
        m = _PATTERN.search(tok.string)
        if m is None:
            continue
        lineno = tok.start[0]
        own_line = tok.line.strip().startswith("#")
        raw_rules = (m.group("rules") or "").strip()
        reason = (m.group("reason") or "").strip()
        rules = tuple(r.strip().upper() for r in raw_rules.split(",") if r.strip())
        if not rules:
            sup = Suppression((), reason, lineno, valid=False,
                              error="suppression without a rule list: use "
                                    "`# repro: ignore[RPR00x] -- reason`")
        elif not reason:
            sup = Suppression(rules, "", lineno, valid=False,
                              error=f"suppression of [{', '.join(rules)}] "
                                    "without a reason (reason is mandatory)")
        else:
            sup = Suppression(rules, reason, lineno, valid=True)
        target = lineno
        if own_line:
            target = _next_code_line(lines, lineno)
        out[target] = sup
    return out


def _next_code_line(lines: list[str], comment_line: int) -> int:
    """First line after ``comment_line`` that is not blank or a comment."""
    for i in range(comment_line, len(lines)):
        stripped = lines[i].strip()          # lines[i] is 1-based line i+1
        if stripped and not stripped.startswith("#"):
            return i + 1
    return comment_line
