"""Roofline analysis from compiled dry-run artifacts.

XLA's ``cost_analysis`` visits ``while`` bodies ONCE (verified empirically:
a 10-step scanned matmul reports the FLOPs of one step), so scanned-layer
models would be undercounted ~n_layers×. We therefore parse the optimized
per-device HLO ourselves:

  * symbol table per computation (%name -> shape);
  * ``dot``/``convolution`` FLOPs from shapes + contracting dims;
  * HBM traffic modeled at fusion boundaries (sum of operand/output bytes of
    non-trivial instructions — exactly what must cross HBM between fusions);
  * collective bytes = operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute;
  * ``while`` trip counts recovered from the largest integer constant in the
    loop condition computation (scan bounds), with a config fallback;
  * nested computations multiply by their call-site trip counts.

Shapes in the post-SPMD module are PER-DEVICE, so the three roofline terms
divide by per-chip peaks directly:

    compute_s    = flops_per_dev / 197e12        (TPU v5e bf16)
    memory_s     = hbm_bytes_per_dev / 819e9
    collective_s = coll_bytes_per_dev / 50e9     (per ICI link)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string (tuples summed)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_elems(shape_str: str) -> int:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


@dataclass
class Instr:
    name: str
    opcode: str
    shape: str
    operands: list[str]
    raw: str


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    symbols: dict[str, str] = field(default_factory=dict)  # %name -> shape


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_ATTR_COMP_RE = re.compile(
    r"(condition|body|to_apply|calls|called_computations)=\{?%?([\w.\-]+)")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        # computation header: column-0, has a param list, ends with '{',
        # and is not an instruction (no ' = ' before the brace)
        if (not line.startswith(" ") and stripped.endswith("{")
                and "(" in stripped and " = " not in stripped.split("(")[0]):
            name_tok = stripped.split("(")[0].strip()
            name_tok = name_tok.replace("ENTRY", "").strip().lstrip("%")
            if name_tok:
                cur = Computation(name_tok)
                comps[cur.name] = cur
                continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape, opcode, rest = m.groups()
            args_part = rest.split(")", 1)[0]
            operands = _OPERAND_RE.findall(args_part)
            cur.instrs.append(Instr(name, opcode, shape, operands, line))
            cur.symbols[name] = shape
    return comps


def _trip_count(comps: dict[str, Computation], cond_name: str, default: int) -> int:
    """Loop bound from the condition computation: prefer constants compared
    against the induction variable, fall back to the largest constant."""
    comp = comps.get(cond_name)
    if comp is None:
        return default
    search = [comp]
    for ins in comp.instrs:
        for _attr, target in _ATTR_COMP_RE.findall(ins.raw):
            if target in comps:
                search.append(comps[target])
    cmp_consts: list[int] = []
    all_consts: list[int] = []
    for c in search:
        const_of: dict[str, int] = {}
        for ins in c.instrs:
            if ins.opcode == "constant":
                m = re.search(r"constant\((\d+)\)", ins.raw)
                if m:
                    const_of[ins.name] = int(m.group(1))
                    all_consts.append(int(m.group(1)))
        for ins in c.instrs:
            if ins.opcode == "compare":
                for op in ins.operands:
                    if op in const_of:
                        cmp_consts.append(const_of[op])
    if cmp_consts:
        return max(cmp_consts)
    if all_consts:
        return max(all_consts)
    return default


_TRIVIAL = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "copy", "copy-start", "copy-done", "after-all", "partition-id",
            "replica-id", "iota", "broadcast", "reshape", "convert"}

# fusion roots that are CPU-backend dtype/layout artifacts: on TPU these fold
# into their consumers (bf16 is native), so they carry no HBM traffic of
# their own — producers/consumers are already accounted
_ARTIFACT_ROOTS = {"convert", "copy", "bitcast", "reshape", "broadcast",
                   "transpose"}


def _called_of(ins: "Instr") -> str | None:
    for attr, target in _ATTR_COMP_RE.findall(ins.raw):
        if attr == "calls":
            return target
    return None


def _fusion_root(comps: dict, ins: "Instr"):
    called = comps.get(_called_of(ins))
    if called and called.instrs:
        return called.instrs[-1]
    return None


@dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: dict[str, float] = field(default_factory=dict)
    collective_count: dict[str, int] = field(default_factory=dict)
    max_while_trip: int = 0


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = shape_elems(ins.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.raw)
    k = 1
    if m and ins.operands:
        lhs_shape = comp.symbols.get(ins.operands[0], "")
        sm = _SHAPE_RE.search(lhs_shape)
        if sm and sm.group(2):
            dims = [int(d) for d in sm.group(2).split(",")]
            for ci in m.group(1).split(","):
                if ci != "" and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def analyze(text: str, default_trip: int = 1) -> HloCosts:
    comps = parse_hlo(text)
    entry = None
    for name in comps:
        if "main" in name or entry is None:
            if entry is None or "main" in name:
                entry = name
    costs = HloCosts()

    def walk(comp_name: str, mult: float, fusion_internal: bool = False) -> None:
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.opcode == "while":
                body = cond = None
                for attr, target in _ATTR_COMP_RE.findall(ins.raw):
                    if attr == "body":
                        body = target
                    elif attr == "condition":
                        cond = target
                trips = _trip_count(comps, cond, default_trip) if cond else default_trip
                costs.max_while_trip = max(costs.max_while_trip, int(trips))
                if body:
                    walk(body, mult * trips, fusion_internal)
                continue
            if ins.opcode in ("call", "conditional", "async-start"):
                for attr, target in _ATTR_COMP_RE.findall(ins.raw):
                    if attr in ("to_apply", "calls", "called_computations") and target != comp_name:
                        walk(target, mult, fusion_internal)
            elif ins.opcode == "fusion":
                # fusion internals: count dots, but HBM traffic is the
                # fusion's own operands/outputs (counted at this call site)
                for attr, target in _ATTR_COMP_RE.findall(ins.raw):
                    if attr == "calls" and target != comp_name:
                        walk(target, mult, fusion_internal=True)
                root = _fusion_root(comps, ins)
                if root is not None:
                    if root.opcode in _ARTIFACT_ROOTS and len(ins.operands) <= 2:
                        continue  # dtype/layout artifact: no traffic on TPU
                    if root.opcode == "dynamic-update-slice":
                        # in-place cache update: only the slice moves
                        called = comps.get(_called_of(ins))
                        upd = called.symbols.get(root.operands[1], "") if (
                            called and len(root.operands) > 1) else ""
                        costs.hbm_bytes += mult * 2 * shape_bytes(upd)
                        continue
            if ins.opcode in ("dot", "convolution"):
                costs.flops += mult * _dot_flops(ins, comp)
            base = ins.opcode.replace("-start", "")
            if not fusion_internal and any(base == c for c in _COLLECTIVES):
                b = sum(shape_bytes(comp.symbols.get(op, "")) for op in ins.operands)
                if b == 0:
                    b = shape_bytes(ins.shape)
                costs.collective_bytes += mult * b
                costs.by_collective[base] = costs.by_collective.get(base, 0.0) + mult * b
                costs.collective_count[base] = costs.collective_count.get(base, 0) + 1
            if not fusion_internal and ins.opcode not in _TRIVIAL:
                # HBM traffic model at fusion boundaries. In-place-updatable /
                # gathering ops move only the touched slice, not the buffer:
                if ins.opcode == "dynamic-update-slice":
                    upd = comp.symbols.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
                    io = 2 * shape_bytes(upd)
                elif ins.opcode in ("dynamic-slice", "gather", "scatter",
                                    "select-and-scatter", "pad", "slice",
                                    "concatenate", "transpose", "reverse"):
                    io = 2 * shape_bytes(ins.shape)
                else:
                    io = shape_bytes(ins.shape) + sum(
                        shape_bytes(comp.symbols.get(op, "")) for op in ins.operands)
                costs.hbm_bytes += mult * io

    walk(entry, 1.0)
    return costs


# ---------------------------------------------------------------------------
# roofline report
# ---------------------------------------------------------------------------

@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_dev: float
    hbm_bytes_per_dev: float
    collective_bytes_per_dev: float
    model_flops_total: float
    xla_flops_reported: float
    xla_bytes_reported: float
    by_collective: dict[str, float]
    memory_per_dev_bytes: float = 0.0
    max_while_trip: int = 0

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        hw = self.flops_per_dev * self.n_chips
        return self.model_flops_total / hw if hw else 0.0

    @property
    def roofline_fraction(self) -> float:
        """MODEL_FLOPS-time / dominant-term-time: how close the compiled
        program runs to the pure-compute roofline of the useful math."""
        ideal = self.model_flops_total / (self.n_chips * PEAK_FLOPS)
        actual = max(self.compute_s, self.memory_s, self.collective_s)
        return ideal / actual if actual else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_chips": self.n_chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "flops_per_dev": self.flops_per_dev,
            "hbm_bytes_per_dev": self.hbm_bytes_per_dev,
            "collective_bytes_per_dev": self.collective_bytes_per_dev,
            "model_flops_total": self.model_flops_total,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "xla_flops_reported": self.xla_flops_reported,
            "xla_bytes_reported": self.xla_bytes_reported,
            "by_collective": self.by_collective,
            "memory_per_dev_bytes": self.memory_per_dev_bytes,
            "max_while_trip": self.max_while_trip,
        }


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs: 6·N_active·tokens for training, 2·N_active·tokens
    (+ KV-cache attention reads) for decode/prefill."""
    n_act = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        flops = 6.0 * n_act * B * S
        flops += _attn_flops(cfg, B, S, train=True) * 3  # fwd + bwd(2x)
    elif shape.kind == "prefill":
        flops = 2.0 * n_act * B * S + _attn_flops(cfg, B, S, train=False)
    else:  # decode: one token against S_ctx cache
        flops = 2.0 * n_act * B
        flops += _attn_decode_flops(cfg, B, S)
    return flops


def _attn_layers(cfg) -> int:
    return sum(1 for i in range(cfg.n_layers) if cfg.mixer_of(i) in ("g", "l"))


def _attn_flops(cfg, B, S, train: bool) -> float:
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.mixer_of(i)
        if kind not in ("g", "l"):
            continue
        ctx = min(S, cfg.local_window) if (kind == "l" and cfg.local_window) else S
        # qk^T and att@v: 2 * 2 * B * S * ctx * H * hd, causal halves it
        total += 2.0 * B * S * ctx * cfg.n_heads * cfg.hd
    return total


def _attn_decode_flops(cfg, B, S_ctx) -> float:
    total = 0.0
    for i in range(cfg.n_layers):
        kind = cfg.mixer_of(i)
        if kind not in ("g", "l"):
            continue
        ctx = min(S_ctx, cfg.local_window) if (kind == "l" and cfg.local_window) else S_ctx
        total += 4.0 * B * ctx * cfg.n_heads * cfg.hd
    return total
