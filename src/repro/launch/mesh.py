"""Production mesh definitions.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run pins the fake device count before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} "
            "(the dry-run must set --xla_force_host_platform_device_count=512 "
            "before any jax import)")
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many (fake) devices a test process has."""
    import numpy as np
    from jax.sharding import Mesh

    n = int(np.prod(shape))
    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)
