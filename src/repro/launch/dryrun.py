import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory/cost analysis + roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both \
        --out results/dryrun.json

The two lines above MUST stay the first statements in this module: jax locks
the device count at first init, and only the dry-run may see 512 fake
devices.
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from functools import partial  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.config.base import SHAPES, ShapeConfig  # noqa: E402
from repro.configs import ARCH_IDS, get_arch  # noqa: E402
from repro.launch import roofline as RL  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as MDL  # noqa: E402
from repro.models import sharding as SH  # noqa: E402
from repro.train.optimizer import make_optimizer  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402


def _cache_hlo(arch: str, shape: str, multi_pod: bool, optimized: bool,
               hlo: str, default_trip: int) -> None:
    """Persist compiled HLO (gzip) so analyzer improvements re-analyze
    without recompiling (see --reanalyze)."""
    import gzip

    os.makedirs("results/hlo", exist_ok=True)
    tag = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}" \
          f"{'__opt' if optimized else ''}"
    with gzip.open(f"results/hlo/{tag}.hlo.gz", "wt") as f:
        f.write(f"// default_trip={default_trip}\n")
        f.write(hlo)


def reanalyze(out_path: str) -> None:
    """Recompute roofline terms from cached HLO into an existing results
    json (after analyzer refinements)."""
    import gzip
    import re as _re

    with open(out_path) as f:
        results = json.load(f)
    for key, r in results.items():
        if r.get("status") != "ok":
            continue
        a, s, m = key.split("|")
        tag = f"{a}__{s}__{m}{'__opt' if out_path.endswith('_opt.json') else ''}"
        path = f"results/hlo/{tag}.hlo.gz"
        if not os.path.exists(path):
            continue
        with gzip.open(path, "rt") as f:
            text = f.read()
        trip = int(_re.match(r"// default_trip=(\d+)", text).group(1))
        costs = RL.analyze(text, default_trip=trip)
        r["flops_per_dev"] = costs.flops
        r["hbm_bytes_per_dev"] = costs.hbm_bytes
        r["collective_bytes_per_dev"] = costs.collective_bytes
        r["by_collective"] = costs.by_collective
        r["compute_s"] = costs.flops / RL.PEAK_FLOPS
        r["memory_s"] = costs.hbm_bytes / RL.HBM_BW
        r["collective_s"] = costs.collective_bytes / RL.ICI_BW
        terms = {"compute": r["compute_s"], "memory": r["memory_s"],
                 "collective": r["collective_s"]}
        r["bottleneck"] = max(terms, key=terms.get)
        ideal = r["model_flops_total"] / (r["n_chips"] * RL.PEAK_FLOPS)
        actual = max(terms.values())
        r["roofline_fraction"] = ideal / actual if actual else 0.0
    with open(out_path, "w") as f:
        json.dump(results, f, indent=1)
    print(f"reanalyzed {out_path}")


def cell_skip_reason(arch_id: str, shape_name: str) -> str | None:
    cfg = get_arch(arch_id)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return "full attention is quadratic at 500k (DESIGN.md §4)"
    return None


def _opt_state_shardings(mesh, params_shape, p_shardings, opt_state_shape):
    """Optimizer states: mirror parameter shardings where shapes match,
    replicate factored/scalar states (they are tiny)."""
    flat_params = {tuple(str(getattr(k, 'key', k)) for k, _ in []): None}
    p_map = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(params_shape):
        p_map[tuple(str(getattr(k, "key", k)) for k in path)] = leaf.shape
    sh_map = {}
    for path, s in jax.tree_util.tree_leaves_with_path(p_shardings):
        sh_map[tuple(str(getattr(k, "key", k)) for k in path)] = s

    def spec_of(path, leaf):
        keys = tuple(str(getattr(k, "key", k)) for k in path)
        # strip leading optimizer-state keys ("m"/"v") and trailing factored
        for start in range(len(keys)):
            cand = keys[start:]
            if cand in p_map and p_map[cand] == leaf.shape:
                return sh_map[cand]
            if cand[:-1] in p_map and p_map[cand[:-1]] == leaf.shape:
                return sh_map[cand[:-1]]
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))

    return jax.tree_util.tree_map_with_path(spec_of, opt_state_shape)


def optimized_flags(cfg, shape):
    """Per-cell beyond-baseline switches (EXPERIMENTS.md §Perf)."""
    from repro.config.base import PerfFlags

    return PerfFlags(
        chunked_attention=shape.kind != "decode",
        attn_chunk=1024,
        chunked_loss=shape.kind == "train",
        loss_chunk=512,
        mamba_chunk=512 if cfg.ssm is not None else 0,
        mla_absorb=cfg.mla is not None,
        seq_parallel=shape.kind != "decode",
        kv_quant_int8=shape.kind == "decode" and cfg.mla is None,
    )


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool,
               optimized: bool = False) -> dict:
    import dataclasses

    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    dtype = jnp.bfloat16
    if optimized:
        cfg = dataclasses.replace(cfg, perf=optimized_flags(cfg, shape))
        if cfg.perf.seq_parallel and shape.seq_len % mesh.shape["model"] == 0:
            dp = SH.batch_spec(mesh, shape)[0]
            sp_sharding = NamedSharding(mesh, P(dp, "model", None))

            def policy(x, kind):
                if kind == "residual" and x.ndim == 3 and x.shape[1] == shape.seq_len:
                    return jax.lax.with_sharding_constraint(x, sp_sharding)
                return x

            MDL.set_activation_policy(policy)

    params_shape = jax.eval_shape(
        lambda k: MDL.init_params(cfg, k, dtype), jax.random.PRNGKey(0))
    p_shardings = SH.param_shardings(cfg, mesh, params_shape)
    batch_shapes = MDL.input_specs(cfg, shape, dtype)
    bspec = SH.batch_spec(mesh, shape)
    b_shardings = {}
    for k, v in batch_shapes.items():
        if v.ndim == 2:
            b_shardings[k] = NamedSharding(mesh, bspec)
        else:
            b_shardings[k] = NamedSharding(mesh, P(bspec[0], None, "model"))

    t0 = time.time()
    if shape.kind == "train":
        opt_name = "adafactor" if cfg.param_count() > 1e11 else "adamw"
        opt = make_optimizer(opt_name)
        opt_state_shape = jax.eval_shape(opt.init, params_shape)
        o_shardings = _opt_state_shardings(mesh, params_shape, p_shardings,
                                           opt_state_shape)
        step = make_train_step(cfg, opt)
        fn = jax.jit(step,
                     in_shardings=(p_shardings, o_shardings, b_shardings),
                     out_shardings=(p_shardings, o_shardings, None),
                     donate_argnums=(0, 1))
        lowered = fn.lower(params_shape, opt_state_shape, batch_shapes)
        default_trip = MDL.group_structure(cfg)[1] or 1
    elif shape.kind == "prefill":
        def prefill(params, batch):
            logits, _ = MDL.forward(cfg, params, batch)
            return logits
        vocab_ax = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
        fn = jax.jit(prefill, in_shardings=(p_shardings, b_shardings),
                     out_shardings=NamedSharding(mesh, P(bspec[0], None, vocab_ax)))
        lowered = fn.lower(params_shape, batch_shapes)
        default_trip = MDL.group_structure(cfg)[1] or 1
    else:  # decode
        caches_shape = jax.eval_shape(
            partial(MDL.init_decode_caches, cfg, shape.global_batch,
                    shape.seq_len, dtype))
        c_specs = SH.cache_specs(cfg, mesh, shape, caches_shape)
        c_shardings = SH.to_shardings(mesh, c_specs)
        tok_sh = NamedSharding(mesh, bspec)

        def serve_step(params, caches, tokens, pos):
            return MDL.decode_step(cfg, params, caches, tokens, pos)

        fn = jax.jit(serve_step,
                     in_shardings=(p_shardings, c_shardings, tok_sh, None),
                     out_shardings=(None, c_shardings),
                     donate_argnums=(1,))
        tok_s = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        pos_s = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = fn.lower(params_shape, caches_shape, tok_s, pos_s)
        default_trip = MDL.group_structure(cfg)[1] or 1

    compiled = lowered.compile()
    MDL.set_activation_policy(None)
    compile_s = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_dict = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    # repro: ignore[RPR102] -- memory_analysis raises backend-specific types
    # (XlaRuntimeError, NotImplementedError, ...) we cannot enumerate; the
    # failure is recorded in mem_dict["error"] and surfaces in the report
    except Exception as exc:  # pragma: no cover - backend specific
        mem_dict = {"error": str(exc)}
    try:
        cost = compiled.cost_analysis() or {}
    # repro: ignore[RPR102] -- same backend-specific surface as
    # memory_analysis above; cost analysis is optional enrichment and the
    # roofline terms are recomputed from the HLO text regardless
    except Exception:  # pragma: no cover
        cost = {}

    hlo = compiled.as_text()
    _cache_hlo(arch_id, shape_name, multi_pod, optimized, hlo, default_trip)
    costs = RL.analyze(hlo, default_trip=default_trip)
    # explicit per-device memory estimate from argument shardings
    arg_bytes = 0
    for leaf in jax.tree.leaves(params_shape):
        arg_bytes += leaf.size * leaf.dtype.itemsize
    per_dev_param_bytes = arg_bytes // n_chips

    rf = RL.Roofline(
        arch=arch_id, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16",
        n_chips=n_chips,
        flops_per_dev=costs.flops,
        hbm_bytes_per_dev=costs.hbm_bytes,
        collective_bytes_per_dev=costs.collective_bytes,
        model_flops_total=RL.model_flops(cfg, shape),
        xla_flops_reported=float(cost.get("flops", 0.0)),
        xla_bytes_reported=float(cost.get("bytes accessed", 0.0)),
        by_collective=costs.by_collective,
        memory_per_dev_bytes=float(mem_dict.get("peak_bytes") or 0.0),
        max_while_trip=costs.max_while_trip,
    )
    out = rf.to_dict()
    out.update({
        "status": "ok",
        "compile_s": compile_s,
        "memory_analysis": mem_dict,
        "param_bytes_per_dev": per_dev_param_bytes,
        "collective_counts": costs.collective_count,
        "hlo_bytes": len(hlo),
    })
    return out


def lower_fed_cell(multi_pod: bool, optimized: bool = False) -> dict:
    """The paper's own system: canonical federated query step."""
    from repro.engine.distributed import fed_dryrun_lower

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered = fed_dryrun_lower(mesh, cap=8192, table_cap=1 << 20,
                               optimized=optimized)
    compiled = lowered.compile()
    compile_s = time.time() - t0
    hlo = compiled.as_text()
    costs = RL.analyze(hlo, default_trip=1)
    try:
        cost = compiled.cost_analysis() or {}
    # repro: ignore[RPR102] -- backend-specific cost_analysis surface, as in
    # lower_cell; optional enrichment only, roofline terms come from the HLO
    except Exception:
        cost = {}
    rf = RL.Roofline(
        arch="odyssey-fed", shape="fed_query",
        mesh="2x16x16" if multi_pod else "16x16",
        n_chips=mesh.size,
        flops_per_dev=costs.flops,
        hbm_bytes_per_dev=costs.hbm_bytes,
        collective_bytes_per_dev=costs.collective_bytes,
        model_flops_total=0.0,
        xla_flops_reported=float(cost.get("flops", 0.0)),
        xla_bytes_reported=float(cost.get("bytes accessed", 0.0)),
        by_collective=costs.by_collective,
        max_while_trip=costs.max_while_trip,
    )
    out = rf.to_dict()
    out.update({"status": "ok", "compile_s": compile_s,
                "collective_counts": costs.collective_count,
                "hlo_bytes": len(hlo)})
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, 'all', or 'odyssey-fed'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present in --out")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf beyond-baseline flags")
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute terms from cached HLO, no compilation")
    args = ap.parse_args()
    if args.reanalyze:
        reanalyze(args.out)
        return

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results: dict[str, dict] = {}
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    def save():
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    cells = []
    for mp in meshes:
        for a in archs:
            if a == "odyssey-fed":
                cells.append((a, "fed_query", mp))
                continue
            for s in shapes:
                cells.append((a, s, mp))
        if args.arch == "all":
            cells.append(("odyssey-fed", "fed_query", mp))

    for (a, s, mp) in cells:
        key = f"{a}|{s}|{'multi' if mp else 'single'}"
        if args.resume and key in results and results[key].get("status") in ("ok", "skipped"):
            continue
        if a != "odyssey-fed":
            reason = cell_skip_reason(a, s)
            if reason:
                results[key] = {"status": "skipped", "reason": reason,
                                "arch": a, "shape": s}
                save()
                print(f"SKIP {key}: {reason}", flush=True)
                continue
        print(f"LOWER {key} ...", flush=True)
        try:
            if a == "odyssey-fed":
                results[key] = lower_fed_cell(mp, optimized=args.optimized)
            else:
                results[key] = lower_cell(a, s, mp, optimized=args.optimized)
            r = results[key]
            print(f"  ok in {r['compile_s']:.1f}s: bottleneck={r['bottleneck']} "
                  f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                  f"collective={r['collective_s']:.4f}s", flush=True)
        # repro: ignore[RPR102] -- per-cell record-and-continue boundary: a
        # multi-hour sweep must not die on one (arch, shape, mesh) cell; the
        # error + traceback are persisted to --out and counted in the summary
        except Exception as exc:
            results[key] = {"status": "error", "error": str(exc)[:2000],
                            "trace": traceback.format_exc()[-2000:],
                            "arch": a, "shape": s}
            print(f"  ERROR {key}: {exc}", flush=True)
        save()

    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    print(f"dryrun: {n_ok} ok, {n_skip} skipped, {n_err} errors", flush=True)


if __name__ == "__main__":
    main()
