"""Distributed-engine self-test: run the federated workload through the
shard_map executor on a small fake-device mesh and compare against the exact
local engine. Invoked in a subprocess so the fake-device XLA flag never leaks
into the parent (smoke tests must see 1 device).

Usage: python -m repro.launch.dist_selftest [n_dev_data] [n_dev_model]
"""
import os
import sys

_d = int(sys.argv[1]) if len(sys.argv) > 1 else 4
_m = int(sys.argv[2]) if len(sys.argv) > 2 else 2
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_d * _m} "
    + os.environ.get("XLA_FLAGS", "")
)

import jax  # noqa: E402
import numpy as np  # noqa: E402


def main() -> int:
    from repro.core.federation import build_federated_stats
    from repro.core.planner import OdysseyOptimizer
    from repro.engine.distributed import DistributedEngine
    from repro.engine.local import LocalEngine, naive_evaluate
    from repro.rdf.dataset import Federation
    from repro.rdf.generator import fedbench_like_spec, generate_federation, generate_workload

    from repro.rdf.generator import FederationSpec, LinkSpec, SourceSpec

    spec = FederationSpec(sources=[
        SourceSpec("A", n_entities=160, n_templates=6, n_local_preds=10),
        SourceSpec("B", n_entities=120, n_templates=5, n_local_preds=8,
                   links=[LinkSpec("owl:sameAs", "A", 0.5)]),
        SourceSpec("C", n_entities=100, n_templates=4, n_local_preds=8,
                   links=[LinkSpec("c:ref", "B", 0.4), LinkSpec("c:self", "C", 0.3)]),
        SourceSpec("D", n_entities=80, n_templates=4, n_local_preds=8,
                   links=[LinkSpec("owl:sameAs", "A", 0.4)]),
    ][:_d], seed=21)
    fed, gt = generate_federation(spec)
    stats = build_federated_stats(fed)
    queries = generate_workload(fed, gt, n_star=6, n_hybrid=4, n_path=2, seed=9)
    mesh = jax.make_mesh((_d, _m), ("data", "model"))
    opt = OdysseyOptimizer(stats)
    local = LocalEngine(fed)
    aware = os.environ.get("REPRO_PARTITION_AWARE", "1") == "1"
    dist = DistributedEngine(fed, mesh, cap=4096, partition_aware=aware)

    n_ok = 0
    n_run = 0
    for q in queries:
        plan = opt.optimize(q)
        if plan.fallback:
            continue
        res_l = local.execute(plan)
        rel_l, m_l = res_l.rows, res_l.metrics
        proj = q.effective_projection()
        nl = len(next(iter(rel_l.values()))) if rel_l else 0
        want = set(zip(*[rel_l[v].tolist() for v in proj])) if nl else set()
        # gold standard too
        gold = naive_evaluate(fed, q)
        try:
            res_d = dist.execute(plan)
            rel_d, m_d = res_d.rows, res_d.metrics
        except AssertionError:
            continue  # plan shape unsupported (e.g. cartesian) — skip
        nd = len(next(iter(rel_d.values()))) if rel_d else 0
        got = set(zip(*[rel_d[v].tolist() for v in proj])) if nd else set()
        n_run += 1
        if m_d.overflowed:
            print(f"OVERFLOW {q.name}")
            continue
        if got == gold and (not q.distinct or got == want):
            n_ok += 1
        else:
            print(f"FAIL {q.name}: dist={len(got)} gold={len(gold)}")
            a = sorted(gold - got)[:3]
            b = sorted(got - gold)[:3]
            print("  missing:", a, " extra:", b)
    print(f"dist_selftest: {n_ok}/{n_run} queries OK on mesh ({_d},{_m})")
    return 0 if (n_run > 0 and n_ok == n_run) else 1


if __name__ == "__main__":
    sys.exit(main())
