"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
        --steps 100 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt --ckpt-every 50

Wires together: config registry (+ reduced mode for CPU), mesh, sharded
params/optimizer, resumable data loader, train step (microbatching, optional
int8 gradient compression with error feedback), atomic checkpointing with
restart, straggler accounting, and a heartbeat hook. On a real cluster each
host runs this same entrypoint under ``jax.distributed.initialize`` — the
single-process CPU container exercises identical code paths on a 1×1 mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.config.base import PerfFlags, reduced_config
from repro.configs import get_arch
from repro.data.loader import TokenLoader
from repro.ft.resilience import Heartbeat
from repro.models import model as MDL
from repro.train.grad_compress import init_error_feedback
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--d-model", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chunked-loss", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        over = {}
        if args.d_model:
            over["d_model"] = args.d_model
            over["head_dim"] = max(8, args.d_model // 8)
            over["d_ff"] = args.d_model * 4
        if args.layers:
            over["n_layers"] = args.layers
        cfg = reduced_config(cfg, **over)
    if args.chunked_loss:
        cfg = dataclasses.replace(cfg, perf=PerfFlags(chunked_loss=True, loss_chunk=64))

    n_params_est = cfg.param_count()
    print(f"arch={cfg.name} ~{n_params_est / 1e6:.1f}M params "
          f"(family={cfg.family})", flush=True)

    opt = make_optimizer(args.optimizer, lr=args.lr)
    step_fn = jax.jit(make_train_step(cfg, opt, microbatches=args.microbatches,
                                      compress=args.compress_grads))

    loader = TokenLoader(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                         seed=args.seed)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    hb = Heartbeat(timeout_s=60.0)

    params = MDL.init_params(cfg, jax.random.PRNGKey(args.seed), jnp.float32)
    opt_state = opt.init(params)
    error_fb = init_error_feedback(params) if args.compress_grads else None
    start_step = 0
    if mgr is not None and mgr.latest_step() is not None:
        s = mgr.latest_step()
        (params, opt_state), extra = mgr.restore(s, (params, opt_state))
        start_step = extra.get("step", s)
        print(f"restored checkpoint at step {start_step}", flush=True)

    stop = {"now": False}
    signal.signal(signal.SIGTERM, lambda *_: stop.update(now=True))

    losses = []
    t_start = time.time()
    tokens_per_step = args.batch * args.seq
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in loader.batch_at(step).items()}
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros((args.batch, cfg.vlm_prefix,
                                               cfg.d_model), jnp.float32)
        if cfg.encdec:
            batch["frames"] = jnp.zeros((args.batch, cfg.enc_seq, cfg.d_model),
                                        jnp.float32)
        if args.compress_grads:
            params, opt_state, metrics, error_fb = step_fn(params, opt_state,
                                                           batch, error_fb)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        hb.beat("worker0")
        losses.append(float(metrics["nll"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t_start
            tps = tokens_per_step * (step - start_step + 1) / max(dt, 1e-9)
            print(f"step {step:5d} nll={losses[-1]:.4f} "
                  f"grad_norm={float(metrics['grad_norm']):.3f} tok/s={tps:,.0f}",
                  flush=True)
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state), extra={"step": step + 1})
        if stop["now"]:
            if mgr is not None:
                mgr.save(step + 1, (params, opt_state), extra={"step": step + 1})
            print("preempted: checkpoint saved, exiting", flush=True)
            break

    first = float(np.mean(losses[:5])) if len(losses) >= 5 else losses[0]
    last = float(np.mean(losses[-5:]))
    print(f"nll: first5={first:.4f} last5={last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})", flush=True)
    return {"first": first, "last": last, "losses": losses}


if __name__ == "__main__":
    main()
