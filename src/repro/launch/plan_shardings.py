"""Odyssey-style cost-based layout planning for the LM substrate
(beyond-paper, DESIGN.md §4).

The paper's optimizer enumerates plans and picks the argmin of a cost model
over intermediate-result/transfer sizes. This module applies the same
discipline to *sharding/execution layout*: enumerate the layout space
(TP collective mode × attention impl × loss impl × scan chunking), estimate
each candidate's three roofline terms analytically, and return the argmin
plan plus the ranked table — the planner that chose the §Perf winners.

Estimates are per-device, bf16, for one step:
  * compute  : 6·N_active·tokens (+ attention) / peak
  * memory   : weights + boundary activations + impl-specific state traffic
  * collect. : TP mode bytes (all-reduce 2·B·S·D/dev per layer vs
               reduce-scatter+all-gather at 1/tp of that) + DP grad sync
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from itertools import product

from repro.config.base import ArchConfig, PerfFlags, ShapeConfig
from repro.launch import roofline as RL


@dataclass(frozen=True)
class LayoutChoice:
    tp_mode: str           # "allreduce" | "seq_parallel"
    attention: str         # "naive" | "chunked"
    loss: str              # "full" | "chunked"
    mamba: str             # "full" | "chunked"

    def to_flags(self, shape: ShapeConfig) -> PerfFlags:
        return PerfFlags(
            chunked_attention=self.attention == "chunked" and shape.kind != "decode",
            chunked_loss=self.loss == "chunked" and shape.kind == "train",
            mamba_chunk=512 if self.mamba == "chunked" else 0,
            mla_absorb=True,
            seq_parallel=self.tp_mode == "seq_parallel" and shape.kind != "decode",
        )


@dataclass
class LayoutPlan:
    choice: LayoutChoice
    compute_s: float
    memory_s: float
    collective_s: float
    peak_temp_bytes: float
    feasible: bool          # fits a 16 GB chip

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


HBM_CAP = 16e9
DP_AXIS = 16
TP_AXIS = 16


def _terms(cfg: ArchConfig, shape: ShapeConfig, c: LayoutChoice, n_chips: int
           ) -> LayoutPlan:
    B, S = shape.global_batch, shape.seq_len
    tokens = B * (1 if shape.kind == "decode" else S)
    tokens_dev = max(1, tokens // min(n_chips, DP_AXIS * 2))
    d = cfg.d_model
    bytes_ = 2  # bf16

    n_act = cfg.active_param_count()
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd
    flops_dev = 2.0 * n_act * tokens * mult / n_chips
    flops_dev += RL._attn_flops(cfg, B, S, shape.kind == "train") * mult / n_chips \
        if shape.kind != "decode" else RL._attn_decode_flops(cfg, B, S) / n_chips

    # ---- memory traffic -------------------------------------------------
    w_dev = cfg.param_count() * bytes_ / n_chips
    mem = w_dev * (3.0 if shape.kind == "train" else 1.0)  # read + grad rw
    boundaries = 10.0  # fusion boundaries per layer (norms, residuals, proj IO)
    act = cfg.n_layers * tokens_dev * d * bytes_ * boundaries * (2 if shape.kind == "train" else 1)
    mem += act
    peak = w_dev * (3.0 if shape.kind == "train" else 1.0)
    # attention state
    attn_layers = RL._attn_layers(cfg)
    if shape.kind == "decode":
        kv_dev = attn_layers * B * S * (cfg.mla.kv_lora + cfg.mla.rope_dim if cfg.mla
                                        else 2 * cfg.n_kv_heads * cfg.hd) * bytes_ / n_chips
        mem += kv_dev
        peak += kv_dev
    elif c.attention == "naive":
        sc = attn_layers * tokens_dev * S * cfg.n_heads * 4.0  # f32 scores
        mem += sc * (2 if shape.kind == "train" else 1)
        peak += sc / max(1, cfg.n_layers)  # one layer live at a time (remat)
    else:  # chunked/flash: tiles live in VMEM (kernel); only QKVO traffic
        qkvo = attn_layers * tokens_dev * cfg.n_heads * cfg.hd * bytes_ * 4
        mem += qkvo
        peak += tokens_dev * d * bytes_ * 4
    # loss head: chunking keeps traffic (all chunks still computed) but
    # bounds the live logits to one chunk — a capacity lever, like flash
    if shape.kind == "train":
        logits = tokens_dev * cfg.vocab * 4.0 / TP_AXIS
        mem += 2 * logits
        peak += logits if c.loss == "full" else logits / max(1, S // 512)
    # mamba state
    if cfg.ssm is not None and shape.kind != "decode":
        di = cfg.ssm.expand * d
        state = cfg.n_layers * tokens_dev * di * cfg.ssm.d_state * 4.0
        if c.mamba == "full":
            mem += state * 2
            peak += state / cfg.n_layers
        else:
            mem += state * 2 / max(1, S // 512)
            peak += state / cfg.n_layers / max(1, S // 512)

    # ---- collectives ----------------------------------------------------
    act_bytes = tokens_dev * d * bytes_
    per_layer = 2 * act_bytes  # two TP syncs per block
    if c.tp_mode == "allreduce":
        coll = cfg.n_layers * 2 * per_layer            # ring all-reduce ~2x
        if shape.kind == "train":
            coll *= 2.0                                 # remat re-runs them
    else:
        coll = cfg.n_layers * 2 * per_layer / TP_AXIS  # rs+ag move 1/tp
    if shape.kind == "train":
        coll += 2 * w_dev                               # DP grad sync
    return LayoutPlan(c, flops_dev / RL.PEAK_FLOPS, mem / RL.HBM_BW,
                      coll / RL.ICI_BW, peak, peak < HBM_CAP)


def plan_layout(cfg: ArchConfig, shape: ShapeConfig, n_chips: int = 256
                ) -> tuple[LayoutPlan, list[LayoutPlan]]:
    """Enumerate layouts, rank by estimated step time among feasible ones."""
    cands = [LayoutChoice(tp, at, ls, mb)
             for tp, at, ls, mb in product(("allreduce", "seq_parallel"),
                                           ("naive", "chunked"),
                                           ("full", "chunked"),
                                           ("full", "chunked"))]
    plans = [_terms(cfg, shape, c, n_chips) for c in cands]
    # feasibility first, then step time, then peak memory (headroom = more
    # batch per chip — ties between equal-traffic layouts go to lower peak)
    ranked = sorted(plans, key=lambda p: (not p.feasible, p.step_s, p.peak_temp_bytes))
    return ranked[0], ranked
