"""Deterministic, resumable, shard-aware synthetic token pipeline.

Every batch is a pure function of (seed, step, dp_rank), so:
  * restart-at-step-k replays exactly the same stream (checkpoint/restart
    correctness — property-tested);
  * each data-parallel rank draws a disjoint slice without coordination
    (1000-node scalable: no shared queue, no filesystem state);
  * elastic re-scaling: rank count is an argument, not baked state.

A zipfian unigram + shifted-markov structure gives the loss a learnable
signal for the end-to-end train example (not pure noise).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class TokenLoader:
    vocab: int
    batch: int            # per-rank batch
    seq: int
    seed: int = 0
    dp_rank: int = 0
    dp_size: int = 1
    zipf_a: float = 1.3

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.dp_rank)
        ranks = np.arange(1, self.vocab + 1)
        probs = 1.0 / ranks ** self.zipf_a
        probs /= probs.sum()
        base = rng.choice(self.vocab, size=(self.batch, self.seq + 1), p=probs)
        # learnable structure: next token correlates with current
        shift = (base[:, :-1] * 31 + 17) % self.vocab
        mix = rng.random((self.batch, self.seq)) < 0.5
        nxt = np.where(mix, shift, base[:, 1:])
        tokens = base[:, :-1].astype(np.int32)
        labels = nxt.astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
