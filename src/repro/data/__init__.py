from repro.data.loader import TokenLoader

__all__ = ["TokenLoader"]
