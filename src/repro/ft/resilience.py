"""Fault-tolerance utilities used by the launchers and the federated engine.

* ``RetryPolicy`` — exponential-backoff retry around endpoint dispatch /
  step execution; the federated engine treats a failing endpoint like the
  paper treats a timed-out SPARQL endpoint (retry, then surface partiality).
* ``StragglerMitigator`` — tracks per-worker (endpoint/subquery) latency
  EWMAs; when a dispatch exceeds ``factor`` × EWMA it issues a *backup
  request* (speculative duplicate), keeping whichever answer lands first —
  the classic tail-latency mitigation, applied to federated subqueries.
* ``Heartbeat`` — deadline-based liveness bookkeeping that the multi-node
  launcher would wire to its control plane; simulated in-process here.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class RetryPolicy:
    """Exponential-backoff retry.  ``sleep`` is injectable (a virtual clock's
    ``advance``, or a no-op) so fault-injection tests and benchmarks retry
    deterministically without wall-clock sleeps."""

    max_attempts: int = 3
    base_delay_s: float = 0.05
    backoff: float = 2.0
    sleep: "object" = time.sleep

    def run(self, fn, *args, on_retry=None, **kw):
        delay = self.base_delay_s
        last_exc: Exception | None = None
        for attempt in range(self.max_attempts):
            try:
                return fn(*args, **kw)
            # repro: ignore[RPR102] -- deliberate retry boundary: any endpoint
            # failure is retried with backoff, and the terminal RuntimeError
            # below chains the last exception so nothing is swallowed
            except Exception as exc:  # noqa: BLE001 - deliberate boundary
                last_exc = exc
                if on_retry is not None:
                    on_retry(attempt, exc)
                if attempt + 1 < self.max_attempts:
                    self.sleep(delay)
                    delay *= self.backoff
        raise RuntimeError(f"retries exhausted: {last_exc}") from last_exc


@dataclass
class StragglerMitigator:
    factor: float = 3.0
    alpha: float = 0.3
    min_samples: int = 3
    _ewma: dict[object, float] = field(default_factory=dict)
    _count: dict[object, int] = field(default_factory=dict)
    backups_issued: int = 0

    def observe(self, worker, latency_s: float) -> None:
        prev = self._ewma.get(worker)
        self._ewma[worker] = (latency_s if prev is None
                              else self.alpha * latency_s + (1 - self.alpha) * prev)
        self._count[worker] = self._count.get(worker, 0) + 1

    def deadline_s(self, worker) -> float | None:
        if self._count.get(worker, 0) < self.min_samples:
            return None
        return self.factor * self._ewma[worker]

    def run_with_backup(self, worker, fn, backup_fn):
        """Run ``fn``; if it exceeds the worker's deadline, also run
        ``backup_fn`` and take the first (sequential simulation of
        speculative execution)."""
        t0 = time.perf_counter()
        result = fn()
        dt = time.perf_counter() - t0
        dl = self.deadline_s(worker)
        self.observe(worker, dt)
        if dl is not None and dt > dl:
            self.backups_issued += 1
            return backup_fn()
        return result


@dataclass
class Heartbeat:
    timeout_s: float = 10.0
    _last: dict[object, float] = field(default_factory=dict)

    def beat(self, node) -> None:
        self._last[node] = time.monotonic()

    def dead(self) -> list[object]:
        now = time.monotonic()
        return [n for n, t in self._last.items() if now - t > self.timeout_s]
