"""Endpoint failover for the federated engine.

A SPARQL federation loses endpoints routinely; the paper's engines time out.
Here failures are first-class: ``execute_with_failover`` retries a failing
dispatch (RetryPolicy), and if an endpoint stays dead it *re-plans* against
the surviving federation — source selection runs again without the dead
source, so the no-false-negative guarantee holds **relative to the live
data** and the result is flagged partial (the honest contract; silently
complete-looking results are the failure mode to avoid).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.federation import FederatedStats
from repro.core.planner import OdysseyOptimizer, PhysicalPlan
from repro.engine.local import ExecutionMetrics, LocalEngine
from repro.ft.resilience import RetryPolicy
from repro.query.algebra import BGPQuery
from repro.rdf.dataset import Federation, Source


class EndpointDown(RuntimeError):
    pass


class FlakySource(Source):
    """Test/simulation wrapper: raises for the first ``fail_times`` scans."""

    def __init__(self, src: Source, fail_times: int = 0, dead: bool = False):
        super().__init__(src.name, src.table, src.sid)
        self._fails_left = fail_times
        self.dead = dead

    def check(self) -> None:
        if self.dead:
            raise EndpointDown(self.name)
        if self._fails_left > 0:
            self._fails_left -= 1
            raise EndpointDown(f"{self.name} (transient)")


class FailoverEngine(LocalEngine):
    """LocalEngine that honors FlakySource failures at dispatch time."""

    def _eval_subquery(self, node, metrics, bindings=None):
        for sid in node.sources:
            src = self.fed.sources[sid]
            if isinstance(src, FlakySource):
                src.check()
        return super()._eval_subquery(node, metrics, bindings)


@dataclass
class FailoverResult:
    rows: dict
    metrics: ExecutionMetrics
    partial: bool                 # True => some endpoint was excluded
    excluded: list[str]
    replans: int = 0


def execute_with_failover(fed: Federation, stats: FederatedStats,
                          query: BGPQuery,
                          retry: RetryPolicy | None = None) -> FailoverResult:
    retry = retry or RetryPolicy(max_attempts=3, base_delay_s=0.001)
    engine = FailoverEngine(fed)
    excluded: list[str] = []
    live = list(range(len(fed.sources)))
    replans = 0

    def attempt(current_fed: Federation, current_stats: FederatedStats):
        opt = OdysseyOptimizer(current_stats)
        plan = opt.optimize(query)
        eng = FailoverEngine(current_fed)
        return eng.execute(plan)

    cur_fed, cur_stats = fed, stats
    while True:
        try:
            rows, metrics = retry.run(attempt, cur_fed, cur_stats)
            return FailoverResult(rows=rows, metrics=metrics,
                                  partial=bool(excluded), excluded=excluded,
                                  replans=replans)
        except RuntimeError as exc:
            # a dead endpoint survived retries: exclude it and re-plan
            dead_name = None
            for s in cur_fed.sources:
                if isinstance(s, FlakySource) and s.dead:
                    dead_name = s.name
                    break
            if dead_name is None:
                raise
            excluded.append(dead_name)
            replans += 1
            keep = [s for s in cur_fed.sources if s.name != dead_name]
            if not keep:
                raise
            cur_fed = Federation(keep, cur_fed.dictionary)
            from repro.core.federation import build_federated_stats

            cur_stats = build_federated_stats(cur_fed, use_summaries=False)
