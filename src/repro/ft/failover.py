"""Endpoint failover for the federated engine, on the versioned statistics
lifecycle.

A SPARQL federation loses endpoints routinely; the paper's engines time out.
Here failures are first-class and *cheap*: a ``FailoverSession`` owns one
long-lived ``OdysseyOptimizer``.  Transient failures are retried without
replanning (RetryPolicy); an endpoint that stays dead is excluded via
``FederatedStats.remove_source`` — only the dead source's statistics are
dropped (the survivors' CS/CP state and memoized formulas are reused, no
rebuild) — and the epoch bump lazily evicts exactly the now-stale cached
plans, so a templated workload re-warms the plan cache after the first
replan instead of losing it.  Recovery is symmetric: ``restore`` re-adds a
source incrementally (``add_source``).

Since the operator-pipeline refactor (docs/execution.md) a death
*mid-execution* is cheaper still: the session salvages the pipeline's
already-produced operator state — only the dead endpoint's scans drop (or
re-route to an alternate relevant source), no completed scan re-executes —
instead of replanning and re-running the query from scratch
(``salvage=False`` restores the legacy loop).

Source selection runs again without the dead source, so the
no-false-negative guarantee holds **relative to the live data** and the
result is flagged partial (the honest contract; silently complete-looking
results are the failure mode to avoid).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.federation import FederatedStats
from repro.core.planner import OdysseyOptimizer, PhysicalPlan
from repro.engine.local import ExecutionMetrics, LocalEngine
from repro.ft.resilience import RetryPolicy
from repro.query.algebra import BGPQuery
from repro.rdf.dataset import Federation, Source


class EndpointDown(RuntimeError):
    pass


class FlakySource(Source):
    """Test/simulation fault- and latency-injection wrapper.

    Three failure axes, all deterministic:

    * ``fail_times`` — ``check()`` raises for the first N dispatches
      (transient outage, healed by a retry);
    * ``dead`` — ``check()`` always raises (hard death at dispatch);
    * ``die_after_tuples`` — ``note_tuples()`` flips ``dead`` and raises the
      moment the endpoint has served more than N tuples (death *mid-scan*:
      earlier completed scans stay shipped, the crossing scan is lost).

    ``latency_s`` is a deterministic per-scan latency the pipeline's
    ``SourceChannel`` charges to an injectable virtual clock (no wall-clock
    sleeps — the pattern of ``tests/test_serve_scheduler.py``), which is what
    makes adaptive-vs-static routing measurable.
    """

    def __init__(self, src: Source, fail_times: int = 0, dead: bool = False,
                 die_after_tuples: "int | None" = None,
                 latency_s: float = 0.0):
        super().__init__(src.name, src.table, src.sid)
        self._fails_left = fail_times
        self.dead = dead
        self.die_after_tuples = die_after_tuples
        self.latency_s = latency_s
        self.tuples_served = 0

    def check(self) -> None:
        if self.dead:
            raise EndpointDown(self.name)
        if self._fails_left > 0:
            self._fails_left -= 1
            raise EndpointDown(f"{self.name} (transient)")

    def note_tuples(self, n: int) -> None:
        """Physical-scan accounting hook (called by ``SourceChannel`` per
        cache-missing scan); the mid-scan death trigger."""
        self.tuples_served += n
        if (self.die_after_tuples is not None
                and self.tuples_served > self.die_after_tuples):
            self.dead = True
            raise EndpointDown(
                f"{self.name} (died mid-scan after {self.die_after_tuples} "
                f"tuples)")


class FailoverEngine(LocalEngine):
    """LocalEngine that honors FlakySource failures.  On the pipeline path
    the ``SourceChannel`` enforces faults per scan task (``honor_faults``);
    the recursive path keeps the legacy whole-subquery dispatch check."""

    honor_faults = True

    def _eval_subquery(self, node, metrics, bindings=None):
        for sid in node.sources:
            src = self.fed.sources[sid]
            if isinstance(src, FlakySource):
                src.check()
        return super()._eval_subquery(node, metrics, bindings)


@dataclass
class FailoverResult:
    rows: dict
    metrics: ExecutionMetrics
    partial: bool                 # True => some endpoint was excluded
    excluded: list[str]
    replans: int = 0
    salvages: int = 0             # mid-query salvages (operator state kept)
    cache_hit: bool = False       # plan served from the optimizer's plan cache
    stats_epoch: int = 0          # statistics epoch the answer was planned under
    rerouted: "list[tuple[str, str]]" = None  # (dead, alternate) re-routes
    card_log: tuple = ()          # observed-vs-estimated cardinality samples

    def __post_init__(self):
        if self.rerouted is None:
            self.rerouted = []


class FailoverSession:
    """Long-lived failover executor: one optimizer, one live federation.

    The session clones ``stats`` once (cheap: the clone shares the statistics
    arrays) so endpoint exclusion never writes through to the caller's
    statistics.  Across queries the plan cache and the untouched sources'
    memoized formulas survive every exclusion — previously each dead endpoint
    threw away the optimizer and rebuilt the whole federation's statistics.
    """

    def __init__(self, fed: Federation, stats: FederatedStats,
                 retry: RetryPolicy | None = None, clone_stats: bool = True,
                 salvage: bool = True, scan_policy: str = "static"):
        self.retry = retry or RetryPolicy(max_attempts=3, base_delay_s=0.001)
        self.optimizer = OdysseyOptimizer(stats.clone() if clone_stats else stats)
        self.fed = fed
        self.salvage = salvage
        self.scan_policy = scan_policy
        self.excluded: list[str] = []
        self._all_sources: dict[str, Source] = {s.name: s for s in fed.sources}
        self._base_sources: list[Source] = list(fed.sources)

    @property
    def stats(self) -> FederatedStats:
        return self.optimizer.stats

    def _compile(self, plan: PhysicalPlan, fed: Federation):
        from repro.engine.pipeline import compile_plan
        return compile_plan(plan, fed, honor_faults=True,
                            policy=self.scan_policy)

    def execute(self, query: BGPQuery) -> FailoverResult:
        """Execute with mid-query salvage: an endpoint death keeps the
        pipeline's already-produced operator state (no completed scan is
        re-executed — the dead endpoint's scans drop or re-route) instead of
        replanning from scratch.  ``salvage=False`` restores the legacy
        exclude-and-replan loop.  ``partial``/``excluded`` semantics are
        identical either way."""
        replans = salvages = 0
        plan = self.optimizer.optimize(query)
        exec_ = self._compile(plan, self.fed)
        while True:
            try:
                res = self.retry.run(exec_.run)
                return FailoverResult(rows=res.rows, metrics=res.metrics,
                                      partial=bool(self.excluded),
                                      excluded=list(self.excluded),
                                      replans=replans, salvages=salvages,
                                      cache_hit=plan.cached,
                                      stats_epoch=self.stats.epoch,
                                      rerouted=list(exec_.rerouted),
                                      card_log=res.card_log)
            except RuntimeError:
                # a dead endpoint survived retries
                sid = self._find_dead()
                if sid is None:
                    raise
                name = self.exclude(sid)
                if self.salvage:
                    # drop/re-route only the dead endpoint's scans; survivors'
                    # shipped parts stay salvaged inside the execution
                    exec_.drop_source(name)
                    salvages += 1
                else:
                    replans += 1
                    plan = self.optimizer.optimize(query)
                    exec_ = self._compile(plan, self.fed)

    def execute_batch(self, queries: "list[BGPQuery]") -> "list[FailoverResult]":
        """Failover-aware batch execution on the truly batched planner: the
        whole batch is planned in one ``optimize_batch`` call (shared source
        selection, one DP sweep per shape, one epoch snapshot), then executed
        query by query.  When an endpoint turns out dead it is excluded once
        and the *remaining* queries are replanned as a (smaller) batch under
        the new epoch — completed queries keep their results, so a mid-batch
        death costs one exclusion plus one batched replan, not per-query
        rebuilds.  With ``salvage`` (the default) the query that was running
        when the endpoint died additionally completes on its salvaged
        operator state instead of joining the replan.

        A ``RuntimeError`` with no dead endpoint to blame propagates and the
        call is all-or-nothing — the same contract as the sequential
        ``[session.execute(q) for q in queries]`` it replaces; callers that
        must keep partial progress through *non-endpoint* failures should
        fall back to per-query ``execute``."""
        results: "list[FailoverResult | None]" = [None] * len(queries)
        pending = list(range(len(queries)))
        replans = 0
        while pending:
            plans = self.optimizer.optimize_batch([queries[i] for i in pending])
            fed_now = self.fed          # the federation these plans address
            still: list[int] = []
            excluded_now = False
            for i, plan in zip(pending, plans):
                if excluded_now:
                    still.append(i)       # replan under the new epoch
                    continue
                exec_ = self._compile(plan, fed_now)
                while True:
                    try:
                        res = self.retry.run(exec_.run)
                    except RuntimeError:
                        sid = self._find_dead()
                        if sid is None:
                            raise
                        name = self.exclude(sid)
                        excluded_now = True
                        replans += 1      # the remainder replans either way
                        if self.salvage:
                            # finish *this* query on its salvaged operator
                            # state; the rest of the batch replans under the
                            # new epoch (their plans still address the dead
                            # endpoint)
                            exec_.drop_source(name)
                            continue
                        still.append(i)
                        res = None
                        break
                    break
                if res is None:
                    continue
                results[i] = FailoverResult(
                    rows=res.rows, metrics=res.metrics,
                    partial=bool(self.excluded),
                    excluded=list(self.excluded), replans=replans,
                    salvages=exec_.salvages, cache_hit=plan.cached,
                    stats_epoch=plan.stats_epoch,
                    rerouted=list(exec_.rerouted), card_log=res.card_log)
            pending = still
        return results      # type: ignore[return-value]

    def _find_dead(self) -> int | None:
        for i, s in enumerate(self.fed.sources):
            if isinstance(s, FlakySource) and s.dead:
                return i
        return None

    def exclude(self, sid: int) -> str:
        """Drop source ``sid`` from the live federation and its statistics.
        Incremental: survivors keep their statistics and warm caches; the
        epoch bump makes the plan cache lazily evict only stale plans."""
        keep = self.fed.sources[:sid] + self.fed.sources[sid + 1:]
        if not keep:
            raise RuntimeError("every endpoint is dead")
        name = self.fed.sources[sid].name
        # mutate the statistics first: session bookkeeping (the `partial`
        # contract reads `excluded`) must only record what actually happened
        self.stats.remove_source(sid)
        self.excluded.append(name)
        self.fed = self._rebuild_fed(keep)
        return name

    def restore(self, name: str) -> int:
        """Recovery: re-admit a previously excluded source.  Its statistics
        (and the federated CPs incident to it) are rebuilt incrementally via
        ``add_source``; everything else is reused.  Returns the new sid."""
        if name not in self.excluded:
            raise ValueError(f"source {name!r} is not excluded")
        src = self._all_sources[name]
        # add_source does real work (local stats + Algorithm 1 pairs) and may
        # raise; only clear the exclusion once the source is really back,
        # otherwise later results would look complete while it is absent
        sid = self.stats.add_source(src.table)
        self.excluded.remove(name)
        self.fed = self._rebuild_fed(self.fed.sources + [src])
        return sid

    def _rebuild_fed(self, sources: list[Source]) -> Federation:
        """Live federation over the (shared) Source objects.  Federation's
        __post_init__ renumbers ``src.sid`` in place on those shared objects;
        restore the caller's numbering afterwards — engines address sources
        by list index, never by the sid field, so the session works either
        way but the caller's original federation must stay intact."""
        fed = Federation(sources, self.fed.dictionary)
        for i, s in enumerate(self._base_sources):
            s.sid = i
        return fed


def execute_with_failover(fed: Federation, stats: FederatedStats,
                          query: BGPQuery,
                          retry: RetryPolicy | None = None,
                          session: FailoverSession | None = None) -> FailoverResult:
    """One-shot convenience wrapper around ``FailoverSession``.  Pass a
    ``session`` to amortize the optimizer, plan cache and statistics across a
    workload (templated queries then hit the plan cache even after a replan)."""
    if session is None:
        session = FailoverSession(fed, stats, retry=retry)
    elif retry is not None:
        raise ValueError("pass the retry policy to the FailoverSession, not "
                         "alongside it (a session owns its retry policy)")
    return session.execute(query)
