from repro.ft.resilience import RetryPolicy, StragglerMitigator, Heartbeat

__all__ = ["RetryPolicy", "StragglerMitigator", "Heartbeat"]
