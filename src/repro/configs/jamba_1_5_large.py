"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, vocab=65536, Mamba:attn 7:1 interleave, MoE 16e top-2 every other
layer. [arXiv:2403.19887; hf]"""
from repro.config.base import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65_536,
    rope_theta=10_000.0,
    layer_pattern="mmmmammm",  # 1 attention layer per 8 (1:7)
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, every=2, d_ff_dense=24576),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    sub_quadratic=True,        # only 9/72 layers attend -> runs long_500k
)
