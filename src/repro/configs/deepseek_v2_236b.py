"""deepseek-v2-236b [moe]: 60L d_model=5120 128H MLA (kv_lora=512)
moe_d_ff=1536, vocab=102400, 2 shared + 160 routed top-6, first layer dense.
[arXiv:2405.04434; hf]"""
from repro.config.base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,            # MLA: per-head keys derived from the latent
    d_ff=1536,
    vocab=102_400,
    head_dim=192,              # nope 128 + rope 64
    rope_theta=10_000.0,
    layer_pattern="g",
    mla=MLAConfig(q_lora=1536, kv_lora=512, nope_dim=128, rope_dim=64, v_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared=2,
                  first_k_dense=1, d_ff_dense=12288),
    notes="MLA caches the 512-d latent + 64-d rope key per token (decode memory win)",
)
