"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion VQ image tokens, qk-norm.
Modality frontend is a stub: input_specs() provides patch embeddings for the
leading ``vlm_prefix`` positions. [arXiv:2405.09818; unverified]"""
from repro.config.base import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=65_536,
    qk_norm=True,
    rope_theta=10_000.0,
    layer_pattern="g",
    vlm_prefix=1024,           # leading image-token positions (stubbed embeds)
    notes="early fusion: VQ image tokens share the text vocab; frontend stubbed",
)
