"""whisper-tiny [audio]: enc-dec, 4L each, d_model=384 6H d_ff=1536
vocab=51865 — conv frontend stubbed (input_specs provides log-mel frame
embeddings). decode_32k exceeds the published 448 max target positions; the
position table is sized from the shape config for the dry-run (DESIGN.md).
[arXiv:2212.04356; unverified]"""
from repro.config.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51_865,
    layer_pattern="g",
    encdec=True,
    enc_layers=4,
    enc_seq=1500,
    rope_theta=0.0,            # learned absolute positions
    notes="conv frontend stubbed; learned positions sized per shape",
)
