"""falcon-mamba-7b [ssm]: 64L d_model=4096 attention-free mamba1,
ssm_state=16, vocab=65024. [arXiv:2410.05355; unverified]"""
from repro.config.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,                 # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,                    # mamba block subsumes the FFN
    vocab=65_024,
    layer_pattern="m",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    sub_quadratic=True,        # O(1) state per token -> runs long_500k
)
