"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family; unverified]"""
from repro.config.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab=262_144,
    head_dim=256,
    qk_norm=True,
    rope_theta=1_000_000.0,
    local_window=1024,
    layer_pattern="lllllg",    # 5 local : 1 global
    tie_embeddings=True,
    sub_quadratic=False,       # global layers are full attention -> no 500k
    notes="5:1 local:global interleave; local layers use a 1024 sliding window",
)
