"""Registry of assigned architectures (+ the paper's own federated-engine
"architecture"). ``get_arch(id)`` returns the exact published config."""
from __future__ import annotations

from repro.config.base import ArchConfig

_REGISTRY: dict[str, str] = {
    "gemma3-12b": "repro.configs.gemma3_12b",
    "qwen1.5-32b": "repro.configs.qwen1_5_32b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "qwen2-0.5b": "repro.configs.qwen2_0_5b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large",
    "whisper-tiny": "repro.configs.whisper_tiny",
}

ARCH_IDS = list(_REGISTRY)


def get_arch(arch_id: str) -> ArchConfig:
    import importlib

    mod = importlib.import_module(_REGISTRY[arch_id])
    return mod.CONFIG
