"""Shared transformer layers: RMSNorm, RoPE, GQA attention (global/local,
qk-norm, bias), SwiGLU MLP. Functional style over dict-pytree params; every
function takes the activation dtype from its inputs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig

NEG_INF = -1e9  # additive mask value (bf16-safe)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd) (hd even); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)                 # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def init_attention(cfg: ArchConfig, key, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, h * hd), dtype) * scale,
        "wk": jax.random.normal(k2, (d, kv * hd), dtype) * scale,
        "wv": jax.random.normal(k3, (d, kv * hd), dtype) * scale,
        "wo": jax.random.normal(k4, (h * hd, d), dtype) * scale,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _project_qkv(p: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array,
                 use_rope: bool = True):
    B = x.shape[0]
    S = x.shape[1]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, kv, hd)
    v = v.reshape(B, S, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if use_rope and cfg.rope_theta > 0:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, H, hd), k: (B, Sk, KV, hd) -> (B, KV, G, Sq, Sk)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / (hd ** 0.5)


def gqa_output(w: jax.Array, v: jax.Array) -> jax.Array:
    """w: (B, KV, G, Sq, Sk), v: (B, Sk, KV, hd) -> (B, Sq, H, hd)."""
    B, KV, G, Sq, Sk = w.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, KV * G, -1)


def attention(p: dict, cfg: ArchConfig, x: jax.Array, *, local: bool,
              causal: bool = True, positions: jax.Array | None = None) -> jax.Array:
    """Full-sequence (training/prefill) attention."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    window = cfg.local_window if local else 0
    if cfg.perf.chunked_attention and S > cfg.perf.attn_chunk:
        # largest chunk <= attn_chunk that divides S (whisper's 1500-frame
        # encoder doesn't divide 1024; fall back to naive if none >= 64)
        c = cfg.perf.attn_chunk
        while c >= 64 and S % c:
            c //= 2
        if S % c == 0 and c >= 64:
            from repro.models.attention_chunked import chunked_gqa_attention

            out = chunked_gqa_attention(q, k, v, causal=causal, window=window,
                                        q_chunk=c, k_chunk=c).astype(x.dtype)
            return out.reshape(B, S, -1) @ p["wo"]
    scores = gqa_scores(q, k).astype(jnp.float32)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.zeros((S, S), jnp.float32)
    if causal:
        mask = jnp.where(j > i, NEG_INF, mask)
    if window:
        mask = jnp.where(i - j >= window, NEG_INF, mask)
    w = jax.nn.softmax(scores + mask, axis=-1).astype(x.dtype)
    out = gqa_output(w, v)
    return out.reshape(B, S, -1) @ p["wo"]


def decode_positions(pos: jax.Array, B: int) -> jax.Array:
    """pos: () shared or (B,) per-slot -> (B, 1) positions."""
    if pos.ndim == 0:
        return jnp.full((B, 1), pos, jnp.int32)
    return pos[:, None].astype(jnp.int32)


def cache_insert(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write new (B, 1, ...) at per-row (or shared) position along axis 1."""
    if pos.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), pos, axis=1)
    B = cache.shape[0]
    return cache.at[jnp.arange(B), pos].set(new[:, 0].astype(cache.dtype))


def _quant_kv(t: jax.Array) -> tuple[jax.Array, jax.Array]:
    """t: (B, 1, KV, hd) -> int8 values + per-(token, head) f32 scales."""
    s = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0
    s = jnp.maximum(s, 1e-8)
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / s[..., None]), -127, 127)
    return q.astype(jnp.int8), s


def attention_decode(p: dict, cfg: ArchConfig, x: jax.Array, cache: dict,
                     pos: jax.Array, *, local: bool) -> tuple[jax.Array, dict]:
    """One-token decode against a preallocated KV cache.

    cache: {"k": (B, S_ctx, KV, hd), "v": same} (+ "k_scale"/"v_scale" when
    the cache is int8-quantized); ``pos``: () int32 shared or (B,) per-slot —
    the index the new token writes to; attends to [0, pos].
    """
    B = x.shape[0]
    positions = decode_positions(pos, B)
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    if cfg.perf.kv_quant_int8:
        kq, ks = _quant_kv(k_new)
        vq, vs = _quant_kv(v_new)
        cache = {
            "k": cache_insert(cache["k"], kq, pos),
            "v": cache_insert(cache["v"], vq, pos),
            "k_scale": cache_insert(cache["k_scale"], ks, pos),
            "v_scale": cache_insert(cache["v_scale"], vs, pos),
        }
        k = cache["k"].astype(x.dtype) * cache["k_scale"][..., None].astype(x.dtype)
        v = cache["v"].astype(x.dtype) * cache["v_scale"][..., None].astype(x.dtype)
    else:
        k = cache_insert(cache["k"], k_new, pos)
        v = cache_insert(cache["v"], v_new, pos)
        cache = {"k": k, "v": v}
    S_ctx = k.shape[1]
    scores = gqa_scores(q, k).astype(jnp.float32)    # (B, KV, G, 1, S_ctx)
    j = jnp.arange(S_ctx)[None, None, None, None, :]
    pb = positions[:, 0][:, None, None, None, None]  # (B,1,1,1,1)
    mask = jnp.where(j > pb, NEG_INF, 0.0)
    if local and cfg.local_window:
        mask = mask + jnp.where(pb - j >= cfg.local_window, NEG_INF, 0.0)
    w = jax.nn.softmax(scores + mask, axis=-1).astype(x.dtype)
    out = gqa_output(w, v).reshape(B, 1, -1) @ p["wo"]
    return out, cache


def attention_prefill(p: dict, cfg: ArchConfig, x: jax.Array, *, local: bool,
                      positions: jax.Array | None = None
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence causal attention that also returns the rope'd (k, v) for
    seeding a decode cache (serving prefill path)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(p, cfg, x, positions)
    scores = gqa_scores(q, k).astype(jnp.float32)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.where(j > i, NEG_INF, 0.0)
    if local and cfg.local_window:
        mask = mask + jnp.where(i - j >= cfg.local_window, NEG_INF, 0.0)
    w = jax.nn.softmax(scores + mask, axis=-1).astype(x.dtype)
    out = gqa_output(w, v).reshape(B, S, -1) @ p["wo"]
    return out, k, v


def init_mlp(d: int, f: int, key, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": jax.random.normal(k1, (d, f), dtype) * d ** -0.5,
        "wg": jax.random.normal(k2, (d, f), dtype) * d ** -0.5,
        "wo": jax.random.normal(k3, (f, d), dtype) * f ** -0.5,
    }


def swiglu(p: dict, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])) @ p["wo"]
