"""Unified decoder-LM / enc-dec model over the arch zoo.

Layers are grouped by the arch's repeating pattern and scanned with remat
(``jax.lax.scan`` over stacked group params) so 48–72-layer configs lower to
compact HLO. Heterogeneous patterns (gemma local/global, jamba mamba/attn/MoE
interleaves) unroll *within* a group; irregular prelude layers (DeepSeek's
first dense-FFN layer) stay outside the scan.

Entry points:
  init_params / forward (train & prefill) / decode_step / init_decode_caches /
  input_specs (ShapeDtypeStructs for the dry-run).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig, ShapeConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import mla as MLA
from repro.models import moe as MOE


# ---------------------------------------------------------------------------
# activation-sharding policy (set by the launcher/dry-run; model code stays
# mesh-agnostic). kinds: "residual" (between blocks)
# ---------------------------------------------------------------------------

_ACT_POLICY = None


def set_activation_policy(fn) -> None:
    """fn(x, kind) -> x, e.g. a with_sharding_constraint for seq-parallel TP."""
    global _ACT_POLICY
    _ACT_POLICY = fn


def _constrain(x: jax.Array, kind: str) -> jax.Array:
    if _ACT_POLICY is None:
        return x
    return _ACT_POLICY(x, kind)


# ---------------------------------------------------------------------------
# structure
# ---------------------------------------------------------------------------

def group_structure(cfg: ArchConfig) -> tuple[list[int], int, int]:
    """(prelude layer indices, n_groups, pattern_len)."""
    prelude = list(range(cfg.moe.first_k_dense)) if cfg.moe else []
    body = cfg.n_layers - len(prelude)
    pat = cfg.pattern_len
    if body % pat != 0:  # fall back to unscanned prelude remainder
        extra = body % pat
        prelude = prelude + list(range(len(prelude), len(prelude) + extra))
        body -= extra
    return prelude, body // pat, pat


def _layer_kinds(cfg: ArchConfig, layer_idx: int) -> tuple[str, str]:
    """(mixer kind, ffn kind) for an absolute layer index."""
    mixer = cfg.mixer_of(layer_idx)
    if cfg.d_ff == 0 and not (cfg.moe and cfg.ffn_is_moe(layer_idx)):
        ffn = "none"
    elif cfg.ffn_is_moe(layer_idx):
        ffn = "moe"
    else:
        ffn = "dense"
    return mixer, ffn


def init_layer(cfg: ArchConfig, layer_idx: int, key, dtype) -> dict:
    mixer, ffn = _layer_kinds(cfg, layer_idx)
    k1, k2 = jax.random.split(key)
    p: dict = {"mixer_norm": jnp.ones((cfg.d_model,), dtype)}
    if mixer == "m":
        p["mixer"] = M.init_mamba(cfg, k1, dtype)
    elif cfg.mla is not None:
        p["mixer"] = MLA.init_mla(cfg, k1, dtype)
    else:
        p["mixer"] = L.init_attention(cfg, k1, dtype)
    if ffn == "dense":
        d_ff = cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense) else cfg.d_ff
        p["ffn"] = L.init_mlp(cfg.d_model, d_ff, k2, dtype)
        p["ffn_norm"] = jnp.ones((cfg.d_model,), dtype)
    elif ffn == "moe":
        p["ffn"] = MOE.init_moe(cfg, k2, dtype)
        p["ffn_norm"] = jnp.ones((cfg.d_model,), dtype)
    return p


def _apply_layer(cfg: ArchConfig, lp: dict, layer_idx: int, x: jax.Array,
                 positions: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    mixer, ffn = _layer_kinds(cfg, layer_idx)
    h = L.rmsnorm(x, lp["mixer_norm"], cfg.norm_eps)
    if mixer == "m":
        h = M.mamba_block(lp["mixer"], cfg, h)
    elif cfg.mla is not None:
        h = MLA.mla_attention(lp["mixer"], cfg, h, positions)
    else:
        h = L.attention(lp["mixer"], cfg, h, local=(mixer == "l"), positions=positions)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if ffn == "dense":
        x = x + L.swiglu(lp["ffn"], L.rmsnorm(x, lp["ffn_norm"], cfg.norm_eps))
    elif ffn == "moe":
        out, aux = MOE.moe_ffn(lp["ffn"], cfg, L.rmsnorm(x, lp["ffn_norm"], cfg.norm_eps))
        x = x + out
    return x, aux


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key, dtype=jnp.bfloat16) -> dict:
    prelude, n_groups, pat = group_structure(cfg)
    keys = jax.random.split(key, 8)
    p: dict = {"embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), dtype) * 0.02}
    p["final_norm"] = jnp.ones((cfg.d_model,), dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(keys[1], (cfg.d_model, cfg.vocab), dtype) * cfg.d_model ** -0.5

    for li in prelude:
        p[f"prelude_{li}"] = init_layer(cfg, li, jax.random.fold_in(keys[2], li), dtype)

    if n_groups > 0:
        def one_group(gkey):
            base = len(prelude)
            return {f"slot_{s}": init_layer(cfg, base + s, jax.random.fold_in(gkey, s), dtype)
                    for s in range(pat)}
        gs = [one_group(jax.random.fold_in(keys[3], g)) for g in range(n_groups)]
        p["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *gs)

    if cfg.encdec:
        ed: dict = {"pos": jax.random.normal(keys[4], (8192, cfg.d_model), dtype) * 0.02,
                    "enc_pos": jax.random.normal(keys[5], (cfg.enc_seq, cfg.d_model), dtype) * 0.02,
                    "enc_final_norm": jnp.ones((cfg.d_model,), dtype)}
        for i in range(cfg.enc_layers):
            k = jax.random.fold_in(keys[6], i)
            ed[f"enc_{i}"] = {
                "mixer_norm": jnp.ones((cfg.d_model,), dtype),
                "mixer": L.init_attention(cfg, k, dtype),
                "ffn_norm": jnp.ones((cfg.d_model,), dtype),
                "ffn": L.init_mlp(cfg.d_model, cfg.d_ff, jax.random.fold_in(k, 1), dtype),
            }
        for i in range(cfg.n_layers):
            k = jax.random.fold_in(keys[7], i)
            ed[f"cross_{i}"] = {
                "norm": jnp.ones((cfg.d_model,), dtype),
                "attn": L.init_attention(cfg, k, dtype),
            }
        p["encdec"] = ed
    return p


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ArchConfig, params: dict, batch: dict) -> jax.Array:
    x = params["embed"][batch["tokens"]]
    if cfg.vlm_prefix and "patch_embeds" in batch:
        pe = batch["patch_embeds"].astype(x.dtype)
        x = jnp.concatenate([pe, x[:, cfg.vlm_prefix:]], axis=1)
    return x


def _encoder(cfg: ArchConfig, params: dict, frames: jax.Array) -> jax.Array:
    ed = params["encdec"]
    x = frames.astype(params["embed"].dtype) + ed["enc_pos"][None, : frames.shape[1]]
    for i in range(cfg.enc_layers):
        lp = ed[f"enc_{i}"]
        h = L.rmsnorm(x, lp["mixer_norm"], cfg.norm_eps)
        x = x + L.attention(lp["mixer"], cfg, h, local=False, causal=False)
        x = x + L.swiglu(lp["ffn"], L.rmsnorm(x, lp["ffn_norm"], cfg.norm_eps))
    return L.rmsnorm(x, ed["enc_final_norm"], cfg.norm_eps)


def _cross_attention(cfg: ArchConfig, cp: dict, x: jax.Array, enc: jax.Array) -> jax.Array:
    """Decoder cross-attention (bidirectional over encoder states)."""
    p = cp["attn"]
    B, S, _ = x.shape
    T = enc.shape[1]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (L.rmsnorm(x, cp["norm"], cfg.norm_eps) @ p["wq"]).reshape(B, S, h, hd)
    k = (enc @ p["wk"]).reshape(B, T, kv, hd)
    v = (enc @ p["wv"]).reshape(B, T, kv, hd)
    scores = L.gqa_scores(q, k).astype(jnp.float32)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = L.gqa_output(w, v).reshape(B, S, -1) @ p["wo"]
    return x + out


def forward_hidden(cfg: ArchConfig, params: dict, batch: dict, remat: bool = True
                   ) -> tuple[jax.Array, jax.Array]:
    """Final-norm hidden states (pre-head): (B, S, D), moe aux loss."""
    if cfg.encdec:
        return _forward_encdec_hidden(cfg, params, batch)
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    aux = jnp.zeros((), jnp.float32)
    prelude, n_groups, pat = group_structure(cfg)
    for li in prelude:
        x, a = _apply_layer(cfg, params[f"prelude_{li}"], li, x, positions)
        aux += a

    if n_groups > 0:
        base = len(prelude)

        def group_body(carry, gp):
            x, aux = carry
            for s in range(pat):
                x, a = _apply_layer(cfg, gp[f"slot_{s}"], base + s, x, positions)
                x = _constrain(x, "residual")
                aux += a
            return (x, aux), None

        body = jax.checkpoint(group_body) if remat else group_body
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["groups"])

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def lm_head(cfg: ArchConfig, params: dict) -> jax.Array:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(cfg: ArchConfig, params: dict, batch: dict, remat: bool = True
            ) -> tuple[jax.Array, jax.Array]:
    """Returns (logits, moe_aux_loss)."""
    x, aux = forward_hidden(cfg, params, batch, remat)
    return x @ lm_head(cfg, params), aux


def _forward_encdec_hidden(cfg: ArchConfig, params: dict, batch: dict):
    enc = _encoder(cfg, params, batch["frames"])
    ed = params["encdec"]
    tokens = batch["tokens"]
    B, S = tokens.shape
    pos_table = ed["pos"]
    x = params["embed"][tokens] + jnp.take(pos_table, jnp.arange(S) % pos_table.shape[0],
                                           axis=0)[None]
    aux = jnp.zeros((), jnp.float32)
    prelude, n_groups, pat = group_structure(cfg)
    # whisper decoder is shallow: unscanned, cross-attn interleaved
    all_layers = prelude + [len(prelude) + g * pat + s
                            for g in range(n_groups) for s in range(pat)]
    for li in all_layers:
        lp = params[f"prelude_{li}"] if li in prelude else jax.tree.map(
            lambda v, g=(li - len(prelude)) // pat: v[g],
            params["groups"])[f"slot_{(li - len(prelude)) % pat}"]
        x, a = _apply_layer(cfg, lp, li, x, None)
        aux += a
        x = _cross_attention(cfg, ed[f"cross_{li}"], x, enc)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg: ArchConfig, layer_idx: int, B: int, S_ctx: int, dtype):
    mixer, _ = _layer_kinds(cfg, layer_idx)
    if mixer == "m":
        d_inner, d_state, d_conv, _ = M._dims(cfg)
        return {"conv": jnp.zeros((B, d_conv - 1, d_inner), dtype),
                "state": jnp.zeros((B, d_inner, d_state), jnp.float32)}
    if cfg.mla is not None:
        m = cfg.mla
        return {"latent": jnp.zeros((B, S_ctx, m.kv_lora), dtype),
                "k_rope": jnp.zeros((B, S_ctx, 1, m.rope_dim), dtype)}
    if cfg.perf.kv_quant_int8:
        return {"k": jnp.zeros((B, S_ctx, cfg.n_kv_heads, cfg.hd), jnp.int8),
                "v": jnp.zeros((B, S_ctx, cfg.n_kv_heads, cfg.hd), jnp.int8),
                "k_scale": jnp.zeros((B, S_ctx, cfg.n_kv_heads), jnp.float32),
                "v_scale": jnp.zeros((B, S_ctx, cfg.n_kv_heads), jnp.float32)}
    return {"k": jnp.zeros((B, S_ctx, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((B, S_ctx, cfg.n_kv_heads, cfg.hd), dtype)}


def init_decode_caches(cfg: ArchConfig, B: int, S_ctx: int, dtype=jnp.bfloat16) -> dict:
    prelude, n_groups, pat = group_structure(cfg)
    caches: dict = {}
    for li in prelude:
        caches[f"prelude_{li}"] = _init_layer_cache(cfg, li, B, S_ctx, dtype)
    if n_groups > 0:
        base = len(prelude)
        one = {f"slot_{s}": _init_layer_cache(cfg, base + s, B, S_ctx, dtype)
               for s in range(pat)}
        caches["groups"] = jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (n_groups,) + v.shape), one)
    if cfg.encdec:
        caches["enc_out"] = jnp.zeros((B, cfg.enc_seq, cfg.d_model), dtype)
    return caches


def _decode_layer(cfg: ArchConfig, lp: dict, cache: dict, layer_idx: int,
                  x: jax.Array, pos: jax.Array) -> tuple[jax.Array, dict, jax.Array]:
    mixer, ffn = _layer_kinds(cfg, layer_idx)
    h = L.rmsnorm(x, lp["mixer_norm"], cfg.norm_eps)
    if mixer == "m":
        h, cache = M.mamba_decode(lp["mixer"], cfg, h, cache)
    elif cfg.mla is not None:
        h, cache = MLA.mla_decode(lp["mixer"], cfg, h, cache, pos)
    else:
        h, cache = L.attention_decode(lp["mixer"], cfg, h, cache, pos, local=(mixer == "l"))
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if ffn == "dense":
        x = x + L.swiglu(lp["ffn"], L.rmsnorm(x, lp["ffn_norm"], cfg.norm_eps))
    elif ffn == "moe":
        out, aux = MOE.moe_ffn(lp["ffn"], cfg, L.rmsnorm(x, lp["ffn_norm"], cfg.norm_eps))
        x = x + out
    return x, cache, aux


def decode_step(cfg: ArchConfig, params: dict, caches: dict, tokens: jax.Array,
                pos: jax.Array) -> tuple[jax.Array, dict]:
    """One new token against a seq_len-sized cache (serve_step of the decode
    shapes). tokens: (B, 1); pos: () int32."""
    x = params["embed"][tokens]
    prelude, n_groups, pat = group_structure(cfg)

    if cfg.encdec:
        # shallow enc-dec decoder: unscanned, cross-attention interleaved
        ed = params["encdec"]
        pos_table = ed["pos"]
        posb = L.decode_positions(pos, x.shape[0])[:, 0]
        x = x + jnp.take(pos_table, posb % pos_table.shape[0], axis=0)[:, None]
        caches = dict(caches)
        new_groups = jax.tree.map(lambda v: v, caches.get("groups", {}))
        for li in range(cfg.n_layers):
            if li in prelude:
                lp = params[f"prelude_{li}"]
                cache = caches[f"prelude_{li}"]
            else:
                g, s = (li - len(prelude)) // pat, (li - len(prelude)) % pat
                lp = jax.tree.map(lambda v, g=g: v[g], params["groups"])[f"slot_{s}"]
                cache = jax.tree.map(lambda v, g=g: v[g], new_groups)[f"slot_{s}"]
            x, cache, _ = _decode_layer(cfg, lp, cache, li, x, pos)
            x = _cross_attention(cfg, ed[f"cross_{li}"], x, caches["enc_out"])
            if li in prelude:
                caches[f"prelude_{li}"] = cache
            else:
                for key, v in cache.items():
                    tgt = new_groups[f"slot_{s}"]
                    tgt[key] = tgt[key].at[g].set(v)
        if "groups" in caches:
            caches["groups"] = new_groups
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        return x @ head, caches

    for li in prelude:
        x, caches[f"prelude_{li}"], _ = _decode_layer(
            cfg, params[f"prelude_{li}"], caches[f"prelude_{li}"], li, x, pos)

    if n_groups > 0:
        base = len(prelude)

        def group_body(x, gp_cache):
            gp, gcache = gp_cache
            new_cache = {}
            for s in range(pat):
                x, c, _ = _decode_layer(cfg, gp[f"slot_{s}"], gcache[f"slot_{s}"],
                                        base + s, x, pos)
                new_cache[f"slot_{s}"] = c
            return x, new_cache

        x, new_caches = jax.lax.scan(group_body, x, (params["groups"], caches["groups"]))
        caches = dict(caches)
        caches["groups"] = new_caches

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, caches


# ---------------------------------------------------------------------------
# serving prefill: run the prompt full-seq and seed the decode caches
# ---------------------------------------------------------------------------

def _prefill_layer(cfg: ArchConfig, lp: dict, layer_idx: int, x: jax.Array,
                   positions, S_ctx: int, dtype) -> tuple[jax.Array, dict]:
    mixer, ffn = _layer_kinds(cfg, layer_idx)
    B, T, _ = x.shape
    h = L.rmsnorm(x, lp["mixer_norm"], cfg.norm_eps)
    if mixer == "m":
        h, cache = M.mamba_prefill(lp["mixer"], cfg, h)
    elif cfg.mla is not None:
        h, latent, k_rope = MLA.mla_prefill(lp["mixer"], cfg, h, positions)
        m = cfg.mla
        cache = {
            "latent": jnp.zeros((B, S_ctx, m.kv_lora), dtype).at[:, :T].set(
                latent.astype(dtype)),
            "k_rope": jnp.zeros((B, S_ctx, 1, m.rope_dim), dtype).at[:, :T].set(
                k_rope.astype(dtype)),
        }
    else:
        h, k, v = L.attention_prefill(lp["mixer"], cfg, h, local=(mixer == "l"),
                                      positions=positions)
        kvshape = (B, S_ctx, cfg.n_kv_heads, cfg.hd)
        if cfg.perf.kv_quant_int8:
            kq, ks = L._quant_kv(k)
            vq, vs = L._quant_kv(v)
            cache = {
                "k": jnp.zeros(kvshape, jnp.int8).at[:, :T].set(kq),
                "v": jnp.zeros(kvshape, jnp.int8).at[:, :T].set(vq),
                "k_scale": jnp.zeros(kvshape[:3], jnp.float32).at[:, :T].set(ks),
                "v_scale": jnp.zeros(kvshape[:3], jnp.float32).at[:, :T].set(vs),
            }
        else:
            cache = {"k": jnp.zeros(kvshape, dtype).at[:, :T].set(k.astype(dtype)),
                     "v": jnp.zeros(kvshape, dtype).at[:, :T].set(v.astype(dtype))}
    x = x + h
    if ffn == "dense":
        x = x + L.swiglu(lp["ffn"], L.rmsnorm(x, lp["ffn_norm"], cfg.norm_eps))
    elif ffn == "moe":
        out, _ = MOE.moe_ffn(lp["ffn"], cfg, L.rmsnorm(x, lp["ffn_norm"], cfg.norm_eps))
        x = x + out
    return x, cache


def prefill_with_caches(cfg: ArchConfig, params: dict, tokens: jax.Array,
                        S_ctx: int, dtype=jnp.float32
                        ) -> tuple[jax.Array, dict]:
    """tokens: (B, T) prompt. Returns (last-token logits (B, 1, V), decode
    caches positioned at T). Decoder-only path (enc-dec admits via its
    encoder + token-by-token decode)."""
    assert not cfg.encdec, "enc-dec prefill goes through the encoder"
    B, T = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(T)[None, :]
    prelude, n_groups, pat = group_structure(cfg)
    caches: dict = {}
    for li in prelude:
        x, caches[f"prelude_{li}"] = _prefill_layer(
            cfg, params[f"prelude_{li}"], li, x, positions, S_ctx, dtype)

    if n_groups > 0:
        base = len(prelude)

        def group_body(x, gp):
            out_caches = {}
            for s in range(pat):
                x, c = _prefill_layer(cfg, gp[f"slot_{s}"], base + s, x,
                                      positions, S_ctx, dtype)
                out_caches[f"slot_{s}"] = c
            return x, out_caches

        x, group_caches = jax.lax.scan(group_body, x, params["groups"])
        caches["groups"] = group_caches

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x[:, -1:] @ head, caches


# ---------------------------------------------------------------------------
# dry-run input specs
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
    elif shape.kind == "prefill":
        batch = {"tokens": sds((B, S), i32)}
    else:  # decode
        batch = {"tokens": sds((B, 1), i32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["patch_embeds"] = sds((B, cfg.vlm_prefix, cfg.d_model), dtype)
    if cfg.encdec and shape.kind != "decode":
        batch["frames"] = sds((B, cfg.enc_seq, cfg.d_model), dtype)
    return batch
