from repro.models.model import (
    init_params,
    forward,
    decode_step,
    init_decode_caches,
    input_specs,
)
from repro.models.sharding import param_shardings, batch_spec

__all__ = [
    "init_params",
    "forward",
    "decode_step",
    "init_decode_caches",
    "input_specs",
    "param_shardings",
    "batch_spec",
]
