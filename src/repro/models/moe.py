"""Mixture-of-Experts FFN with sort-based dispatch.

The GShard one-hot dispatch tensor is O(T·K·E·C) — hopeless at 1M-token
batches. Production TPU MoE sorts (token, k) assignments by expert id,
ranks within expert (capacity C ≈ cf·T·K/E), and scatters/gathers through an
(E·C, D) buffer: O(T·K·D + E·C·D) memory, and under GSPMD the scatter from
DP-sharded tokens into the EP-sharded expert buffers lowers to the expected
all-to-all. Shared experts (DeepSeek) run densely for every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig


def init_moe(cfg: ArchConfig, key, dtype) -> dict:
    m = cfg.moe
    d = cfg.d_model
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": jax.random.normal(k1, (d, m.n_experts), jnp.float32) * d ** -0.5,
        "wi": jax.random.normal(k2, (m.n_experts, d, m.d_expert), dtype) * d ** -0.5,
        "wg": jax.random.normal(k3, (m.n_experts, d, m.d_expert), dtype) * d ** -0.5,
        "wo": jax.random.normal(k4, (m.n_experts, m.d_expert, d), dtype) * m.d_expert ** -0.5,
    }
    if m.n_shared:
        ks = jax.random.split(k5, 3)
        p["shared_wi"] = jax.random.normal(ks[0], (d, m.n_shared * m.d_expert), dtype) * d ** -0.5
        p["shared_wg"] = jax.random.normal(ks[1], (d, m.n_shared * m.d_expert), dtype) * d ** -0.5
        p["shared_wo"] = jax.random.normal(ks[2], (m.n_shared * m.d_expert, d), dtype) * (m.n_shared * m.d_expert) ** -0.5
    return p


def moe_ffn(p: dict, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux load-balance loss)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    K = m.top_k
    E = m.n_experts
    C = int(max(4, round(m.capacity_factor * T * K / E)))
    xt = x.reshape(T, D)

    logits = xt.astype(jnp.float32) @ p["router"]              # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)              # (T, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch): density × mean router prob
    density = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(1.0) / (T * K)
    aux = (density * probs.mean(0)).sum() * E

    # sort (token, k) pairs by expert, rank within expert
    flat_e = gate_idx.reshape(-1)                              # (T·K,)
    flat_t = jnp.arange(T * K, dtype=jnp.int32) // K
    flat_w = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    st = flat_t[order]
    sw = flat_w[order]
    rank = jnp.arange(T * K) - jnp.searchsorted(se, se, side="left")
    ok = rank < C
    slot = se * C + rank                                       # (T·K,)
    tgt = jnp.where(ok, slot, E * C)                           # overflow -> dropped

    buf = jnp.zeros((E * C, D), x.dtype).at[tgt].set(xt[st], mode="drop")
    eb = buf.reshape(E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, p["wg"]))
    h = h * jnp.einsum("ecd,edf->ecf", eb, p["wi"])
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(E * C, D)

    contrib = jnp.where(ok[:, None], out_e[jnp.clip(slot, 0, E * C - 1)], 0)
    contrib = contrib * sw[:, None].astype(x.dtype)
    out = jnp.zeros((T, D), x.dtype).at[st].add(contrib)

    if m.n_shared:
        sh = jax.nn.silu(xt @ p["shared_wg"]) * (xt @ p["shared_wi"])
        out = out + sh @ p["shared_wo"]
    return out.reshape(B, S, D), aux
