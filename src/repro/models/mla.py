"""Multi-head Latent Attention (DeepSeek-V2): queries via a low-rank
projection; keys/values decompressed from a 512-d shared latent; decoupled
rope key. Decode caches only (latent, rope-key) per token — the paper-adjacent
memory-roofline win for decode shapes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig
from repro.models.layers import NEG_INF, rmsnorm, rope


def init_mla(cfg: ArchConfig, key, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    return {
        "wdq": jax.random.normal(ks[0], (d, m.q_lora), dtype) * s,
        "q_norm": jnp.ones((m.q_lora,), dtype),
        "wuq": jax.random.normal(ks[1], (m.q_lora, h * (m.nope_dim + m.rope_dim)), dtype) * m.q_lora ** -0.5,
        "wdkv": jax.random.normal(ks[2], (d, m.kv_lora), dtype) * s,
        "kv_norm": jnp.ones((m.kv_lora,), dtype),
        "wkr": jax.random.normal(ks[3], (d, m.rope_dim), dtype) * s,
        "wuk": jax.random.normal(ks[4], (m.kv_lora, h * m.nope_dim), dtype) * m.kv_lora ** -0.5,
        "wuv": jax.random.normal(ks[5], (m.kv_lora, h * m.v_dim), dtype) * m.kv_lora ** -0.5,
        "wo": jax.random.normal(ks[6], (h * m.v_dim, d), dtype) * (h * m.v_dim) ** -0.5,
    }


def _mla_qkv(p: dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.n_heads
    q = rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps) @ p["wuq"]
    q = q.reshape(B, S, h, m.nope_dim + m.rope_dim)
    q_nope, q_rope = q[..., : m.nope_dim], q[..., m.nope_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    latent = rmsnorm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)      # (B, S, kv_lora)
    k_rope = rope((x @ p["wkr"])[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,r)
    return q_nope, q_rope, latent, k_rope


def _mla_attend(p, cfg, q_nope, q_rope, latent, k_rope, mask):
    """Attention given (possibly cached) latent + rope keys."""
    m = cfg.mla
    B, S, h, _ = q_nope.shape
    T = latent.shape[1]
    k_nope = (latent @ p["wuk"]).reshape(B, T, h, m.nope_dim)
    v = (latent @ p["wuv"]).reshape(B, T, h, m.v_dim)
    scores = (jnp.einsum("bqhd,bthd->bhqt", q_nope, k_nope)
              + jnp.einsum("bqhr,btxr->bhqt", q_rope, k_rope))
    scores = scores.astype(jnp.float32) / ((m.nope_dim + m.rope_dim) ** 0.5)
    w = jax.nn.softmax(scores + mask, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhqt,bthd->bqhd", w, v).reshape(B, S, h * m.v_dim)
    return out @ p["wo"]


def mla_attention(p: dict, cfg: ArchConfig, x: jax.Array,
                  positions: jax.Array | None = None) -> jax.Array:
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope, latent, k_rope = _mla_qkv(p, cfg, x, positions)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.where(j > i, NEG_INF, 0.0)
    return _mla_attend(p, cfg, q_nope, q_rope, latent, k_rope, mask)


def mla_prefill(p: dict, cfg: ArchConfig, x: jax.Array,
                positions: jax.Array | None = None
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence MLA that also returns (latent, k_rope) for the decode
    cache."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope, latent, k_rope = _mla_qkv(p, cfg, x, positions)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.where(j > i, NEG_INF, 0.0)
    out = _mla_attend(p, cfg, q_nope, q_rope, latent, k_rope, mask)
    return out, latent, k_rope


def mla_decode(p: dict, cfg: ArchConfig, x: jax.Array, cache: dict,
               pos: jax.Array) -> tuple[jax.Array, dict]:
    """cache: {"latent": (B, S_ctx, kv_lora), "k_rope": (B, S_ctx, 1, rope)}"""
    from repro.models.layers import cache_insert, decode_positions

    B = x.shape[0]
    positions = decode_positions(pos, B)
    q_nope, q_rope, latent_new, k_rope_new = _mla_qkv(p, cfg, x, positions)
    latent = cache_insert(cache["latent"], latent_new, pos)
    k_rope = cache_insert(cache["k_rope"], k_rope_new, pos)
    T = latent.shape[1]
    pb = positions[:, 0][:, None, None, None]        # (B,1,1,1)
    mask = jnp.where(jnp.arange(T)[None, None, None, :] > pb, NEG_INF, 0.0)
    if cfg.perf.mla_absorb:
        out = _mla_attend_absorbed(p, cfg, q_nope, q_rope, latent, k_rope, mask)
    else:
        out = _mla_attend(p, cfg, q_nope, q_rope, latent, k_rope, mask)
    return out, {"latent": latent, "k_rope": k_rope}


def _mla_attend_absorbed(p, cfg, q_nope, q_rope, latent, k_rope, mask):
    """Decode with the absorption trick: fold W_uk into the query and W_uv
    into the output so attention runs *in latent space* — the per-token cache
    is never re-expanded to per-head keys/values. FLOPs per step drop from
    O(T·h·(nope+v)·kv_lora) to O(T·h·kv_lora) (~128× for DeepSeek-V2)."""
    m = cfg.mla
    B, S, h, _ = q_nope.shape
    wuk_h = p["wuk"].reshape(m.kv_lora, h, m.nope_dim)
    q_lat = jnp.einsum("bqhn,khn->bqhk", q_nope, wuk_h)        # (B,S,h,kv_lora)
    scores = (jnp.einsum("bqhk,btk->bhqt", q_lat, latent)
              + jnp.einsum("bqhr,btxr->bhqt", q_rope, k_rope))
    scores = scores.astype(jnp.float32) / ((m.nope_dim + m.rope_dim) ** 0.5)
    w = jax.nn.softmax(scores + mask, axis=-1).astype(latent.dtype)
    o_lat = jnp.einsum("bhqt,btk->bqhk", w, latent)            # (B,S,h,kv_lora)
    wuv_h = p["wuv"].reshape(m.kv_lora, h, m.v_dim)
    out = jnp.einsum("bqhk,khv->bqhv", o_lat, wuv_h).reshape(B, S, h * m.v_dim)
    return out @ p["wo"]
