"""Flash-style chunked attention in pure jnp (the dry-run lowering path).

The naive formulation materializes (B, H, S, S) scores — 4.3e15 elements for
chameleon prefill_32k, impossible on any chip. This implements the online-
softmax algorithm as a double scan over (query chunks × key chunks) with a
running (max, denom, accumulator) carry: peak activation is O(B·H·cq·ck).
The inner body is checkpointed so backward recomputes per-tile scores instead
of storing them (same trade flash attention makes).

``repro.kernels.flash_attention`` is the Pallas TPU kernel with identical
math; this module is what the 512-device dry-run lowers (interpret-mode
Pallas inside SPMD scans is impractically slow to trace on CPU).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import NEG_INF


def chunked_gqa_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          causal: bool, window: int = 0,
                          q_chunk: int = 1024, k_chunk: int = 1024) -> jax.Array:
    """q: (B, S, H, hd); k, v: (B, S, KV, hd) -> (B, S, H, hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    cq = min(q_chunk, S)
    ck = min(k_chunk, S)
    nq = S // cq
    nk = S // ck
    assert S % cq == 0 and S % ck == 0
    scale = hd ** -0.5

    qc = q.reshape(B, nq, cq, KV, G, hd)
    kc = k.reshape(B, nk, ck, KV, hd)
    vc = v.reshape(B, nk, ck, KV, hd)

    def q_block(qi, q_blk):
        # q_blk: (B, cq, KV, G, hd)
        def kv_step(carry, kj):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_index_in_dim(kc, kj, 1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vc, kj, 1, keepdims=False)
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk) * scale  # (B,KV,G,cq,ck)
            s = s.astype(jnp.float32)
            qpos = qi * cq + jnp.arange(cq)[:, None]
            kpos = kj * ck + jnp.arange(ck)[None, :]
            mask = jnp.zeros((cq, ck), jnp.float32)
            if causal:
                mask = jnp.where(kpos > qpos, NEG_INF, mask)
            if window:
                mask = jnp.where(qpos - kpos >= window, NEG_INF, mask)
            s = s + mask
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            # Flash-2 style: p in the activation dtype for the PV matmul
            # (halves the tile traffic), f32 accumulator via the dot itself
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        # (B, KV, G, cq, hd) -> (B, cq, H, hd)
        return jnp.moveaxis(out, 3, 1).reshape(B, cq, KV * G, hd)

    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), jnp.moveaxis(qc, 1, 0)))
    # outs: (nq, B, cq, H, hd) -> (B, S, H, hd)
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H, hd)
