"""Sharding rules: parameter/cache/batch PartitionSpecs for the production
meshes (Megatron-style TP over ``model``, optional FSDP over ``data``,
DP over ``pod`` × ``data``; GSPMD padding absorbs non-divisible dims like
qwen's 40 heads — noted in the roofline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ArchConfig, ShapeConfig

MODEL = "model"


def _rule(path: str, shape: tuple[int, ...], fsdp: str | None) -> P:
    """PartitionSpec for one parameter leaf (without the scan group dim)."""
    nd = len(shape)
    f = fsdp

    def has(*names: str) -> bool:
        return any(n in path for n in names)

    if has("embed") and nd == 2:
        return P(MODEL, None)
    if has("lm_head"):
        return P(None, MODEL)
    if has("pos", "enc_pos") and nd == 2:
        return P(None, None)
    if has("router"):
        return P(None, None)
    # MoE experts: EP over the expert dim
    if nd == 3 and has("ffn"):
        if has("wo"):
            return P(MODEL, None, f)
        return P(MODEL, f, None)
    if has("shared_wo"):
        return P(MODEL, f)
    if has("shared_wi", "shared_wg"):
        return P(f, MODEL)
    # MLA
    if has("wdq", "wdkv"):
        return P(f, None)
    if has("wkr"):
        return P(None, None)
    if has("wuq", "wuk", "wuv"):
        return P(None, MODEL)
    # Mamba
    if has("in_proj"):
        return P(f, MODEL)
    if has("conv_w"):
        return P(None, MODEL)
    if has("x_proj", "A_log", "out_proj") and nd == 2:
        return P(MODEL, f if has("out_proj") else None)
    if has("dt_proj"):
        return P(None, MODEL)
    if has("conv_b", "dt_bias") and nd == 1:
        return P(MODEL)
    if path.endswith("D") and nd == 1:
        return P(MODEL)
    # attention / dense mlp
    if has("wq", "wk", "wv", "wi", "wg") and nd == 2:
        return P(f, MODEL)
    if has("wo") and nd == 2:
        return P(MODEL, f)
    if has("bq", "bk", "bv") and nd == 1:
        return P(MODEL)
    return P(*([None] * nd))  # norms, scalars


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", k)) for k in path)


def _drop_indivisible(spec: P, shape: tuple[int, ...], mesh_sizes: dict | None) -> P:
    """Explicitly-sharded jit arguments must divide evenly; drop axes that
    don't (e.g. whisper's 51865 vocab over 16-way model)."""
    if mesh_sizes is None:
        return spec
    out = []
    for dim, axes in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        size = 1
        for a in ax_tuple:
            size *= mesh_sizes.get(a, 1)
        out.append(axes if shape[dim] % size == 0 else None)
    return P(*out)


def param_specs(cfg: ArchConfig, params_shape, fsdp: bool = True,
                mesh_sizes: dict | None = None):
    """Pytree of PartitionSpecs matching a params (shape-)pytree."""
    f = "data" if fsdp else None

    def spec_of(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if "groups" in ps:  # scan-stacked: leading group dim unsharded
            inner = _drop_indivisible(_rule(ps, shape[1:], f), shape[1:], mesh_sizes)
            return P(*(None,) + tuple(inner))
        return _drop_indivisible(_rule(ps, shape, f), shape, mesh_sizes)

    return jax.tree_util.tree_map_with_path(spec_of, params_shape)


def param_shardings(cfg: ArchConfig, mesh: Mesh, params_shape, fsdp: bool = True):
    specs = param_specs(cfg, params_shape, fsdp, dict(mesh.shape))
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_spec(mesh: Mesh, shape: ShapeConfig) -> P:
    """Token batches shard over the DP axes (pod × data)."""
    dp = dp_axes(mesh)
    B = shape.global_batch
    usable = []
    size = 1
    for a in dp:
        if B % (size * mesh.shape[a]) == 0:
            usable.append(a)
            size *= mesh.shape[a]
    return P(tuple(usable) if usable else None, None)


def activation_spec(mesh: Mesh, shape: ShapeConfig) -> P:
    dp = batch_spec(mesh, shape)[0]
    return P(dp, None, MODEL)


def cache_specs(cfg: ArchConfig, mesh: Mesh, shape: ShapeConfig, caches_shape):
    """Decode-cache shardings: batch over DP axes when divisible, sequence
    over the model axis (plus idle DP axes for tiny batches — long_500k's
    B=1 spreads its 512k-token cache over every chip)."""
    dp = batch_spec(mesh, shape)[0]            # tuple | None
    idle = tuple(a for a in dp_axes(mesh) if dp is None or a not in dp)
    seq_axes = idle + (MODEL,)                 # axes available for seq/feature

    def spec_of(path, leaf):
        ps = _path_str(path)
        shape_ = leaf.shape
        lead = ("groups" in ps)
        nd = len(shape_) - (1 if lead else 0)
        if "enc_out" in ps:
            s = P(dp, None, MODEL)
        elif "latent" in ps:                   # (B, S, kv_lora)
            s = P(dp, seq_axes, None)
        elif "k_rope" in ps:                   # (B, S, 1, rope)
            s = P(dp, seq_axes, None, None)
        elif "k_scale" in ps or "v_scale" in ps:  # (B, S, KV)
            s = P(dp, seq_axes, None)
        elif "conv" in ps and nd == 3:         # (B, d_conv-1, d_inner)
            s = P(dp, None, seq_axes)
        elif "state" in ps:                    # (B, d_inner, N)
            s = P(dp, seq_axes, None)
        elif nd == 4:                          # attention k/v (B, S, KV, hd)
            s = P(dp, seq_axes, None, None)
        else:
            s = P(*([None] * nd))
        if lead:
            s = P(*((None,) + tuple(s)))
        return s

    return jax.tree_util.tree_map_with_path(spec_of, caches_shape)


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
