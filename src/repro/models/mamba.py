"""Mamba-1 block (selective SSM): in-proj -> causal conv -> selective scan ->
gated out-proj. Training/prefill uses an associative scan over the sequence;
decode is the O(1) single-step recurrence on (conv window, SSM state)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config.base import ArchConfig


def _dims(cfg: ArchConfig) -> tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or cfg.d_model // 16
    return d_inner, s.d_state, s.d_conv, dt_rank


def init_mamba(cfg: ArchConfig, key, dtype) -> dict:
    d = cfg.d_model
    d_inner, d_state, d_conv, dt_rank = _dims(cfg)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * d_inner), dtype) * s,
        "conv_w": jax.random.normal(ks[1], (d_conv, d_inner), dtype) * 0.2,
        "conv_b": jnp.zeros((d_inner,), dtype),
        "x_proj": jax.random.normal(ks[2], (d_inner, dt_rank + 2 * d_state), dtype) * d_inner ** -0.5,
        "dt_proj": jax.random.normal(ks[3], (dt_rank, d_inner), dtype) * dt_rank ** -0.5,
        "dt_bias": jnp.zeros((d_inner,), dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (d_inner, 1))),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (d_inner, d), dtype) * d_inner ** -0.5,
    }


def _ssm_params(p: dict, cfg: ArchConfig, xc: jax.Array):
    """xc: (B, S, d_inner) post-conv activations -> dt, B_t, C_t."""
    _, d_state, _, dt_rank = _dims(cfg)
    proj = xc @ p["x_proj"]                                  # (B, S, R+2N)
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    B_t = proj[..., dt_rank: dt_rank + d_state]
    C_t = proj[..., dt_rank + d_state:]
    return dt.astype(jnp.float32), B_t.astype(jnp.float32), C_t.astype(jnp.float32)


def _scan_chunk(p, dt, B_t, C_t, xc, h_in):
    """Selective scan over one chunk given carry state h_in: (B, di, N)."""
    A = -jnp.exp(p["A_log"])                                 # (d_inner, N)
    dtA = dt[..., None] * A                                  # (B, c, di, N)
    dA = jnp.exp(dtA)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * B_t[:, :, None, :]

    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, b1 * a2 + b2

    _, h_local = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    # carry-in propagated by the running product of dA
    dA_cum = jnp.exp(jnp.cumsum(dtA, axis=1))
    h = h_local + dA_cum * h_in[:, None]
    y = jnp.einsum("bsdn,bsn->bsd", h, C_t) + p["D"] * xc.astype(jnp.float32)
    return y, h[:, -1]


def mamba_block(p: dict, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Full-sequence pass. x: (B, S, D)."""
    B, S, D = x.shape
    d_inner, d_state, d_conv, _ = _dims(cfg)
    xz = x @ p["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)                        # (B, S, d_inner)
    # causal depthwise conv
    pad = jnp.pad(xr, ((0, 0), (d_conv - 1, 0), (0, 0)))
    xc = sum(pad[:, i: i + S] * p["conv_w"][i] for i in range(d_conv)) + p["conv_b"]
    xc = jax.nn.silu(xc)

    dt, B_t, C_t = _ssm_params(p, cfg, xc)
    chunk = cfg.perf.mamba_chunk
    if chunk and S > chunk and S % chunk == 0:
        # chunked scan: O(B·c·di·N) peak instead of O(B·S·di·N)
        nc = S // chunk

        def body(h, i):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, i * chunk, chunk, 1)
            y, h = _scan_chunk(p, sl(dt), sl(B_t), sl(C_t), sl(xc), h)
            return h, y

        h0 = jnp.zeros((B, d_inner, d_state), jnp.float32)
        _, ys = jax.lax.scan(jax.checkpoint(body), h0, jnp.arange(nc))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d_inner)
    else:
        h0 = jnp.zeros((B, d_inner, d_state), jnp.float32)
        y, _ = _scan_chunk(p, dt, B_t, C_t, xc, h0)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_prefill(p: dict, cfg: ArchConfig, x: jax.Array
                  ) -> tuple[jax.Array, dict]:
    """Full-sequence pass that also returns the decode cache after the last
    token: {"conv": last d_conv-1 raw inputs, "state": final SSM state}."""
    B, S, D = x.shape
    d_inner, d_state, d_conv, _ = _dims(cfg)
    xz = x @ p["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)
    pad = jnp.pad(xr, ((0, 0), (d_conv - 1, 0), (0, 0)))
    xc = sum(pad[:, i: i + S] * p["conv_w"][i] for i in range(d_conv)) + p["conv_b"]
    xc = jax.nn.silu(xc)
    dt, B_t, C_t = _ssm_params(p, cfg, xc)
    h0 = jnp.zeros((B, d_inner, d_state), jnp.float32)
    y, h_last = _scan_chunk(p, dt, B_t, C_t, xc, h0)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    window = pad[:, -(d_conv - 1):] if d_conv > 1 else xr[:, :0]
    return y @ p["out_proj"], {"conv": window, "state": h_last}


def mamba_decode(p: dict, cfg: ArchConfig, x: jax.Array, cache: dict,
                 ) -> tuple[jax.Array, dict]:
    """Single-token step. cache: {"conv": (B, d_conv-1, d_inner),
    "state": (B, d_inner, N)} — O(1) in context length."""
    B = x.shape[0]
    d_inner, d_state, d_conv, _ = _dims(cfg)
    xz = x[:, 0] @ p["in_proj"]
    xr, z = jnp.split(xz, 2, axis=-1)                        # (B, d_inner)
    window = jnp.concatenate([cache["conv"], xr[:, None]], axis=1)  # (B, d_conv, di)
    xc = jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    dt, B_t, C_t = _ssm_params(p, cfg, xc[:, None])
    dt, B_t, C_t = dt[:, 0], B_t[:, 0], C_t[:, 0]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)                          # (B, di, N)
    state = cache["state"] * dA + (dt * xc.astype(jnp.float32))[..., None] * B_t[:, None, :]
    y = jnp.einsum("bdn,bn->bd", state, C_t) + p["D"] * xc.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = (y @ p["out_proj"])[:, None]
    return out, {"conv": window[:, 1:], "state": state}
