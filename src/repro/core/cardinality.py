"""Cardinality estimation — formulas (1)–(4) of the paper.

(1) exact DISTINCT star cardinality
(2) non-DISTINCT star estimate via per-predicate duplication factors
(3) exact DISTINCT linked-star cardinality over CPs
(4) non-DISTINCT linked-star estimate with per-CS duplication factors

Formulas (2)/(4) follow the paper's aggregation: occurrences are summed over
all *relevant* CSs before forming the ratios (that is how the running example
83,438 · (109,830/83,438) · (83,448/83,438) · (110,460/83,438) = 145,417 is
computed).
"""
from __future__ import annotations

import numpy as np

from repro.core.characteristic_pairs import CPStats
from repro.core.characteristic_sets import CSStats


def star_cardinality_distinct(cs: CSStats, preds: list[int], rel: np.ndarray | None = None) -> int:
    """Formula (1): cardinality(P) = Σ_{P ⊆ R} count(R)."""
    if rel is None:
        rel = cs.relevant_cs(preds)
    return int(cs.cs_count[rel].sum())


def star_cardinality_estimate(cs: CSStats, preds: list[int], rel: np.ndarray | None = None) -> float:
    """Formula (2): cardinality(P) · Π_p occ(p, P) / cardinality(P), with
    occ aggregated over the relevant CSs."""
    if rel is None:
        rel = cs.relevant_cs(preds)
    card = float(cs.cs_count[rel].sum())
    if card == 0:
        return 0.0
    est = card
    for p in preds:
        occ = float(sum(cs.occurrences(int(c), int(p)) for c in rel))
        est *= occ / card
    return est


def _dup_factor(cs: CSStats, c: int, preds: "list[int]") -> float:
    """Π_{p ∈ preds} occ(p, C)/count(C) — per-CS duplication factor."""
    cnt = float(cs.cs_count[c])
    if cnt == 0:
        return 0.0
    f = 1.0
    for p in preds:
        f *= cs.occurrences(int(c), int(p)) / cnt
    return f


def linked_star_cardinality_distinct(
    cp: CPStats,
    cs1: CSStats,
    cs2: CSStats,
    preds1: list[int],
    preds2: list[int],
    link_pred: int,
) -> int:
    """Formula (3): Σ_{S1 ⊆ T1 ∧ S2 ⊆ T2} count(T1, T2, p)."""
    rel1 = cs1.relevant_cs(preds1)
    rel2 = cs2.relevant_cs(preds2)
    rows = cp.select(link_pred, rel1, rel2)
    return int(cp.count[rows].sum())


def linked_star_cardinality_estimate(
    cp: CPStats,
    cs1: CSStats,
    cs2: CSStats,
    preds1: list[int],
    preds2: list[int],
    link_pred: int,
) -> float:
    """Formula (4): per relevant CP, scale count(T1,T2,p) by the duplication
    factors of T1 over S1−{p} and of T2 over S2 (p's selectivity is already in
    the CP count)."""
    rel1 = cs1.relevant_cs(preds1)
    rel2 = cs2.relevant_cs(preds2)
    rows = cp.select(link_pred, rel1, rel2)
    if len(rows) == 0:
        return 0.0
    p1 = [p for p in preds1 if p != link_pred]
    f1: dict[int, float] = {}
    f2: dict[int, float] = {}
    est = 0.0
    for r in rows:
        t1 = int(cp.cs1[r])
        t2 = int(cp.cs2[r])
        if t1 not in f1:
            f1[t1] = _dup_factor(cs1, t1, p1)
        if t2 not in f2:
            f2[t2] = _dup_factor(cs2, t2, preds2)
        est += float(cp.count[r]) * f1[t1] * f2[t2]
    return est


# --------------------------------------------------------------------------
# Memoized forms — the planner hot path re-evaluates the same (preds, CS
# restriction) combinations across subsets, queries and batches; results are
# cached on the statistics objects themselves (``CSStats._card_cache`` /
# ``CPStats._card_cache``) so the cache lives exactly as long as the stats.
# Long-lived serving processes see unbounded key diversity, so each cache is
# wiped once it reaches ``CARD_CACHE_MAX`` entries (cheap: entries are pure
# recomputation, and a wipe preserves the steady-state hit rate for
# templated workloads).
# --------------------------------------------------------------------------

CARD_CACHE_MAX = 1 << 16


def _rel_key(rel: np.ndarray | None) -> bytes | None:
    return None if rel is None else np.ascontiguousarray(rel).tobytes()


def _cache_put(cache: dict, key, value):
    if len(cache) >= CARD_CACHE_MAX:
        cache.clear()
    cache[key] = value
    return value


def star_cardinality_distinct_cached(cs: CSStats, preds: list[int],
                                     rel: np.ndarray | None = None) -> int:
    key = ("sd", tuple(int(p) for p in preds), _rel_key(rel))
    cache = cs._card_cache
    v = cache.get(key)
    if v is None:
        v = _cache_put(cache, key, star_cardinality_distinct(cs, preds, rel))
    return v


def star_cardinality_estimate_cached(cs: CSStats, preds: list[int],
                                     rel: np.ndarray | None = None) -> float:
    key = ("se", tuple(int(p) for p in preds), _rel_key(rel))
    cache = cs._card_cache
    v = cache.get(key)
    if v is None:
        v = _cache_put(cache, key, star_cardinality_estimate(cs, preds, rel))
    return v


def linked_star_cardinality_distinct_cached(
    cp: CPStats, cs1: CSStats, cs2: CSStats,
    preds1: list[int], preds2: list[int], link_pred: int,
) -> int:
    key = ("ld", tuple(int(p) for p in preds1), tuple(int(p) for p in preds2),
           int(link_pred))
    cache = cp._card_cache
    v = cache.get(key)
    if v is None:
        v = _cache_put(cache, key, linked_star_cardinality_distinct(cp, cs1, cs2, preds1, preds2, link_pred))
    return v


def linked_star_cardinality_estimate_cached(
    cp: CPStats, cs1: CSStats, cs2: CSStats,
    preds1: list[int], preds2: list[int], link_pred: int,
) -> float:
    key = ("le", tuple(int(p) for p in preds1), tuple(int(p) for p in preds2),
           int(link_pred))
    cache = cp._card_cache
    v = cache.get(key)
    if v is None:
        v = _cache_put(cache, key, linked_star_cardinality_estimate(cp, cs1, cs2, preds1, preds2, link_pred))
    return v


def clear_card_caches(stats) -> None:
    """Drop every memoized formula result (and predicate index) attached to
    a ``FederatedStats``' CS/CP objects.

    The statistics lifecycle rarely needs this: ``refresh_source`` replaces
    the affected CS/CP objects (per-source cache scoping for free) and
    ``remove_source`` invalidates nothing — surviving sources' caches are
    keyed only on their own unchanged arrays.  Prefer
    ``FederatedStats.invalidate_caches`` (which calls this *and* bumps the
    epoch so the plan cache follows); this is only the object-level part."""
    for cs in stats.cs:
        cs.invalidate_caches()
    for cp in stats.intra_cp:
        cp.invalidate_caches()
    for cp in stats.fed_cp.values():
        cp.invalidate_caches()


def join_selectivity(
    cp: CPStats,
    cs1: CSStats,
    cs2: CSStats,
    preds1: list[int],
    preds2: list[int],
    link_pred: int,
) -> float:
    """Selectivity of the link join: |S1 ⋈_p S2| / (|S1| · |S2|), from CPs.

    Used by the meta-node DP when composing more than two stars.
    """
    c1 = star_cardinality_distinct(cs1, preds1)
    c2 = star_cardinality_distinct(cs2, preds2)
    if c1 == 0 or c2 == 0:
        return 0.0
    links = linked_star_cardinality_distinct(cp, cs1, cs2, preds1, preds2, link_pred)
    return links / (c1 * c2)
