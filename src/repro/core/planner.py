"""The Odyssey optimizer (paper §3.4): preprocessing + source selection,
join-order optimization, subquery optimization (merging), and plan emission.

``OdysseyOptimizer.optimize`` produces a ``PhysicalPlan`` the engines
(``repro.engine.local`` / ``repro.engine.distributed``) execute, plus the
paper's plan-level metrics (optimization time, #selected sources,
#subqueries).

Serving-scale additions on top of the paper:

* **Plan cache** — plans are keyed by a canonical query signature
  (``query_signature``: pattern structure with variables canonicalized by
  first occurrence, constant ids verbatim, plus the DISTINCT flag).  A
  repeated or templated query skips decomposition, source selection and the
  join-order DP entirely; on a hit the cached plan is rebound to the incoming
  query (variables renamed if the new query uses different names).  Entries
  are *epoch-keyed*: each records the statistics epoch it was planned under,
  and a hit under a newer epoch (after ``FederatedStats.remove_source`` /
  ``add_source`` / ``refresh_source``) is a miss — the stale entry is
  lazily evicted and the structure-only signature re-warms naturally.
* **Batch planning** — ``optimize_batch`` routes through the truly batched
  pipeline in ``repro.core.batch_planner``: one statistics-epoch snapshot
  for the whole batch, plan-cache hits and exact-signature duplicates
  rebound per query, then the remaining queries share a single source-
  selection pass (per-star/per-probe memo over the union of their stars)
  and one stacked DP sweep per structural *shape* (star-graph topology +
  per-star predicate signatures + DISTINCT).  Per query the result is
  bit-identical to calling ``optimize`` in a loop.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field, replace

from repro.core.cost import CostModel
from repro.core.decomposition import StarGraph, decompose, decompose_patterns
from repro.core.federation import FederatedStats
from repro.core.join_order import (
    DP_BACKENDS,
    JoinTree,
    dp_join_order,
    order_star_patterns,
    star_source_cardinalities,
)
from repro.core.source_selection import (
    SourceSelection,
    concat_selections,
    select_sources,
)
from repro.query.algebra import (
    And,
    BGPQuery,
    Bgp,
    Comparison,
    Const,
    Expr,
    Filter,
    GroupNode,
    Join,
    LeftJoin,
    Not,
    Or,
    Term,
    TriplePattern,
    Union,
    Var,
    expr_variables,
    group_variables,
    is_well_designed,
    normalize,
)


@dataclass
class PlanNode:
    pass


@dataclass
class SubqueryNode(PlanNode):
    """One SPARQL subquery dispatched to ``sources`` (merged stars ==
    exclusive group executed remotely as a single query)."""

    stars: list[int]
    patterns: list[TriplePattern]            # in execution order
    sources: list[int]
    est_cardinality: float = 0.0
    # per-source expected rows, aligned with ``sources`` — what the pipeline
    # scores each endpoint's observed scan cardinality against (feedback)
    est_source_cards: "list[float] | None" = None


@dataclass
class JoinPlanNode(PlanNode):
    left: PlanNode
    right: PlanNode
    strategy: str                            # "hash" | "bind"
    join_vars: list[str] = field(default_factory=list)
    est_cardinality: float = 0.0


@dataclass
class LeftJoinPlanNode(PlanNode):
    """OPTIONAL: every left row survives; right columns are UNDEF where the
    arm found no match.  Child order is semantic (never commuted)."""

    left: PlanNode
    right: PlanNode
    join_vars: list[str] = field(default_factory=list)
    est_cardinality: float = 0.0


@dataclass
class UnionPlanNode(PlanNode):
    """UNION: outer union of the children's results, schemas aligned with
    UNDEF padding."""

    children: list[PlanNode] = field(default_factory=list)
    est_cardinality: float = 0.0


@dataclass
class FilterPlanNode(PlanNode):
    """FILTER over the child's rows.  The normalization pass places these at
    the deepest point where the expression's variables are certainly bound,
    so the engine evaluates them as early as possible."""

    expr: Expr
    child: PlanNode
    est_cardinality: float = 0.0


@dataclass
class PhysicalPlan:
    root: PlanNode
    query: BGPQuery
    graph: StarGraph
    selection: SourceSelection
    optimization_ms: float = 0.0
    fallback: bool = False                   # variable-predicate fallback
    cached: bool = False                     # served from the plan cache
    stats_epoch: int = 0                     # statistics epoch it was planned under
    well_designed: bool = True               # OPTIONAL reordering was licensed

    def subqueries(self) -> list[SubqueryNode]:
        out: list[SubqueryNode] = []

        def walk(n: PlanNode) -> None:
            if isinstance(n, SubqueryNode):
                out.append(n)
            elif isinstance(n, (JoinPlanNode, LeftJoinPlanNode)):
                walk(n.left)
                walk(n.right)
            elif isinstance(n, UnionPlanNode):
                for c in n.children:
                    walk(c)
            elif isinstance(n, FilterPlanNode):
                walk(n.child)

        walk(self.root)
        return out

    @property
    def n_subqueries(self) -> int:
        """NSQ: subqueries dispatched (a subquery sent to k sources counts k,
        matching how the FedBench studies count endpoint requests)."""
        return sum(max(1, len(sq.sources)) for sq in self.subqueries())

    @property
    def n_selected_sources(self) -> int:
        """NSS: Σ over triple patterns of #selected sources."""
        return self.selection.pattern_source_count(self.graph)


# --------------------------------------------------------------------------
# Plan cache
# --------------------------------------------------------------------------

def query_signature(query: BGPQuery) -> tuple[tuple, tuple[str, ...]]:
    """Canonical signature of a BGP query: pattern structure with variables
    numbered by first occurrence, constant term ids verbatim, and the
    DISTINCT flag.  Returns ``(signature, var_order)`` where ``var_order``
    lists the query's variable names in canonical-index order (used to rebind
    a cached plan onto a query that differs only in variable names).

    Queries differing in any constant, in DISTINCT, or in pattern order get
    distinct signatures; the projection does not affect the plan shape and is
    re-attached from the incoming query on a hit.

    A query carrying a group tree (``query.root``) is hashed over the *full
    algebra*: node kinds, filter expressions, and child order (LeftJoin child
    order is semantic).  The degenerate ``root is None`` case keeps the
    legacy flat-pattern signature bit-for-bit, and the algebra signatures
    live under a distinct ``"alg"`` tag — an OPTIONAL/UNION/FILTER variant
    of a template can never alias its plain-BGP cache entry.
    """
    names: dict[str, int] = {}

    def term_key(t: Term) -> tuple:
        if isinstance(t, Const):
            return ("c", t.tid)
        assert isinstance(t, Var)
        return ("v", names.setdefault(t.name, len(names)))

    if query.root is None:
        pats = tuple((term_key(tp.s), term_key(tp.p), term_key(tp.o))
                     for tp in query.patterns)
        return (pats, bool(query.distinct)), tuple(names)

    def expr_key(e: Expr) -> tuple:
        if isinstance(e, Comparison):
            return ("cmp", e.op, term_key(e.lhs), term_key(e.rhs))
        if isinstance(e, (And, Or)):
            tag = "and" if isinstance(e, And) else "or"
            return (tag, tuple(expr_key(p) for p in e.parts))
        assert isinstance(e, Not)
        return ("not", expr_key(e.part))

    def node_key(n: GroupNode) -> tuple:
        if isinstance(n, Bgp):
            return ("bgp", tuple((term_key(tp.s), term_key(tp.p),
                                  term_key(tp.o)) for tp in n.patterns))
        if isinstance(n, Join):
            return ("join", tuple(node_key(c) for c in n.children))
        if isinstance(n, LeftJoin):
            return ("leftjoin", node_key(n.left), node_key(n.right))
        if isinstance(n, Union):
            return ("union", tuple(node_key(m) for m in n.members))
        assert isinstance(n, Filter)
        return ("filter", expr_key(n.expr), node_key(n.child))

    sig = ("alg", node_key(query.root))
    # filter-only variables may trail the pattern variables; make sure every
    # query variable has a canonical index so rebinding can rename the tree
    for tp in query.patterns:
        for t in (tp.s, tp.p, tp.o):
            if isinstance(t, Var):
                names.setdefault(t.name, len(names))
    return (sig, bool(query.distinct)), tuple(names)


@dataclass
class CacheEntry:
    plan: PhysicalPlan                        # pristine, detached copy
    var_order: tuple[str, ...]
    epoch: int = 0                            # stats epoch it was planned under


class PlanCache:
    """LRU map: query signature -> pristine plan + the statistics epoch it
    was planned under.

    Epoch-aware: a lookup under a *newer* epoch is a miss — the entry was
    planned over statistics that have since been mutated (source removed,
    added or refreshed), so its source ids and cardinalities may be stale.
    Eviction is lazy: stale entries are dropped on touch, and because
    ``query_signature`` is structure-only, a templated workload re-warms the
    cache naturally after a refresh (first arrival per template replans, the
    rest hit)."""

    def __init__(self, max_entries: int = 1024):
        self.max_entries = max_entries
        self._entries: OrderedDict[tuple, CacheEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.stale_evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, sig: tuple, epoch: int | None = None) -> CacheEntry | None:
        entry = self._entries.get(sig)
        if entry is None:
            self.misses += 1
            return None
        if epoch is not None and entry.epoch != epoch:
            del self._entries[sig]            # lazy eviction of a stale plan
            self.stale_evictions += 1
            self.misses += 1
            return None
        self._entries.move_to_end(sig)
        self.hits += 1
        # repro: ignore[RPR002] -- entry.plan is stored pre-detached (put() runs
        # _detach_plan) and every hit site re-detaches before handing the plan
        # to callers (see optimize()/_rebind); the entry itself never escapes
        return entry

    def put(self, sig: tuple, plan: PhysicalPlan, var_order: tuple[str, ...],
            epoch: int = 0) -> None:
        # store a pristine, detached plan: the caller keeps (and may mutate)
        # `plan`, its tree, its selection and its graph
        self._entries[sig] = CacheEntry(_detach_plan(plan), var_order, epoch)
        self._entries.move_to_end(sig)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)

    def clear(self) -> None:
        self._entries.clear()
        self.hits = 0
        self.misses = 0
        self.stale_evictions = 0


def _detach_plan(plan: PhysicalPlan) -> PhysicalPlan:
    """A plan that shares no mutable state with ``plan``: fresh tree, fresh
    selection containers (empty per-query memo), fresh graph containers.
    Without this, a caller mutating ``plan.selection.star_sources`` (exactly
    what failover-style source exclusion does) corrupts every later hit."""
    return replace(plan, root=_copy_node(plan.root),
                   selection=plan.selection.detach(),
                   graph=plan.graph.detach())


def _copy_node(node: PlanNode) -> PlanNode:
    """Fresh plan tree with fresh mutable fields.  Cached plans must never
    share their ``root`` with plans handed to callers: engines and callers
    adjust ``est_cardinality`` / ``sources`` in place, which would silently
    corrupt every later cache hit.  Every ``PlanNode`` variant must be
    handled here — an unhandled variant would alias the stored entry
    (RPR002 checks this mechanically)."""
    if isinstance(node, SubqueryNode):
        return SubqueryNode(stars=list(node.stars), patterns=list(node.patterns),
                            sources=list(node.sources),
                            est_cardinality=node.est_cardinality,
                            est_source_cards=(None if node.est_source_cards is None
                                              else list(node.est_source_cards)))
    if isinstance(node, LeftJoinPlanNode):
        return LeftJoinPlanNode(left=_copy_node(node.left),
                                right=_copy_node(node.right),
                                join_vars=list(node.join_vars),
                                est_cardinality=node.est_cardinality)
    if isinstance(node, UnionPlanNode):
        return UnionPlanNode(children=[_copy_node(c) for c in node.children],
                             est_cardinality=node.est_cardinality)
    if isinstance(node, FilterPlanNode):
        # Expr trees are frozen dataclasses (immutable): shared by contract
        return FilterPlanNode(expr=node.expr, child=_copy_node(node.child),
                              est_cardinality=node.est_cardinality)
    assert isinstance(node, JoinPlanNode)
    return JoinPlanNode(left=_copy_node(node.left), right=_copy_node(node.right),
                        strategy=node.strategy, join_vars=list(node.join_vars),
                        est_cardinality=node.est_cardinality)


def _rename_term(t: Term, ren: dict[str, str]) -> Term:
    return Var(ren[t.name]) if isinstance(t, Var) else t


def _rename_expr(e: Expr, ren: dict[str, str]) -> Expr:
    if isinstance(e, Comparison):
        return Comparison(e.op, _rename_term(e.lhs, ren), _rename_term(e.rhs, ren))
    if isinstance(e, And):
        return And(tuple(_rename_expr(p, ren) for p in e.parts))
    if isinstance(e, Or):
        return Or(tuple(_rename_expr(p, ren) for p in e.parts))
    assert isinstance(e, Not)
    return Not(_rename_expr(e.part, ren))


def _rename_node(node: PlanNode, ren: dict[str, str]) -> PlanNode:
    if isinstance(node, SubqueryNode):
        pats = [TriplePattern(_rename_term(tp.s, ren), _rename_term(tp.p, ren),
                              _rename_term(tp.o, ren)) for tp in node.patterns]
        return SubqueryNode(stars=list(node.stars), patterns=pats,
                            sources=list(node.sources),
                            est_cardinality=node.est_cardinality,
                            est_source_cards=(None if node.est_source_cards is None
                                              else list(node.est_source_cards)))
    if isinstance(node, LeftJoinPlanNode):
        return LeftJoinPlanNode(left=_rename_node(node.left, ren),
                                right=_rename_node(node.right, ren),
                                join_vars=sorted(ren[v] for v in node.join_vars),
                                est_cardinality=node.est_cardinality)
    if isinstance(node, UnionPlanNode):
        return UnionPlanNode(children=[_rename_node(c, ren)
                                       for c in node.children],
                             est_cardinality=node.est_cardinality)
    if isinstance(node, FilterPlanNode):
        return FilterPlanNode(expr=_rename_expr(node.expr, ren),
                              child=_rename_node(node.child, ren),
                              est_cardinality=node.est_cardinality)
    assert isinstance(node, JoinPlanNode)
    return JoinPlanNode(left=_rename_node(node.left, ren),
                        right=_rename_node(node.right, ren),
                        strategy=node.strategy,
                        join_vars=sorted(ren[v] for v in node.join_vars),
                        est_cardinality=node.est_cardinality)


def _rename_graph(graph: StarGraph, ren: dict[str, str]) -> StarGraph:
    """Rename the variables of a (detached) star graph in place of
    re-decomposing: algebra plans concatenate per-block graphs, a shape
    ``decompose(query)`` cannot reproduce."""
    from repro.core.decomposition import Edge, Star

    def rn_tp(tp: TriplePattern) -> TriplePattern:
        return TriplePattern(_rename_term(tp.s, ren), _rename_term(tp.p, ren),
                             _rename_term(tp.o, ren))

    stars = [Star(s.idx, _rename_term(s.subject, ren), [rn_tp(tp) for tp in s.patterns])
             for s in graph.stars]
    edges = [Edge(src=e.src, dst=e.dst, pred=e.pred,
                  pattern=rn_tp(e.pattern) if e.pattern is not None else None,
                  generic=e.generic,
                  var=ren.get(e.var, e.var) if e.var is not None else None)
             for e in graph.edges]
    return StarGraph(stars=stars, edges=edges, query=graph.query)


class OdysseyOptimizer:
    """Cost-based federated optimizer over CS/CP statistics, with an LRU plan
    cache in front of the full optimization pipeline."""

    def __init__(self, stats: FederatedStats, cost_model: CostModel | None = None,
                 plan_cache_size: int = 1024, dp_block_bytes: int | None = None,
                 dp_backend: str = "numpy"):
        self.stats = stats
        self.cost_model = cost_model or CostModel()
        self.plan_cache: PlanCache | None = (
            PlanCache(plan_cache_size) if plan_cache_size > 0 else None)
        # peak bytes for the join-order DP's per-layer candidate tiles
        # (None == repro.core.join_order.DP_BLOCK_BYTES)
        self.dp_block_bytes = dp_block_bytes
        # who runs the DP sweep: 'numpy' (in-process tiled layer loop) or
        # 'jax' (one device-resident repro.kernels.dp_layer program per
        # sweep, per-layer kernel tiles as the oversized-schedule fallback);
        # plans are bit-identical either way
        if dp_backend not in DP_BACKENDS:
            raise ValueError(f"unknown dp_backend {dp_backend!r} "
                             f"(expected one of {DP_BACKENDS})")
        self.dp_backend = dp_backend
        # what the last optimize_batch call shared (BatchPlanReport)
        self.last_batch_report = None

    @property
    def stats_epoch(self) -> int:
        """Epoch of the underlying statistics (0 for legacy stats objects)."""
        return getattr(self.stats, "epoch", 0)

    def optimize(self, query: BGPQuery, use_cache: bool = True) -> PhysicalPlan:
        t0 = time.perf_counter()
        epoch = self.stats_epoch               # one snapshot per planning call
        sig = var_order = None
        if use_cache and self.plan_cache is not None:
            sig, var_order = query_signature(query)
            entry = self.plan_cache.get(sig, epoch=epoch)
            if entry is not None:
                plan = self._rebind(entry, var_order, query)
                plan.optimization_ms = (time.perf_counter() - t0) * 1e3
                return plan
        plan = self._optimize_uncached(query, t0)
        plan.stats_epoch = epoch
        if sig is not None:
            self.plan_cache.put(sig, plan, var_order, epoch=epoch)
        return plan

    def optimize_batch(self, queries: "list[BGPQuery]") -> "list[PhysicalPlan]":
        """Plan a batch through the truly batched pipeline
        (``repro.core.batch_planner.plan_batch``): one epoch snapshot,
        plan-cache hits and exact-signature duplicates rebound per query,
        one shared source-selection pass over the union of the remaining
        queries' stars, and one stacked DP sweep per structural shape.
        Bit-identical per query to ``[self.optimize(q) for q in queries]``
        — batching changes the planning cost, never the plans.  The
        sharing achieved is reported on ``self.last_batch_report``."""
        from repro.core.batch_planner import plan_batch

        return plan_batch(self, queries)

    def _optimize_uncached(self, query: BGPQuery, t0: float) -> PhysicalPlan:
        if not query.is_conjunctive():
            return self._optimize_algebra(query, t0)
        graph = decompose(query)
        sel = select_sources(graph, self.stats)
        tree = dp_join_order(graph, self.stats, sel, self.cost_model, query.distinct,
                             block_bytes=self.dp_block_bytes,
                             dp_backend=self.dp_backend)
        root = self._emit(tree, graph, sel, query)
        plan = PhysicalPlan(root=root, query=query, graph=graph, selection=sel,
                            stats_epoch=self.stats_epoch)
        plan.fallback = any(s.has_var_pred for s in graph.stars)
        plan.optimization_ms = (time.perf_counter() - t0) * 1e3
        return plan

    # -- group-tree (OPTIONAL / UNION / FILTER) planning --------------------
    def _optimize_algebra(self, query: BGPQuery, t0: float) -> PhysicalPlan:
        """Compositional planning over the normalized group tree: each ``Bgp``
        block runs the unchanged conjunctive pipeline (star decomposition →
        source selection → bitmask DP → emission), and the blocks are composed
        with LeftJoin/Union/Filter plan nodes costed by ``CostModel``.  The
        plan-level graph/selection concatenate the per-block results so NSS
        and source-failover keep working on extended plans."""
        root_alg = normalize(query.algebra())
        graphs: list[StarGraph] = []
        sels: list[SourceSelection] = []
        root = self._plan_group(root_alg, query, graphs, sels)
        graph, sel = concat_selections(graphs, sels, query)
        plan = PhysicalPlan(root=root, query=query, graph=graph, selection=sel,
                            stats_epoch=self.stats_epoch,
                            well_designed=is_well_designed(root_alg))
        plan.fallback = any(s.has_var_pred for s in graph.stars)
        plan.optimization_ms = (time.perf_counter() - t0) * 1e3
        return plan

    def _plan_group(self, node: GroupNode, query: BGPQuery,
                    graphs: "list[StarGraph]",
                    sels: "list[SourceSelection]") -> PlanNode:
        cm = self.cost_model
        if isinstance(node, Bgp):
            if not node.patterns:
                raise ValueError(
                    "empty group pattern (e.g. a bare OPTIONAL) is not "
                    "supported — every group needs at least one triple pattern")
            block = decompose_patterns(list(node.patterns), query)
            sel = select_sources(block, self.stats)
            tree = dp_join_order(block, self.stats, sel, cm, query.distinct,
                                 block_bytes=self.dp_block_bytes,
                                 dp_backend=self.dp_backend)
            planned = self._emit(tree, block, sel, query)
            soff = sum(len(g.stars) for g in graphs)
            if soff:
                _offset_stars(planned, soff)
            graphs.append(block)
            sels.append(sel)
            return planned
        if isinstance(node, Join):
            children = [self._plan_group(c, query, graphs, sels)
                        for c in node.children]
            # left-deep, cheapest block first (stable: ties keep group order)
            children.sort(key=lambda n: n.est_cardinality)
            cur = children[0]
            for nxt in children[1:]:
                shared = sorted(_vars_of(cur) & _vars_of(nxt))
                card = cm.cross_join_card(cur.est_cardinality,
                                          nxt.est_cardinality, len(shared))
                cur = JoinPlanNode(left=cur, right=nxt, strategy="hash",
                                   join_vars=shared, est_cardinality=card)
            return cur
        if isinstance(node, LeftJoin):
            left = self._plan_group(node.left, query, graphs, sels)
            right = self._plan_group(node.right, query, graphs, sels)
            shared = sorted(_vars_of(left) & _vars_of(right))
            card_join = cm.cross_join_card(left.est_cardinality,
                                           right.est_cardinality, len(shared))
            return LeftJoinPlanNode(
                left=left, right=right, join_vars=shared,
                est_cardinality=cm.left_join_card(left.est_cardinality,
                                                  card_join))
        if isinstance(node, Union):
            children = [self._plan_group(m, query, graphs, sels)
                        for m in node.members]
            card = cm.union_card([c.est_cardinality for c in children])
            return UnionPlanNode(children=children, est_cardinality=card)
        assert isinstance(node, Filter)
        child = self._plan_group(node.child, query, graphs, sels)
        card = child.est_cardinality * cm.filter_selectivity(node.expr)
        return FilterPlanNode(expr=node.expr, child=child, est_cardinality=card)

    def _rebind(self, entry: CacheEntry, var_order: tuple[str, ...],
                query: BGPQuery) -> PhysicalPlan:
        """Attach a cached plan to an equivalent incoming query.  Stars keep
        their indices under variable renaming (decomposition groups patterns
        by first occurrence of the subject), so the source selection carries
        over; only variable names inside the plan tree may need rewriting.

        Every hit owns its tree, selection and graph: callers mutate
        est_cardinality/sources/star_sources in place, and aliasing the
        cached copy (or another hit) would corrupt every later hit."""
        cached, cached_order = entry.plan, entry.var_order
        if cached_order == var_order:
            return replace(cached, root=_copy_node(cached.root), query=query,
                           selection=cached.selection.detach(),
                           graph=cached.graph.detach(), cached=True,
                           stats_epoch=entry.epoch)
        ren = dict(zip(cached_order, var_order))
        root = _rename_node(cached.root, ren)
        if query.root is None:
            graph = decompose(query)
        else:
            # algebra plans concatenate per-block star graphs — a shape
            # decompose(query) cannot rebuild — so rename the cached one
            graph = _rename_graph(cached.graph, ren)
            graph.query = query
        return replace(cached, root=root, query=query, graph=graph,
                       selection=cached.selection.detach(), cached=True,
                       stats_epoch=entry.epoch)

    # -- plan emission with subquery merging (§3.4 step iii) ---------------
    def _emit(self, tree: JoinTree, graph: StarGraph, sel: SourceSelection,
              query: BGPQuery) -> PlanNode:
        if tree.kind == "leaf":
            stars = sorted(tree.stars)
            patterns: list[TriplePattern] = []
            for si in stars:
                patterns.extend(order_star_patterns(graph.stars[si], self.stats, sel,
                                                    query.distinct))
            sources = tree.sources if tree.sources is not None else sel.star_sources[stars[0]]
            sources = list(sources)
            # estimate plumb-through for the pipeline's cardinality feedback:
            # a single-star leaf gets the per-source split of its star
            # cardinality; a merged exclusive group joins remotely, so the
            # best attribution is an even split of the group estimate
            if len(stars) == 1:
                per = star_source_cardinalities(graph.stars[stars[0]], self.stats,
                                                sel, query.distinct, sources)
            else:
                n = max(1, len(sources))
                per = [tree.cardinality / n] * len(sources)
            return SubqueryNode(stars=stars, patterns=patterns, sources=sources,
                                est_cardinality=tree.cardinality,
                                est_source_cards=per)
        left = self._emit(tree.left, graph, sel, query)    # type: ignore[arg-type]
        right = self._emit(tree.right, graph, sel, query)  # type: ignore[arg-type]
        join_vars = sorted(_vars_of(left) & _vars_of(right))
        return JoinPlanNode(left=left, right=right, strategy=tree.strategy or "hash",
                            join_vars=join_vars, est_cardinality=tree.cardinality)


def _vars_of(node: PlanNode) -> set[str]:
    if isinstance(node, SubqueryNode):
        out: set[str] = set()
        for tp in node.patterns:
            out |= set(tp.variables())
        return out
    if isinstance(node, (JoinPlanNode, LeftJoinPlanNode)):
        return _vars_of(node.left) | _vars_of(node.right)
    if isinstance(node, UnionPlanNode):
        out = set()
        for c in node.children:
            out |= _vars_of(c)
        return out
    assert isinstance(node, FilterPlanNode)
    return _vars_of(node.child) | set(expr_variables(node.expr))


def _offset_stars(node: PlanNode, off: int) -> None:
    """Shift the star indices of one planned block so they index into the
    concatenated plan-level graph (``concat_selections``).  Block trees only
    contain Subquery/Join nodes — composition nodes are added above them."""
    if isinstance(node, SubqueryNode):
        node.stars = [s + off for s in node.stars]
        return
    assert isinstance(node, JoinPlanNode)
    _offset_stars(node.left, off)
    _offset_stars(node.right, off)
