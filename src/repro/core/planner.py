"""The Odyssey optimizer (paper §3.4): preprocessing + source selection,
join-order optimization, subquery optimization (merging), and plan emission.

``OdysseyOptimizer.optimize`` produces a ``PhysicalPlan`` the engines
(``repro.engine.local`` / ``repro.engine.distributed``) execute, plus the
paper's plan-level metrics (optimization time, #selected sources,
#subqueries).

Serving-scale additions on top of the paper:

* **Plan cache** — plans are keyed by a canonical query signature
  (``query_signature``: pattern structure with variables canonicalized by
  first occurrence, constant ids verbatim, plus the DISTINCT flag).  A
  repeated or templated query skips decomposition, source selection and the
  join-order DP entirely; on a hit the cached plan is rebound to the incoming
  query (variables renamed if the new query uses different names).  Entries
  are *epoch-keyed*: each records the statistics epoch it was planned under,
  and a hit under a newer epoch (after ``FederatedStats.remove_source`` /
  ``add_source`` / ``refresh_source``) is a miss — the stale entry is
  lazily evicted and the structure-only signature re-warms naturally.
* **Batch planning** — ``optimize_batch`` routes through the truly batched
  pipeline in ``repro.core.batch_planner``: one statistics-epoch snapshot
  for the whole batch, plan-cache hits and exact-signature duplicates
  rebound per query, then the remaining queries share a single source-
  selection pass (per-star/per-probe memo over the union of their stars)
  and one stacked DP sweep per structural *shape* (star-graph topology +
  per-star predicate signatures + DISTINCT).  Per query the result is
  bit-identical to calling ``optimize`` in a loop.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field, replace

from repro.core.cost import CostModel
from repro.core.decomposition import StarGraph, decompose
from repro.core.federation import FederatedStats
from repro.core.join_order import (
    DP_BACKENDS,
    JoinTree,
    dp_join_order,
    order_star_patterns,
)
from repro.core.source_selection import SourceSelection, select_sources
from repro.query.algebra import BGPQuery, Const, Term, TriplePattern, Var


@dataclass
class PlanNode:
    pass


@dataclass
class SubqueryNode(PlanNode):
    """One SPARQL subquery dispatched to ``sources`` (merged stars ==
    exclusive group executed remotely as a single query)."""

    stars: list[int]
    patterns: list[TriplePattern]            # in execution order
    sources: list[int]
    est_cardinality: float = 0.0


@dataclass
class JoinPlanNode(PlanNode):
    left: PlanNode
    right: PlanNode
    strategy: str                            # "hash" | "bind"
    join_vars: list[str] = field(default_factory=list)
    est_cardinality: float = 0.0


@dataclass
class PhysicalPlan:
    root: PlanNode
    query: BGPQuery
    graph: StarGraph
    selection: SourceSelection
    optimization_ms: float = 0.0
    fallback: bool = False                   # variable-predicate fallback
    cached: bool = False                     # served from the plan cache
    stats_epoch: int = 0                     # statistics epoch it was planned under

    def subqueries(self) -> list[SubqueryNode]:
        out: list[SubqueryNode] = []

        def walk(n: PlanNode) -> None:
            if isinstance(n, SubqueryNode):
                out.append(n)
            elif isinstance(n, JoinPlanNode):
                walk(n.left)
                walk(n.right)

        walk(self.root)
        return out

    @property
    def n_subqueries(self) -> int:
        """NSQ: subqueries dispatched (a subquery sent to k sources counts k,
        matching how the FedBench studies count endpoint requests)."""
        return sum(max(1, len(sq.sources)) for sq in self.subqueries())

    @property
    def n_selected_sources(self) -> int:
        """NSS: Σ over triple patterns of #selected sources."""
        return self.selection.pattern_source_count(self.graph)


# --------------------------------------------------------------------------
# Plan cache
# --------------------------------------------------------------------------

def query_signature(query: BGPQuery) -> tuple[tuple, tuple[str, ...]]:
    """Canonical signature of a BGP query: pattern structure with variables
    numbered by first occurrence, constant term ids verbatim, and the
    DISTINCT flag.  Returns ``(signature, var_order)`` where ``var_order``
    lists the query's variable names in canonical-index order (used to rebind
    a cached plan onto a query that differs only in variable names).

    Queries differing in any constant, in DISTINCT, or in pattern order get
    distinct signatures; the projection does not affect the plan shape and is
    re-attached from the incoming query on a hit.
    """
    names: dict[str, int] = {}

    def term_key(t: Term) -> tuple:
        if isinstance(t, Const):
            return ("c", t.tid)
        assert isinstance(t, Var)
        return ("v", names.setdefault(t.name, len(names)))

    pats = tuple((term_key(tp.s), term_key(tp.p), term_key(tp.o))
                 for tp in query.patterns)
    return (pats, bool(query.distinct)), tuple(names)


@dataclass
class CacheEntry:
    plan: PhysicalPlan                        # pristine, detached copy
    var_order: tuple[str, ...]
    epoch: int = 0                            # stats epoch it was planned under


class PlanCache:
    """LRU map: query signature -> pristine plan + the statistics epoch it
    was planned under.

    Epoch-aware: a lookup under a *newer* epoch is a miss — the entry was
    planned over statistics that have since been mutated (source removed,
    added or refreshed), so its source ids and cardinalities may be stale.
    Eviction is lazy: stale entries are dropped on touch, and because
    ``query_signature`` is structure-only, a templated workload re-warms the
    cache naturally after a refresh (first arrival per template replans, the
    rest hit)."""

    def __init__(self, max_entries: int = 1024):
        self.max_entries = max_entries
        self._entries: OrderedDict[tuple, CacheEntry] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.stale_evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, sig: tuple, epoch: int | None = None) -> CacheEntry | None:
        entry = self._entries.get(sig)
        if entry is None:
            self.misses += 1
            return None
        if epoch is not None and entry.epoch != epoch:
            del self._entries[sig]            # lazy eviction of a stale plan
            self.stale_evictions += 1
            self.misses += 1
            return None
        self._entries.move_to_end(sig)
        self.hits += 1
        # repro: ignore[RPR002] -- entry.plan is stored pre-detached (put() runs
        # _detach_plan) and every hit site re-detaches before handing the plan
        # to callers (see optimize()/_rebind); the entry itself never escapes
        return entry

    def put(self, sig: tuple, plan: PhysicalPlan, var_order: tuple[str, ...],
            epoch: int = 0) -> None:
        # store a pristine, detached plan: the caller keeps (and may mutate)
        # `plan`, its tree, its selection and its graph
        self._entries[sig] = CacheEntry(_detach_plan(plan), var_order, epoch)
        self._entries.move_to_end(sig)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)

    def clear(self) -> None:
        self._entries.clear()
        self.hits = 0
        self.misses = 0
        self.stale_evictions = 0


def _detach_plan(plan: PhysicalPlan) -> PhysicalPlan:
    """A plan that shares no mutable state with ``plan``: fresh tree, fresh
    selection containers (empty per-query memo), fresh graph containers.
    Without this, a caller mutating ``plan.selection.star_sources`` (exactly
    what failover-style source exclusion does) corrupts every later hit."""
    return replace(plan, root=_copy_node(plan.root),
                   selection=plan.selection.detach(),
                   graph=plan.graph.detach())


def _copy_node(node: PlanNode) -> PlanNode:
    """Fresh plan tree with fresh mutable fields.  Cached plans must never
    share their ``root`` with plans handed to callers: engines and callers
    adjust ``est_cardinality`` / ``sources`` in place, which would silently
    corrupt every later cache hit."""
    if isinstance(node, SubqueryNode):
        return SubqueryNode(stars=list(node.stars), patterns=list(node.patterns),
                            sources=list(node.sources),
                            est_cardinality=node.est_cardinality)
    assert isinstance(node, JoinPlanNode)
    return JoinPlanNode(left=_copy_node(node.left), right=_copy_node(node.right),
                        strategy=node.strategy, join_vars=list(node.join_vars),
                        est_cardinality=node.est_cardinality)


def _rename_term(t: Term, ren: dict[str, str]) -> Term:
    return Var(ren[t.name]) if isinstance(t, Var) else t


def _rename_node(node: PlanNode, ren: dict[str, str]) -> PlanNode:
    if isinstance(node, SubqueryNode):
        pats = [TriplePattern(_rename_term(tp.s, ren), _rename_term(tp.p, ren),
                              _rename_term(tp.o, ren)) for tp in node.patterns]
        return SubqueryNode(stars=list(node.stars), patterns=pats,
                            sources=list(node.sources),
                            est_cardinality=node.est_cardinality)
    assert isinstance(node, JoinPlanNode)
    return JoinPlanNode(left=_rename_node(node.left, ren),
                        right=_rename_node(node.right, ren),
                        strategy=node.strategy,
                        join_vars=sorted(ren[v] for v in node.join_vars),
                        est_cardinality=node.est_cardinality)


class OdysseyOptimizer:
    """Cost-based federated optimizer over CS/CP statistics, with an LRU plan
    cache in front of the full optimization pipeline."""

    def __init__(self, stats: FederatedStats, cost_model: CostModel | None = None,
                 plan_cache_size: int = 1024, dp_block_bytes: int | None = None,
                 dp_backend: str = "numpy"):
        self.stats = stats
        self.cost_model = cost_model or CostModel()
        self.plan_cache: PlanCache | None = (
            PlanCache(plan_cache_size) if plan_cache_size > 0 else None)
        # peak bytes for the join-order DP's per-layer candidate tiles
        # (None == repro.core.join_order.DP_BLOCK_BYTES)
        self.dp_block_bytes = dp_block_bytes
        # who runs the DP sweep: 'numpy' (in-process tiled layer loop) or
        # 'jax' (one device-resident repro.kernels.dp_layer program per
        # sweep, per-layer kernel tiles as the oversized-schedule fallback);
        # plans are bit-identical either way
        if dp_backend not in DP_BACKENDS:
            raise ValueError(f"unknown dp_backend {dp_backend!r} "
                             f"(expected one of {DP_BACKENDS})")
        self.dp_backend = dp_backend
        # what the last optimize_batch call shared (BatchPlanReport)
        self.last_batch_report = None

    @property
    def stats_epoch(self) -> int:
        """Epoch of the underlying statistics (0 for legacy stats objects)."""
        return getattr(self.stats, "epoch", 0)

    def optimize(self, query: BGPQuery, use_cache: bool = True) -> PhysicalPlan:
        t0 = time.perf_counter()
        epoch = self.stats_epoch               # one snapshot per planning call
        sig = var_order = None
        if use_cache and self.plan_cache is not None:
            sig, var_order = query_signature(query)
            entry = self.plan_cache.get(sig, epoch=epoch)
            if entry is not None:
                plan = self._rebind(entry, var_order, query)
                plan.optimization_ms = (time.perf_counter() - t0) * 1e3
                return plan
        plan = self._optimize_uncached(query, t0)
        plan.stats_epoch = epoch
        if sig is not None:
            self.plan_cache.put(sig, plan, var_order, epoch=epoch)
        return plan

    def optimize_batch(self, queries: "list[BGPQuery]") -> "list[PhysicalPlan]":
        """Plan a batch through the truly batched pipeline
        (``repro.core.batch_planner.plan_batch``): one epoch snapshot,
        plan-cache hits and exact-signature duplicates rebound per query,
        one shared source-selection pass over the union of the remaining
        queries' stars, and one stacked DP sweep per structural shape.
        Bit-identical per query to ``[self.optimize(q) for q in queries]``
        — batching changes the planning cost, never the plans.  The
        sharing achieved is reported on ``self.last_batch_report``."""
        from repro.core.batch_planner import plan_batch

        return plan_batch(self, queries)

    def _optimize_uncached(self, query: BGPQuery, t0: float) -> PhysicalPlan:
        graph = decompose(query)
        sel = select_sources(graph, self.stats)
        tree = dp_join_order(graph, self.stats, sel, self.cost_model, query.distinct,
                             block_bytes=self.dp_block_bytes,
                             dp_backend=self.dp_backend)
        root = self._emit(tree, graph, sel, query)
        plan = PhysicalPlan(root=root, query=query, graph=graph, selection=sel,
                            stats_epoch=self.stats_epoch)
        plan.fallback = any(s.has_var_pred for s in graph.stars)
        plan.optimization_ms = (time.perf_counter() - t0) * 1e3
        return plan

    def _rebind(self, entry: CacheEntry, var_order: tuple[str, ...],
                query: BGPQuery) -> PhysicalPlan:
        """Attach a cached plan to an equivalent incoming query.  Stars keep
        their indices under variable renaming (decomposition groups patterns
        by first occurrence of the subject), so the source selection carries
        over; only variable names inside the plan tree may need rewriting.

        Every hit owns its tree, selection and graph: callers mutate
        est_cardinality/sources/star_sources in place, and aliasing the
        cached copy (or another hit) would corrupt every later hit."""
        cached, cached_order = entry.plan, entry.var_order
        if cached_order == var_order:
            return replace(cached, root=_copy_node(cached.root), query=query,
                           selection=cached.selection.detach(),
                           graph=cached.graph.detach(), cached=True,
                           stats_epoch=entry.epoch)
        ren = dict(zip(cached_order, var_order))
        root = _rename_node(cached.root, ren)
        return replace(cached, root=root, query=query, graph=decompose(query),
                       selection=cached.selection.detach(), cached=True,
                       stats_epoch=entry.epoch)

    # -- plan emission with subquery merging (§3.4 step iii) ---------------
    def _emit(self, tree: JoinTree, graph: StarGraph, sel: SourceSelection,
              query: BGPQuery) -> PlanNode:
        if tree.kind == "leaf":
            stars = sorted(tree.stars)
            patterns: list[TriplePattern] = []
            for si in stars:
                patterns.extend(order_star_patterns(graph.stars[si], self.stats, sel,
                                                    query.distinct))
            sources = tree.sources if tree.sources is not None else sel.star_sources[stars[0]]
            return SubqueryNode(stars=stars, patterns=patterns, sources=list(sources),
                                est_cardinality=tree.cardinality)
        left = self._emit(tree.left, graph, sel, query)    # type: ignore[arg-type]
        right = self._emit(tree.right, graph, sel, query)  # type: ignore[arg-type]
        join_vars = sorted(_vars_of(left) & _vars_of(right))
        return JoinPlanNode(left=left, right=right, strategy=tree.strategy or "hash",
                            join_vars=join_vars, est_cardinality=tree.cardinality)


def _vars_of(node: PlanNode) -> set[str]:
    if isinstance(node, SubqueryNode):
        out: set[str] = set()
        for tp in node.patterns:
            out |= set(tp.variables())
        return out
    assert isinstance(node, JoinPlanNode)
    return _vars_of(node.left) | _vars_of(node.right)
