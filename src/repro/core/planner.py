"""The Odyssey optimizer (paper §3.4): preprocessing + source selection,
join-order optimization, subquery optimization (merging), and plan emission.

``OdysseyOptimizer.optimize`` produces a ``PhysicalPlan`` the engines
(``repro.engine.local`` / ``repro.engine.distributed``) execute, plus the
paper's plan-level metrics (optimization time, #selected sources,
#subqueries).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.core.cost import CostModel
from repro.core.decomposition import StarGraph, decompose
from repro.core.federation import FederatedStats
from repro.core.join_order import JoinTree, dp_join_order, order_star_patterns
from repro.core.source_selection import SourceSelection, select_sources
from repro.query.algebra import BGPQuery, TriplePattern


@dataclass
class PlanNode:
    pass


@dataclass
class SubqueryNode(PlanNode):
    """One SPARQL subquery dispatched to ``sources`` (merged stars ==
    exclusive group executed remotely as a single query)."""

    stars: list[int]
    patterns: list[TriplePattern]            # in execution order
    sources: list[int]
    est_cardinality: float = 0.0


@dataclass
class JoinPlanNode(PlanNode):
    left: PlanNode
    right: PlanNode
    strategy: str                            # "hash" | "bind"
    join_vars: list[str] = field(default_factory=list)
    est_cardinality: float = 0.0


@dataclass
class PhysicalPlan:
    root: PlanNode
    query: BGPQuery
    graph: StarGraph
    selection: SourceSelection
    optimization_ms: float = 0.0
    fallback: bool = False                   # variable-predicate fallback

    def subqueries(self) -> list[SubqueryNode]:
        out: list[SubqueryNode] = []

        def walk(n: PlanNode) -> None:
            if isinstance(n, SubqueryNode):
                out.append(n)
            elif isinstance(n, JoinPlanNode):
                walk(n.left)
                walk(n.right)

        walk(self.root)
        return out

    @property
    def n_subqueries(self) -> int:
        """NSQ: subqueries dispatched (a subquery sent to k sources counts k,
        matching how the FedBench studies count endpoint requests)."""
        return sum(max(1, len(sq.sources)) for sq in self.subqueries())

    @property
    def n_selected_sources(self) -> int:
        """NSS: Σ over triple patterns of #selected sources."""
        return self.selection.pattern_source_count(self.graph)


class OdysseyOptimizer:
    """Cost-based federated optimizer over CS/CP statistics."""

    def __init__(self, stats: FederatedStats, cost_model: CostModel | None = None):
        self.stats = stats
        self.cost_model = cost_model or CostModel()

    def optimize(self, query: BGPQuery) -> PhysicalPlan:
        t0 = time.perf_counter()
        graph = decompose(query)
        sel = select_sources(graph, self.stats)
        tree = dp_join_order(graph, self.stats, sel, self.cost_model, query.distinct)
        root = self._emit(tree, graph, sel, query)
        plan = PhysicalPlan(root=root, query=query, graph=graph, selection=sel)
        plan.fallback = any(s.has_var_pred for s in graph.stars)
        plan.optimization_ms = (time.perf_counter() - t0) * 1e3
        return plan

    # -- plan emission with subquery merging (§3.4 step iii) ---------------
    def _emit(self, tree: JoinTree, graph: StarGraph, sel: SourceSelection,
              query: BGPQuery) -> PlanNode:
        if tree.kind == "leaf":
            stars = sorted(tree.stars)
            patterns: list[TriplePattern] = []
            for si in stars:
                patterns.extend(order_star_patterns(graph.stars[si], self.stats, sel,
                                                    query.distinct))
            sources = tree.sources if tree.sources is not None else sel.star_sources[stars[0]]
            return SubqueryNode(stars=stars, patterns=patterns, sources=list(sources),
                                est_cardinality=tree.cardinality)
        left = self._emit(tree.left, graph, sel, query)    # type: ignore[arg-type]
        right = self._emit(tree.right, graph, sel, query)  # type: ignore[arg-type]
        join_vars = sorted(_vars_of(left) & _vars_of(right))
        return JoinPlanNode(left=left, right=right, strategy=tree.strategy or "hash",
                            join_vars=join_vars, est_cardinality=tree.cardinality)


def _vars_of(node: PlanNode) -> set[str]:
    if isinstance(node, SubqueryNode):
        out: set[str] = set()
        for tp in node.patterns:
            out |= set(tp.variables())
        return out
    assert isinstance(node, JoinPlanNode)
    return _vars_of(node.left) | _vars_of(node.right)
