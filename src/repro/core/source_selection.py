"""Source selection (paper §3.4 step i): CS/CP-based relevance with
link-aware pruning — never produces false negatives.

1. A source is a candidate for a star iff it has at least one CS containing
   *all* of the star's bound predicates (plus federated-CS handling for
   entities split across datasets).
2. CP pruning: for every object->subject edge between stars, a source pair
   (a, b) is viable only if a CP (intra for a == b, federated otherwise)
   links a relevant CS of the edge's source star in ``a`` to a relevant CS of
   its target star in ``b`` via the edge predicate. Sources that appear in no
   viable pair for some incident edge are pruned. Iterated to fixpoint.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.decomposition import Star, StarGraph
from repro.core.federation import FederatedStats
from repro.query.algebra import Const


@dataclass
class SourceSelection:
    star_sources: list[list[int]]                        # per star
    star_cs: list[dict[int, np.ndarray]]                 # star -> {src: relevant CS}
    edge_pairs: dict[int, set[tuple[int, int]]] = field(default_factory=dict)
    # memo for per-(star, preds) cardinalities / per-edge selectivities; the
    # selection is per-query, so the memo's lifetime matches the planning call
    _memo: dict = field(default_factory=dict, repr=False)

    def pattern_source_count(self, graph: StarGraph) -> int:
        """NSS metric: Σ over triple patterns of #selected sources."""
        return sum(len(self.star_sources[s.idx]) * len(s.patterns) for s in graph.stars)

    def detach(self) -> "SourceSelection":
        """Copy with fresh containers and an *empty* memo.  Cached plans must
        never hand out the stored selection by reference: a caller mutating
        ``star_sources``/``star_cs`` (failover-style source exclusion does
        exactly that) would corrupt every later cache hit, and the shared
        ``_memo`` would outlive its documented per-query lifetime."""
        return SourceSelection(
            star_sources=[list(s) for s in self.star_sources],
            star_cs=[dict(d) for d in self.star_cs],
            edge_pairs={k: set(v) for k, v in self.edge_pairs.items()},
        )


# --------------------------------------------------------------------------
# Shared probe/stat memo for batched selection
# --------------------------------------------------------------------------

def _star_key(star: Star) -> tuple:
    """Everything per-star relevance depends on: subject constant (or var),
    the *ordered* bound-predicate list, and the unprunable-var-pred flag.
    Object constants are deliberately absent — they never affect selection,
    which is what lets templated queries share one selection."""
    subj = star.subject.tid if isinstance(star.subject, Const) else None
    return (subj, tuple(star.bound_preds()), star.has_var_pred)


def selection_key(graph: StarGraph) -> tuple:
    """Everything ``select_sources`` depends on: per-star keys plus the
    ordered edge list.  Graphs with equal keys get equal selections, so a
    batch computes one selection per distinct key."""
    return (tuple(_star_key(s) for s in graph.stars),
            tuple((e.src, e.dst, e.pred, e.generic) for e in graph.edges))


class SelectionMemo:
    """Cross-query memo for ``select_sources_batch``: per-star relevant-CS
    scans, federated-CS candidate sets, and CP edge-viability probes are
    priced once for the whole batch.  Values are exactly what the unmemoized
    code computes (same functions, same inputs), so memoized selections stay
    bit-identical to ``select_sources`` without a memo; the arrays stored
    here are treated as immutable (the same contract ``star_cs`` already
    has across ``SourceSelection.detach`` copies)."""

    def __init__(self) -> None:
        self.star_rel: dict[tuple, tuple[list[int], dict[int, np.ndarray]]] = {}
        self.fed_cand: dict[frozenset, set[int]] = {}
        self.cp_probe: dict[tuple, bool] = {}

    def edge_viable(self, stats: FederatedStats, a: int, b: int, pred: int,
                    rel1: np.ndarray, rel2: np.ndarray) -> bool:
        """Memoized "does a CP link a relevant CS of ``a`` to one of ``b``
        via ``pred``" probe — the inner test of the CP pruning fixpoint."""
        key = (a, b, pred, rel1.tobytes(), rel2.tobytes())
        hit = self.cp_probe.get(key)
        if hit is None:
            cp = stats.cp_between(a, b)
            hit = cp is not None and len(cp.select(pred, rel1, rel2)) > 0
            self.cp_probe[key] = hit
        return hit


def _star_relevant_cs(star: Star, stats: FederatedStats, src: int) -> np.ndarray:
    cs = stats.cs[src]
    preds = star.bound_preds()
    if isinstance(star.subject, Const):
        c = cs.cs_of_entity(star.subject.tid)
        if c < 0:
            return np.zeros(0, np.int32)
        have = set(cs.preds_of(c).tolist())
        if all(p in have for p in preds):
            return np.asarray([c], np.int32)
        return np.zeros(0, np.int32)
    return cs.relevant_cs(preds)


def _fed_cs_candidates(star: Star, stats: FederatedStats) -> set[int]:
    """Sources that can contribute via *federated CSs* (entity described in
    two datasets whose combined predicate set covers the star)."""
    out: set[int] = set()
    preds = set(star.bound_preds())
    if not preds:
        return out
    for (a, b), triples in stats.fed_cs.items():
        for (ca, cb, _cnt) in triples:
            pa = set(stats.cs[a].preds_of(ca).tolist())
            pb = set(stats.cs[b].preds_of(cb).tolist())
            if preds <= (pa | pb) and not (preds <= pa) and not (preds <= pb):
                out.add(a)
                out.add(b)
    return out


def _star_candidates(star: Star, stats: FederatedStats,
                     memo: SelectionMemo | None,
                     ) -> tuple[list[int], dict[int, np.ndarray]]:
    """Pre-pruning candidates of one star: ``(star_sources, star_cs)``.
    Memoized on ``_star_key`` when a batch memo is supplied — the block
    depends on nothing else."""
    key = _star_key(star) if memo is not None else None
    if memo is not None:
        hit = memo.star_rel.get(key)
        if hit is not None:
            srcs, rel = hit
            return list(srcs), dict(rel)
    n_src = len(stats.cs)
    if star.has_var_pred and not star.bound_preds():
        # variable predicate with nothing to prune on: all sources
        srcs = list(range(n_src))
        rel = {s: np.arange(stats.cs[s].n_cs, dtype=np.int32) for s in srcs}
    else:
        rel = {}
        for s in range(n_src):
            r = _star_relevant_cs(star, stats, s)
            if len(r):
                rel[s] = r
        if memo is not None:
            fkey = frozenset(star.bound_preds())
            fed = memo.fed_cand.get(fkey)
            if fed is None:
                fed = _fed_cs_candidates(star, stats)
                memo.fed_cand[fkey] = fed
        else:
            fed = _fed_cs_candidates(star, stats)
        for s in fed:
            if s not in rel:
                rel[s] = np.arange(stats.cs[s].n_cs, dtype=np.int32)
        srcs = sorted(rel)
    if memo is not None:
        memo.star_rel[key] = (list(srcs), dict(rel))
    return srcs, rel


def select_sources(graph: StarGraph, stats: FederatedStats,
                   memo: SelectionMemo | None = None) -> SourceSelection:
    star_sources: list[list[int]] = []
    star_cs: list[dict[int, np.ndarray]] = []

    for star in graph.stars:
        srcs, rel = _star_candidates(star, stats, memo)
        star_cs.append(rel)
        star_sources.append(srcs)

    sel = SourceSelection(star_sources=star_sources, star_cs=star_cs)

    # --- CP-based edge pruning to fixpoint ---------------------------------
    changed = True
    while changed:
        changed = False
        for ei, e in enumerate(graph.edges):
            if e.generic or e.pred is None:
                continue
            viable: set[tuple[int, int]] = set()
            ok_src: set[int] = set()
            ok_dst: set[int] = set()
            for a in sel.star_sources[e.src]:
                rel1 = sel.star_cs[e.src].get(a)
                if rel1 is None or len(rel1) == 0:
                    continue
                for b in sel.star_sources[e.dst]:
                    rel2 = sel.star_cs[e.dst].get(b)
                    if rel2 is None or len(rel2) == 0:
                        continue
                    if memo is not None:
                        hit = memo.edge_viable(stats, a, b, e.pred, rel1, rel2)
                    else:
                        cp = stats.cp_between(a, b)
                        hit = cp is not None and len(cp.select(e.pred, rel1, rel2)) > 0
                    if hit:
                        viable.add((a, b))
                        ok_src.add(a)
                        ok_dst.add(b)
            sel.edge_pairs[ei] = viable
            new_src = [s for s in sel.star_sources[e.src] if s in ok_src]
            new_dst = [s for s in sel.star_sources[e.dst] if s in ok_dst]
            if new_src != sel.star_sources[e.src]:
                sel.star_sources[e.src] = new_src
                _prune_star_cs(sel.star_cs[e.src], new_src)
                changed = True
            if new_dst != sel.star_sources[e.dst]:
                sel.star_sources[e.dst] = new_dst
                _prune_star_cs(sel.star_cs[e.dst], new_dst)
                changed = True
    # the final (no-change) sweep computed every edge's viable pairs against
    # the fixpoint star_sources, so edge_pairs is consistent; filter anyway so
    # the invariant holds even for degenerate single-pass exits
    for ei, pairs in sel.edge_pairs.items():
        e = graph.edges[ei]
        keep_a = set(sel.star_sources[e.src])
        keep_b = set(sel.star_sources[e.dst])
        sel.edge_pairs[ei] = {(a, b) for (a, b) in pairs
                              if a in keep_a and b in keep_b}
    return sel


def concat_selections(graphs: "list[StarGraph]",
                      sels: "list[SourceSelection]",
                      query=None) -> "tuple[StarGraph, SourceSelection]":
    """Concatenate per-block star graphs and selections into one plan-level
    (graph, selection) pair with stars/edges reindexed by block offset.

    The group-tree planner decomposes and selects each conjunctive block
    independently; ``PhysicalPlan.graph``/``selection`` (the NSS metric,
    failover's source exclusion) want one object covering the whole query.
    Containers are fresh (``detach``-grade) so the blocks' own selections
    are not aliased."""
    stars: list[Star] = []
    edges: list = []
    star_sources: list[list[int]] = []
    star_cs: list[dict[int, np.ndarray]] = []
    edge_pairs: dict[int, set[tuple[int, int]]] = {}
    soff = eoff = 0
    for g, sel in zip(graphs, sels):
        for s in g.stars:
            stars.append(Star(s.idx + soff, s.subject, list(s.patterns)))
        for e in g.edges:
            edges.append(type(e)(src=e.src + soff, dst=e.dst + soff,
                                 pred=e.pred, pattern=e.pattern,
                                 generic=e.generic, var=e.var))
        star_sources.extend(list(x) for x in sel.star_sources)
        star_cs.extend(dict(x) for x in sel.star_cs)
        for ei, pairs in sel.edge_pairs.items():
            edge_pairs[ei + eoff] = set(pairs)
        soff += len(g.stars)
        eoff += len(g.edges)
    graph = StarGraph(stars=stars, edges=edges, query=query)
    return graph, SourceSelection(star_sources=star_sources, star_cs=star_cs,
                                  edge_pairs=edge_pairs)


def select_sources_batch(graphs: "list[StarGraph]", stats: FederatedStats,
                         memo: SelectionMemo | None = None,
                         ) -> "list[SourceSelection]":
    """Source selection over a whole batch, priced once where queries agree:

    * graphs with equal ``selection_key`` (selection ignores object
      constants, so every instance of a query template shares one key) run
      the pruning fixpoint **once**; every member receives a detached copy
      (fresh containers + empty per-query memo) of the shared result;
    * across *distinct* keys the per-star relevant-CS scans, federated-CS
      candidate sets and CP edge-viability probes still dedupe through the
      shared ``SelectionMemo``, so a star repeated across shapes is priced
      once for the union of the batch's stars.

    Each returned selection is bit-identical to ``select_sources(graph,
    stats)`` on its own — the memo only skips recomputing values the
    unmemoized path would derive identically."""
    memo = memo if memo is not None else SelectionMemo()
    done: dict[tuple, SourceSelection] = {}
    out: list[SourceSelection] = []
    for g in graphs:
        key = selection_key(g)
        base = done.get(key)
        if base is None:
            base = select_sources(g, stats, memo=memo)
            done[key] = base
        out.append(base.detach())
    return out


def _prune_star_cs(rel: dict[int, np.ndarray], keep: list[int]) -> None:
    """Keep ``star_cs`` consistent with a pruned ``star_sources``: consumers
    that read ``star_cs`` directly (federated-CS fallback entries included)
    must not see CS sets for sources the CP fixpoint eliminated."""
    keep_set = set(keep)
    for s in [s for s in rel if s not in keep_set]:
        del rel[s]
