"""Entity summaries (paper §3.3) — TPU-adapted PARTree/Q-Tree.

The paper partitions entities by IRI "type" using a Radix tree and summarizes
the leaves with Q-Trees over least-significant bytes (LSBs) of hashed IRI
suffixes. A radix *trie over strings* does not vectorize, so we keep the same
two guarantees with TPU-friendly structures (DESIGN.md D2):

  * partition by IRI **authority** (the paper itself switches to authorities,
    "inspired by [14]");
  * within (authority, CS), a fixed-width **bitset signature** over
    ``splitmix64(entity_id) mod B`` bits, with per-bucket multiplicities so
    entity removal (dataset updates, §3.3) is supported.

Determinism of the hash gives the crucial property: an entity present in two
datasets sets the *same* bit in both summaries ⇒ candidate generation by
bitset-AND has **no false negatives**. False positives are pruned by the exact
intersection that follows (``federation.compute_federated_cps``).

The batched AND+popcount hot loop has a Pallas kernel
(``repro.kernels.lsb_summary``); numpy here is the canonical oracle.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.common.hashing import splitmix64
from repro.core.characteristic_sets import CSStats
from repro.rdf.dataset import TripleTable

DEFAULT_BITS = 1 << 14  # 16,384 buckets / 2 KiB per signature


def _signature(ents: np.ndarray, n_bits: int) -> np.ndarray:
    """Bitset (uint64 words) of hashed entity ids."""
    words = np.zeros(n_bits // 64, dtype=np.uint64)
    if len(ents) == 0:
        return words
    h = splitmix64(ents.astype(np.uint64)) % np.uint64(n_bits)
    np.bitwise_or.at(words, (h // np.uint64(64)).astype(np.int64), np.uint64(1) << (h % np.uint64(64)))
    return words


def _bucket_counts(ents: np.ndarray, n_bits: int) -> np.ndarray:
    h = (splitmix64(ents.astype(np.uint64)) % np.uint64(n_bits)).astype(np.int64)
    return np.bincount(h, minlength=n_bits).astype(np.uint16)


@dataclass
class EntitySummary:
    """Summary of one dataset: per-(authority, CS) subject signatures and
    per-(authority, CS, pred) object signatures."""

    src: int
    n_bits: int
    # subjects: keys aligned arrays + signature matrix rows
    subj_auth: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    subj_cs: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    subj_sig: np.ndarray = field(default_factory=lambda: np.zeros((0, 0), np.uint64))
    # objects: (authority, cs, pred) rows
    obj_auth: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    obj_cs: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    obj_pred: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int32))
    obj_sig: np.ndarray = field(default_factory=lambda: np.zeros((0, 0), np.uint64))
    # multiplicities for updates (optional, §3.3 "often updated" datasets)
    subj_counts: np.ndarray | None = None

    def nbytes(self) -> int:
        n = self.subj_sig.nbytes + self.obj_sig.nbytes
        n += self.subj_auth.nbytes + self.subj_cs.nbytes
        n += self.obj_auth.nbytes + self.obj_cs.nbytes + self.obj_pred.nbytes
        if self.subj_counts is not None:
            n += self.subj_counts.nbytes
        return int(n)

    def retag(self, src: int) -> "EntitySummary":
        """Renumber the source tag (statistics-lifecycle source removal);
        signatures are position-independent and stay valid."""
        self.src = src
        return self

    def remove_entities(self, ents: np.ndarray, cs_idx: int, auth: int) -> None:
        """Update support: decrement bucket multiplicities; clear a bit only
        when its bucket count reaches zero (paper §3.3)."""
        if self.subj_counts is None:
            raise ValueError("summary built without multiplicities")
        row = np.nonzero((self.subj_auth == auth) & (self.subj_cs == cs_idx))[0]
        if len(row) == 0:
            return
        r = int(row[0])
        h = (splitmix64(ents.astype(np.uint64)) % np.uint64(self.n_bits)).astype(np.int64)
        dec = np.bincount(h, minlength=self.n_bits)
        cnt = self.subj_counts[r].astype(np.int64) - dec
        cnt = np.maximum(cnt, 0)
        self.subj_counts[r] = cnt.astype(np.uint16)
        alive = cnt > 0
        words = np.zeros(self.n_bits // 64, dtype=np.uint64)
        idx = np.nonzero(alive)[0]
        np.bitwise_or.at(words, idx // 64, np.uint64(1) << (idx % 64).astype(np.uint64))
        self.subj_sig[r] = words


def build_summary(
    table: TripleTable,
    cs: CSStats,
    authorities: np.ndarray,
    src: int = 0,
    n_bits: int = DEFAULT_BITS,
    entity_mask: np.ndarray | None = None,
    with_counts: bool = False,
) -> EntitySummary:
    """Build the per-dataset summary the source shares with the engine.

    ``authorities``: term id -> authority id (from the dictionary).
    ``entity_mask``: term id -> bool, True if the term can be an entity
    (IRI); literal objects are not summarized (paper partitions IRIs only).
    """
    summ = EntitySummary(src=src, n_bits=n_bits)

    # subjects --------------------------------------------------------------
    keys: list[tuple[int, int]] = []
    sigs: list[np.ndarray] = []
    counts: list[np.ndarray] = []
    ent_auth = authorities[cs.ent_ids]
    for c in range(cs.n_cs):
        ents_c = cs.ent_ids[cs.ent_cs == c]
        for a in np.unique(ent_auth[cs.ent_cs == c]):
            ents = ents_c[authorities[ents_c] == a]
            keys.append((int(a), c))
            sigs.append(_signature(ents, n_bits))
            if with_counts:
                counts.append(_bucket_counts(ents, n_bits))
    if keys:
        summ.subj_auth = np.array([k[0] for k in keys], np.int32)
        summ.subj_cs = np.array([k[1] for k in keys], np.int32)
        summ.subj_sig = np.stack(sigs)
        if with_counts:
            summ.subj_counts = np.stack(counts)

    # objects ---------------------------------------------------------------
    c1 = cs.cs_of_entities(table.s)
    is_ent = authorities[table.o] >= 0
    if entity_mask is not None:
        is_ent = entity_mask[table.o]
    ok = (c1 >= 0) & is_ent
    okeys: list[tuple[int, int, int]] = []
    osigs: list[np.ndarray] = []
    if ok.any():
        cs_sel = c1[ok].astype(np.int64)
        p_sel = table.p[ok].astype(np.int64)
        o_sel = table.o[ok]
        a_sel = authorities[o_sel].astype(np.int64)
        n_cs = max(1, cs.n_cs)
        n_pred = int(p_sel.max()) + 1
        key = (a_sel * n_cs + cs_sel) * n_pred + p_sel
        order = np.argsort(key, kind="stable")
        key_s = key[order]
        o_s = o_sel[order]
        starts = np.nonzero(np.concatenate([[True], key_s[1:] != key_s[:-1]]))[0]
        ends = np.append(starts[1:], len(key_s))
        for st, en in zip(starts, ends):
            k = int(key_s[st])
            p = k % n_pred
            c_ = (k // n_pred) % n_cs
            a = k // (n_pred * n_cs)
            okeys.append((int(a), int(c_), int(p)))
            osigs.append(_signature(np.unique(o_s[st:en]), n_bits))
    if okeys:
        summ.obj_auth = np.array([k[0] for k in okeys], np.int32)
        summ.obj_cs = np.array([k[1] for k in okeys], np.int32)
        summ.obj_pred = np.array([k[2] for k in okeys], np.int32)
        summ.obj_sig = np.stack(osigs)
    return summ


def candidate_cs_pairs(obj_summary: EntitySummary, subj_summary: EntitySummary) -> np.ndarray:
    """All (obj_row, subj_row) index pairs whose signatures intersect on the
    same authority — the no-false-negative candidate set for Algorithm 1.

    Returns an (n, 2) int32 array of row indices into ``obj_summary`` objects
    and ``subj_summary`` subjects.
    """
    if len(obj_summary.obj_auth) == 0 or len(subj_summary.subj_auth) == 0:
        return np.zeros((0, 2), np.int32)
    out: list[tuple[int, int]] = []
    # group subject rows by authority for pruning
    for a in np.unique(obj_summary.obj_auth):
        orows = np.nonzero(obj_summary.obj_auth == a)[0]
        srows = np.nonzero(subj_summary.subj_auth == a)[0]
        if len(srows) == 0:
            continue
        osig = obj_summary.obj_sig[orows]            # (no, W)
        ssig = subj_summary.subj_sig[srows]          # (ns, W)
        inter = (osig[:, None, :] & ssig[None, :, :])
        hit = inter.any(axis=2)
        oi, si = np.nonzero(hit)
        out.extend(zip(orows[oi].tolist(), srows[si].tolist()))
    if not out:
        return np.zeros((0, 2), np.int32)
    return np.asarray(out, dtype=np.int32)
