"""Federated statistics (paper §3.2): link exports + Algorithm 1.

Each source computes, alongside its CS statistics:
  * ``subjects``: per CS, the sorted set of its subject entity ids;
  * ``objects``: per (CS, predicate), the sorted set of linked object entity
    ids with per-object link multiplicities (#subjects of the CS pointing at
    the object via the predicate).

``compute_federated_cps`` is Algorithm 1: intersect source A's ``objects``
with source B's ``subjects``; every common entity contributes its multiplicity
to ``count(cs1, cs2, p)``. Entity summaries (§3.3) prune the candidate
(cs1, p) × cs2 space first — never dropping a true link — after which only the
surviving pairs are intersected exactly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from dataclasses import replace as dc_replace

import numpy as np

from repro.core.characteristic_pairs import CPStats
from repro.core.characteristic_sets import CSStats, compute_characteristic_sets
from repro.core.summaries import DEFAULT_BITS as DEFAULT_SUMMARY_BITS
from repro.core.summaries import EntitySummary, build_summary, candidate_cs_pairs
from repro.rdf.dataset import Federation, TripleTable


@dataclass
class LinkExport:
    """The per-source structures of Fig. 1 (a)/(b)."""

    src: int
    # subjects: CSR over CS index
    n_cs: int
    subj_indptr: np.ndarray      # (n_cs + 1,)
    subj_ents: np.ndarray        # sorted within each CS
    # objects: one row per (cs, pred)
    obj_cs: np.ndarray           # (n_rows,) int32
    obj_pred: np.ndarray         # (n_rows,) int32
    obj_indptr: np.ndarray       # (n_rows + 1,)
    obj_ents: np.ndarray         # sorted within each row
    obj_mult: np.ndarray         # int32 aligned with obj_ents

    def subjects_of(self, c: int) -> np.ndarray:
        return self.subj_ents[self.subj_indptr[c]: self.subj_indptr[c + 1]]

    def objects_row(self, r: int) -> tuple[np.ndarray, np.ndarray]:
        sl = slice(self.obj_indptr[r], self.obj_indptr[r + 1])
        return self.obj_ents[sl], self.obj_mult[sl]

    def nbytes(self) -> int:
        return int(sum(a.nbytes for a in (
            self.subj_indptr, self.subj_ents, self.obj_cs, self.obj_pred,
            self.obj_indptr, self.obj_ents, self.obj_mult)))


def export_link_stats(table: TripleTable, cs: CSStats, src: int = 0,
                      entity_mask: np.ndarray | None = None) -> LinkExport:
    """Compute the source's ``subjects``/``objects`` export (cheap, columnar)."""
    # subjects CSR
    order = np.argsort(cs.ent_cs, kind="stable")
    subj_ents_grouped = cs.ent_ids[order]
    counts = np.bincount(cs.ent_cs, minlength=cs.n_cs)
    subj_indptr = np.zeros(cs.n_cs + 1, np.int64)
    subj_indptr[1:] = np.cumsum(counts)
    # sort entities within each CS
    for c in range(cs.n_cs):
        sl = slice(subj_indptr[c], subj_indptr[c + 1])
        subj_ents_grouped[sl] = np.sort(subj_ents_grouped[sl])

    # objects rows
    c1 = cs.cs_of_entities(table.s)
    ok = c1 >= 0
    if entity_mask is not None:
        ok &= entity_mask[table.o]
    obj_cs_l: list[int] = []
    obj_pred_l: list[int] = []
    ent_chunks: list[np.ndarray] = []
    mult_chunks: list[np.ndarray] = []
    indptr = [0]
    if ok.any():
        cs_sel = c1[ok].astype(np.int64)
        p_sel = table.p[ok].astype(np.int64)
        o_sel = table.o[ok].astype(np.int64)
        n_pred = int(p_sel.max()) + 1
        key = cs_sel * n_pred + p_sel
        order = np.lexsort((o_sel, key))
        key_s, o_s = key[order], o_sel[order]
        starts = np.nonzero(np.concatenate([[True], key_s[1:] != key_s[:-1]]))[0]
        ends = np.append(starts[1:], len(key_s))
        for st, en in zip(starts, ends):
            k = int(key_s[st])
            obj_cs_l.append(k // n_pred)
            obj_pred_l.append(k % n_pred)
            ents, mult = np.unique(o_s[st:en], return_counts=True)
            ent_chunks.append(ents.astype(np.int32))
            mult_chunks.append(mult.astype(np.int32))
            indptr.append(indptr[-1] + len(ents))
    return LinkExport(
        src=src,
        n_cs=cs.n_cs,
        subj_indptr=subj_indptr,
        subj_ents=subj_ents_grouped.astype(np.int32),
        obj_cs=np.asarray(obj_cs_l, np.int32),
        obj_pred=np.asarray(obj_pred_l, np.int32),
        obj_indptr=np.asarray(indptr, np.int64),
        obj_ents=np.concatenate(ent_chunks).astype(np.int32) if ent_chunks else np.zeros(0, np.int32),
        obj_mult=np.concatenate(mult_chunks).astype(np.int32) if mult_chunks else np.zeros(0, np.int32),
    )


@dataclass
class FedCPResult:
    cps: CPStats
    n_checked_pairs: int     # exact intersections performed
    n_possible_pairs: int    # |objects rows| × |subject CSs| without pruning


def compute_federated_cps(
    obj_export: LinkExport,
    subj_export: LinkExport,
    obj_summary: EntitySummary | None = None,
    subj_summary: EntitySummary | None = None,
) -> FedCPResult:
    """Algorithm 1 (ComputeFedCPs): federated CPs from pre-computed exports.

    With summaries, only candidate (objects-row, cs2) pairs whose bitset
    signatures intersect are checked exactly — the paper's pruning — which is
    guaranteed to retain every true link (tests assert equality with the
    unpruned run).
    """
    n_rows = len(obj_export.obj_cs)
    n_possible = n_rows * subj_export.n_cs
    pred_l: list[int] = []
    cs1_l: list[int] = []
    cs2_l: list[int] = []
    cnt_l: list[int] = []
    checked = 0

    if obj_summary is not None and subj_summary is not None:
        cand = candidate_cs_pairs(obj_summary, subj_summary)
        # map summary rows -> export rows: summary object rows are keyed by
        # (auth, cs, pred); export rows by (cs, pred). A (cs, pred) export row
        # may span several authorities; dedupe the (export_row, cs2) pairs.
        okey = {}
        for r in range(n_rows):
            okey.setdefault((int(obj_export.obj_cs[r]), int(obj_export.obj_pred[r])), r)
        seen: set[tuple[int, int]] = set()
        pairs: list[tuple[int, int]] = []
        for oi, si in cand:
            key = (int(obj_summary.obj_cs[oi]), int(obj_summary.obj_pred[oi]))
            r = okey.get(key)
            if r is None:
                continue
            c2 = int(subj_summary.subj_cs[si])
            if (r, c2) not in seen:
                seen.add((r, c2))
                pairs.append((r, c2))
    else:
        pairs = [(r, c2) for r in range(n_rows) for c2 in range(subj_export.n_cs)]

    for r, c2 in pairs:
        ents, mult = obj_export.objects_row(r)
        subj = subj_export.subjects_of(c2)
        if len(ents) == 0 or len(subj) == 0:
            continue
        checked += 1
        common, i1, _ = np.intersect1d(ents, subj, assume_unique=True, return_indices=True)
        if len(common) == 0:
            continue
        pred_l.append(int(obj_export.obj_pred[r]))
        cs1_l.append(int(obj_export.obj_cs[r]))
        cs2_l.append(c2)
        cnt_l.append(int(mult[i1].sum()))

    cps = CPStats.from_rows(
        np.asarray(pred_l, np.int32), np.asarray(cs1_l, np.int32),
        np.asarray(cs2_l, np.int32), np.asarray(cnt_l, np.int64),
        src1=obj_export.src, src2=subj_export.src,
    )
    return FedCPResult(cps=cps, n_checked_pairs=checked, n_possible_pairs=n_possible)


def compute_federated_css(subj_a: LinkExport, subj_b: LinkExport) -> list[tuple[int, int, int]]:
    """Federated CSs: entities described in both datasets (§3.2, "similar
    principle ... considering the subjects shared by different datasets").
    Returns (csA, csB, #common entities) triples."""
    out: list[tuple[int, int, int]] = []
    for ca in range(subj_a.n_cs):
        ea = subj_a.subjects_of(ca)
        if len(ea) == 0:
            continue
        for cb in range(subj_b.n_cs):
            eb = subj_b.subjects_of(cb)
            if len(eb) == 0:
                continue
            common = np.intersect1d(ea, eb, assume_unique=True)
            if len(common):
                out.append((ca, cb, len(common)))
    return out


# --------------------------------------------------------------------------
# Federation-wide statistics store + versioned lifecycle
# --------------------------------------------------------------------------

@dataclass
class FederatedStats:
    """Everything the Odyssey optimizer needs, for all sources.

    The store is *versioned*: ``epoch`` increases monotonically on every
    mutation (``remove_source`` / ``add_source`` / ``refresh_source``), and
    epoch-aware consumers (the plan cache) treat entries planned under an
    older epoch as misses.  Mutators recompute only the affected source's
    CS/CP/link-export/summary state plus the federated CPs incident to it —
    the other sources' ``LinkExport``s are reused via Algorithm 1 — and are
    differentially tested to be bit-identical to a from-scratch
    ``build_federated_stats`` of the same federation.

    Per-source cache scoping falls out of object replacement: a mutated
    source's ``CSStats``/``CPStats`` objects (and their ``_card_cache``
    memos) are replaced wholesale, while untouched sources keep their warm
    caches, which stay valid because their underlying arrays are unchanged.
    """

    cs: list[CSStats]                                  # per source
    intra_cp: list[CPStats]                            # per source
    fed_cp: dict[tuple[int, int], CPStats] = field(default_factory=dict)
    fed_cs: dict[tuple[int, int], list[tuple[int, int, int]]] = field(default_factory=dict)
    exports: list[LinkExport] = field(default_factory=list)
    summaries: list[EntitySummary] = field(default_factory=list)
    pruning_checked: int = 0
    pruning_possible: int = 0
    epoch: int = 0
    # build-time configuration, carried so the incremental mutators reproduce
    # exactly what build_federated_stats computes from scratch
    use_summaries: bool = True
    n_bits: int = DEFAULT_SUMMARY_BITS
    max_cs: int | None = None
    dictionary: object | None = None                   # TermDict of the federation
    # per ordered source pair: (exact checks, possible pairs) from Algorithm 1
    _pair_pruning: dict[tuple[int, int], tuple[int, int]] = field(
        default_factory=dict, repr=False)

    @property
    def n_sources(self) -> int:
        return len(self.cs)

    def cp_between(self, src1: int, src2: int) -> CPStats | None:
        if src1 == src2:
            return self.intra_cp[src1]
        return self.fed_cp.get((src1, src2))

    def nbytes(self) -> int:
        n = sum(c.nbytes() for c in self.cs) + sum(c.nbytes() for c in self.intra_cp)
        n += sum(c.nbytes() for c in self.fed_cp.values())
        n += sum(s.nbytes() for s in self.summaries)
        return int(n)

    # -- lifecycle ----------------------------------------------------------

    def clone(self) -> "FederatedStats":
        """Cheap detached copy: shares the statistics *arrays* (which are
        never mutated in place) but owns every container and every src-tagged
        wrapper, so incremental mutators on the clone never write through to
        ``self`` — the safe starting point for a failover session or an A/B
        statistics experiment over shared base stats."""
        return FederatedStats(
            cs=list(self.cs),
            intra_cp=[dc_replace(c) for c in self.intra_cp],
            fed_cp={k: dc_replace(c) for k, c in self.fed_cp.items()},
            fed_cs={k: list(v) for k, v in self.fed_cs.items()},
            exports=[dc_replace(e) for e in self.exports],
            summaries=[dc_replace(s) for s in self.summaries],
            pruning_checked=self.pruning_checked,
            pruning_possible=self.pruning_possible,
            epoch=self.epoch,
            use_summaries=self.use_summaries,
            n_bits=self.n_bits,
            max_cs=self.max_cs,
            dictionary=self.dictionary,
            _pair_pruning=dict(self._pair_pruning),
        )

    def invalidate_caches(self) -> None:
        """Blunt-hammer invalidation: drop every memoized formula and
        predicate index on every CS/CP object and bump the epoch (so the
        plan cache treats existing entries as stale).  The incremental
        mutators do *not* need this (they scope invalidation by object
        replacement); it exists for callers that mutate statistics arrays
        out-of-band."""
        from repro.core.cardinality import clear_card_caches

        clear_card_caches(self)
        self.epoch += 1

    def _require_lifecycle(self) -> None:
        if self.dictionary is None:
            raise ValueError(
                "statistics lifecycle needs the federation dictionary; build "
                "this FederatedStats via build_federated_stats (or set "
                ".dictionary) before calling remove/add/refresh_source")

    def _local_stats(self, table: TripleTable, src: int):
        """One source's CS / intra-CP / link-export / summary — exactly the
        per-source loop body of ``build_federated_stats``."""
        from repro.core.characteristic_pairs import compute_characteristic_pairs
        from repro.stats.reduce import reduce_cs

        auth = self.dictionary.authority_array()
        kinds = np.asarray(self.dictionary.kinds, np.int8)
        entity_mask = kinds == 0  # IRI
        cs = compute_characteristic_sets(table)
        if self.max_cs is not None and cs.n_cs > self.max_cs:
            cs = reduce_cs(cs, self.max_cs)
        cp = compute_characteristic_pairs(table, cs, src=src)
        exp = export_link_stats(table, cs, src=src, entity_mask=entity_mask)
        summ = (build_summary(table, cs, auth, src=src, n_bits=self.n_bits,
                              entity_mask=entity_mask)
                if self.use_summaries else None)
        return cs, cp, exp, summ

    def _compute_pair(self, i: int, j: int) -> None:
        """(Re)run Algorithm 1 for the ordered pair (i, j), updating
        ``fed_cp`` and the per-pair pruning ledger."""
        res = compute_federated_cps(
            self.exports[i], self.exports[j],
            self.summaries[i] if self.use_summaries else None,
            self.summaries[j] if self.use_summaries else None,
        )
        self._pair_pruning[(i, j)] = (res.n_checked_pairs, res.n_possible_pairs)
        if res.cps.n_cp:
            self.fed_cp[(i, j)] = res.cps
        else:
            self.fed_cp.pop((i, j), None)

    def _refresh_pruning_totals(self) -> None:
        self.pruning_checked = sum(c for c, _ in self._pair_pruning.values())
        self.pruning_possible = sum(p for _, p in self._pair_pruning.values())

    def remove_source(self, sid: int) -> None:
        """Drop source ``sid`` and renumber the survivors — no statistic is
        recomputed (every surviving CS/CP/export/summary is reused; only the
        source tags and pair keys shift), so an N-source federation loses an
        endpoint in O(#pairs) dict work instead of an O(N²) rebuild.  Pure
        bookkeeping: unlike add/refresh it needs no build metadata, so it
        also works on directly-constructed stats."""
        if not 0 <= sid < self.n_sources:
            raise IndexError(f"source {sid} out of range (n={self.n_sources})")
        del self.cs[sid]
        del self.intra_cp[sid]
        if self.exports:                   # absent on directly-built stats
            del self.exports[sid]
        if self.summaries:
            del self.summaries[sid]

        def remap(i: int) -> int:
            return i - 1 if i > sid else i

        for j in range(sid, self.n_sources):
            self.intra_cp[j].retag(j, j)
            if self.exports:
                self.exports[j].src = j
            if self.summaries:
                self.summaries[j].retag(j)
        fed_cp: dict[tuple[int, int], CPStats] = {}
        for (i, j), cp in self.fed_cp.items():
            if sid in (i, j):
                continue
            cp.retag(remap(i), remap(j))
            fed_cp[(remap(i), remap(j))] = cp
        self.fed_cp = fed_cp
        self.fed_cs = {(remap(i), remap(j)): v for (i, j), v in self.fed_cs.items()
                       if sid not in (i, j)}
        self._pair_pruning = {(remap(i), remap(j)): v
                              for (i, j), v in self._pair_pruning.items()
                              if sid not in (i, j)}
        self._refresh_pruning_totals()
        self.epoch += 1

    def add_source(self, table: TripleTable) -> int:
        """Append a new source (recovery / federation growth): compute its
        local statistics plus the 2·N federated-CP pairs incident to it,
        reusing every existing source's ``LinkExport``/summary.  Returns the
        new source id."""
        self._require_lifecycle()
        src = self.n_sources
        cs, cp, exp, summ = self._local_stats(table, src)
        self.cs.append(cs)
        self.intra_cp.append(cp)
        self.exports.append(exp)
        if self.use_summaries:
            self.summaries.append(summ)
        for i in range(src):
            self._compute_pair(i, src)
            self._compute_pair(src, i)
        self._refresh_pruning_totals()
        self.epoch += 1
        return src

    def refresh_source(self, sid: int, table: TripleTable) -> None:
        """Re-derive source ``sid`` from (possibly changed) data: its local
        CS/CP/export/summary state is replaced wholesale — which also retires
        exactly its memoized-formula caches — and only the federated CPs
        incident to it are recomputed."""
        self._require_lifecycle()
        if not 0 <= sid < self.n_sources:
            raise IndexError(f"source {sid} out of range (n={self.n_sources})")
        cs, cp, exp, summ = self._local_stats(table, sid)
        self.cs[sid] = cs
        self.intra_cp[sid] = cp
        self.exports[sid] = exp
        if self.use_summaries:
            self.summaries[sid] = summ
        for i in range(self.n_sources):
            if i != sid:
                self._compute_pair(i, sid)
                self._compute_pair(sid, i)
        self._refresh_pruning_totals()
        self.epoch += 1


def build_federated_stats(fed: Federation, use_summaries: bool = True,
                          n_bits: int = 1 << 14, max_cs: int | None = None) -> FederatedStats:
    """End-to-end statistics pipeline for a federation (what a deployment's
    statistics service runs)."""
    from repro.core.characteristic_pairs import compute_characteristic_pairs
    from repro.stats.reduce import reduce_cs

    auth = fed.dictionary.authority_array()
    kinds = np.asarray(fed.dictionary.kinds, np.int8)
    entity_mask = kinds == 0  # IRI

    cs_list: list[CSStats] = []
    cp_list: list[CPStats] = []
    exports: list[LinkExport] = []
    summaries: list[EntitySummary] = []
    for i, src in enumerate(fed.sources):
        cs = compute_characteristic_sets(src.table)
        if max_cs is not None and cs.n_cs > max_cs:
            cs = reduce_cs(cs, max_cs)
        cs_list.append(cs)
        cp_list.append(compute_characteristic_pairs(src.table, cs, src=i))
        exports.append(export_link_stats(src.table, cs, src=i, entity_mask=entity_mask))
        if use_summaries:
            summaries.append(build_summary(src.table, cs, auth, src=i, n_bits=n_bits,
                                           entity_mask=entity_mask))

    stats = FederatedStats(cs=cs_list, intra_cp=cp_list, exports=exports, summaries=summaries,
                           use_summaries=use_summaries, n_bits=n_bits, max_cs=max_cs,
                           dictionary=fed.dictionary)
    for i in range(len(fed.sources)):
        for j in range(len(fed.sources)):
            if i == j:
                continue
            stats._compute_pair(i, j)
    stats._refresh_pruning_totals()
    return stats
