"""Join ordering (paper §3.1 + §3.4 step ii).

* Inside a star: the greedy recursive scheme of §3.1 — estimate the
  cardinality of every (k-1)-subset with formula (1)/(2); the pattern missing
  from the cheapest subset is executed last; recurse on the cheapest subset.
* Across stars: stars collapse into meta-nodes; exact dynamic programming over
  connected subsets, with cardinalities from CS/CP statistics and the §3.4
  cost function (intermediate results + transfers).

Two DP implementations share the same plan space and cost model:

``dp_join_order``      vectorized bitmask DP — subsets are integer bitmasks,
                       per-subset cardinalities / connectivity / exclusive
                       groups are precomputed numpy arrays, and each popcount
                       layer costs its (subset, partition) candidates with
                       array ops.  Only *connected* subsets are enumerated,
                       and only partitions into two connected halves are
                       costed (DPccp-style csg/cmp pairs — on chains and
                       trees the layer work collapses from all ``2^n`` masks
                       to the sparse connected family), in fixed-size tiles
                       whose peak memory is bounded by ``block_bytes``
                       (default ``DP_BLOCK_BYTES``) regardless of the star
                       count.  Star cardinalities and edge selectivities are
                       memoized per query (and the underlying CS/CP formulas
                       on the statistics objects, see
                       ``repro.core.cardinality``), so batches of related
                       queries amortize the statistics work.  This is the
                       optimizer hot path.  ``dp_join_order_batch`` runs the
                       same sweep once over a whole *shape group* — queries
                       with identical ``star_graph_topology`` — stacking the
                       per-layer candidate tensors along a member axis, and
                       returns per-member trees bit-identical to planning
                       each member alone.  Both forms take
                       ``dp_backend='numpy'|'jax'``: the numpy backend runs
                       the tiled layer sweep in-process; the jax backend
                       runs the whole sweep as one device-resident XLA
                       program (``repro.kernels.dp_layer.dp_sweep_resident``
                       — host enumerates the topology's layer schedule once,
                       the DP state stays on device across layers) whenever
                       the schedule fits the tile budget, falling back to
                       the per-layer Pallas kernel otherwise, with identical
                       enumeration order and first-strict-minimum
                       tie-breaking, so the two backends return
                       bit-identical plans.
``dp_join_order_ref``  the original frozenset/`itertools.combinations`
                       formulation with unmemoized statistics, kept as the
                       reference oracle — tests assert the bitmask DP returns
                       plans with identical cost and leaf order.

Both enumerate candidates in the same order (exclusive-group leaf, then for
each proper submask in (popcount asc, combination-lex) order: hash join, then
bind join) and break cost ties by first occurrence, so they pick the same
plan even when several plans share the optimal cost.
"""
from __future__ import annotations

import contextlib
from collections import OrderedDict
from dataclasses import dataclass
from itertools import combinations

import numpy as np

from repro.core.cardinality import (
    linked_star_cardinality_distinct,
    linked_star_cardinality_distinct_cached,
    linked_star_cardinality_estimate,
    linked_star_cardinality_estimate_cached,
    star_cardinality_distinct,
    star_cardinality_distinct_cached,
    star_cardinality_estimate,
    star_cardinality_estimate_cached,
)
from repro.core.cost import CostModel
from repro.core.decomposition import Edge, Star, StarGraph
from repro.core.federation import FederatedStats
from repro.core.source_selection import SourceSelection
from repro.query.algebra import Const, TriplePattern, Var

GENERIC_EDGE_SELECTIVITY = 1e-3  # fallback for non object->subject joins


def _bound_object_factor(star: Star, preds: list[int], stats: FederatedStats,
                         sources: list[int]) -> float:
    """Extra selectivity for patterns with a constant object: 1/#distinct
    objects of the predicate (uniformity only where CSs cannot help — the CS
    statistics do not condition on object values)."""
    f = 1.0
    for tp in star.patterns:
        if isinstance(tp.p, Const) and isinstance(tp.o, Const):
            n_obj = 0
            for s in sources:
                cs = stats.cs[s]
                rel = cs.relevant_cs(preds)
                occ = sum(cs.occurrences(int(c), tp.p.tid) for c in rel)
                n_obj = max(n_obj, occ)
            f *= 1.0 / max(1.0, float(n_obj)) * max(1.0, float(len(sources)))
            f = min(f, 1.0)
    return f


def star_cardinality(star: Star, stats: FederatedStats, sel: SourceSelection,
                     distinct: bool, preds: list[int] | None = None,
                     use_cache: bool = True) -> float:
    """Cardinality of one star over its selected sources (formulas 1/2,
    summed over sources — each entity lives in one source, footnote 4).

    Memoized on the (per-query) source selection keyed by (star, preds,
    distinct); ``use_cache=False`` recomputes from scratch (the reference
    path used by ``dp_join_order_ref``)."""
    if use_cache:
        key = ("sc", star.idx, None if preds is None else tuple(preds), distinct)
        memo = sel._memo
        v = memo.get(key)
        if v is not None:
            return v
    if preds is None:
        preds = star.bound_preds()
    srcs = sel.star_sources[star.idx]
    total = 0.0
    for s in srcs:
        rel = sel.star_cs[star.idx].get(s)
        cs = stats.cs[s]
        if rel is None:
            rel = cs.relevant_cs(preds)
        else:
            rel = np.intersect1d(rel, cs.relevant_cs(preds), assume_unique=False)
        if distinct:
            total += (star_cardinality_distinct_cached(cs, preds, rel) if use_cache
                      else star_cardinality_distinct(cs, preds, rel))
        else:
            total += (star_cardinality_estimate_cached(cs, preds, rel) if use_cache
                      else star_cardinality_estimate(cs, preds, rel))
    if isinstance(star.subject, Const):
        total = min(total, 1.0) if distinct else total / max(1.0, total)
    else:
        total *= _bound_object_factor(star, preds, stats, srcs)
    if use_cache:
        memo[key] = total
    return total


def star_source_cardinalities(star: Star, stats: FederatedStats,
                              sel: SourceSelection, distinct: bool,
                              sources: "list[int]") -> "list[float]":
    """Per-source split of ``star_cardinality`` over ``sources`` — the
    estimate each endpoint's scan of this star is expected to ship, the
    baseline the pipeline's observed-cardinality feedback scores endpoints
    against.  The raw per-source formula-1/2 totals are scaled so they sum to
    the star's memoized (factor-adjusted) cardinality; every per-CS term is a
    cache hit after the DP already priced the star."""
    preds = star.bound_preds()
    per: "list[float]" = []
    for s in sources:
        rel = sel.star_cs[star.idx].get(s)
        cs = stats.cs[s]
        if rel is None:
            rel = cs.relevant_cs(preds)
        else:
            rel = np.intersect1d(rel, cs.relevant_cs(preds), assume_unique=False)
        per.append(star_cardinality_distinct_cached(cs, preds, rel) if distinct
                   else star_cardinality_estimate_cached(cs, preds, rel))
    total = star_cardinality(star, stats, sel, distinct)
    raw = sum(per)
    scale = (total / raw) if raw > 0 else 0.0
    return [p * scale for p in per]


def order_star_patterns(star: Star, stats: FederatedStats, sel: SourceSelection,
                        distinct: bool) -> list[TriplePattern]:
    """§3.1 greedy: drop the pattern absent from the cheapest (k-1)-subset."""
    patterns = list(star.patterns)
    bound = [tp for tp in patterns if isinstance(tp.p, Const)]
    unbound = [tp for tp in patterns if not isinstance(tp.p, Const)]
    if len(bound) <= 1:
        return bound + unbound

    order_tail: list[TriplePattern] = []
    current = bound
    while len(current) > 2:
        best_sub = None
        best_card = None
        for sub in combinations(current, len(current) - 1):
            preds = [tp.p.tid for tp in sub]
            card = star_cardinality(star, stats, sel, distinct, preds)
            if best_card is None or card < best_card:
                best_card = card
                best_sub = sub
        dropped = [tp for tp in current if tp not in best_sub][0]
        order_tail.append(dropped)
        current = list(best_sub)
    # order the final pair: cheaper single pattern first
    c0 = star_cardinality(star, stats, sel, distinct, [current[0].p.tid])
    c1 = star_cardinality(star, stats, sel, distinct, [current[1].p.tid])
    first_two = current if c0 <= c1 else [current[1], current[0]]
    return first_two + order_tail[::-1] + unbound


def edge_selectivity(edge: Edge, graph: StarGraph, stats: FederatedStats,
                     sel: SourceSelection, distinct: bool,
                     use_cache: bool = True) -> float:
    """Join selectivity of a star-link from CP statistics, aggregated over the
    viable source pairs of the edge.  Memoized like ``star_cardinality``."""
    if edge.generic or edge.pred is None:
        return GENERIC_EDGE_SELECTIVITY
    if use_cache:
        key = ("es", edge.src, edge.dst, edge.pred, distinct)
        memo = sel._memo
        v = memo.get(key)
        if v is not None:
            return v
    s1 = graph.stars[edge.src]
    s2 = graph.stars[edge.dst]
    p1 = s1.bound_preds()
    p2 = s2.bound_preds()
    links = 0.0
    for a in sel.star_sources[edge.src]:
        for b in sel.star_sources[edge.dst]:
            cp = stats.cp_between(a, b)
            if cp is None:
                continue
            if distinct:
                links += (linked_star_cardinality_distinct_cached(
                    cp, stats.cs[a], stats.cs[b], p1, p2, edge.pred) if use_cache
                    else linked_star_cardinality_distinct(
                        cp, stats.cs[a], stats.cs[b], p1, p2, edge.pred))
            else:
                links += (linked_star_cardinality_estimate_cached(
                    cp, stats.cs[a], stats.cs[b], p1, p2, edge.pred) if use_cache
                    else linked_star_cardinality_estimate(
                        cp, stats.cs[a], stats.cs[b], p1, p2, edge.pred))
    c1 = max(1.0, star_cardinality(s1, stats, sel, True, use_cache=use_cache))
    c2 = max(1.0, star_cardinality(s2, stats, sel, True, use_cache=use_cache))
    out = min(1.0, links / (c1 * c2))
    if use_cache:
        memo[key] = out
    return out


# --------------------------------------------------------------------------
# DP over meta-nodes
# --------------------------------------------------------------------------

@dataclass
class JoinTree:
    kind: str                      # "leaf" | "join"
    stars: frozenset[int]
    cardinality: float
    cost: float
    left: "JoinTree | None" = None
    right: "JoinTree | None" = None
    strategy: str = ""
    sources: list[int] | None = None      # for leaves (merged => exclusive)

    def leaf_order(self) -> list[int]:
        if self.kind == "leaf":
            return sorted(self.stars)
        return self.left.leaf_order() + self.right.leaf_order()  # type: ignore[union-attr]


def _star_edge_statistics(graph: StarGraph, stats: FederatedStats,
                          sel: SourceSelection, distinct: bool,
                          use_cache: bool = True,
                          ) -> tuple[list[float], list[float]]:
    """Per-star cardinalities and per-edge selectivities (same values on both
    paths; the cached path memoizes on the selection / statistics objects)."""
    star_card = [max(star_cardinality(s, stats, sel, distinct, use_cache=use_cache), 0.0)
                 for s in graph.stars]
    edge_sel = [edge_selectivity(e, graph, stats, sel, distinct, use_cache=use_cache)
                for e in graph.edges]
    return star_card, edge_sel


# -- vectorized bitmask DP ---------------------------------------------------

# Default budget (bytes) for a layer's candidate tiles.  When every pair of
# a dense tile survives the connectivity filter, the live state per pair is
# the int64 submask/complement matrices plus the compacted index, cost-model
# input and candidate-cost arrays — ~150 bytes at the worst stage (measured
# on clique layers) — so tiles are sized at ``block_bytes / _PAIR_BYTES``
# pairs and the sweep materializes at most about ``block_bytes`` of
# candidate state at any time regardless of star count — the knob that
# removed the old 14-star ``MAX_BITMASK_STARS`` cliff.
DP_BLOCK_BYTES = 256 * 1024 * 1024
_PAIR_BYTES = 160

# Floor on the per-tile pair count.  Without it a large member count (or a
# tiny ``block_bytes``) degenerates ``block_bytes / (_PAIR_BYTES * B)`` to
# 1-pair tiles, turning the vectorized sweep into a Python-level per-pair
# loop.  When a member-stacked sweep cannot afford this floor within its
# budget, ``_dp_sweep`` splits the *member axis* into sub-batches that can
# (plans are per-member bit-identical either way); a single-member sweep
# keeps the floor even when it nominally exceeds a pathological budget —
# bounded planning time wins over a sub-kilobyte memory cap.
MIN_TILE_ELEMS = 1024

DP_BACKENDS = ("numpy", "jax")

_STRAT_SINGLE, _STRAT_EXCL, _STRAT_HASH, _STRAT_BIND = 1, 2, 3, 4

# Observability for the jax backend's two execution modes: 'resident' == the
# whole sweep ran as one compiled device program (kernels.dp_layer.
# dp_sweep_resident), 'tiled' == it fell back to per-layer-tile kernel calls
# (schedule too large for the memory budget, or n too big for int32 masks).
DP_SWEEP_COUNTERS = {"resident": 0, "tiled": 0,
                     "schedule_builds": 0, "schedule_hits": 0}

# Resident sweeps ship int32 mask indices; past this star count the dense
# 2^n state wouldn't fit a sane budget anyway (the roadmap's hash-indexed
# connected-subsets table is the real fix for 22+ stars).
_RESIDENT_MAX_STARS = 20

# Rough bytes of live device state per scheduled candidate pair during one
# scan step of the resident program (the ~10 concurrent (B, P) float64
# gather/pricing arrays), used for the budget eligibility check.
_RESIDENT_PAIR_BYTES = 88

# Proper nonempty submasks of an s-element set, *relative* to the set's bit
# positions (bit j == j-th smallest member), in the reference enumeration
# order: popcount ascending, combination-lex within a popcount.  Lex order on
# ascending position tuples equals descending numeric order of the
# bit-reversed mask, so the table is one stable lexsort.  Depends only on s,
# cached across calls for the common sizes.
_REL_SUBMASKS: dict[int, np.ndarray] = {}
_REL_SUBMASK_CACHE_MAX_S = 16   # cache tables up to 2^16 entries (~0.5 MB)


def _rel_submasks(s: int) -> np.ndarray:
    rel = _REL_SUBMASKS.get(s)
    if rel is None:
        t = np.arange(1, (1 << s) - 1, dtype=np.int64)
        pop = np.zeros(len(t), np.int64)
        rev = np.zeros(len(t), np.int64)
        for j in range(s):
            bit = (t >> j) & 1
            pop += bit
            rev |= bit << (s - 1 - j)
        rel = t[np.lexsort((-rev, pop))]
        if s <= _REL_SUBMASK_CACHE_MAX_S:
            _REL_SUBMASKS[s] = rel
    return rel


# Small-star fast path: for n <= 10 the *dense* per-layer structures (masks,
# bit positions, and the full (submask A, complement B) matrices — at most
# 3^10 ≈ 59k pairs) are graph-independent and tiny, so they are built once
# per star count and reused across queries.  The sweep then skips the
# per-call submask deposit entirely; enumeration order and reduction are
# shared with the tiled path.  Entry per layer s = 2..n:
#   (S_all (n_S,), idx (n_S, s), pow2 (n_S, s), A (n_t, n_S), B (n_t, n_S))
_SKEL_CACHE: dict[int, list] = {}
_SKEL_CACHE_MAX_N = 10


def _layer_skeletons(n: int) -> list:
    skel = _SKEL_CACHE.get(n)
    if skel is None:
        masks = np.arange(1 << n, dtype=np.int64)
        pop = np.zeros(1 << n, np.int64)
        for i in range(n):
            pop += (masks >> i) & 1
        skel = []
        for s in range(2, n + 1):
            S_all = masks[pop == s]
            bitm = ((S_all[:, None] >> np.arange(n, dtype=np.int64)) & 1) == 1
            idx = np.nonzero(bitm)[1].reshape(len(S_all), s).astype(np.int64)
            pw = np.int64(1) << idx
            rel = _rel_submasks(s)
            A = np.zeros((len(rel), len(S_all)), np.int64)
            for j in range(s):
                A += ((rel >> j) & 1)[:, None] * pw[:, j][None, :]
            skel.append((S_all, idx, pw, A, S_all[None, :] ^ A))
        _SKEL_CACHE[n] = skel
    return skel


def _subset_cardinalities(graph: StarGraph, star_card: list[float],
                          edge_sel: list[float], masks: np.ndarray) -> np.ndarray:
    """`card[m]` = Π star_card over members · Π edge selectivities of edges
    inside `m` (each (min, max, pred) key counted once, first edge wins).
    Folds run member-ascending then edge-ascending — the same multiplication
    order as the reference's per-subset products."""
    n = len(graph.stars)
    card = np.ones(len(masks))
    for i in range(n):
        member = ((masks >> i) & 1) == 1
        card[member] *= star_card[i]
    seen: set[tuple[int, int, int | None]] = set()
    for k, e in enumerate(graph.edges):
        key = (min(e.src, e.dst), max(e.src, e.dst), e.pred)
        if key in seen:
            continue
        seen.add(key)
        em = (1 << e.src) | (1 << e.dst)
        inside = (masks & em) == em
        card[inside] *= edge_sel[k]
    return card


def star_graph_topology(graph: StarGraph) -> tuple:
    """Structural identity of a star graph as the DP sees it: star count plus
    the ordered edge list (endpoints, link predicate, generic flag).  Graphs
    with equal topology share the DP's mask/connectivity/enumeration
    structure and the edge-dedupe fold of ``_subset_cardinalities`` — only
    the numeric inputs (star cardinalities, edge selectivities, per-star
    source lists) differ, which is what ``dp_join_order_batch`` exploits."""
    return (len(graph.stars),
            tuple((e.src, e.dst, e.pred, e.generic) for e in graph.edges))


# -- resident-sweep layer schedule -------------------------------------------

@dataclass
class _DPSchedule:
    """The member-independent layer schedule of one graph topology, flattened
    for the resident device program: per popcount layer, the connected
    subsets (``layer_cols``) and the flat (submask A, complement B) candidate
    pairs in the reference enumeration order — column-major over the layer's
    connected subsets, relative submasks ascending within a column
    (``pair_seg`` is the pair's column position; sentinel values mark
    padding).  Extents are padded to shared power-of-two buckets so nearby
    topologies reuse one compiled program."""

    n: int
    pair_a: np.ndarray          # (L, P) int32, sentinel-padded with 0
    pair_b: np.ndarray          # (L, P) int32
    pair_seg: np.ndarray        # (L, P) int32, sentinel == C (padded extent)
    layer_cols: np.ndarray      # (L, C) int32, sentinel == 2**n
    n_pairs: int
    nbytes: int
    dev: "tuple | None" = None  # lazily cached device copies of the four
                                # index arrays (uploaded once per topology,
                                # not once per sweep)


_SCHEDULE_CACHE: "OrderedDict[tuple, _DPSchedule | None]" = OrderedDict()
_SCHEDULE_CACHE_MAX_ENTRIES = 32
_SCHEDULE_CACHE_MAX_BYTES = 256 * 1024 * 1024


def _pow2_bucket(v: int, lo: int = 8) -> int:
    p = lo
    while p < v:
        p *= 2
    return p


def _dp_schedule(graph: StarGraph, budget: int, B: int) -> "_DPSchedule | None":
    """Build (or fetch) the flat layer schedule for ``graph``'s topology.

    Returns ``None`` when the resident program would not fit the tile-memory
    budget for this member count — the caller falls back to the tiled
    per-layer path.  The eligibility bound is computed from connectivity
    alone (``n_cols * (2^s - 2)`` pairs per layer) *before* the O(pairs)
    enumeration, so an oversized clique never pays the build either."""
    n = len(graph.stars)
    if n > _RESIDENT_MAX_STARS:
        return None
    key = star_graph_topology(graph)
    sched = _SCHEDULE_CACHE.get(key)
    if sched is not None:
        DP_SWEEP_COUNTERS["schedule_hits"] += 1
        _SCHEDULE_CACHE.move_to_end(key)
        return sched

    size = 1 << n
    masks = np.arange(size, dtype=np.int64)
    pop = np.zeros(size, np.int64)
    for i in range(n):
        pop += (masks >> i) & 1
    adj = np.zeros(n, np.int64)
    for e in graph.edges:
        adj[e.src] |= np.int64(1) << e.dst
        adj[e.dst] |= np.int64(1) << e.src
    conn = np.zeros(size, bool)
    for i in range(n):
        conn[1 << i] = True

    layer_cols_raw: list[np.ndarray] = []
    for s in range(2, n + 1):
        S_all = masks[pop == s]
        conn_s = np.zeros(len(S_all), bool)
        for i in range(n):
            bit = np.int64(1) << i
            has = (S_all & bit) != 0
            Si = S_all[has]
            conn_s[has] |= conn[Si ^ bit] & ((adj[i] & Si) != 0)
        conn[S_all] = conn_s
        layer_cols_raw.append(S_all[conn_s])

    # budget gate from connectivity alone (upper bound: every submask pair
    # of every connected subset survives).  An oversized topology is NOT
    # cached — eligibility depends on the caller's member count and budget,
    # and a smaller batch may still fit later.
    p_bound = max((len(c) * ((1 << (s + 2)) - 2)
                   for s, c in enumerate(layer_cols_raw)), default=0)
    if _pow2_bucket(p_bound) * B * _RESIDENT_PAIR_BYTES > budget:
        return None
    else:
        DP_SWEEP_COUNTERS["schedule_builds"] += 1
        flat_per_layer: list[tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        p_max = c_max = n_pairs = 0
        row_chunk = max(1, (budget // 32) // max(1, max(
            (len(c) for c in layer_cols_raw), default=1)))
        for s_i, cols in enumerate(layer_cols_raw):
            s = s_i + 2
            if len(cols) == 0:
                flat_per_layer.append((np.empty(0, np.int64),) * 3)
                continue
            idx = np.nonzero(((cols[:, None] >> np.arange(n, dtype=np.int64))
                              & 1) == 1)[1].reshape(len(cols), s)
            pw = np.int64(1) << idx
            rel = _rel_submasks(s)
            fa, fb, fs = [], [], []
            for r0 in range(0, len(rel), row_chunk):
                relb = rel[r0:r0 + row_chunk]
                A = np.zeros((len(relb), len(cols)), np.int64)
                for j in range(s):
                    A += ((relb >> j) & 1)[:, None] * pw[:, j][None, :]
                Bm = cols[None, :] ^ A
                valid = conn[A] & conn[Bm]
                ci, ri = np.nonzero(valid.T)   # col-major: rows asc per col
                fa.append(A[ri, ci])
                fb.append(Bm[ri, ci])
                fs.append(ci)
            a = np.concatenate(fa)
            flat_per_layer.append((a, np.concatenate(fb), np.concatenate(fs)))
            n_pairs += len(a)
            p_max = max(p_max, len(a))
            c_max = max(c_max, len(cols))

        L = n - 1
        P = _pow2_bucket(p_max)
        C = _pow2_bucket(c_max)
        pair_a = np.zeros((L, P), np.int32)
        pair_b = np.zeros((L, P), np.int32)
        pair_seg = np.full((L, P), C, np.int32)        # sentinel == C
        layer_cols = np.full((L, C), size, np.int32)   # sentinel == size
        for li, ((a, b, seg), cols) in enumerate(
                zip(flat_per_layer, layer_cols_raw)):
            pair_a[li, :len(a)] = a
            pair_b[li, :len(a)] = b
            pair_seg[li, :len(a)] = seg
            layer_cols[li, :len(cols)] = cols
        nbytes = (pair_a.nbytes + pair_b.nbytes + pair_seg.nbytes
                  + layer_cols.nbytes)
        sched = _DPSchedule(n, pair_a, pair_b, pair_seg, layer_cols,
                            n_pairs, nbytes)

    _SCHEDULE_CACHE[key] = sched
    total = sum(s.nbytes for s in _SCHEDULE_CACHE.values())
    while _SCHEDULE_CACHE and (
            len(_SCHEDULE_CACHE) > _SCHEDULE_CACHE_MAX_ENTRIES
            or total > _SCHEDULE_CACHE_MAX_BYTES):
        _, old = _SCHEDULE_CACHE.popitem(last=False)
        total -= old.nbytes
    return sched


def _resident_fits(sched: "_DPSchedule | None", B: int, budget: int) -> bool:
    """Device-memory eligibility of the resident program: the scan step's
    live (B, P) pricing state, the (B, 2^n) resident DP state (6 float64
    planes plus the int32 winner planes) and the schedule itself must fit
    the layer-tile budget."""
    if sched is None:
        return False
    size = 1 << sched.n
    state = B * size * 8 * 6 + B * size * 4 * 2
    step = B * sched.pair_a.shape[1] * _RESIDENT_PAIR_BYTES
    return state + step + sched.nbytes <= budget


def _subset_cardinalities_b(graph: StarGraph, star_card: np.ndarray,
                            edge_sel: np.ndarray, masks: np.ndarray) -> np.ndarray:
    """Member-batched ``_subset_cardinalities``: ``star_card``/``edge_sel``
    are ``(B, n)`` / ``(B, n_edges)``; returns ``card`` of shape
    ``(B, len(masks))``.  The fold order (member-ascending, then
    edge-ascending with first-edge-wins dedupe) matches the single-member
    form element for element, so row ``b`` is bit-identical to
    ``_subset_cardinalities(graph, star_card[b], edge_sel[b], masks)``."""
    n = len(graph.stars)
    card = np.ones((star_card.shape[0], len(masks)))
    for i in range(n):
        member = ((masks >> i) & 1) == 1
        card[:, member] *= star_card[:, i:i + 1]
    seen: set[tuple[int, int, int | None]] = set()
    for k, e in enumerate(graph.edges):
        key = (min(e.src, e.dst), max(e.src, e.dst), e.pred)
        if key in seen:
            continue
        seen.add(key)
        em = (1 << e.src) | (1 << e.dst)
        inside = (masks & em) == em
        card[:, inside] *= edge_sel[:, k:k + 1]
    return card


def dp_join_order(
    graph: StarGraph,
    stats: FederatedStats,
    sel: SourceSelection,
    cost_model: CostModel | None = None,
    distinct: bool = True,
    block_bytes: int | None = None,
    dp_backend: str = "numpy",
) -> JoinTree:
    """Exact DP over connected star subsets, vectorized over bitmasks.

    Candidate plans per subset (same space as ``dp_join_order_ref``):
      * exclusive-group leaf — every star served by the same single source:
        the merged subquery runs remotely, only results ship (§3.4 subquery
        optimization, folded into the DP);
      * hash join of two subplans (both results at the engine);
      * bind join of a subplan with a leaf-able right side (bindings shipped
        out, matches shipped back — replaces the right leaf's transfer).

    Subsets are integer bitmasks.  Per-subset cardinalities are precomputed
    once; subset connectivity is filled in layer by layer (a set is connected
    iff dropping some member with a neighbor inside keeps it connected).  A
    popcount layer enumerates only its *connected* subsets, and for each the
    (submask A, complement B) partitions in the reference order — popcount
    ascending, combination-lex within a popcount.  Partitions are generated
    in tiles of at most ``block_bytes / _PAIR_BYTES`` candidates (peak tile
    memory is bounded no matter the star count), filtered to connected A and
    connected B (a cut of a connected subset always has a crossing edge, so the
    explicit cross-edge test is implied), and only the surviving csg/cmp
    pairs are costed.  Per-tile segmented first-minimum plus strictly-less
    running updates across tiles reproduce the reference's first-strict-
    minimum tie-breaking exactly, so both DPs return the same plan.

    Implemented as the single-member case of ``_dp_sweep`` — the same sweep
    ``dp_join_order_batch`` runs over a whole shape group at once.
    ``dp_backend='jax'`` runs the whole sweep as one device-resident program
    (``repro.kernels.dp_layer.dp_sweep_resident``) when the topology's layer
    schedule fits the tile budget, and prices per-layer tiles through the
    Pallas kernel otherwise; plans are bit-identical across backends."""
    cm = cost_model or CostModel()
    star_card, edge_sel = _star_edge_statistics(graph, stats, sel, distinct)
    return _dp_sweep(graph, [sel], [star_card], [edge_sel], cm, block_bytes,
                     dp_backend)[0]


def dp_join_order_batch(
    graphs: "list[StarGraph]",
    stats: FederatedStats,
    sels: "list[SourceSelection]",
    cost_model: CostModel | None = None,
    distinct: bool = True,
    block_bytes: int | None = None,
    dp_backend: str = "numpy",
) -> "list[JoinTree]":
    """One DP sweep over a *shape group*: queries whose star graphs share
    ``star_graph_topology`` (star count + ordered edge list).  The layer
    structure — connected-subset enumeration, (A, B) partition tiles, the
    connectivity filter, the segmented reduction layout — is computed once
    for the whole group; only the numeric state (cardinalities, costs,
    source counts/weights) carries a member axis, costed blockwise through
    the broadcasting ``CostModel.*_v`` forms.  Per member the candidate
    order, the float operations and the first-strict-minimum tie-breaking
    are element-for-element those of ``dp_join_order``, so each returned
    tree is bit-identical to planning that member alone.

    Tile sizing divides the ``block_bytes`` budget by the member count
    (down to the ``MIN_TILE_ELEMS`` floor — past it, the member axis is
    split across sweeps instead), so a group sweep obeys the same
    peak-memory bound as a single query.  ``dp_backend='jax'`` runs the
    per-layer candidate pricing + reduction on-device through
    ``repro.kernels.dp_layer`` with bit-identical plans."""
    if not graphs:
        return []
    if len(graphs) != len(sels):
        raise ValueError("one SourceSelection per graph")
    topo = star_graph_topology(graphs[0])
    for g in graphs[1:]:
        if star_graph_topology(g) != topo:
            raise ValueError("dp_join_order_batch needs topology-identical "
                             "graphs (group by star_graph_topology first)")
    cm = cost_model or CostModel()
    star_cards: list[list[float]] = []
    edge_sels: list[list[float]] = []
    for g, sel in zip(graphs, sels):
        sc, es = _star_edge_statistics(g, stats, sel, distinct)
        star_cards.append(sc)
        edge_sels.append(es)
    return _dp_sweep(graphs[0], sels, star_cards, edge_sels, cm, block_bytes,
                     dp_backend)


def _dp_sweep(
    graph: StarGraph,
    sels: "list[SourceSelection]",
    star_cards: "list[list[float]]",
    edge_sels: "list[list[float]]",
    cm: CostModel,
    block_bytes: int | None = None,
    dp_backend: str = "numpy",
) -> "list[JoinTree]":
    """The csg/cmp sweep over ``B = len(sels)`` members sharing one graph
    topology.  Mask enumeration, connectivity and tile layout are
    member-independent; every numeric array carries a leading member axis.
    ``dp_backend`` selects the sweep engine: ``'numpy'`` runs the in-process
    tiled layer loop; ``'jax'`` runs the whole sweep as one device-resident
    program when the topology's layer schedule fits the budget
    (``_resident_sweep``) and falls back to pricing the layer tiles through
    the ``repro.kernels.dp_layer`` Pallas kernel when it doesn't.  All
    paths produce bit-identical plans."""
    if dp_backend not in DP_BACKENDS:
        raise ValueError(f"unknown dp_backend {dp_backend!r} "
                         f"(expected one of {DP_BACKENDS})")
    n = len(graph.stars)
    B = len(sels)
    if n == 1:
        out = []
        for sel, sc in zip(sels, star_cards):
            ss = frozenset([0])
            out.append(JoinTree("leaf", ss, sc[0],
                                cm.leaf_cost(sc[0], sel.star_sources[0]),
                                sources=list(sel.star_sources[0])))
        return out

    # the tile budget covers the whole member-stacked candidate state, so a
    # B-member sweep divides the per-tile pair count by B — but never below
    # the MIN_TILE_ELEMS floor: a group too wide for its budget is split
    # along the member axis (per-member plans are identical either way)
    budget = int(block_bytes or DP_BLOCK_BYTES)
    tile_elems = budget // (_PAIR_BYTES * B)
    if tile_elems < MIN_TILE_ELEMS and B > 1:
        b_max = max(1, budget // (_PAIR_BYTES * MIN_TILE_ELEMS))
        out = []
        for i in range(0, B, b_max):
            out.extend(_dp_sweep(graph, sels[i:i + b_max],
                                 star_cards[i:i + b_max],
                                 edge_sels[i:i + b_max], cm, block_bytes,
                                 dp_backend))
        return out
    tile_elems = max(tile_elems, MIN_TILE_ELEMS)

    size = 1 << n
    masks = np.arange(size, dtype=np.int64)
    sc_b = np.asarray(star_cards, dtype=np.float64)        # (B, n)
    es_b = (np.asarray(edge_sels, dtype=np.float64)
            if graph.edges else np.zeros((B, 0)))
    card = _subset_cardinalities_b(graph, sc_b, es_b, masks)

    # star neighborhoods (all edges, including generic/duplicate ones)
    adj = np.zeros(n, np.int64)
    for e in graph.edges:
        adj[e.src] |= np.int64(1) << e.dst
        adj[e.dst] |= np.int64(1) << e.src

    # exclusive groups: stars pinned to exactly one source (per member)
    single_src = np.full((B, n), -1, np.int64)
    single_mask = np.zeros(B, np.int64)
    for b, sel in enumerate(sels):
        for i, srcs in enumerate(sel.star_sources):
            if len(srcs) == 1:
                single_src[b, i] = srcs[0]
                single_mask[b] |= np.int64(1) << i

    # per-(member, mask) best-plan state (cost == inf encodes "no plan")
    INF = np.inf
    cost = np.full((B, size), INF)
    conn = np.zeros(size, bool)                  # member-independent
    bindable = np.zeros((B, size), bool)         # leaf with >=1 source
    n_src = np.zeros((B, size), np.int64)
    src_w = np.ones((B, size))
    STRAT_SINGLE, STRAT_EXCL, STRAT_HASH, STRAT_BIND = (
        _STRAT_SINGLE, _STRAT_EXCL, _STRAT_HASH, _STRAT_BIND)
    strat = np.zeros((B, size), np.int8)
    split = np.zeros((B, size), np.int64)
    excl_of = np.full((B, size), -1, np.int64)

    for i in range(n):
        m = 1 << i
        conn[m] = True
        for b, sel in enumerate(sels):
            srcs = sel.star_sources[i]
            cost[b, m] = cm.leaf_cost(star_cards[b][i], srcs)
            bindable[b, m] = len(srcs) > 0
            n_src[b, m] = len(srcs)
            src_w[b, m] = cm.src_w(srcs)
            strat[b, m] = STRAT_SINGLE

    any_single = bool(single_mask.any())
    # per-source weight lookup for the exclusive-group seed: one interpreted
    # cm.src_w call per source id instead of one per (member, column) tile
    # cell (index -1, "no single source", resolves to the appended 1.0 —
    # cm.src_w([-1]) for an id absent from source_weight)
    w_lut = None
    if cm.source_weight:
        hi = int(single_src.max()) + 1 if single_src.size else 0
        w_lut = np.array([cm.src_w([s]) for s in range(hi)] + [1.0])

    # jax backend: run the whole sweep as one compiled device program when
    # the topology's layer schedule fits the budget — the full DP state
    # stays resident on device across layers, only int32 index tiles plus
    # the seed state go up and the final plan state comes down (one
    # host<->device round trip for the whole sweep).  Oversized schedules
    # fall back to the tiled per-layer kernel path, with x64 entered once
    # around the whole sweep instead of per layer tile.
    resident = False
    if dp_backend == "jax":
        sched = _dp_schedule(graph, budget, B)
        if _resident_fits(sched, B, budget):
            _resident_sweep(sched, cm, card, cost, bindable, n_src, src_w,
                            strat, split, excl_of, single_mask, single_src,
                            w_lut)
            resident = True
            DP_SWEEP_COUNTERS["resident"] += 1
        else:
            DP_SWEEP_COUNTERS["tiled"] += 1
    if not resident:
        ctx = contextlib.nullcontext()
        if dp_backend == "jax":
            from jax.experimental import enable_x64
            ctx = enable_x64()
        with ctx:
            _tiled_layer_sweep(cm, dp_backend, n, B, tile_elems, masks, adj,
                               conn, card, cost, bindable, n_src, src_w,
                               strat, split, excl_of, single_mask,
                               single_src, any_single, w_lut)

    def build(b: int, m: int) -> JoinTree:
        ss = frozenset(i for i in range(n) if (m >> i) & 1)
        st = int(strat[b, m])
        if st == STRAT_SINGLE:
            i = next(iter(ss))
            return JoinTree("leaf", ss, star_cards[b][i], float(cost[b, m]),
                            sources=list(sels[b].star_sources[i]))
        if st == STRAT_EXCL:
            return JoinTree("leaf", ss, float(card[b, m]), float(cost[b, m]),
                            sources=[int(excl_of[b, m])])
        am = int(split[b, m])
        return JoinTree("join", ss, float(card[b, m]), float(cost[b, m]),
                        build(b, am), build(b, m ^ am),
                        "hash" if st == STRAT_HASH else "bind")

    full = size - 1
    comps = None
    out: list[JoinTree] = []
    for b in range(B):
        if np.isfinite(cost[b, full]):
            out.append(build(b, full))
            continue
        # disconnected query: cartesian-combine components by ascending
        # cardinality (component masks are member-independent)
        if comps is None:
            comps = _components(graph)
        trees = sorted((build(b, sum(1 << i for i in c)) for c in comps),
                       key=lambda t: t.cardinality)
        tree = trees[0]
        for t in trees[1:]:
            cardx = tree.cardinality * t.cardinality
            tree = JoinTree("join", tree.stars | t.stars, cardx,
                            tree.cost + t.cost + cm.intermediate_weight * cardx,
                            tree, t, "hash", None)
        out.append(tree)
    return out


def _tiled_layer_sweep(cm: CostModel, dp_backend: str, n: int, B: int,
                       tile_elems: int, masks: np.ndarray, adj: np.ndarray,
                       conn: np.ndarray, card: np.ndarray, cost: np.ndarray,
                       bindable: np.ndarray, n_src: np.ndarray,
                       src_w: np.ndarray, strat: np.ndarray,
                       split: np.ndarray, excl_of: np.ndarray,
                       single_mask: np.ndarray, single_src: np.ndarray,
                       any_single: bool, w_lut: "np.ndarray | None") -> None:
    """The tiled csg/cmp layer loop over the mutable per-(member, mask) DP
    state — the in-process fallback shared by the numpy backend and by jax
    sweeps whose layer schedule exceeds the resident program's budget.
    Mutates ``conn``/``cost``/``bindable``/``n_src``/``src_w``/``strat``/
    ``split``/``excl_of`` in place; jax callers enter ``enable_x64`` once
    around this call (the per-tile kernel skips re-entering it)."""
    INF = np.inf
    STRAT_EXCL, STRAT_HASH, STRAT_BIND = (_STRAT_EXCL, _STRAT_HASH,
                                          _STRAT_BIND)
    size = 1 << n
    # small-star fast path: dense per-layer structures cached across calls,
    # taken whenever the whole dense layer set (< 3^n pairs) fits the budget
    skel = (_layer_skeletons(n)
            if n <= _SKEL_CACHE_MAX_N and tile_elems >= 3 ** n else None)
    if skel is None:
        pop = np.zeros(size, np.int64)
        for i in range(n):
            pop += (masks >> i) & 1

    for s in range(2, n + 1):
        # layer connectivity: S is connected iff some member i has a neighbor
        # in S and S \ {i} is connected (spanning-tree leaf argument)
        if skel is not None:
            S_all, idx_all, pow2_all, A_all, B_all = skel[s - 2]
            S_col = S_all[:, None]
            conn_s = (conn[S_col ^ pow2_all]
                      & ((adj[idx_all] & S_col) != 0)).any(axis=1)
        else:
            S_all = masks[pop == s]
            conn_s = np.zeros(len(S_all), bool)
            for i in range(n):
                bit = np.int64(1) << i
                has = (S_all & bit) != 0
                Si = S_all[has]
                conn_s[has] |= conn[Si ^ bit] & ((adj[i] & Si) != 0)
        conn[S_all] = conn_s
        cols = S_all[conn_s]
        n_cols = len(cols)
        if n_cols == 0:
            continue

        card_S = card[:, cols]
        hj = cm.hash_join_cost_v(card_S)

        # running per-(member, subset) best across tiles; strat 0 == no
        # candidate yet.  Seeded below with the exclusive-group leaf
        # (candidate index 0 in the reference order), which pair candidates
        # must beat strictly.
        run_cost = np.full((B, n_cols), INF)
        run_split = np.zeros((B, n_cols), np.int64)
        run_strat = np.zeros((B, n_cols), np.int8)
        excl_w = np.ones((B, n_cols))
        excl_src = np.full((B, n_cols), -1, np.int64)

        rel = _rel_submasks(s)
        n_rows = len(rel)
        if skel is not None:
            row_block, col_block = n_rows, n_cols          # one dense tile
            colidx = np.flatnonzero(conn_s)
        else:
            row_block = max(1, min(n_rows, tile_elems))
            col_block = max(1, tile_elems // max(row_block, n))

        for c0 in range(0, n_cols, col_block):
            c1 = min(c0 + col_block, n_cols)
            Sb = cols[c0:c1]
            if skel is not None:
                all_conn = n_cols == len(S_all)
                sub = None if all_conn else colidx[c0:c1]
                idx_b = idx_all if all_conn else idx_all[sub]
            else:
                bitm = ((Sb[:, None] >> np.arange(n, dtype=np.int64)) & 1) == 1
                idx_b = np.nonzero(bitm)[1].reshape(len(Sb), s).astype(np.int64)
                pow2_b = np.int64(1) << idx_b

            if any_single:
                in_single = (Sb[None, :] & ~single_mask[:, None]) == 0
                if in_single.any():
                    srcs_mat = single_src[:, idx_b]        # (B, nb, s)
                    excl_ok = in_single & (srcs_mat == srcs_mat[:, :, :1]).all(axis=2)
                    excl_src[:, c0:c1] = srcs_mat[:, :, 0]
                    if excl_ok.any():
                        w = excl_w[:, c0:c1]
                        if w_lut is not None:
                            w = w_lut[srcs_mat[:, :, 0]]
                            excl_w[:, c0:c1] = w
                        run_cost[:, c0:c1] = np.where(
                            excl_ok, cm.leaf_cost_v(card_S[:, c0:c1], 1, w), INF)
                        run_strat[:, c0:c1] = np.where(excl_ok, STRAT_EXCL,
                                                       0).astype(np.int8)

            for r0 in range(0, n_rows, row_block):
                if skel is not None:
                    A = A_all if all_conn else A_all[:, sub]
                    Bm = B_all if all_conn else B_all[:, sub]
                else:
                    relb = rel[r0:r0 + row_block]
                    # deposit the relative submasks into each column's bit
                    # positions: A[r, c] has relb[r]'s bits at Sb[c]'s members
                    A = np.zeros((len(relb), len(Sb)), np.int64)
                    for j in range(s):
                        A += ((relb >> j) & 1)[:, None] * pow2_b[:, j][None, :]
                    Bm = Sb[None, :] ^ A
                valid = conn[A] & conn[Bm]
                if not valid.any():
                    continue
                if dp_backend == "jax":
                    _layer_tile_jax(cm, cost, card, n_src, src_w, bindable,
                                    A, Bm, valid, card_S, c0, c1,
                                    run_cost, run_split, run_strat)
                    continue
                ci, ri = np.nonzero(valid.T)   # col-major: rows asc per col
                Af = A[ri, ci]
                Bf = Bm[ri, ci]
                del A, Bm, valid, ri           # dense tile state: off-peak
                                               # before the per-pair gathers
                gci = c0 + ci
                pair_c, is_bind = cm.join_candidates_v(
                    cost[:, Af], cost[:, Bf], card_S[:, gci], hj[:, gci],
                    card[:, Af], n_src[:, Bf], src_w[:, Bf], bindable[:, Bf])
                # ci is sorted; segment = run of equal column indices
                change = np.empty(len(ci), bool)
                change[0] = True
                np.not_equal(ci[1:], ci[:-1], out=change[1:])
                seg_starts = np.flatnonzero(change)
                seg_cols = ci[seg_starts]
                seg_min = np.minimum.reduceat(pair_c, seg_starts, axis=1)
                seg_of = np.cumsum(change) - 1
                # first candidate attaining the segment minimum == the
                # reference's first-strict-minimum tie-breaking
                flat = np.where(pair_c == seg_min[:, seg_of],
                                np.arange(len(ci))[None, :], len(ci))
                first = np.minimum.reduceat(flat, seg_starts, axis=1)
                g = c0 + seg_cols
                upd = seg_min < run_cost[:, g]
                if upd.any():
                    bu, su = np.nonzero(upd)
                    gu = g[su]
                    fu = first[bu, su]
                    run_cost[bu, gu] = seg_min[bu, su]
                    run_split[bu, gu] = Af[fu]
                    run_strat[bu, gu] = np.where(is_bind[bu, fu],
                                                 STRAT_BIND, STRAT_HASH)

        ok = run_strat != 0
        if not ok.any():
            continue
        bo, ko = np.nonzero(ok)
        S_ok = cols[ko]
        st_ok = run_strat[bo, ko]
        is_excl = st_ok == STRAT_EXCL
        cost[bo, S_ok] = run_cost[bo, ko]
        strat[bo, S_ok] = st_ok
        split[bo, S_ok] = np.where(is_excl, 0, run_split[bo, ko])
        bindable[bo, S_ok] = is_excl
        n_src[bo, S_ok] = np.where(is_excl, 1, 0)
        src_w[bo, S_ok] = np.where(is_excl, excl_w[bo, ko], 1.0)
        excl_of[bo, S_ok] = np.where(is_excl, excl_src[bo, ko], -1)


def _resident_sweep(sched: _DPSchedule, cm: CostModel, card: np.ndarray,
                    cost: np.ndarray, bindable: np.ndarray,
                    n_src: np.ndarray, src_w: np.ndarray, strat: np.ndarray,
                    split: np.ndarray, excl_of: np.ndarray,
                    single_mask: np.ndarray, single_src: np.ndarray,
                    w_lut: "np.ndarray | None") -> None:
    """Host glue for the device-resident sweep: precompute the exclusive-
    group leaf seeds over *every* mask (the device program cannot interpret
    source sets), ship the seeds + the topology's index schedule through
    ``dp_sweep_resident`` in one round trip, and merge the returned winner
    planes back into the mutable DP state.  The seed math is the tiled
    path's element for element — same ``leaf_cost_v`` inputs, same
    ``w_lut`` lookups — so plans stay bit-identical across paths."""
    from repro.kernels.dp_layer import dp_sweep_resident

    B, size = cost.shape
    n = sched.n

    excl_cost = np.full((B, size), np.inf)
    excl_w = np.ones((B, size))
    excl_src_all = np.full((B, size), -1, np.int64)
    union = int(np.bitwise_or.reduce(single_mask)) if B else 0
    if union:
        # only subsets of some member's single mask can host a group leaf
        # (every member pinned to exactly one source), so the seed math runs
        # over that — usually tiny — candidate set, not all 2^n masks.
        # ref_src is the lowest member's source, the tiled path's
        # ``srcs_mat[:, :, 0]``; the group leaf exists iff every member
        # star shares it
        masks = np.arange(size, dtype=np.int64)
        cand = masks[(masks & ~np.int64(union)) == 0]
        ref_src = np.full((B, len(cand)), -1, np.int64)
        same = np.ones((B, len(cand)), bool)
        npop = np.zeros(len(cand), np.int64)
        for i in range(n):
            if not (union >> i) & 1:
                continue
            has = ((cand >> i) & 1) == 1
            npop += has
            s_i = single_src[:, i:i + 1]
            mism = has[None, :] & (ref_src >= 0) & (ref_src != s_i)
            ref_src = np.where(has[None, :] & (ref_src < 0), s_i, ref_src)
            same &= ~mism
        in_single = (cand[None, :] & ~single_mask[:, None]) == 0
        ok = in_single & same & (npop[None, :] >= 2) & (ref_src >= 0)
        w = w_lut[ref_src] if w_lut is not None else 1.0
        if w_lut is not None:
            excl_w[:, cand] = np.where(ok, w, 1.0)
        excl_cost[:, cand] = np.where(ok, cm.leaf_cost_v(card[:, cand], 1, w),
                                      np.inf)
        excl_src_all[:, cand] = np.where(ok, ref_src, -1)

    if sched.dev is None:
        import jax.numpy as jnp

        sched.dev = tuple(jnp.asarray(x) for x in (
            sched.pair_a, sched.pair_b, sched.pair_seg, sched.layer_cols))
    params = (cm.intermediate_weight, cm.transfer_weight, cm.request_cost,
              cm.bind_batch)
    cost_d, strat_d, split_d = dp_sweep_resident(
        params, *sched.dev, card, excl_cost, excl_w, cost,
        n_src.astype(np.float64), src_w)

    # strat 0 == the device never wrote the mask (singletons, disconnected
    # or unreachable subsets): those keep their host-seeded state.  Only
    # the planes ``build()`` reads are merged — bindable/n_src/src_w are
    # dead once the sweep is over
    written = strat_d != 0
    np.copyto(cost, cost_d, where=written)
    np.copyto(strat, strat_d.astype(np.int8), where=written)
    np.copyto(split, split_d.astype(np.int64), where=written)
    is_excl = written & (strat_d == _STRAT_EXCL)
    np.copyto(excl_of, excl_src_all, where=is_excl)


def _layer_tile_jax(cm: CostModel, cost: np.ndarray, card: np.ndarray,
                    n_src: np.ndarray, src_w: np.ndarray, bindable: np.ndarray,
                    A: np.ndarray, Bm: np.ndarray, valid: np.ndarray,
                    card_S: np.ndarray, c0: int, c1: int,
                    run_cost: np.ndarray, run_split: np.ndarray,
                    run_strat: np.ndarray) -> None:
    """Price one dense ``(rows, cols)`` layer tile through the Pallas kernel
    and fold the per-column winners into the running state.

    The kernel sees the same candidates as the numpy path — the dense
    ``(submask A, complement B)`` matrices with the connectivity mask, rows
    in the reference enumeration order — gathered into ``(B, rows, cols)``
    per-pair state (the per-subset hash-join cost is derived on-device from
    ``card_S`` via ``CostModel.hash_join_cost_jnp``, bit-identical to the
    host ``hash_join_cost_v`` form), and returns each column's first strict
    minimum.  The strictly-less fold against ``run_cost`` matches the numpy
    path's cross-tile merge, so backends tie-break identically."""
    from repro.kernels.dp_layer import dp_layer

    best_c, best_r, best_b = dp_layer(
        cost[:, A], cost[:, Bm], card[:, A], n_src[:, Bm].astype(np.float64),
        src_w[:, Bm], bindable[:, Bm], valid, card_S[:, c0:c1],
        (cm.intermediate_weight, cm.transfer_weight, cm.request_cost,
         cm.bind_batch))
    upd = best_c < run_cost[:, c0:c1]
    if upd.any():
        bu, cu = np.nonzero(upd)
        gu = c0 + cu
        ru = best_r[bu, cu]
        run_cost[bu, gu] = best_c[bu, cu]
        run_split[bu, gu] = A[ru, cu]
        run_strat[bu, gu] = np.where(best_b[bu, cu], _STRAT_BIND,
                                     _STRAT_HASH).astype(np.int8)


# -- reference DP (oracle) ---------------------------------------------------

def dp_join_order_ref(
    graph: StarGraph,
    stats: FederatedStats,
    sel: SourceSelection,
    cost_model: CostModel | None = None,
    distinct: bool = True,
    use_cache: bool = False,
) -> JoinTree:
    """The original frozenset-subset DP (paper: "dynamic programming becomes
    affordable" because #stars << #triple patterns), with unmemoized
    statistics by default — the seed implementation, kept as the reference
    oracle and benchmark baseline for ``dp_join_order``.  Same plan space,
    same tie-breaking, identical statistics values."""
    cm = cost_model or CostModel()
    n = len(graph.stars)
    star_card, edge_sel = _star_edge_statistics(graph, stats, sel, distinct,
                                                use_cache=use_cache)

    def subset_card(ss: frozenset[int]) -> float:
        card = 1.0
        for i in sorted(ss):    # ascending, matching the bitmask path's fold
            card *= max(star_card[i], 0.0)
        counted: set[tuple[int, int, int | None]] = set()
        for k, e in enumerate(graph.edges):
            if e.src in ss and e.dst in ss:
                key = (min(e.src, e.dst), max(e.src, e.dst), e.pred)
                if key in counted:
                    continue
                counted.add(key)
                card *= edge_sel[k]
        return card

    def exclusive(ss: frozenset[int]) -> int | None:
        if not all(len(sel.star_sources[i]) == 1 for i in ss):
            return None
        srcs = {sel.star_sources[i][0] for i in ss}
        return next(iter(srcs)) if len(srcs) == 1 else None

    def is_connected(ss: frozenset[int]) -> bool:
        if len(ss) == 1:
            return True
        seen = {next(iter(ss))}
        frontier = list(seen)
        while frontier:
            cur = frontier.pop()
            for e in graph.edges:
                for a, b in ((e.src, e.dst), (e.dst, e.src)):
                    if a == cur and b in ss and b not in seen:
                        seen.add(b)
                        frontier.append(b)
        return seen == set(ss)

    best: dict[frozenset[int], JoinTree] = {}
    for i in range(n):
        ss = frozenset([i])
        card = star_card[i]
        best[ss] = JoinTree("leaf", ss, card, cm.leaf_cost(card, sel.star_sources[i]),
                            sources=list(sel.star_sources[i]))

    for size in range(2, n + 1):
        for combo in combinations(range(n), size):
            ss = frozenset(combo)
            cand: JoinTree | None = None
            card = subset_card(ss)
            # exclusive-group leaf candidate
            excl = exclusive(ss)
            if excl is not None and is_connected(ss):
                cand = JoinTree("leaf", ss, card, cm.leaf_cost(card, [excl]),
                                sources=[excl])
            for k in range(1, size):
                for sub in combinations(combo, k):
                    a = frozenset(sub)
                    b = ss - a
                    if a not in best or b not in best:
                        continue
                    if not graph.connected(a, b) and n > 1:
                        continue
                    ta, tb = best[a], best[b]
                    # hash join
                    cost = ta.cost + tb.cost + cm.hash_join_cost(card)
                    if cand is None or cost < cand.cost:
                        cand = JoinTree("join", ss, card, cost, ta, tb, "hash")
                    # bind join: right side must be dispatchable as one
                    # subquery (a leaf — single star or exclusive group)
                    if tb.kind == "leaf" and tb.sources:
                        bcost = ta.cost + cm.bind_join_cost(ta.cardinality, card, tb.sources)
                        if bcost < cand.cost:
                            cand = JoinTree("join", ss, card, bcost, ta, tb, "bind")
            if cand is not None:
                prev = best.get(ss)
                if prev is None or cand.cost < prev.cost:
                    best[ss] = cand

    full = frozenset(range(n))
    if full in best:
        return best[full]
    # disconnected query: cartesian-combine components by ascending cardinality
    comps = _components(graph)
    trees = sorted((best[frozenset(c)] for c in comps), key=lambda t: t.cardinality)
    tree = trees[0]
    for t in trees[1:]:
        card = tree.cardinality * t.cardinality
        tree = JoinTree("join", tree.stars | t.stars, card,
                        tree.cost + t.cost + cm.intermediate_weight * card,
                        tree, t, "hash", None)
    return tree


def _components(graph: StarGraph) -> list[set[int]]:
    n = len(graph.stars)
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in graph.edges:
        a, b = find(e.src), find(e.dst)
        if a != b:
            parent[a] = b
    comps: dict[int, set[int]] = {}
    for i in range(n):
        comps.setdefault(find(i), set()).add(i)
    return list(comps.values())
