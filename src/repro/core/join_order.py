"""Join ordering (paper §3.1 + §3.4 step ii).

* Inside a star: the greedy recursive scheme of §3.1 — estimate the
  cardinality of every (k-1)-subset with formula (1)/(2); the pattern missing
  from the cheapest subset is executed last; recurse on the cheapest subset.
* Across stars: stars collapse into meta-nodes; exact dynamic programming over
  connected subsets, with cardinalities from CS/CP statistics and the §3.4
  cost function (intermediate results + transfers).
"""
from __future__ import annotations

from dataclasses import dataclass
from itertools import combinations

import numpy as np

from repro.core.cardinality import (
    linked_star_cardinality_distinct,
    linked_star_cardinality_estimate,
    star_cardinality_distinct,
    star_cardinality_estimate,
)
from repro.core.cost import CostModel
from repro.core.decomposition import Edge, Star, StarGraph
from repro.core.federation import FederatedStats
from repro.core.source_selection import SourceSelection
from repro.query.algebra import Const, TriplePattern, Var

GENERIC_EDGE_SELECTIVITY = 1e-3  # fallback for non object->subject joins


def _bound_object_factor(star: Star, preds: list[int], stats: FederatedStats,
                         sources: list[int]) -> float:
    """Extra selectivity for patterns with a constant object: 1/#distinct
    objects of the predicate (uniformity only where CSs cannot help — the CS
    statistics do not condition on object values)."""
    f = 1.0
    for tp in star.patterns:
        if isinstance(tp.p, Const) and isinstance(tp.o, Const):
            n_obj = 0
            for s in sources:
                cs = stats.cs[s]
                rel = cs.relevant_cs(preds)
                occ = sum(cs.occurrences(int(c), tp.p.tid) for c in rel)
                n_obj = max(n_obj, occ)
            f *= 1.0 / max(1.0, float(n_obj)) * max(1.0, float(len(sources)))
            f = min(f, 1.0)
    return f


def star_cardinality(star: Star, stats: FederatedStats, sel: SourceSelection,
                     distinct: bool, preds: list[int] | None = None) -> float:
    """Cardinality of one star over its selected sources (formulas 1/2,
    summed over sources — each entity lives in one source, footnote 4)."""
    if preds is None:
        preds = star.bound_preds()
    srcs = sel.star_sources[star.idx]
    total = 0.0
    for s in srcs:
        rel = sel.star_cs[star.idx].get(s)
        cs = stats.cs[s]
        if rel is None:
            rel = cs.relevant_cs(preds)
        else:
            rel = np.intersect1d(rel, cs.relevant_cs(preds), assume_unique=False)
        if distinct:
            total += star_cardinality_distinct(cs, preds, rel)
        else:
            total += star_cardinality_estimate(cs, preds, rel)
    if isinstance(star.subject, Const):
        return min(total, 1.0) if distinct else total / max(1.0, total)
    total *= _bound_object_factor(star, preds, stats, srcs)
    return total


def order_star_patterns(star: Star, stats: FederatedStats, sel: SourceSelection,
                        distinct: bool) -> list[TriplePattern]:
    """§3.1 greedy: drop the pattern absent from the cheapest (k-1)-subset."""
    patterns = list(star.patterns)
    bound = [tp for tp in patterns if isinstance(tp.p, Const)]
    unbound = [tp for tp in patterns if not isinstance(tp.p, Const)]
    if len(bound) <= 1:
        return bound + unbound

    order_tail: list[TriplePattern] = []
    current = bound
    while len(current) > 2:
        best_sub = None
        best_card = None
        for sub in combinations(current, len(current) - 1):
            preds = [tp.p.tid for tp in sub]
            card = star_cardinality(star, stats, sel, distinct, preds)
            if best_card is None or card < best_card:
                best_card = card
                best_sub = sub
        dropped = [tp for tp in current if tp not in best_sub][0]
        order_tail.append(dropped)
        current = list(best_sub)
    # order the final pair: cheaper single pattern first
    c0 = star_cardinality(star, stats, sel, distinct, [current[0].p.tid])
    c1 = star_cardinality(star, stats, sel, distinct, [current[1].p.tid])
    first_two = current if c0 <= c1 else [current[1], current[0]]
    return first_two + order_tail[::-1] + unbound


def edge_selectivity(edge: Edge, graph: StarGraph, stats: FederatedStats,
                     sel: SourceSelection, distinct: bool) -> float:
    """Join selectivity of a star-link from CP statistics, aggregated over the
    viable source pairs of the edge."""
    if edge.generic or edge.pred is None:
        return GENERIC_EDGE_SELECTIVITY
    s1 = graph.stars[edge.src]
    s2 = graph.stars[edge.dst]
    p1 = s1.bound_preds()
    p2 = s2.bound_preds()
    links = 0.0
    for a in sel.star_sources[edge.src]:
        for b in sel.star_sources[edge.dst]:
            cp = stats.cp_between(a, b)
            if cp is None:
                continue
            if distinct:
                links += linked_star_cardinality_distinct(cp, stats.cs[a], stats.cs[b], p1, p2, edge.pred)
            else:
                links += linked_star_cardinality_estimate(cp, stats.cs[a], stats.cs[b], p1, p2, edge.pred)
    c1 = max(1.0, star_cardinality(s1, stats, sel, True))
    c2 = max(1.0, star_cardinality(s2, stats, sel, True))
    return min(1.0, links / (c1 * c2))


# --------------------------------------------------------------------------
# DP over meta-nodes
# --------------------------------------------------------------------------

@dataclass
class JoinTree:
    kind: str                      # "leaf" | "join"
    stars: frozenset[int]
    cardinality: float
    cost: float
    left: "JoinTree | None" = None
    right: "JoinTree | None" = None
    strategy: str = ""
    sources: list[int] | None = None      # for leaves (merged => exclusive)

    def leaf_order(self) -> list[int]:
        if self.kind == "leaf":
            return sorted(self.stars)
        return self.left.leaf_order() + self.right.leaf_order()  # type: ignore[union-attr]


def dp_join_order(
    graph: StarGraph,
    stats: FederatedStats,
    sel: SourceSelection,
    cost_model: CostModel | None = None,
    distinct: bool = True,
) -> JoinTree:
    """Exact bitmask DP over connected star subsets (paper: "dynamic
    programming becomes affordable" because #stars << #triple patterns).

    Candidate plans per subset:
      * exclusive-group leaf — every star served by the same single source:
        the merged subquery runs remotely, only results ship (§3.4 subquery
        optimization, folded into the DP);
      * hash join of two subplans (both results at the engine);
      * bind join of a subplan with a leaf-able right side (bindings shipped
        out, matches shipped back — replaces the right leaf's transfer).
    """
    cm = cost_model or CostModel()
    n = len(graph.stars)
    star_card = [max(star_cardinality(s, stats, sel, distinct), 0.0) for s in graph.stars]
    edge_sel = [edge_selectivity(e, graph, stats, sel, distinct) for e in graph.edges]

    def subset_card(ss: frozenset[int]) -> float:
        card = 1.0
        for i in ss:
            card *= max(star_card[i], 0.0)
        counted: set[tuple[int, int, int | None]] = set()
        for k, e in enumerate(graph.edges):
            if e.src in ss and e.dst in ss:
                key = (min(e.src, e.dst), max(e.src, e.dst), e.pred)
                if key in counted:
                    continue
                counted.add(key)
                card *= edge_sel[k]
        return card

    def exclusive(ss: frozenset[int]) -> int | None:
        if not all(len(sel.star_sources[i]) == 1 for i in ss):
            return None
        srcs = {sel.star_sources[i][0] for i in ss}
        return next(iter(srcs)) if len(srcs) == 1 else None

    def is_connected(ss: frozenset[int]) -> bool:
        if len(ss) == 1:
            return True
        seen = {next(iter(ss))}
        frontier = list(seen)
        while frontier:
            cur = frontier.pop()
            for e in graph.edges:
                for a, b in ((e.src, e.dst), (e.dst, e.src)):
                    if a == cur and b in ss and b not in seen:
                        seen.add(b)
                        frontier.append(b)
        return seen == set(ss)

    best: dict[frozenset[int], JoinTree] = {}
    for i in range(n):
        ss = frozenset([i])
        card = star_card[i]
        best[ss] = JoinTree("leaf", ss, card, cm.leaf_cost(card, sel.star_sources[i]),
                            sources=list(sel.star_sources[i]))

    for size in range(2, n + 1):
        for combo in combinations(range(n), size):
            ss = frozenset(combo)
            cand: JoinTree | None = None
            card = subset_card(ss)
            # exclusive-group leaf candidate
            excl = exclusive(ss)
            if excl is not None and is_connected(ss):
                cand = JoinTree("leaf", ss, card, cm.leaf_cost(card, [excl]),
                                sources=[excl])
            for k in range(1, size):
                for sub in combinations(combo, k):
                    a = frozenset(sub)
                    b = ss - a
                    if a not in best or b not in best:
                        continue
                    if not graph.connected(a, b) and n > 1:
                        continue
                    ta, tb = best[a], best[b]
                    # hash join
                    cost = ta.cost + tb.cost + cm.hash_join_cost(card)
                    if cand is None or cost < cand.cost:
                        cand = JoinTree("join", ss, card, cost, ta, tb, "hash")
                    # bind join: right side must be dispatchable as one
                    # subquery (a leaf — single star or exclusive group)
                    if tb.kind == "leaf" and tb.sources:
                        bcost = ta.cost + cm.bind_join_cost(ta.cardinality, card, tb.sources)
                        if bcost < cand.cost:
                            cand = JoinTree("join", ss, card, bcost, ta, tb, "bind")
            if cand is not None:
                prev = best.get(ss)
                if prev is None or cand.cost < prev.cost:
                    best[ss] = cand

    full = frozenset(range(n))
    if full in best:
        return best[full]
    # disconnected query: cartesian-combine components by ascending cardinality
    comps = _components(graph)
    trees = sorted((best[frozenset(c)] for c in comps), key=lambda t: t.cardinality)
    tree = trees[0]
    for t in trees[1:]:
        card = tree.cardinality * t.cardinality
        tree = JoinTree("join", tree.stars | t.stars, card,
                        tree.cost + t.cost + cm.intermediate_weight * card,
                        tree, t, "hash", None)
    return tree


def _components(graph: StarGraph) -> list[set[int]]:
    n = len(graph.stars)
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in graph.edges:
        a, b = find(e.src), find(e.dst)
        if a != b:
            parent[a] = b
    comps: dict[int, set[int]] = {}
    for i in range(n):
        comps.setdefault(find(i), set()).add(i)
    return list(comps.values())
