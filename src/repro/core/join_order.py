"""Join ordering (paper §3.1 + §3.4 step ii).

* Inside a star: the greedy recursive scheme of §3.1 — estimate the
  cardinality of every (k-1)-subset with formula (1)/(2); the pattern missing
  from the cheapest subset is executed last; recurse on the cheapest subset.
* Across stars: stars collapse into meta-nodes; exact dynamic programming over
  connected subsets, with cardinalities from CS/CP statistics and the §3.4
  cost function (intermediate results + transfers).

Two DP implementations share the same plan space and cost model:

``dp_join_order``      vectorized bitmask DP — subsets are integer bitmasks,
                       per-subset cardinalities / connectivity / exclusive
                       groups are precomputed numpy arrays, and each popcount
                       layer costs every (subset, partition) candidate with
                       one set of array ops.  Star cardinalities and edge
                       selectivities are memoized per query (and the
                       underlying CS/CP formulas on the statistics objects,
                       see ``repro.core.cardinality``), so batches of related
                       queries amortize the statistics work.  This is the
                       optimizer hot path.
``dp_join_order_ref``  the original frozenset/`itertools.combinations`
                       formulation with unmemoized statistics, kept as the
                       reference oracle — tests assert the bitmask DP returns
                       plans with identical cost and leaf order.

Both enumerate candidates in the same order (exclusive-group leaf, then for
each proper submask in (popcount asc, combination-lex) order: hash join, then
bind join) and break cost ties by first occurrence, so they pick the same
plan even when several plans share the optimal cost.
"""
from __future__ import annotations

from dataclasses import dataclass
from itertools import combinations

import numpy as np

from repro.core.cardinality import (
    linked_star_cardinality_distinct,
    linked_star_cardinality_distinct_cached,
    linked_star_cardinality_estimate,
    linked_star_cardinality_estimate_cached,
    star_cardinality_distinct,
    star_cardinality_distinct_cached,
    star_cardinality_estimate,
    star_cardinality_estimate_cached,
)
from repro.core.cost import CostModel
from repro.core.decomposition import Edge, Star, StarGraph
from repro.core.federation import FederatedStats
from repro.core.source_selection import SourceSelection
from repro.query.algebra import Const, TriplePattern, Var

GENERIC_EDGE_SELECTIVITY = 1e-3  # fallback for non object->subject joins

# Above this star count the bitmask DP's per-layer candidate matrices stop
# fitting comfortably in memory; fall back to the reference DP (queries this
# large are far past what either implementation handles interactively).
MAX_BITMASK_STARS = 14


def _bound_object_factor(star: Star, preds: list[int], stats: FederatedStats,
                         sources: list[int]) -> float:
    """Extra selectivity for patterns with a constant object: 1/#distinct
    objects of the predicate (uniformity only where CSs cannot help — the CS
    statistics do not condition on object values)."""
    f = 1.0
    for tp in star.patterns:
        if isinstance(tp.p, Const) and isinstance(tp.o, Const):
            n_obj = 0
            for s in sources:
                cs = stats.cs[s]
                rel = cs.relevant_cs(preds)
                occ = sum(cs.occurrences(int(c), tp.p.tid) for c in rel)
                n_obj = max(n_obj, occ)
            f *= 1.0 / max(1.0, float(n_obj)) * max(1.0, float(len(sources)))
            f = min(f, 1.0)
    return f


def star_cardinality(star: Star, stats: FederatedStats, sel: SourceSelection,
                     distinct: bool, preds: list[int] | None = None,
                     use_cache: bool = True) -> float:
    """Cardinality of one star over its selected sources (formulas 1/2,
    summed over sources — each entity lives in one source, footnote 4).

    Memoized on the (per-query) source selection keyed by (star, preds,
    distinct); ``use_cache=False`` recomputes from scratch (the reference
    path used by ``dp_join_order_ref``)."""
    if use_cache:
        key = ("sc", star.idx, None if preds is None else tuple(preds), distinct)
        memo = sel._memo
        v = memo.get(key)
        if v is not None:
            return v
    if preds is None:
        preds = star.bound_preds()
    srcs = sel.star_sources[star.idx]
    total = 0.0
    for s in srcs:
        rel = sel.star_cs[star.idx].get(s)
        cs = stats.cs[s]
        if rel is None:
            rel = cs.relevant_cs(preds)
        else:
            rel = np.intersect1d(rel, cs.relevant_cs(preds), assume_unique=False)
        if distinct:
            total += (star_cardinality_distinct_cached(cs, preds, rel) if use_cache
                      else star_cardinality_distinct(cs, preds, rel))
        else:
            total += (star_cardinality_estimate_cached(cs, preds, rel) if use_cache
                      else star_cardinality_estimate(cs, preds, rel))
    if isinstance(star.subject, Const):
        total = min(total, 1.0) if distinct else total / max(1.0, total)
    else:
        total *= _bound_object_factor(star, preds, stats, srcs)
    if use_cache:
        memo[key] = total
    return total


def order_star_patterns(star: Star, stats: FederatedStats, sel: SourceSelection,
                        distinct: bool) -> list[TriplePattern]:
    """§3.1 greedy: drop the pattern absent from the cheapest (k-1)-subset."""
    patterns = list(star.patterns)
    bound = [tp for tp in patterns if isinstance(tp.p, Const)]
    unbound = [tp for tp in patterns if not isinstance(tp.p, Const)]
    if len(bound) <= 1:
        return bound + unbound

    order_tail: list[TriplePattern] = []
    current = bound
    while len(current) > 2:
        best_sub = None
        best_card = None
        for sub in combinations(current, len(current) - 1):
            preds = [tp.p.tid for tp in sub]
            card = star_cardinality(star, stats, sel, distinct, preds)
            if best_card is None or card < best_card:
                best_card = card
                best_sub = sub
        dropped = [tp for tp in current if tp not in best_sub][0]
        order_tail.append(dropped)
        current = list(best_sub)
    # order the final pair: cheaper single pattern first
    c0 = star_cardinality(star, stats, sel, distinct, [current[0].p.tid])
    c1 = star_cardinality(star, stats, sel, distinct, [current[1].p.tid])
    first_two = current if c0 <= c1 else [current[1], current[0]]
    return first_two + order_tail[::-1] + unbound


def edge_selectivity(edge: Edge, graph: StarGraph, stats: FederatedStats,
                     sel: SourceSelection, distinct: bool,
                     use_cache: bool = True) -> float:
    """Join selectivity of a star-link from CP statistics, aggregated over the
    viable source pairs of the edge.  Memoized like ``star_cardinality``."""
    if edge.generic or edge.pred is None:
        return GENERIC_EDGE_SELECTIVITY
    if use_cache:
        key = ("es", edge.src, edge.dst, edge.pred, distinct)
        memo = sel._memo
        v = memo.get(key)
        if v is not None:
            return v
    s1 = graph.stars[edge.src]
    s2 = graph.stars[edge.dst]
    p1 = s1.bound_preds()
    p2 = s2.bound_preds()
    links = 0.0
    for a in sel.star_sources[edge.src]:
        for b in sel.star_sources[edge.dst]:
            cp = stats.cp_between(a, b)
            if cp is None:
                continue
            if distinct:
                links += (linked_star_cardinality_distinct_cached(
                    cp, stats.cs[a], stats.cs[b], p1, p2, edge.pred) if use_cache
                    else linked_star_cardinality_distinct(
                        cp, stats.cs[a], stats.cs[b], p1, p2, edge.pred))
            else:
                links += (linked_star_cardinality_estimate_cached(
                    cp, stats.cs[a], stats.cs[b], p1, p2, edge.pred) if use_cache
                    else linked_star_cardinality_estimate(
                        cp, stats.cs[a], stats.cs[b], p1, p2, edge.pred))
    c1 = max(1.0, star_cardinality(s1, stats, sel, True, use_cache=use_cache))
    c2 = max(1.0, star_cardinality(s2, stats, sel, True, use_cache=use_cache))
    out = min(1.0, links / (c1 * c2))
    if use_cache:
        memo[key] = out
    return out


# --------------------------------------------------------------------------
# DP over meta-nodes
# --------------------------------------------------------------------------

@dataclass
class JoinTree:
    kind: str                      # "leaf" | "join"
    stars: frozenset[int]
    cardinality: float
    cost: float
    left: "JoinTree | None" = None
    right: "JoinTree | None" = None
    strategy: str = ""
    sources: list[int] | None = None      # for leaves (merged => exclusive)

    def leaf_order(self) -> list[int]:
        if self.kind == "leaf":
            return sorted(self.stars)
        return self.left.leaf_order() + self.right.leaf_order()  # type: ignore[union-attr]


def _star_edge_statistics(graph: StarGraph, stats: FederatedStats,
                          sel: SourceSelection, distinct: bool,
                          use_cache: bool = True,
                          ) -> tuple[list[float], list[float]]:
    """Per-star cardinalities and per-edge selectivities (same values on both
    paths; the cached path memoizes on the selection / statistics objects)."""
    star_card = [max(star_cardinality(s, stats, sel, distinct, use_cache=use_cache), 0.0)
                 for s in graph.stars]
    edge_sel = [edge_selectivity(e, graph, stats, sel, distinct, use_cache=use_cache)
                for e in graph.edges]
    return star_card, edge_sel


# -- vectorized bitmask DP ---------------------------------------------------

# Proper nonempty submasks of an s-element set, as an (n_t, s) bit matrix in
# the reference enumeration order: popcount ascending, combination-lex within
# a popcount.  Depends only on s, cached across calls.
_SUBMASK_BITS: dict[int, np.ndarray] = {}


def _submask_bits(s: int) -> np.ndarray:
    bits = _SUBMASK_BITS.get(s)
    if bits is None:
        ts = [sum(1 << j for j in sub)
              for k in range(1, s) for sub in combinations(range(s), k)]
        t = np.asarray(ts, np.int64)
        bits = ((t[:, None] >> np.arange(s, dtype=np.int64)) & 1).astype(np.int64)
        _SUBMASK_BITS[s] = bits
    return bits


# Per-layer index structures: everything about "subsets of popcount s over n
# stars and their partitions" is graph-independent, so it is computed once per
# star count and reused across queries.  Entry per layer s = 2..n:
#   S_layer (n_S,)   masks of popcount s, ascending
#   idx_mat (n_S, s) bit positions of each mask, ascending
#   pow2    (n_S, s) = 1 << idx_mat
#   A, B    (n_t, n_S) submask / complement pairs of each mask, rows in the
#                      reference enumeration order
_LAYER_CACHE: dict[int, list] = {}
_LAYER_CACHE_MAX_N = 10  # 3^10 ≈ 59k candidate pairs; bigger n is built per call


def _layers(n: int) -> list:
    layers = _LAYER_CACHE.get(n)
    if layers is not None:
        return layers
    masks = np.arange(1 << n, dtype=np.int64)
    pop = np.zeros(1 << n, np.int64)
    for i in range(n):
        pop += (masks >> i) & 1
    layers = []
    for s in range(2, n + 1):
        S_layer = masks[pop == s]
        bitmat = ((S_layer[:, None] >> np.arange(n, dtype=np.int64)) & 1) == 1
        idx_mat = np.nonzero(bitmat)[1].reshape(len(S_layer), s).astype(np.int64)
        pow2 = np.int64(1) << idx_mat
        A = _submask_bits(s) @ pow2.T
        B = S_layer[None, :] ^ A
        layers.append((S_layer, idx_mat, pow2, A, B, np.arange(len(S_layer))))
    if n <= _LAYER_CACHE_MAX_N:
        _LAYER_CACHE[n] = layers
    return layers


def _subset_cardinalities(graph: StarGraph, star_card: list[float],
                          edge_sel: list[float], masks: np.ndarray) -> np.ndarray:
    """`card[m]` = Π star_card over members · Π edge selectivities of edges
    inside `m` (each (min, max, pred) key counted once, first edge wins).
    Folds run member-ascending then edge-ascending — the same multiplication
    order as the reference's per-subset products."""
    n = len(graph.stars)
    card = np.ones(len(masks))
    for i in range(n):
        member = ((masks >> i) & 1) == 1
        card[member] *= star_card[i]
    seen: set[tuple[int, int, int | None]] = set()
    for k, e in enumerate(graph.edges):
        key = (min(e.src, e.dst), max(e.src, e.dst), e.pred)
        if key in seen:
            continue
        seen.add(key)
        em = (1 << e.src) | (1 << e.dst)
        inside = (masks & em) == em
        card[inside] *= edge_sel[k]
    return card


def dp_join_order(
    graph: StarGraph,
    stats: FederatedStats,
    sel: SourceSelection,
    cost_model: CostModel | None = None,
    distinct: bool = True,
) -> JoinTree:
    """Exact DP over connected star subsets, vectorized over bitmasks.

    Candidate plans per subset (same space as ``dp_join_order_ref``):
      * exclusive-group leaf — every star served by the same single source:
        the merged subquery runs remotely, only results ship (§3.4 subquery
        optimization, folded into the DP);
      * hash join of two subplans (both results at the engine);
      * bind join of a subplan with a leaf-able right side (bindings shipped
        out, matches shipped back — replaces the right leaf's transfer).

    Subsets are integer bitmasks.  Per-subset cardinality and neighborhood
    arrays are precomputed once; subset connectivity is filled in layer by
    layer (a set is connected iff dropping some member keeps it connected and
    that member has a neighbor inside).  Each popcount layer then costs every
    (subset, partition) candidate with one set of array ops and reduces with
    ``argmin`` — first minimum == the reference's tie-breaking."""
    cm = cost_model or CostModel()
    n = len(graph.stars)
    if n > MAX_BITMASK_STARS:
        return dp_join_order_ref(graph, stats, sel, cm, distinct, use_cache=True)
    star_card, edge_sel = _star_edge_statistics(graph, stats, sel, distinct)
    if n == 1:
        ss = frozenset([0])
        card0 = star_card[0]
        return JoinTree("leaf", ss, card0, cm.leaf_cost(card0, sel.star_sources[0]),
                        sources=list(sel.star_sources[0]))

    size = 1 << n
    masks = np.arange(size, dtype=np.int64)
    card = _subset_cardinalities(graph, star_card, edge_sel, masks)

    # neighborhoods (all edges, including generic/duplicate ones)
    adj = np.zeros(n, np.int64)
    for e in graph.edges:
        adj[e.src] |= np.int64(1) << e.dst
        adj[e.dst] |= np.int64(1) << e.src
    nbr = np.zeros(size, np.int64)
    for i in range(n):
        member = ((masks >> i) & 1) == 1
        nbr[member] |= adj[i]

    # exclusive groups: stars pinned to exactly one source
    single_src = np.full(n, -1, np.int64)
    single_mask = np.int64(0)
    for i, srcs in enumerate(sel.star_sources):
        if len(srcs) == 1:
            single_src[i] = srcs[0]
            single_mask |= np.int64(1) << i

    # per-mask best-plan state (cost == inf encodes "no plan")
    INF = np.inf
    cost = np.full(size, INF)
    conn = np.zeros(size, bool)
    bindable = np.zeros(size, bool)         # leaf with >=1 source
    n_src = np.zeros(size, np.int64)
    src_w = np.ones(size)
    STRAT_SINGLE, STRAT_EXCL, STRAT_HASH, STRAT_BIND = 1, 2, 3, 4
    strat = np.zeros(size, np.int8)
    split = np.zeros(size, np.int64)
    excl_of = np.full(size, -1, np.int64)

    for i in range(n):
        m = 1 << i
        srcs = sel.star_sources[i]
        cost[m] = cm.leaf_cost(star_card[i], srcs)
        conn[m] = True
        bindable[m] = len(srcs) > 0
        n_src[m] = len(srcs)
        src_w[m] = cm.src_w(srcs)
        strat[m] = STRAT_SINGLE

    for (S_layer, idx_mat, pow2, A, B, arange_cols) in _layers(n):
        conn_l = None
        if single_mask:
            S_col = S_layer[:, None]
            # connectivity (used only to gate exclusive-group leaves): S is
            # connected iff some member i has a neighbor in S and S \ {i} is
            # connected (spanning-tree leaf argument)
            conn_l = (conn[S_col ^ pow2] & ((adj[idx_mat] & S_col) != 0)).any(axis=1)
            conn[S_layer] = conn_l

        card_S = card[S_layer]
        hj = cm.hash_join_cost_v(card_S)
        cost_a = cost[A]
        cross = (nbr[A] & B) != 0
        hash_c = cost_a + cost[B]
        hash_c += hj
        hash_c[~cross] = INF

        bl = bindable[B] & cross
        if bl.any():
            bind_c = cost_a + cm.bind_join_cost_v(card[A], card_S, n_src[B], src_w[B])
            bind_c[~bl] = INF
        else:
            bind_c = None

        excl_c = None
        excl_ok = None
        excl_w = 1.0
        if single_mask:
            in_single = (S_layer & ~single_mask) == 0
            if in_single.any():
                srcs_mat = single_src[idx_mat]
                excl_ok = (in_single & (srcs_mat == srcs_mat[:, :1]).all(axis=1)
                           & conn_l)
                if excl_ok.any():
                    if cm.source_weight:
                        excl_w = np.array([cm.src_w([int(x)]) for x in srcs_mat[:, 0]])
                    excl_c = np.where(excl_ok,
                                      cm.leaf_cost_v(card_S, 1, excl_w), INF)

        cand = np.empty((1 + 2 * len(A), len(S_layer)))
        cand[0] = INF if excl_c is None else excl_c
        cand[1::2] = hash_c
        cand[2::2] = INF if bind_c is None else bind_c
        win = np.argmin(cand, axis=0)
        best = cand[win, arange_cols]
        okm = np.isfinite(best)
        if not okm.any():
            continue
        Sm, wm, cols = S_layer[okm], win[okm], arange_cols[okm]
        cost[Sm] = best[okm]
        is_excl = wm == 0
        strat[Sm] = np.where(is_excl, STRAT_EXCL, STRAT_HASH + ((wm - 1) & 1))
        split[Sm] = np.where(is_excl, 0, A[(wm - 1) >> 1, cols])
        if is_excl.any():
            bindable[Sm] = is_excl
            n_src[Sm] = np.where(is_excl, 1, 0)
            ew = excl_w[cols] if isinstance(excl_w, np.ndarray) else excl_w
            src_w[Sm] = np.where(is_excl, ew, 1.0)
            excl_of[Sm] = np.where(is_excl, single_src[idx_mat[cols, 0]], -1)

    def build(m: int) -> JoinTree:
        ss = frozenset(i for i in range(n) if (m >> i) & 1)
        st = int(strat[m])
        if st == STRAT_SINGLE:
            i = next(iter(ss))
            return JoinTree("leaf", ss, star_card[i], float(cost[m]),
                            sources=list(sel.star_sources[i]))
        if st == STRAT_EXCL:
            return JoinTree("leaf", ss, float(card[m]), float(cost[m]),
                            sources=[int(excl_of[m])])
        am = int(split[m])
        return JoinTree("join", ss, float(card[m]), float(cost[m]),
                        build(am), build(m ^ am),
                        "hash" if st == STRAT_HASH else "bind")

    full = size - 1
    if np.isfinite(cost[full]):
        return build(full)
    # disconnected query: cartesian-combine components by ascending cardinality
    comps = _components(graph)
    trees = sorted((build(sum(1 << i for i in c)) for c in comps),
                   key=lambda t: t.cardinality)
    tree = trees[0]
    for t in trees[1:]:
        cardx = tree.cardinality * t.cardinality
        tree = JoinTree("join", tree.stars | t.stars, cardx,
                        tree.cost + t.cost + cm.intermediate_weight * cardx,
                        tree, t, "hash", None)
    return tree


# -- reference DP (oracle) ---------------------------------------------------

def dp_join_order_ref(
    graph: StarGraph,
    stats: FederatedStats,
    sel: SourceSelection,
    cost_model: CostModel | None = None,
    distinct: bool = True,
    use_cache: bool = False,
) -> JoinTree:
    """The original frozenset-subset DP (paper: "dynamic programming becomes
    affordable" because #stars << #triple patterns), with unmemoized
    statistics by default — the seed implementation, kept as the reference
    oracle and benchmark baseline for ``dp_join_order``.  Same plan space,
    same tie-breaking, identical statistics values.  (``dp_join_order``'s
    beyond-``MAX_BITMASK_STARS`` fallback calls this with ``use_cache=True``
    to keep the memoization benefits.)"""
    cm = cost_model or CostModel()
    n = len(graph.stars)
    star_card, edge_sel = _star_edge_statistics(graph, stats, sel, distinct,
                                                use_cache=use_cache)

    def subset_card(ss: frozenset[int]) -> float:
        card = 1.0
        for i in sorted(ss):    # ascending, matching the bitmask path's fold
            card *= max(star_card[i], 0.0)
        counted: set[tuple[int, int, int | None]] = set()
        for k, e in enumerate(graph.edges):
            if e.src in ss and e.dst in ss:
                key = (min(e.src, e.dst), max(e.src, e.dst), e.pred)
                if key in counted:
                    continue
                counted.add(key)
                card *= edge_sel[k]
        return card

    def exclusive(ss: frozenset[int]) -> int | None:
        if not all(len(sel.star_sources[i]) == 1 for i in ss):
            return None
        srcs = {sel.star_sources[i][0] for i in ss}
        return next(iter(srcs)) if len(srcs) == 1 else None

    def is_connected(ss: frozenset[int]) -> bool:
        if len(ss) == 1:
            return True
        seen = {next(iter(ss))}
        frontier = list(seen)
        while frontier:
            cur = frontier.pop()
            for e in graph.edges:
                for a, b in ((e.src, e.dst), (e.dst, e.src)):
                    if a == cur and b in ss and b not in seen:
                        seen.add(b)
                        frontier.append(b)
        return seen == set(ss)

    best: dict[frozenset[int], JoinTree] = {}
    for i in range(n):
        ss = frozenset([i])
        card = star_card[i]
        best[ss] = JoinTree("leaf", ss, card, cm.leaf_cost(card, sel.star_sources[i]),
                            sources=list(sel.star_sources[i]))

    for size in range(2, n + 1):
        for combo in combinations(range(n), size):
            ss = frozenset(combo)
            cand: JoinTree | None = None
            card = subset_card(ss)
            # exclusive-group leaf candidate
            excl = exclusive(ss)
            if excl is not None and is_connected(ss):
                cand = JoinTree("leaf", ss, card, cm.leaf_cost(card, [excl]),
                                sources=[excl])
            for k in range(1, size):
                for sub in combinations(combo, k):
                    a = frozenset(sub)
                    b = ss - a
                    if a not in best or b not in best:
                        continue
                    if not graph.connected(a, b) and n > 1:
                        continue
                    ta, tb = best[a], best[b]
                    # hash join
                    cost = ta.cost + tb.cost + cm.hash_join_cost(card)
                    if cand is None or cost < cand.cost:
                        cand = JoinTree("join", ss, card, cost, ta, tb, "hash")
                    # bind join: right side must be dispatchable as one
                    # subquery (a leaf — single star or exclusive group)
                    if tb.kind == "leaf" and tb.sources:
                        bcost = ta.cost + cm.bind_join_cost(ta.cardinality, card, tb.sources)
                        if bcost < cand.cost:
                            cand = JoinTree("join", ss, card, bcost, ta, tb, "bind")
            if cand is not None:
                prev = best.get(ss)
                if prev is None or cand.cost < prev.cost:
                    best[ss] = cand

    full = frozenset(range(n))
    if full in best:
        return best[full]
    # disconnected query: cartesian-combine components by ascending cardinality
    comps = _components(graph)
    trees = sorted((best[frozenset(c)] for c in comps), key=lambda t: t.cardinality)
    tree = trees[0]
    for t in trees[1:]:
        card = tree.cardinality * t.cardinality
        tree = JoinTree("join", tree.stars | t.stars, card,
                        tree.cost + t.cost + cm.intermediate_weight * card,
                        tree, t, "hash", None)
    return tree


def _components(graph: StarGraph) -> list[set[int]]:
    n = len(graph.stars)
    parent = list(range(n))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for e in graph.edges:
        a, b = find(e.src), find(e.dst)
        if a != b:
            parent[a] = b
    comps: dict[int, set[int]] = {}
    for i in range(n):
        comps.setdefault(find(i), set()).add(i)
    return list(comps.values())
