"""Characteristic Pairs (paper §3.1 "Arbitrary Queries", after [8, 10]).

A CP ``(C_i, C_j, p)`` counts the links via predicate ``p`` from entities with
CS ``C_i`` to entities with CS ``C_j`` — ``count(C_i, C_j, p)`` is the number
of (subject, object) pairs, i.e. of triples, connecting the two CSs.

Intra-dataset CPs come from a single triple table; *federated* CPs (across
datasets) are produced by ``repro.core.federation`` (Algorithm 1) and share the
same ``CPStats`` container so cardinality estimation (formulas 3/4) is
identical for both.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.characteristic_sets import CSStats
from repro.rdf.dataset import TripleTable


@dataclass
class CPStats:
    """Columnar CP statistics, sorted by (pred, cs1, cs2).

    ``cs1``/``cs2`` index into the CS spaces identified by ``src1``/``src2``
    (dataset ids; equal for intra-dataset CPs).
    """

    pred: np.ndarray    # (n_cp,) int32
    cs1: np.ndarray     # (n_cp,) int32 — subject-side CS
    cs2: np.ndarray     # (n_cp,) int32 — object-side CS
    count: np.ndarray   # (n_cp,) int64 — #links (entity pairs / triples)
    src1: int = 0
    src2: int = 0
    _card_cache: dict = field(default_factory=dict, repr=False)  # memoized formulas

    @property
    def n_cp(self) -> int:
        return len(self.pred)

    def with_pred(self, p: int) -> np.ndarray:
        lo, hi = np.searchsorted(self.pred, [p, p + 1])
        return np.arange(lo, hi)

    def select(self, p: int, rel1: np.ndarray, rel2: np.ndarray) -> np.ndarray:
        """Row indices with predicate ``p``, cs1 ∈ rel1, cs2 ∈ rel2."""
        rows = self.with_pred(p)
        if len(rows) == 0:
            return rows
        m = np.isin(self.cs1[rows], rel1) & np.isin(self.cs2[rows], rel2)
        return rows[m]

    def nbytes(self) -> int:
        return int(self.pred.nbytes + self.cs1.nbytes + self.cs2.nbytes + self.count.nbytes)

    def retag(self, src1: int, src2: int) -> "CPStats":
        """Renumber the source tags (statistics-lifecycle source removal).
        The CS indices and counts are untouched, so the memoized-formula
        cache — keyed only on predicate sets — stays valid."""
        self.src1 = src1
        self.src2 = src2
        return self

    def invalidate_caches(self) -> None:
        self._card_cache.clear()

    @staticmethod
    def from_rows(pred: np.ndarray, cs1: np.ndarray, cs2: np.ndarray, count: np.ndarray,
                  src1: int = 0, src2: int = 0) -> "CPStats":
        pred = np.asarray(pred, np.int32)
        cs1 = np.asarray(cs1, np.int32)
        cs2 = np.asarray(cs2, np.int32)
        count = np.asarray(count, np.int64)
        order = np.lexsort((cs2, cs1, pred))
        return CPStats(pred[order], cs1[order], cs2[order], count[order], src1, src2)


def compute_characteristic_pairs(table: TripleTable, cs: CSStats, src: int = 0) -> CPStats:
    """Intra-dataset CPs: aggregate triples whose subject *and* object are
    entities (subjects) of the dataset, keyed by (pred, cs(s), cs(o))."""
    c1 = cs.cs_of_entities(table.s)
    c2 = cs.cs_of_entities(table.o)
    ok = (c1 >= 0) & (c2 >= 0)
    if not ok.any():
        e = np.zeros(0, np.int32)
        return CPStats(e, e.copy(), e.copy(), np.zeros(0, np.int64), src, src)
    p = table.p[ok].astype(np.int64)
    a = c1[ok].astype(np.int64)
    b = c2[ok].astype(np.int64)
    n_cs = max(1, cs.n_cs)
    key = (p * n_cs + a) * n_cs + b
    uk, cnt = np.unique(key, return_counts=True)
    b_ = uk % n_cs
    a_ = (uk // n_cs) % n_cs
    p_ = uk // (n_cs * n_cs)
    return CPStats.from_rows(p_, a_, b_, cnt, src, src)
