"""Query decomposition (paper §3.4 step i): BGP -> star-shaped subqueries.

Stars group triple patterns by subject (footnote 3); links between stars are
object->subject variable chains described by CPs. Other shared variables
(e.g. object-object joins) become generic edges with fallback selectivity —
the paper notes the same principles apply to those join types.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.query.algebra import BGPQuery, Const, TriplePattern, Var


@dataclass
class Star:
    idx: int
    subject: object                     # Var | Const
    patterns: list[TriplePattern]

    def bound_preds(self) -> list[int]:
        return [tp.p.tid for tp in self.patterns if isinstance(tp.p, Const)]

    @property
    def has_var_pred(self) -> bool:
        return any(isinstance(tp.p, Var) for tp in self.patterns)

    def variables(self) -> frozenset[str]:
        out: frozenset[str] = frozenset()
        for tp in self.patterns:
            out |= tp.variables()
        return out


@dataclass
class Edge:
    """star ``src`` --pred--> star ``dst`` (pattern's object is dst's subject
    variable). ``pred`` is None for variable predicates; ``generic`` edges are
    shared-variable joins that are not object->subject chains."""

    src: int
    dst: int
    pred: int | None
    pattern: TriplePattern | None
    generic: bool = False
    var: str | None = None


@dataclass
class StarGraph:
    stars: list[Star]
    edges: list[Edge] = field(default_factory=list)
    query: BGPQuery | None = None

    def edges_of(self, i: int) -> list[Edge]:
        return [e for e in self.edges if e.src == i or e.dst == i]

    def connected(self, a: frozenset[int], b: frozenset[int]) -> list[Edge]:
        return [e for e in self.edges
                if (e.src in a and e.dst in b) or (e.src in b and e.dst in a)]

    def detach(self) -> "StarGraph":
        """Copy with fresh Star/Edge containers (terms/patterns are immutable
        and shared).  Plan-cache entries store and serve detached graphs so a
        caller mutating a plan's graph cannot corrupt later hits."""
        stars = [Star(s.idx, s.subject, list(s.patterns)) for s in self.stars]
        edges = [Edge(src=e.src, dst=e.dst, pred=e.pred, pattern=e.pattern,
                      generic=e.generic, var=e.var) for e in self.edges]
        return StarGraph(stars=stars, edges=edges, query=self.query)


def decompose(query: BGPQuery) -> StarGraph:
    return decompose_patterns(query.patterns, query)


def decompose_patterns(patterns: list[TriplePattern],
                       query: BGPQuery | None = None) -> StarGraph:
    """Star decomposition of one conjunctive pattern block.  ``decompose``
    is the whole-query form; the group-tree planner calls this per ``Bgp``
    block of the normalized algebra (each block is its own star graph)."""
    by_subject: dict[object, list[TriplePattern]] = {}
    for tp in patterns:
        key = tp.s  # Var/Const are frozen dataclasses -> hashable
        by_subject.setdefault(key, []).append(tp)
    stars = [Star(i, subj, pats) for i, (subj, pats) in enumerate(by_subject.items())]

    subj_var_of = {s.subject.name: s.idx for s in stars if isinstance(s.subject, Var)}
    edges: list[Edge] = []
    seen_obj_pairs: set[tuple[int, int, int | None]] = set()
    for s in stars:
        for tp in s.patterns:
            if isinstance(tp.o, Var) and tp.o.name in subj_var_of:
                j = subj_var_of[tp.o.name]
                if j != s.idx:
                    pred = tp.p.tid if isinstance(tp.p, Const) else None
                    edges.append(Edge(src=s.idx, dst=j, pred=pred, pattern=tp))
                    seen_obj_pairs.add((s.idx, j, pred))
    # generic shared-variable edges (object-object etc.)
    var_stars: dict[str, set[int]] = {}
    for s in stars:
        for tp in s.patterns:
            for t in (tp.o,):
                if isinstance(t, Var) and t.name not in subj_var_of:
                    var_stars.setdefault(t.name, set()).add(s.idx)
    for v, ss in var_stars.items():
        ss_l = sorted(ss)
        for i in range(len(ss_l)):
            for j in range(i + 1, len(ss_l)):
                edges.append(Edge(src=ss_l[i], dst=ss_l[j], pred=None, pattern=None,
                                  generic=True, var=v))
    return StarGraph(stars=stars, edges=edges, query=query)
