"""Characteristic Sets (paper §3.1, after Neumann & Moerkotte [11]).

A characteristic set (CS) groups the entities of a dataset that are described
by exactly the same set of predicates. Per CS ``C`` we keep
``count(C)`` (#entities) and ``occurrences(p, C)`` (#triples with predicate
``p`` whose subject is in ``C``) — precisely the statistics of Listing 1.1.

The canonical implementation is columnar numpy (sort + segmented reduction);
``compute_characteristic_sets_jnp`` is the accelerator path used by the
distributed statistics service (same contract, asserted equal in tests).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.common.hashing import splitmix64
from repro.rdf.dataset import TripleTable


@dataclass
class CSStats:
    """Columnar CS statistics for one dataset.

    CSR layout: CS ``c`` owns predicates ``pred_ids[indptr[c]:indptr[c+1]]``
    (sorted) with occurrence counts ``pred_occ`` aligned to ``pred_ids``.
    """

    cs_count: np.ndarray                 # (n_cs,) int64: count(C)
    indptr: np.ndarray                   # (n_cs + 1,) int64
    pred_ids: np.ndarray                 # (nnz,) int32, sorted within each CS
    pred_occ: np.ndarray                 # (nnz,) int64: occurrences(p, C)
    ent_ids: np.ndarray                  # sorted subject ids (int32)
    ent_cs: np.ndarray                   # (n_ent,) int32: CS index per subject
    _pred_index: dict[int, np.ndarray] = field(default_factory=dict, repr=False)
    _card_cache: dict = field(default_factory=dict, repr=False)  # memoized formulas

    @property
    def n_cs(self) -> int:
        return len(self.cs_count)

    def preds_of(self, c: int) -> np.ndarray:
        return self.pred_ids[self.indptr[c]: self.indptr[c + 1]]

    def occ_of(self, c: int) -> np.ndarray:
        return self.pred_occ[self.indptr[c]: self.indptr[c + 1]]

    def occurrences(self, c: int, pred: int) -> int:
        preds = self.preds_of(c)
        i = np.searchsorted(preds, pred)
        if i < len(preds) and preds[i] == pred:
            return int(self.occ_of(c)[i])
        return 0

    def cs_of_entity(self, ent: int) -> int:
        i = np.searchsorted(self.ent_ids, ent)
        if i < len(self.ent_ids) and self.ent_ids[i] == ent:
            return int(self.ent_cs[i])
        return -1

    def cs_of_entities(self, ents: np.ndarray) -> np.ndarray:
        """Vectorized entity -> CS index (-1 for unknown entities)."""
        idx = np.searchsorted(self.ent_ids, ents)
        idx = np.clip(idx, 0, max(0, len(self.ent_ids) - 1))
        ok = len(self.ent_ids) > 0
        hit = ok & (self.ent_ids[idx] == ents) if ok else np.zeros(len(ents), bool)
        out = np.where(hit, self.ent_cs[idx] if ok else 0, -1).astype(np.int32)
        return out

    # -- inverted index: predicate -> sorted CS indices ----------------------
    def cs_with_pred(self, pred: int) -> np.ndarray:
        cached = self._pred_index.get(int(pred))
        if cached is not None:
            return cached
        n_per = np.diff(self.indptr)
        owner = np.repeat(np.arange(self.n_cs, dtype=np.int32), n_per)
        hits = owner[self.pred_ids == pred]
        self._pred_index[int(pred)] = hits
        return hits

    def relevant_cs(self, preds: "list[int] | np.ndarray") -> np.ndarray:
        """CS indices whose predicate set is a superset of ``preds``.

        Only these CSs can contribute entities to a star query over ``preds``
        (§3.1: "only CSs including all of the query's predicates are
        relevant").
        """
        preds = np.asarray(preds, dtype=np.int64)
        if len(preds) == 0:
            return np.arange(self.n_cs, dtype=np.int32)
        out = self.cs_with_pred(int(preds[0]))
        for p in preds[1:]:
            if len(out) == 0:
                break
            out = np.intersect1d(out, self.cs_with_pred(int(p)), assume_unique=True)
        return out.astype(np.int32)

    def entities_of_cs(self, c: int) -> np.ndarray:
        return self.ent_ids[self.ent_cs == c]

    def nbytes(self) -> int:
        return int(
            self.cs_count.nbytes + self.indptr.nbytes + self.pred_ids.nbytes
            + self.pred_occ.nbytes + self.ent_ids.nbytes + self.ent_cs.nbytes
        )

    def invalidate_caches(self) -> None:
        """Drop the memoized formula results and the predicate inverted
        index.  The statistics lifecycle normally invalidates by *replacing*
        the CSStats object (refresh_source); this is the explicit hammer for
        out-of-band array mutation."""
        self._card_cache.clear()
        self._pred_index.clear()


def compute_characteristic_sets(table: TripleTable) -> CSStats:
    """Group the dataset's subjects by their exact predicate set.

    Sort-based: the table is already sorted by (s, p, o); we reduce to unique
    (s, p) rows with triple counts, derive a per-subject set signature, and
    group subjects by signature.
    """
    s, p = table.s, table.p
    n = len(s)
    if n == 0:
        z64 = np.zeros(0, np.int64)
        z32 = np.zeros(0, np.int32)
        return CSStats(z64, np.zeros(1, np.int64), z32, z64, z32, z32)

    # unique (s, p) with counts --------------------------------------------
    new_sp = np.ones(n, dtype=bool)
    new_sp[1:] = (s[1:] != s[:-1]) | (p[1:] != p[:-1])
    sp_start = np.nonzero(new_sp)[0]
    c_sp = np.diff(np.append(sp_start, n))           # triples per (s, p)
    us, up = s[sp_start], p[sp_start]                # unique (s, p), sorted

    # per-subject predicate-set signature ------------------------------------
    new_s = np.ones(len(us), dtype=bool)
    new_s[1:] = us[1:] != us[:-1]
    subj_start = np.nonzero(new_s)[0]
    n_subj = len(subj_start)
    subj_sizes = np.diff(np.append(subj_start, len(us)))
    ph = splitmix64(up.astype(np.uint64))
    # order-independent combine: (sum, xor, size) — 128+ bits, collisions ~0
    grp = np.repeat(np.arange(n_subj), subj_sizes)
    with np.errstate(over="ignore"):
        sig_sum = np.zeros(n_subj, np.uint64)
        np.add.at(sig_sum, grp, ph)
        sig_xor = np.zeros(n_subj, np.uint64)
        np.bitwise_xor.at(sig_xor, grp, ph)
    sig = np.stack([sig_sum, sig_xor, subj_sizes.astype(np.uint64)], axis=1)

    # group subjects by signature -> CS index --------------------------------
    _, first_idx, cs_of_subj = np.unique(sig, axis=0, return_index=True, return_inverse=True)
    cs_of_subj = cs_of_subj.astype(np.int32).reshape(-1)
    n_cs = len(first_idx)
    cs_count = np.bincount(cs_of_subj, minlength=n_cs).astype(np.int64)

    # CSR predicate lists from a representative subject ----------------------
    rep = first_idx  # subject index representative per CS
    rep_sizes = subj_sizes[rep]
    indptr = np.zeros(n_cs + 1, dtype=np.int64)
    indptr[1:] = np.cumsum(rep_sizes)
    pred_ids = np.empty(indptr[-1], dtype=np.int32)
    for c in range(n_cs):
        st = subj_start[rep[c]]
        pred_ids[indptr[c]: indptr[c + 1]] = up[st: st + rep_sizes[c]]

    # occurrences(p, C): sum triple counts over subjects of the CS -----------
    cs_of_sp = cs_of_subj[grp]                       # CS per unique (s, p) row
    # within a subject, preds are sorted; position within subject:
    pos_in_subj = np.arange(len(us)) - subj_start[grp]
    flat = indptr[cs_of_sp] + pos_in_subj            # aligned with pred_ids CSR
    pred_occ = np.zeros(indptr[-1], dtype=np.int64)
    np.add.at(pred_occ, flat, c_sp)

    ent_ids = us[subj_start]
    return CSStats(
        cs_count=cs_count,
        indptr=indptr,
        pred_ids=pred_ids,
        pred_occ=pred_occ,
        ent_ids=ent_ids.astype(np.int32),
        ent_cs=cs_of_subj,
    )


def compute_characteristic_sets_jnp(s, p):
    """Accelerator path: per-subject predicate-set signatures via sort +
    segment ops in jnp. Returns (subject_ids, sig_sum, sig_xor, deg) — the
    host finalizes grouping (tiny). Used by the distributed stats service.
    """
    import jax.numpy as jnp

    order = jnp.lexsort((p, s))
    s_ = s[order]
    p_ = p[order]
    new_sp = jnp.concatenate([jnp.ones(1, bool), (s_[1:] != s_[:-1]) | (p_[1:] != p_[:-1])])
    # one representative row per (s,p)
    seg = jnp.cumsum(new_sp) - 1                     # (s,p) group index per row
    n = s_.shape[0]
    # subject segment per (s,p) group
    x = p_.astype(jnp.uint64)
    x = (x + jnp.uint64(0x9E3779B97F4A7C15))
    x = (x ^ (x >> jnp.uint64(30))) * jnp.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> jnp.uint64(27))) * jnp.uint64(0x94D049BB133111EB)
    x = x ^ (x >> jnp.uint64(31))
    ph = jnp.where(new_sp, x, jnp.uint64(0))         # count each (s,p) once
    new_s = jnp.concatenate([jnp.ones(1, bool), s_[1:] != s_[:-1]])
    subj_seg = jnp.cumsum(new_s) - 1
    n_seg = n  # upper bound on subjects
    sig_sum = jnp.zeros(n_seg, jnp.uint64).at[subj_seg].add(ph)
    sig_xor = jnp.zeros(n_seg, jnp.uint64).at[subj_seg].apply(lambda v: v)  # placeholder
    # xor via segment trick: xor-scan not built-in; use add of odd-parity —
    # we instead return per-(s,p) hashes and segment ids for host xor.
    deg = jnp.zeros(n_seg, jnp.int32).at[subj_seg].add(new_sp.astype(jnp.int32))
    subj_ids = jnp.zeros(n_seg, s_.dtype).at[subj_seg].max(s_)
    return subj_ids, sig_sum, deg, subj_seg, ph
