"""Cost model (paper §3.4): intermediate-result cardinalities + transfer.

"In our current implementation, the cost function is solely defined on the
cardinalities of intermediate results and how many results need to be
transferred between endpoints during execution." — we implement exactly that,
with the endpoint-characteristics extension point the paper mentions
(per-source weight multipliers).

Each formula exists in three forms: the scalar form used when costing a
single plan node, a vectorized form (``*_v``) over numpy arrays used by the
bitmask DP to cost every candidate partition of a subset at once, and a
broadcasting jax form (``*_jnp``) used by the on-device layer sweep
(``repro.kernels.dp_layer``, ``dp_backend='jax'``).  The vectorized and jax
forms keep the exact operation order of the scalar ones — the same
additions and multiplications, associated the same way — so all paths
produce bit-identical float64 costs for the same inputs.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# cross-*block* join selectivity: blocks (conjunctive cores of the group
# tree) join on shared variables the CS/CP statistics do not describe, so
# each shared variable contributes this generic factor — the same fallback
# ``repro.core.join_order`` uses for non object->subject edges inside a BGP
CROSS_BLOCK_SELECTIVITY = 1e-3

# filter selectivity priors (System-R style): equality is selective,
# inequality keeps about a third, disequality drops almost nothing
FILTER_EQ_SELECTIVITY = 0.1
FILTER_NEQ_SELECTIVITY = 0.9
FILTER_RANGE_SELECTIVITY = 1.0 / 3.0


@dataclass
class CostModel:
    intermediate_weight: float = 1.0
    transfer_weight: float = 1.0
    request_cost: float = 5.0           # per subquery dispatched
    bind_batch: int = 20                # bindings shipped per bind-join request
    source_weight: dict[int, float] = field(default_factory=dict)  # endpoint tuning

    def src_w(self, sources: "list[int]") -> float:
        # empty `sources` (a star pruned to zero endpoints) weighs 1.0, like
        # an unknown id — leaf costing must not crash on an unsatisfiable
        # star just because per-endpoint weights are configured
        if not self.source_weight or not sources:
            return 1.0
        return max(self.source_weight.get(s, 1.0) for s in sources)

    def leaf_cost(self, card: float, sources: "list[int]") -> float:
        """Evaluate a (possibly merged/exclusive) subquery at its endpoints and
        ship the result rows to the engine."""
        return (self.transfer_weight * card * self.src_w(sources)
                + self.request_cost * max(1, len(sources)))

    def hash_join_cost(self, card_out: float) -> float:
        """Both inputs are already at the engine (their own costs cover the
        shipping); the join itself only materializes intermediates."""
        return self.intermediate_weight * card_out

    def bind_join_cost(self, card_left: float, card_out: float,
                       right_sources: "list[int]") -> float:
        """Ship the left bindings to the right subquery's endpoints in batches
        and receive only the matching rows — replaces the right leaf's cost."""
        n_req = max(1.0, card_left / self.bind_batch) * max(1, len(right_sources))
        return (self.request_cost * n_req
                + self.transfer_weight * card_out * self.src_w(right_sources)
                + self.intermediate_weight * card_out)

    # -- group-tree composition (OPTIONAL / UNION / FILTER plan nodes) -------
    # Blocks (conjunctive cores) are priced by the DP above; these forms
    # compose block estimates through the non-conjunctive operators so the
    # extended plans stay measurable end to end (docs/algebra.md).

    def cross_join_card(self, card_a: float, card_b: float,
                        n_shared_vars: int) -> float:
        """Cardinality of joining two planned blocks: independence times a
        generic per-shared-variable selectivity (cartesian when disjoint)."""
        sel = CROSS_BLOCK_SELECTIVITY ** n_shared_vars
        return card_a * card_b * sel

    def left_join_card(self, card_left: float, card_join: float) -> float:
        """OPTIONAL output estimate: the join estimate plus the unmatched-left
        surplus — every left row survives, matched or not."""
        return card_join + max(0.0, card_left - card_join)

    def union_card(self, cards: "list[float]") -> float:
        """UNION output estimate: branches are disjoint alternatives."""
        return float(sum(cards))

    def filter_selectivity(self, expr) -> float:
        """Selectivity prior of a filter expression (recursive over the
        ``repro.query.algebra`` Expr tree; conjunction multiplies, disjunction
        is inclusion-exclusion under independence, negation complements)."""
        from repro.query.algebra import And, Comparison, Not, Or

        if isinstance(expr, Comparison):
            if expr.op == "=":
                return FILTER_EQ_SELECTIVITY
            if expr.op == "!=":
                return FILTER_NEQ_SELECTIVITY
            return FILTER_RANGE_SELECTIVITY
        if isinstance(expr, And):
            s = 1.0
            for p in expr.parts:
                s *= self.filter_selectivity(p)
            return s
        if isinstance(expr, Or):
            s = 1.0
            for p in expr.parts:
                s *= 1.0 - self.filter_selectivity(p)
            return 1.0 - s
        assert isinstance(expr, Not)
        return 1.0 - self.filter_selectivity(expr.part)

    def left_join_cost(self, card_out: float) -> float:
        """Both inputs already costed; the outer join materializes the
        matched-plus-surplus output like a hash join does."""
        return self.intermediate_weight * card_out

    def union_cost(self, card_out: float) -> float:
        return self.intermediate_weight * card_out

    def filter_cost(self, card_out: float) -> float:
        return self.intermediate_weight * card_out

    # -- vectorized forms (arrays of candidates at once) ---------------------

    def leaf_cost_v(self, card: np.ndarray, n_src: np.ndarray,
                    src_w: np.ndarray | float) -> np.ndarray:
        """``leaf_cost`` over arrays: ``card``/``n_src``/``src_w`` aligned."""
        return (self.transfer_weight * card * src_w
                + self.request_cost * np.maximum(1, n_src))

    def hash_join_cost_v(self, card_out: np.ndarray) -> np.ndarray:
        return self.intermediate_weight * card_out

    def bind_join_cost_v(self, card_left: np.ndarray, card_out: np.ndarray,
                         n_src: np.ndarray, src_w: np.ndarray | float) -> np.ndarray:
        """``bind_join_cost`` over candidate arrays; ``n_src`` must already be
        >= 1 (callers mask out source-less right sides)."""
        n_req = np.maximum(1.0, card_left / self.bind_batch) * n_src
        return (self.request_cost * n_req
                + self.transfer_weight * card_out * src_w
                + self.intermediate_weight * card_out)

    def join_candidates_v(self, cost_a: np.ndarray, cost_b: np.ndarray,
                          card_out: np.ndarray, hash_out: np.ndarray,
                          card_a: np.ndarray, n_src_b: np.ndarray,
                          src_w_b: np.ndarray,
                          bindable_b: np.ndarray) -> "tuple[np.ndarray, np.ndarray]":
        """Blockwise candidate costing for one flat tile of (A, B) partition
        pairs: the hash-join cost of every pair, replaced by the bind-join
        alternative where the right side is dispatchable as one subquery and
        strictly cheaper.  Returns ``(cost, is_bind)``; hash wins ties
        because the reference enumerates hash before bind.  ``hash_out`` is
        ``hash_join_cost_v(card_out)``, precomputed once per subset so tiles
        share it; operation order matches the scalar forms exactly."""
        hc = cost_a + cost_b
        hc = hc + hash_out
        bc = cost_a + self.bind_join_cost_v(card_a, card_out, n_src_b, src_w_b)
        is_bind = bindable_b & (bc < hc)
        return np.where(is_bind, bc, hc), is_bind

    # -- jax twins (broadcasting; used by the on-device layer sweep) ---------
    # jax is imported lazily so the numpy planning path never pays for it;
    # callers (repro.kernels.dp_layer) run under jax.experimental.enable_x64
    # so every formula evaluates in float64, exactly like the numpy forms.

    def leaf_cost_jnp(self, card, n_src, src_w):
        import jax.numpy as jnp

        return (self.transfer_weight * card * src_w
                + self.request_cost * jnp.maximum(1, n_src))

    def hash_join_cost_jnp(self, card_out):
        return self.intermediate_weight * card_out

    def bind_join_cost_jnp(self, card_left, card_out, n_src, src_w):
        import jax.numpy as jnp

        n_req = jnp.maximum(1.0, card_left / self.bind_batch) * n_src
        return (self.request_cost * n_req
                + self.transfer_weight * card_out * src_w
                + self.intermediate_weight * card_out)

    def join_candidates_jnp(self, cost_a, cost_b, card_out, hash_out,
                            card_a, n_src_b, src_w_b, bindable_b):
        """``join_candidates_v`` over jax arrays with the same operation
        order; operands may broadcast (the layer kernel passes per-column
        ``card_out``/``hash_out`` against per-pair blocks)."""
        import jax.numpy as jnp

        hc = cost_a + cost_b
        hc = hc + hash_out
        bc = cost_a + self.bind_join_cost_jnp(card_a, card_out, n_src_b, src_w_b)
        is_bind = bindable_b & (bc < hc)
        return jnp.where(is_bind, bc, hc), is_bind

    @staticmethod
    def join_candidates_params_jnp(params, cost_a, cost_b, card_out,
                                   card_a, n_src_b, src_w_b, bindable_b):
        """The fused form of ``join_candidates_jnp`` used by the on-device
        sweep programs: the cost-model parameters arrive as a traced ``(4,)``
        array ``(intermediate_weight, transfer_weight, request_cost,
        bind_batch)`` instead of python closure constants, so one compiled
        program serves every ``CostModel`` — a parameter sweep never
        retraces.  The hash-join term is derived from ``card_out`` in place
        (``iw * card_out``, the same single multiply as
        ``hash_join_cost_v``), and every addition/multiplication associates
        exactly as in the scalar/``*_v`` forms, so costs stay bit-identical
        to the numpy path under x64."""
        import jax.numpy as jnp

        iw, tw, rc, bb = params[0], params[1], params[2], params[3]
        hc = cost_a + cost_b
        hc = hc + iw * card_out
        n_req = jnp.maximum(1.0, card_a / bb) * n_src_b
        bc = cost_a + ((rc * n_req + tw * card_out * src_w_b)
                       + iw * card_out)
        is_bind = bindable_b & (bc < hc)
        return jnp.where(is_bind, bc, hc), is_bind


def estimation_error(est: float, obs: float) -> float:
    """Symmetric log-scale q-error between an estimated and an observed
    cardinality: ``|log2((obs + 1) / (est + 1))|``.  The ``+1`` keeps zero
    cardinalities finite, and the log makes over- and under-estimation by the
    same factor score identically — an error of 1.0 means "off by 2x", 2.0
    means "off by 4x".  This is the score ``repro.stats.feedback`` averages
    per source to decide when observed executions have drifted far enough
    from the statistics to warrant a ``refresh_source``."""
    return abs(float(np.log2((float(obs) + 1.0) / (float(est) + 1.0))))
