"""Cost model (paper §3.4): intermediate-result cardinalities + transfer.

"In our current implementation, the cost function is solely defined on the
cardinalities of intermediate results and how many results need to be
transferred between endpoints during execution." — we implement exactly that,
with the endpoint-characteristics extension point the paper mentions
(per-source weight multipliers).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class CostModel:
    intermediate_weight: float = 1.0
    transfer_weight: float = 1.0
    request_cost: float = 5.0           # per subquery dispatched
    bind_batch: int = 20                # bindings shipped per bind-join request
    source_weight: dict[int, float] = field(default_factory=dict)  # endpoint tuning

    def src_w(self, sources: "list[int]") -> float:
        if not self.source_weight:
            return 1.0
        return max(self.source_weight.get(s, 1.0) for s in sources)

    def leaf_cost(self, card: float, sources: "list[int]") -> float:
        """Evaluate a (possibly merged/exclusive) subquery at its endpoints and
        ship the result rows to the engine."""
        return (self.transfer_weight * card * self.src_w(sources)
                + self.request_cost * max(1, len(sources)))

    def hash_join_cost(self, card_out: float) -> float:
        """Both inputs are already at the engine (their own costs cover the
        shipping); the join itself only materializes intermediates."""
        return self.intermediate_weight * card_out

    def bind_join_cost(self, card_left: float, card_out: float,
                       right_sources: "list[int]") -> float:
        """Ship the left bindings to the right subquery's endpoints in batches
        and receive only the matching rows — replaces the right leaf's cost."""
        n_req = max(1.0, card_left / self.bind_batch) * max(1, len(right_sources))
        return (self.request_cost * n_req
                + self.transfer_weight * card_out * self.src_w(right_sources)
                + self.intermediate_weight * card_out)
