# The paper's primary contribution: Odyssey's federated statistics
# (characteristic sets/pairs, entity summaries, Algorithm 1) and the
# cost-based federated query optimizer built on them.
from repro.core.characteristic_sets import CSStats, compute_characteristic_sets
from repro.core.characteristic_pairs import CPStats, compute_characteristic_pairs

__all__ = [
    "CSStats",
    "compute_characteristic_sets",
    "CPStats",
    "compute_characteristic_pairs",
]
