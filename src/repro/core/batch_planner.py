"""Truly batched query planning — the serving path behind
``OdysseyOptimizer.optimize_batch``.

A batch is planned as one pipeline over all of its queries instead of a loop
of independent ``optimize()`` calls:

1. **Epoch snapshot.**  The statistics epoch is read exactly once; every
   plan emitted by the batch is stamped with it and every cache entry is
   keyed under it.  A ``remove_source``/``refresh_source`` landing mid-batch
   can therefore never split the batch across epochs — the whole batch is
   planned "as of" the snapshot, and the epoch bump makes its cache entries
   lazily stale, exactly like a plan cached just before the mutation.
2. **Plan-cache hits.**  Each query's ``query_signature`` is looked up under
   the snapshot epoch; hits are rebound per query as in ``optimize``.
3. **Exact-signature dedupe.**  Later queries with a signature already being
   planned in this batch are rebound from the first member's plan and marked
   ``cached=True`` — a duplicate is a hit whether the entry lives in the
   ``PlanCache`` or only in the batch (the cache-off path behaves the same).
4. **Shape grouping.**  The remaining queries are decomposed up front and
   grouped by *structural shape*: star-graph topology
   (``star_graph_topology`` — star count + ordered edge list), per-star
   predicate signatures, and the DISTINCT flag.  Object constants are
   deliberately not part of the shape, so every instantiation of a query
   template lands in one group.
5. **Shared source selection.**  ``select_sources_batch`` runs over the
   union of the fresh queries' graphs with one ``SelectionMemo``: per-star
   relevant-CS scans, federated-CS candidates and CP edge probes are priced
   once for the batch, and graphs with equal selection keys share one
   pruning fixpoint.
6. **One DP sweep per shape.**  ``dp_join_order_batch`` runs the tiled
   bitmask-DP layer sweep once per group, with the per-layer candidate
   tensors stacked along the member axis; each member's tree is
   bit-identical to planning it alone.
7. **Emit + cache.**  Plans are emitted per member, stamped with the epoch
   snapshot, and inserted into the plan cache under their own signatures.

**Equivalence guarantee.**  Every stage either reuses the single-query code
(``query_signature``, ``_rebind``, ``_emit``) or is differentially held to
bit-identity with it (``select_sources_batch`` vs ``select_sources``,
``dp_join_order_batch`` vs ``dp_join_order``), so
``optimize_batch(queries)`` returns, per query, exactly the plan
``[optimize(q) for q in queries]`` would — batching changes the planning
cost, never the plans.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Iterator

from repro.core.decomposition import StarGraph, decompose
from repro.core.join_order import (DP_SWEEP_COUNTERS, dp_join_order_batch,
                                   star_graph_topology)
from repro.core.source_selection import (
    SelectionMemo,
    select_sources_batch,
    selection_key,
)
from repro.query.algebra import BGPQuery, Const


@dataclass
class BatchPlanReport:
    """What a batch actually shared — attached to the optimizer as
    ``last_batch_report`` after every ``optimize_batch`` call."""

    n_queries: int = 0
    cache_hits: int = 0          # served from the PlanCache under the snapshot
    duplicates: int = 0          # exact-signature repeats rebound in-batch
    n_planned: int = 0           # queries that ran the full pipeline
    n_shapes: int = 0            # distinct shape groups among planned queries
    n_priced: int = 0            # distinct pricing keys (DP members actually swept)
    n_selections: int = 0        # distinct selection fixpoints actually run
    dp_resident: int = 0         # sweeps run as one resident device program
    dp_tiled: int = 0            # jax sweeps that fell back to per-layer tiles
    stats_epoch: int = 0         # the single epoch snapshot
    total_ms: float = 0.0


def shape_key(graph: StarGraph, distinct: bool) -> tuple:
    """Structural shape of a query: star-graph topology (star count + ordered
    edge list), per-star predicate signatures (subject-constant flag + the
    ordered predicate list, ``None`` for variable predicates), and DISTINCT.
    Everything the DP sweep's *structure* depends on is in here; everything
    that only shifts the numbers (constants, selected sources, cardinalities)
    is deliberately out, so template instantiations share one sweep."""
    stars = tuple(
        (isinstance(s.subject, Const),
         tuple(tp.p.tid if isinstance(tp.p, Const) else None
               for tp in s.patterns))
        for s in graph.stars)
    return (star_graph_topology(graph), stars, bool(distinct))


def pricing_key(graph: StarGraph, distinct: bool) -> tuple:
    """Everything the planner's *numbers* depend on: the shape plus subject
    constants (they steer ``cs_of_entity`` relevance and the bounded-subject
    cardinality clamp) and which object positions hold constants.  Object
    constant *values* are deliberately absent: no CS/CP estimate conditions
    on them (``_bound_object_factor`` uses only the predicate's occurrence
    counts), so two queries with equal pricing keys get bit-identical
    selections, statistics, DP state and join trees — the batch prices such
    a family once and only re-emits per member.  If an estimate ever starts
    reading object values, they must join this key."""
    stars = tuple(
        (s.subject.tid if isinstance(s.subject, Const) else None,
         tuple((tp.p.tid if isinstance(tp.p, Const) else None,
                isinstance(tp.o, Const)) for tp in s.patterns))
        for s in graph.stars)
    return (star_graph_topology(graph), stars, bool(distinct))


# -- plan-sharing affinity, without planning ---------------------------------

AFFINITY_TIERS = ("signature", "selection", "pricing", "shape")


@dataclass(frozen=True)
class AffinityKey:
    """The four plan-sharing tiers of one query, deepest first — exactly the
    tiering ``plan_batch`` exploits, computed host-side from the query text
    alone (no statistics, no source selection, no DP).  Two queries that are
    equal at a tier share correspondingly more of the batched pipeline:

    - ``signature``: exact ``query_signature`` — duplicates/cache hits; the
      whole plan is shared (rebound per query).
    - ``selection``: one source-selection fixpoint for the group.
    - ``pricing``: bit-identical statistics, DP state and join tree; priced
      once, re-emitted per member.
    - ``shape``: one stacked DP sweep, per-member costing.

    ``selection``/``pricing``/``shape`` are ``None`` for non-conjunctive
    (group-tree) queries, which only share at the signature tier.
    """

    signature: tuple
    selection: "tuple | None"
    pricing: "tuple | None"
    shape: "tuple | None"

    def tier_keys(self) -> "Iterator[tuple[str, tuple]]":
        """(tier name, key) pairs, deepest tier first, skipping tiers this
        query does not participate in."""
        for name, key in zip(AFFINITY_TIERS, (self.signature, self.selection,
                                              self.pricing, self.shape)):
            if key is not None:
                yield name, key


def plan_affinity(query: BGPQuery) -> AffinityKey:
    """Affinity key of one query for admission-time batch formation (the
    serving scheduler groups queued requests whose keys match at the deepest
    possible tier).  Pure host-side structure: safe to call on every
    ``submit`` without touching statistics or the planner."""
    from repro.core.planner import query_signature

    sig, _ = query_signature(query)
    if not query.is_conjunctive():
        return AffinityKey(signature=sig, selection=None, pricing=None,
                           shape=None)
    graph = decompose(query)
    return AffinityKey(signature=sig,
                       selection=selection_key(graph),
                       pricing=pricing_key(graph, query.distinct),
                       shape=shape_key(graph, query.distinct))


def plan_batch(optimizer, queries: "list[BGPQuery]"):
    """The batched planning pipeline (see the module docstring).  Returns one
    ``PhysicalPlan`` per query, in order."""
    from repro.core.planner import CacheEntry, PhysicalPlan, _detach_plan, \
        query_signature

    t_start = time.perf_counter()
    epoch = optimizer.stats_epoch          # the one and only epoch read
    cache = optimizer.plan_cache
    report = BatchPlanReport(n_queries=len(queries), stats_epoch=epoch)
    dp_ctr0 = (DP_SWEEP_COUNTERS["resident"], DP_SWEEP_COUNTERS["tiled"])
    plans: "list[PhysicalPlan | None]" = [None] * len(queries)

    # -- cache hits + exact-signature dedupe --------------------------------
    sigs = [query_signature(q) for q in queries]
    owner: dict[tuple, int] = {}           # sig -> first fresh member
    dup_of: dict[int, int] = {}
    fresh: list[int] = []
    for i, q in enumerate(queries):
        sig, var_order = sigs[i]
        if sig in owner:                   # duplicate of a plan built below
            dup_of[i] = owner[sig]
            continue
        if cache is not None:
            t0 = time.perf_counter()
            entry = cache.get(sig, epoch=epoch)
            if entry is not None:
                plan = optimizer._rebind(entry, var_order, q)
                plan.optimization_ms = (time.perf_counter() - t0) * 1e3
                plans[i] = plan
                report.cache_hits += 1
                continue
        owner[sig] = i
        fresh.append(i)

    # -- decompose, group by shape, select sources over the union -----------
    local: dict[tuple, CacheEntry] = {}    # owner plans when the cache is off

    # Non-conjunctive (group-tree) queries bypass the stacked conjunctive
    # pipeline: each runs the compositional planner under the same epoch
    # snapshot and lands in the cache like any other owner, so duplicates of
    # an OPTIONAL/UNION/FILTER template still rebind below.
    alg = [i for i in fresh if not queries[i].is_conjunctive()]
    if alg:
        fresh = [i for i in fresh if queries[i].is_conjunctive()]
        for i in alg:
            t0 = time.perf_counter()
            plan = optimizer._optimize_uncached(queries[i], t0)
            plan.stats_epoch = epoch
            plans[i] = plan
            report.n_planned += 1
            sig, var_order = sigs[i]
            if cache is not None:
                cache.put(sig, plan, var_order, epoch=epoch)
            else:
                local[sig] = CacheEntry(_detach_plan(plan), var_order, epoch)

    if fresh:
        t_shared = time.perf_counter()
        graphs = {i: decompose(queries[i]) for i in fresh}
        memo = SelectionMemo()
        sels = dict(zip(fresh, select_sources_batch(
            [graphs[i] for i in fresh], optimizer.stats, memo=memo)))
        report.n_selections = len({selection_key(graphs[i]) for i in fresh})
        groups: dict[tuple, list[int]] = {}
        for i in fresh:
            groups.setdefault(shape_key(graphs[i], queries[i].distinct),
                              []).append(i)
        report.n_shapes = len(groups)
        shared_ms = (time.perf_counter() - t_shared) * 1e3

        # -- one stacked DP sweep per shape, then per-member emission -------
        for key, members in groups.items():
            # price once per distinct pricing key: members differing only in
            # object-constant values share every estimate, so they share one
            # DP member (and its warm statistics memo) and only re-emit
            t_g = time.perf_counter()
            sub: dict[tuple, list[int]] = {}
            for i in members:
                sub.setdefault(pricing_key(graphs[i], queries[i].distinct),
                               []).append(i)
            fams = list(sub.values())
            reps = [fam[0] for fam in fams]
            report.n_priced += len(reps)
            trees = dp_join_order_batch(
                [graphs[r] for r in reps], optimizer.stats,
                [sels[r] for r in reps], optimizer.cost_model,
                distinct=key[-1], block_bytes=optimizer.dp_block_bytes,
                dp_backend=optimizer.dp_backend)
            sweep_ms = (time.perf_counter() - t_g) * 1e3
            for fam, tree in zip(fams, trees):
                rep = fam[0]
                for i in fam:
                    t_e = time.perf_counter()
                    q = queries[i]
                    if i != rep:
                        # identical values by construction: reuse the rep's
                        # warm per-query memo so emission's §3.1 ordering
                        # re-reads instead of re-deriving the cardinalities
                        sels[i]._memo = sels[rep]._memo
                    root = optimizer._emit(tree, graphs[i], sels[i], q)
                    plan = PhysicalPlan(root=root, query=q, graph=graphs[i],
                                        selection=sels[i], stats_epoch=epoch)
                    plan.fallback = any(s.has_var_pred for s in graphs[i].stars)
                    # amortized attribution: the shared decompose+selection
                    # pass over all fresh queries, the group's sweep over its
                    # members, this member's own emission
                    plan.optimization_ms = (
                        shared_ms / len(fresh) + sweep_ms / len(members)
                        + (time.perf_counter() - t_e) * 1e3)
                    plans[i] = plan
                    report.n_planned += 1
                    sig, var_order = sigs[i]
                    if cache is not None:
                        cache.put(sig, plan, var_order, epoch=epoch)
                    else:
                        local[sig] = CacheEntry(_detach_plan(plan), var_order,
                                                epoch)

    # -- rebind exact duplicates: a duplicate is a hit (cached=True) either
    # way; with the cache on it goes through PlanCache.get so hit counters
    # and LRU order match the sequential loop --------------------------------
    for i, j in dup_of.items():
        q = queries[i]
        sig, var_order = sigs[i]
        t0 = time.perf_counter()
        entry = cache.get(sig, epoch=epoch) if cache is not None else local[sig]
        if entry is None:
            # the owner's entry was LRU-evicted within this batch (cache
            # smaller than the batch's distinct signatures): replan, exactly
            # as the sequential loop would on its miss
            plan = optimizer._optimize_uncached(q, t0)
            plan.stats_epoch = epoch
            cache.put(sig, plan, var_order, epoch=epoch)
            plans[i] = plan
            report.n_planned += 1
            continue
        plan = optimizer._rebind(entry, var_order, q)
        plan.optimization_ms = (time.perf_counter() - t0) * 1e3
        plans[i] = plan
        report.duplicates += 1

    report.dp_resident = DP_SWEEP_COUNTERS["resident"] - dp_ctr0[0]
    report.dp_tiled = DP_SWEEP_COUNTERS["tiled"] - dp_ctr0[1]
    report.total_ms = (time.perf_counter() - t_start) * 1e3
    optimizer.last_batch_report = report
    return plans
