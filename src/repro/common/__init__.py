from repro.common.hashing import fnv1a64, splitmix64
from repro.common.util import Timer, stable_unique

__all__ = ["fnv1a64", "splitmix64", "Timer", "stable_unique"]
