"""Deterministic hashing utilities shared by statistics and summaries.

Everything here is pure and reproducible across runs/processes (no PYTHONHASHSEED
dependence) — checkpointable statistics require stable ids.
"""
from __future__ import annotations

import numpy as np

_FNV_OFFSET = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)


def fnv1a64(data: bytes) -> int:
    """FNV-1a 64-bit hash of a byte string (used for term-dictionary ids)."""
    h = _FNV_OFFSET
    with np.errstate(over="ignore"):
        for b in data:
            h = np.uint64(h ^ np.uint64(b)) * _FNV_PRIME
    return int(h)


def fnv1a64_np(strings: list[str]) -> np.ndarray:
    """Vectorized-ish FNV-1a over a list of strings -> uint64 array."""
    out = np.empty(len(strings), dtype=np.uint64)
    for i, s in enumerate(strings):
        out[i] = fnv1a64(s.encode("utf-8"))
    return out


def splitmix64(x: np.ndarray) -> np.ndarray:
    """SplitMix64 finalizer — cheap, high-quality integer mixer.

    Used to hash integer entity ids into summary LSB space. Accepts/returns
    uint64 numpy arrays.
    """
    x = x.astype(np.uint64, copy=True)
    with np.errstate(over="ignore"):
        x += np.uint64(0x9E3779B97F4A7C15)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        x = x ^ (x >> np.uint64(31))
    return x


def mix_pair(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Order-sensitive hash combine of two uint64 arrays."""
    with np.errstate(over="ignore"):
        return splitmix64(a.astype(np.uint64) * np.uint64(0x9E3779B97F4A7C15) ^ splitmix64(b.astype(np.uint64)))
