from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np


@dataclass
class Timer:
    """Accumulating wall-clock timer: ``with timer: ...``; ``timer.total_s``."""

    total_s: float = 0.0
    count: int = 0
    _t0: float = field(default=0.0, repr=False)

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.total_s += time.perf_counter() - self._t0
        self.count += 1

    @property
    def mean_ms(self) -> float:
        return 1e3 * self.total_s / max(1, self.count)


def stable_unique(values: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """np.unique returning (unique_sorted, inverse) with int32 inverse."""
    uniq, inv = np.unique(values, return_inverse=True)
    return uniq, inv.astype(np.int32)


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def next_pow2(x: int) -> int:
    return 1 if x <= 1 else 1 << (x - 1).bit_length()
