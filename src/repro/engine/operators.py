"""Bounded-buffer relational operators in pure jnp (DESIGN.md D1).

Accelerators need static shapes, so every operator takes/returns fixed-
capacity relations:

    rel = (data: (CAP, NCOLS) int32, valid: (CAP,) bool, overflow: bool[])

Rows beyond the live count are zeroed and invalid. Overflow flags propagate so
the host can retry with a doubled capacity (the engine's fallback path).
Compaction uses stable sorts instead of gathers-with-dynamic-shapes; joins use
the counts/offsets construction that ``kernels/join_count`` accelerates.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def make_rel(cap: int, ncols: int):
    return (jnp.zeros((cap, ncols), jnp.int32), jnp.zeros(cap, bool), jnp.zeros((), bool))


def compact(mask: jax.Array, cap: int) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Indices of the first ``cap`` True rows (stable), their validity, and an
    overflow flag."""
    n = mask.shape[0]
    order = jnp.argsort(~mask, stable=True)
    if n >= cap:
        idx = order[:cap]
    else:
        idx = jnp.concatenate([order, jnp.zeros(cap - n, order.dtype)])
    total = jnp.sum(mask)
    valid = jnp.arange(cap) < jnp.minimum(total, n)
    return idx, valid, total > cap


@partial(jax.jit, static_argnames=("cap", "out_cols"))
def scan_pattern(table: jax.Array, trow: jax.Array, pattern: jax.Array,
                 cap: int, out_cols: tuple[int, ...]) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Match (s, p, o) with -1 wildcards against table (N, 3) rows (invalid
    rows marked by ``trow`` False). Returns bounded relation over the columns
    in ``out_cols`` (subset of (0, 1, 2))."""
    s, p, o = pattern[0], pattern[1], pattern[2]
    m = trow
    m &= (s < 0) | (table[:, 0] == s)
    m &= (p < 0) | (table[:, 1] == p)
    m &= (o < 0) | (table[:, 2] == o)
    idx, valid, ovf = compact(m, cap)
    data = table[idx][:, list(out_cols)]
    data = jnp.where(valid[:, None], data, 0)
    return data, valid, ovf


@partial(jax.jit, static_argnames=("cap",))
def semi_bind(rel: jax.Array, valid: jax.Array, keys: jax.Array, kvalid: jax.Array,
              key_col: int, cap: int):
    """Bind-join filter: keep rel rows whose ``key_col`` appears in ``keys``
    (the shipped bindings). Mirrors dispatching a subquery with VALUES."""
    eq = (rel[:, key_col][:, None] == keys[None, :]) & kvalid[None, :]
    m = valid & eq.any(axis=1)
    idx, v, ovf = compact(m, cap)
    return jnp.where(v[:, None], rel[idx], 0), v, ovf


@partial(jax.jit, static_argnames=("cap",))
def merge_join(left: jax.Array, lvalid: jax.Array, lkey: int,
               right: jax.Array, rvalid: jax.Array, rkey: int,
               cap: int):
    """Inner join on one key column with bounded output.

    Sorts the right side by key, computes per-left-row match counts and
    offsets, then materializes output row ``t`` by locating its (left row,
    match rank) via searchsorted on the cumulative counts — no dynamic shapes.
    Output columns: left cols ++ right cols (join key duplicated).
    """
    L = left.shape[0]
    # sort right by key; invalid rows to the end with key = INT32_MAX
    BIG = jnp.int32(2**31 - 1)
    rk = jnp.where(rvalid, right[:, rkey], BIG)
    order = jnp.argsort(rk, stable=True)
    right_s = right[order]
    rvalid_s = rvalid[order]
    rk_s = rk[order]

    lk = jnp.where(lvalid, left[:, lkey], BIG - 1)
    start = jnp.searchsorted(rk_s, lk, side="left")
    end = jnp.searchsorted(rk_s, lk, side="right")
    counts = jnp.where(lvalid, end - start, 0)
    offsets = jnp.cumsum(counts)
    total = offsets[-1]

    t = jnp.arange(cap)
    li = jnp.searchsorted(offsets, t, side="right")
    li_c = jnp.clip(li, 0, L - 1)
    prev = jnp.where(li_c > 0, offsets[li_c - 1], 0)
    rank = t - prev
    ri = jnp.clip(start[li_c] + rank, 0, right.shape[0] - 1)
    valid = (t < total) & lvalid[li_c] & rvalid_s[ri]
    data = jnp.concatenate([left[li_c], right_s[ri]], axis=1)
    data = jnp.where(valid[:, None], data, 0)
    return data, valid, total > cap


@partial(jax.jit, static_argnames=("cap",))
def distinct(rel: jax.Array, valid: jax.Array, cap: int):
    """Sort rows lexicographically and keep first occurrences."""
    keys = [rel[:, c] for c in range(rel.shape[1] - 1, -1, -1)]
    keys.append(~valid)  # invalid rows last  (most significant)
    order = jnp.lexsort(keys[::-1])
    r = rel[order]
    v = valid[order]
    first = jnp.ones(rel.shape[0], bool)
    same = jnp.all(r[1:] == r[:-1], axis=1) & v[1:] & v[:-1]
    first = first.at[1:].set(~same)
    m = v & first
    idx, vv, ovf = compact(m, cap)
    return jnp.where(vv[:, None], r[idx], 0), vv, ovf


@jax.jit
def count_valid(valid: jax.Array) -> jax.Array:
    return jnp.sum(valid.astype(jnp.int32))


# --------------------------------------------------------------------------
# Group-algebra operators (OPTIONAL / UNION / FILTER, docs/algebra.md)
# --------------------------------------------------------------------------

# unbound marker inside int32 columns (mirrors repro.engine.local.UNDEF)
UNDEF = -1

OP_CODES = {"=": 0, "!=": 1, "<": 2, "<=": 3, ">": 4, ">=": 5}


@partial(jax.jit, static_argnames=("cap",))
def left_merge_join(left: jax.Array, lvalid: jax.Array, lkey: int,
                    right: jax.Array, rvalid: jax.Array, rkey: int,
                    cap: int):
    """OPTIONAL on one key column with bounded output: ``merge_join`` plus
    one pad row per unmatched valid left row, right columns set to UNDEF.
    Output columns: left cols ++ right cols, like ``merge_join``."""
    L = left.shape[0]
    BIG = jnp.int32(2**31 - 1)
    rk = jnp.where(rvalid, right[:, rkey], BIG)
    order = jnp.argsort(rk, stable=True)
    right_s = right[order]
    rk_s = rk[order]

    lk = jnp.where(lvalid, left[:, lkey], BIG - 1)
    start = jnp.searchsorted(rk_s, lk, side="left")
    end = jnp.searchsorted(rk_s, lk, side="right")
    counts = jnp.where(lvalid, end - start, 0)
    # every valid left row emits max(matches, 1) rows
    outcnt = jnp.where(lvalid, jnp.maximum(counts, 1), 0)
    offsets = jnp.cumsum(outcnt)
    total = offsets[-1]

    t = jnp.arange(cap)
    li = jnp.searchsorted(offsets, t, side="right")
    li_c = jnp.clip(li, 0, L - 1)
    prev = jnp.where(li_c > 0, offsets[li_c - 1], 0)
    rank = t - prev
    matched = counts[li_c] > 0
    ri = jnp.clip(start[li_c] + rank, 0, right.shape[0] - 1)
    valid = (t < total) & lvalid[li_c]
    rdata = jnp.where(matched[:, None], right_s[ri], jnp.int32(UNDEF))
    data = jnp.concatenate([left[li_c], rdata], axis=1)
    data = jnp.where(valid[:, None], data, 0)
    return data, valid, total > cap


@partial(jax.jit, static_argnames=("col_map",))
def align_columns(rel: jax.Array, valid: jax.Array, col_map: tuple[int, ...]):
    """Schema alignment before ``union_rels``: output column j is input
    column ``col_map[j]``, or UNDEF where ``col_map[j] < 0`` (the variable is
    absent from this branch)."""
    cols = [rel[:, c] if c >= 0
            else jnp.full(rel.shape[0], jnp.int32(UNDEF))
            for c in col_map]
    data = jnp.stack(cols, axis=1)
    return jnp.where(valid[:, None], data, 0), valid


@partial(jax.jit, static_argnames=("cap",))
def union_rels(a: jax.Array, avalid: jax.Array, b: jax.Array, bvalid: jax.Array,
               cap: int):
    """Union of two schema-aligned bounded relations (align branches with
    ``align_columns`` first), a-rows before b-rows, stable."""
    data = jnp.concatenate([a, b], axis=0)
    valid = jnp.concatenate([avalid, bvalid])
    idx, v, ovf = compact(valid, cap)
    return jnp.where(v[:, None], data[idx], 0), v, ovf


@partial(jax.jit, static_argnames=("op", "lhs_col", "rhs_col"))
def compare_mask(rel: jax.Array, valid: jax.Array, op: int,
                 lhs_col: int, rhs_col: int,
                 lhs_const: jax.Array, rhs_const: jax.Array) -> jax.Array:
    """Row mask of one FILTER comparison (``OP_CODES``); a side is a column
    when its ``*_col >= 0``, else the ``*_const`` scalar.  Two-valued: rows
    with an UNDEF side are false.  Combine masks with jnp logical ops for
    &&/||/! and compact with ``filter_rows``."""
    n = rel.shape[0]
    lv = rel[:, lhs_col] if lhs_col >= 0 else jnp.full(n, lhs_const, jnp.int32)
    rv = rel[:, rhs_col] if rhs_col >= 0 else jnp.full(n, rhs_const, jnp.int32)
    bound = (lv != UNDEF) & (rv != UNDEF)
    # op is static, so only the requested comparison is traced
    res = [lambda: lv == rv, lambda: lv != rv, lambda: lv < rv,
           lambda: lv <= rv, lambda: lv > rv, lambda: lv >= rv][op]()
    return valid & bound & res


@partial(jax.jit, static_argnames=("cap",))
def filter_rows(rel: jax.Array, valid: jax.Array, mask: jax.Array, cap: int):
    """Compact the rows where ``mask`` holds (FILTER application)."""
    idx, v, ovf = compact(valid & mask, cap)
    return jnp.where(v[:, None], rel[idx], 0), v, ovf
