from repro.engine.local import (
    ExecutionMetrics,
    ExecutionResult,
    LocalEngine,
    naive_evaluate,
)
from repro.engine.pipeline import (
    CardObservation,
    PipelineExecution,
    SourceChannel,
    VirtualClock,
    compile_plan,
)

__all__ = [
    "LocalEngine",
    "ExecutionMetrics",
    "ExecutionResult",
    "naive_evaluate",
    "CardObservation",
    "PipelineExecution",
    "SourceChannel",
    "VirtualClock",
    "compile_plan",
]
