from repro.engine.local import LocalEngine, ExecutionMetrics, naive_evaluate

__all__ = ["LocalEngine", "ExecutionMetrics", "naive_evaluate"]
