"""Distributed federation executor: the paper's endpoint/engine architecture
mapped onto a TPU mesh (DESIGN.md §2).

Layout
------
* ``data`` axis  = federation endpoints (one source per data shard; the mesh
  is the federation).
* ``model`` axis = intra-endpoint parallelism: each source's triples are
  **hash-partitioned by subject** across the model axis, so star-shaped
  subqueries (subject joins) execute entirely shard-locally — the paper's
  "subqueries evaluated at the endpoint" invariant, in SPMD form.
* ``pod`` axis   = query-batch data parallelism (multi-pod dry-run).

Cross-star joins exchange rows by join-key hash over the model axis
(``all_to_all``) and/or gather the build side over the data axis
(``all_gather``) — the *transferred tuples* of the paper are literally the
collective bytes of this engine, which is what Odyssey's optimizer minimizes.

All relations are bounded buffers (operators.py); overflow flags psum up to
the host, which retries with doubled capacity.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6
    from jax import shard_map as _shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                          check_vma=False)
except (ImportError, TypeError):  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _legacy

    def shard_map(f, mesh, in_specs, out_specs):
        return _legacy(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_rep=False)

from repro.core.planner import JoinPlanNode, PhysicalPlan, PlanNode, SubqueryNode
from repro.engine import operators as ops
from repro.engine.local import ExecutionResult


class AlgebraFallbackWarning(UserWarning):
    """The SPMD engine received an OPTIONAL/UNION/FILTER plan and degraded it
    to ``LocalEngine`` instead of failing (``ExecutionResult.fallback`` names
    the substitution).  Filterable: the fallback changes *where* the plan
    runs, never its rows."""


def _has_algebra_nodes(node: PlanNode) -> bool:
    """True iff the plan tree contains any non-conjunctive operator (the
    forms ``_eval_node`` deliberately rejects)."""
    if isinstance(node, SubqueryNode):
        return False
    if isinstance(node, JoinPlanNode):
        return _has_algebra_nodes(node.left) or _has_algebra_nodes(node.right)
    return True
from repro.query.algebra import Const, TriplePattern, Var
from repro.rdf.dataset import Federation


@dataclass
class DistRelation:
    """Host handle to a device-sharded bounded relation."""

    data: jax.Array          # (d, m, cap, C) int32
    valid: jax.Array         # (d, m, cap) bool
    overflow: jax.Array      # () bool
    columns: list[str]       # var name per column
    partitioned_by: str | None = None  # var whose hash partitions the model axis


@dataclass
class DistMetrics:
    transferred_tuples: int = 0
    collective_bytes: int = 0
    overflowed: bool = False


def _enc_pattern(tp: TriplePattern) -> list[int]:
    s, p, o = tp.constants()
    return [s if s is not None else -1, p if p is not None else -1,
            o if o is not None else -1]


class DistributedEngine:
    """Executes PhysicalPlans on a (data, model) mesh.

    ``cap`` bounds each operator's output rows *per shard*.
    """

    def __init__(self, fed: Federation, mesh: Mesh, cap: int = 2048,
                 table_cap: int | None = None, partition_aware: bool = False):
        # partition_aware: skip the model-axis gather of the build side when
        # it is already hash-partitioned by the join key (§Perf optimization;
        # baseline engines gather unconditionally)
        self.partition_aware = partition_aware
        self.fed = fed
        self.mesh = mesh
        self.cap = cap
        self.d = mesh.shape["data"]
        self.m = mesh.shape["model"]
        assert len(fed.sources) <= self.d, "one endpoint per data shard"
        if table_cap is None:
            table_cap = 1
            for src in fed.sources:
                counts = np.bincount(src.table.s % self.m, minlength=self.m) if len(src.table) else np.zeros(1, np.int64)
                table_cap = max(table_cap, int(counts.max()))
            table_cap = int(2 ** np.ceil(np.log2(table_cap)))
        self.table_cap = table_cap

        tables = np.zeros((self.d, self.m, table_cap, 3), np.int32)
        trow = np.zeros((self.d, self.m, table_cap), bool)
        for sid, src in enumerate(fed.sources):
            t = src.table
            part = t.s % self.m
            for mm in range(self.m):
                rows = np.nonzero(part == mm)[0]
                k = min(len(rows), table_cap)
                tables[sid, mm, :k, 0] = t.s[rows[:k]]
                tables[sid, mm, :k, 1] = t.p[rows[:k]]
                tables[sid, mm, :k, 2] = t.o[rows[:k]]
                trow[sid, mm, :k] = True
        sh = NamedSharding(mesh, P("data", "model"))
        self.tables = jax.device_put(jnp.asarray(tables), sh)
        self.trow = jax.device_put(jnp.asarray(trow), sh)
        self._star_fns: dict[int, object] = {}
        self._spec = P("data", "model")

    # ------------------------------------------------------------------
    # jitted SPMD steps
    # ------------------------------------------------------------------
    def _star_fn(self, n_pat: int):
        """Scan + subject-join ``n_pat`` patterns of one star, shard-local.

        Output columns: [subject, obj_0, ..., obj_{n_pat-1}].
        """
        if n_pat in self._star_fns:
            return self._star_fns[n_pat]
        cap = self.cap

        def per_shard(tables, trow, patterns, source_on):
            tables = tables.reshape(-1, 3)
            trow = trow.reshape(-1) & source_on[0, 0]
            rel, valid, ovf = ops.scan_pattern(tables, trow, patterns[0, 0, 0],
                                               cap, (0, 2))
            for k in range(1, n_pat):
                nxt, nvalid, o2 = ops.scan_pattern(tables, trow, patterns[0, 0, k],
                                                   cap, (0, 2))
                rel, valid, o3 = ops.merge_join(rel, valid, 0, nxt, nvalid, 0, cap)
                # drop duplicated subject col from right side (at ncols_left)
                keep = list(range(rel.shape[1]))
                keep.remove(k + 1)
                rel = rel[:, keep]
                ovf = ovf | o2 | o3
            n = ops.count_valid(valid)
            return rel[None, None], valid[None, None], ovf[None, None], n[None, None]

        fn = shard_map(
            per_shard, self.mesh,
            in_specs=(P("data", "model"), P("data", "model"),
                      P("data", "model"), P("data", "model")),
            out_specs=(P("data", "model"), P("data", "model"),
                       P("data", "model"), P("data", "model")),
        )
        jfn = jax.jit(fn)
        self._star_fns[n_pat] = jfn
        return jfn

    def _exchange_fn(self, right_partitioned: bool = False):
        """Repartition rows over the model axis by hash of a key column, then
        merge-join against a local build side: the distributed hash join.

        ``right_partitioned``: the build side is already hash-partitioned by
        its join key over the model axis (true when joining a star on its
        subject), so the model-axis gather is skipped — m× fewer build bytes
        on the wire."""
        key = ("exch", right_partitioned)
        if key in self._star_fns:
            return self._star_fns[key]
        cap, m = self.cap, self.m

        def per_shard(lrel, lvalid, rrel, rvalid, lkey, rkey):
            lrel = lrel[0, 0]
            lvalid = lvalid[0, 0]
            rrel = rrel[0, 0]
            rvalid = rvalid[0, 0]
            ncols = lrel.shape[1]
            # --- exchange left rows by key % m over the model axis ---------
            keyv = jnp.take(lrel, lkey, axis=1)
            dest = jnp.where(lvalid, keyv % m, m)  # m = drop bucket
            bucket_cap = cap // m
            order = jnp.argsort(dest, stable=True)
            sorted_dest = dest[order]
            idx_in_dest = jnp.arange(cap) - jnp.searchsorted(
                sorted_dest, sorted_dest, side="left")
            ovf = jnp.max(jnp.where(sorted_dest < m, idx_in_dest, 0)) >= bucket_cap
            slot = jnp.clip(idx_in_dest, 0, bucket_cap - 1)
            row_ok = (sorted_dest < m) & (idx_in_dest < bucket_cap)
            tgt = jnp.where(row_ok, sorted_dest, m)  # OOB rows are dropped
            send = jnp.zeros((m, bucket_cap, ncols), jnp.int32)
            send = send.at[tgt, slot].set(lrel[order], mode="drop")
            svalid = jnp.zeros((m, bucket_cap), bool)
            svalid = svalid.at[tgt, slot].set(row_ok, mode="drop")
            shipped = jnp.sum(svalid)
            recv = jax.lax.all_to_all(send, "model", 0, 0, tiled=True)
            vrecv = jax.lax.all_to_all(svalid, "model", 0, 0, tiled=True)
            lrel2 = recv.reshape(-1, ncols)[:cap]
            lvalid2 = vrecv.reshape(-1)[:cap]
            # --- gather the build side across the federation ---------------
            if right_partitioned:
                # build rows already live on the model shard of their key:
                # gather over sources (data) only — m× fewer bytes
                rrel_g = jax.lax.all_gather(rrel, "data", tiled=True)
                rvalid_g = jax.lax.all_gather(rvalid, "data", tiled=True)
                shipped = shipped + jnp.sum(rvalid)
            else:
                rrel_g = jax.lax.all_gather(rrel, "model", tiled=True)
                rvalid_g = jax.lax.all_gather(rvalid, "model", tiled=True)
                rrel_g = jax.lax.all_gather(rrel_g, "data", tiled=True)
                rvalid_g = jax.lax.all_gather(rvalid_g, "data", tiled=True)
                shipped = shipped + jnp.sum(rvalid)
                # keep only build rows whose key hashes to this model shard
                my = jax.lax.axis_index("model")
                rkeyv = jnp.take(rrel_g, rkey, axis=1)
                rvalid_g = rvalid_g & ((rkeyv % m) == my)
            out, ovalid, o2 = ops.merge_join(lrel2, lvalid2, lkey, rrel_g, rvalid_g,
                                             rkey, cap)
            shipped_total = jax.lax.psum(jax.lax.psum(shipped, "model"), "data")
            ovf_any = jax.lax.psum(
                jax.lax.psum((ovf | o2).astype(jnp.int32), "model"), "data") > 0
            return (out[None, None], ovalid[None, None],
                    ovf_any[None, None], shipped_total[None, None])

        fn = shard_map(
            per_shard, self.mesh,
            in_specs=(P("data", "model"), P("data", "model"),
                      P("data", "model"), P("data", "model"), P(), P()),
            out_specs=(P("data", "model"), P("data", "model"),
                       P("data", "model"), P("data", "model")),
        )
        self._star_fns[key] = jax.jit(fn)
        return self._star_fns[key]

    def _collect_fn(self, ncols: int):
        """Gather a sharded relation to every shard (replicated result)."""
        key = ("collect", ncols)
        if key in self._star_fns:
            return self._star_fns[key]

        def per_shard(rel, valid):
            rel = rel[0, 0]
            valid = valid[0, 0]
            rel_g = jax.lax.all_gather(rel, "model", tiled=True)
            val_g = jax.lax.all_gather(valid, "model", tiled=True)
            rel_g = jax.lax.all_gather(rel_g, "data", tiled=True)
            val_g = jax.lax.all_gather(val_g, "data", tiled=True)
            return rel_g[None, None], val_g[None, None]

        fn = shard_map(
            per_shard, self.mesh,
            in_specs=(P("data", "model"), P("data", "model")),
            out_specs=(P(None, None), P(None, None)),
        )
        self._star_fns[key] = jax.jit(fn)
        return self._star_fns[key]

    # ------------------------------------------------------------------
    # plan execution
    # ------------------------------------------------------------------
    def _eval_star(self, node: SubqueryNode, metrics: DistMetrics) -> DistRelation:
        assert len(node.stars) == 1, "merged leaves run on the exclusive path"
        pats = [tp for tp in node.patterns if not isinstance(tp.p, Var)]
        n_pat = len(pats)
        enc = np.full((self.d, self.m, n_pat, 3), -1, np.int32)
        for k, tp in enumerate(pats):
            enc[:, :, k] = _enc_pattern(tp)
        src_on = np.zeros((self.d, self.m), bool)
        for s in node.sources:
            src_on[s] = True
        sh = NamedSharding(self.mesh, P("data", "model"))
        rel, valid, ovf, n = self._star_fn(n_pat)(
            self.tables, self.trow,
            jax.device_put(jnp.asarray(enc), sh),
            jax.device_put(jnp.asarray(src_on), sh),
        )
        metrics.overflowed |= bool(jax.device_get(ovf).any())
        subj = pats[0].s.name if isinstance(pats[0].s, Var) else f"_c{id(node)}"
        cols = [subj] + [tp.o.name if isinstance(tp.o, Var) else f"_o{k}"
                         for k, tp in enumerate(pats)]
        return DistRelation(rel, valid, ovf, cols, partitioned_by=subj)

    def _eval_node(self, node: PlanNode, metrics: DistMetrics) -> DistRelation:
        if isinstance(node, SubqueryNode):
            if len(node.stars) == 1:
                return self._eval_star(node, metrics)
            # exclusive group ("single SPARQL query to one endpoint", §3.4):
            # evaluate each star then join; rows stay within the source.
            return self._join_merged_leaf(node, metrics)
        if not isinstance(node, JoinPlanNode):
            raise NotImplementedError(
                f"the SPMD engine executes conjunctive (Subquery/Join) plans "
                f"only; got {type(node).__name__} — run OPTIONAL/UNION/FILTER "
                "plans on repro.engine.local.LocalEngine")
        left = self._eval_node(node.left, metrics)
        right = self._eval_node(node.right, metrics)
        return self._join(left, right, node.join_vars, metrics)

    def _join_merged_leaf(self, node: SubqueryNode, metrics: DistMetrics) -> DistRelation:
        from repro.core.decomposition import decompose
        from repro.query.algebra import BGPQuery

        graph = decompose(BGPQuery(list(node.patterns)))
        rels: list[DistRelation] = []
        for star in graph.stars:
            sub = SubqueryNode(stars=[0], patterns=star.patterns, sources=node.sources)
            rels.append(self._eval_star(sub, metrics))
        out = rels[0]
        for r in rels[1:]:
            jv = sorted(set(out.columns) & set(r.columns))
            out = self._join(out, r, jv, metrics)
        return out

    def _join(self, left: DistRelation, right: DistRelation, join_vars: list[str],
              metrics: DistMetrics) -> DistRelation:
        assert join_vars, "cartesian joins not supported in the SPMD engine"
        jv = join_vars[0]
        lkey = left.columns.index(jv)
        rkey = right.columns.index(jv)
        right_part = self.partition_aware and right.partitioned_by == jv
        rel, valid, ovf, shipped = self._exchange_fn(right_partitioned=right_part)(
            left.data, left.valid, right.data, right.valid,
            jnp.int32(lkey), jnp.int32(rkey))
        metrics.overflowed |= bool(jax.device_get(ovf).any())
        n_ship = int(jax.device_get(shipped).ravel()[0])
        metrics.transferred_tuples += n_ship
        metrics.collective_bytes += n_ship * 4 * (len(left.columns) + len(right.columns))
        cols = left.columns + right.columns
        # dedupe duplicated join columns by renaming right dup
        seen: dict[str, int] = {}
        final_cols = []
        for c in cols:
            if c in seen:
                final_cols.append(f"{c}__dup{seen[c]}")
                seen[c] += 1
            else:
                seen[c] = 1
                final_cols.append(c)
        out = DistRelation(rel, valid, ovf, final_cols, partitioned_by=jv)
        # secondary join keys: filter equality host-side at collect (rare)
        out._extra_eq = [(cols.index(v), len(left.columns) + right.columns.index(v))
                         for v in join_vars[1:]]  # type: ignore[attr-defined]
        return out

    def execute(self, plan: PhysicalPlan) -> ExecutionResult:
        if _has_algebra_nodes(plan.root):
            # degrade, don't die: the SPMD kernels are conjunctive-only
            # (``_eval_node`` still raises -- that contract is pinned), so
            # OPTIONAL/UNION/FILTER plans run on the host engine with the
            # substitution named on the result instead of surfacing a bare
            # NotImplementedError to serving code
            import warnings

            from repro.engine.local import LocalEngine

            warnings.warn(
                "SPMD engine received an OPTIONAL/UNION/FILTER plan; "
                "degrading to LocalEngine (result.fallback = "
                "'local:algebra'; rows are identical, DistMetrics are not "
                "collected)", AlgebraFallbackWarning, stacklevel=2)
            res = LocalEngine(self.fed).execute(plan)
            return dataclasses.replace(res, fallback="local:algebra")
        metrics = DistMetrics()
        rel = self._eval_node(plan.root, metrics)
        data, valid = self._collect_fn(len(rel.columns))(rel.data, rel.valid)
        data = np.asarray(jax.device_get(data)).reshape(-1, len(rel.columns))
        valid = np.asarray(jax.device_get(valid)).reshape(-1)
        rows = data[valid]
        for (i, j) in getattr(rel, "_extra_eq", []):
            rows = rows[rows[:, i] == rows[:, j]]
        proj = plan.query.effective_projection()
        out: dict[str, np.ndarray] = {}
        for v in proj:
            out[v] = rows[:, rel.columns.index(v)]
        if plan.query.distinct and len(rows):
            stacked = np.stack([out[v] for v in proj], axis=1)
            _, idx = np.unique(stacked, axis=0, return_index=True)
            out = {v: out[v][np.sort(idx)] for v in proj}
        return ExecutionResult(rows=out, metrics=metrics, plan=plan,
                               stats_epoch=plan.stats_epoch)


def _star_subject(tp: TriplePattern):
    return tp.s


# ---------------------------------------------------------------------------
# dry-run lowering (no data, ShapeDtypeStructs only)
# ---------------------------------------------------------------------------

def fed_dryrun_lower(mesh: Mesh, cap: int = 8192, table_cap: int = 1 << 20,
                     n_pat1: int = 3, n_pat2: int = 2, optimized: bool = False):
    """Lower the canonical federated query step (two star scans + distributed
    hash join + collect) on an abstract federation sized like FedBench-at-
    scale: one endpoint per data shard, ``table_cap`` triples per (source,
    model) shard. Returns the jax ``Lowered`` artifact.

    On the multi-pod mesh the engine replicates across the ``pod`` axis
    (independent query streams); tables and relations shard over
    (data, model) exactly as single-pod.
    """
    eng = object.__new__(DistributedEngine)
    eng.mesh = mesh
    eng.cap = cap
    eng.d = mesh.shape["data"]
    eng.m = mesh.shape["model"]
    eng.table_cap = table_cap
    eng._star_fns = {}

    d, m = eng.d, eng.m
    sh = NamedSharding(mesh, P("data", "model"))
    sds = jax.ShapeDtypeStruct
    tables_s = sds((d, m, table_cap, 3), jnp.int32, sharding=sh)
    trow_s = sds((d, m, table_cap), jnp.bool_, sharding=sh)
    pat1_s = sds((d, m, n_pat1, 3), jnp.int32, sharding=sh)
    pat2_s = sds((d, m, n_pat2, 3), jnp.int32, sharding=sh)
    on_s = sds((d, m), jnp.bool_, sharding=sh)

    star1 = eng._star_fn(n_pat1)
    star2 = eng._star_fn(n_pat2)
    # optimized: the right star joins on its own subject, which is exactly its
    # model-axis partition key — skip the model gather of the build side
    exchange = eng._exchange_fn(right_partitioned=optimized)
    collect = eng._collect_fn(n_pat1 + 1 + n_pat2 + 1)

    def fed_query_step(tables, trow, pat1, on1, pat2, on2):
        r1, v1, o1, _ = star1(tables, trow, pat1, on1)
        r2, v2, o2, _ = star2(tables, trow, pat2, on2)
        out, ov, o3, shipped = exchange(r1, v1, r2, v2,
                                        jnp.int32(1), jnp.int32(0))
        rows, valid = collect(out, ov)
        return rows, valid, (o1 | o2 | o3), shipped

    return jax.jit(fed_query_step).lower(tables_s, trow_s, pat1_s, on_s,
                                         pat2_s, on_s)
