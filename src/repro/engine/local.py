"""Host ("oracle") execution engine: exact, dynamically-shaped numpy
evaluation of physical plans over a federation, with the paper's runtime
metrics (NTT = tuples shipped endpoint->engine, requests, wall time).

This is the reference the distributed JAX engine is tested against, and the
executor behind the FedBench-style benchmarks (ET / NTT figures).
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field

import numpy as np

from repro.core.planner import (
    FilterPlanNode,
    JoinPlanNode,
    LeftJoinPlanNode,
    PhysicalPlan,
    PlanNode,
    SubqueryNode,
    UnionPlanNode,
)
from repro.query.algebra import (
    And,
    BGPQuery,
    Bgp,
    Comparison,
    Const,
    Expr,
    Filter,
    GroupNode,
    Join,
    LeftJoin,
    Not,
    Or,
    TriplePattern,
    Union,
    Var,
)
from repro.rdf.dataset import Federation, Source

Relation = dict[str, np.ndarray]  # same-length columns keyed by var name

# Unbound marker inside int32 relation columns (term ids are non-negative).
# OPTIONAL pads unmatched right columns and UNION pads schema gaps with it;
# comparisons involving it are false (two-valued FILTER semantics, see
# docs/algebra.md).  Normalization's well-designed check guarantees a
# possibly-UNDEF variable never becomes a join key of a reordered plan.
UNDEF = int(np.int32(-1))


def _empty(vars_: "list[str]") -> Relation:
    return {v: np.zeros(0, np.int32) for v in vars_}


def _nrows(rel: Relation) -> int:
    if not rel:
        return 0
    return len(next(iter(rel.values())))


def _concat(rels: "list[Relation]") -> Relation:
    """Union of same-schema relations. Keeps the column structure even when
    every input is empty — an empty-with-columns relation annihilates joins,
    whereas the no-columns relation ``{}`` is the join identity."""
    nonempty = [r for r in rels if _nrows(r)]
    if not nonempty:
        for r in rels:
            if r:
                return {k: v[:0] for k, v in r.items()}
        return {}
    keys = nonempty[0].keys()
    return {k: np.concatenate([r[k] for r in nonempty]) for k in keys}


def _dedup(rel: Relation) -> Relation:
    n = _nrows(rel)
    if n == 0:
        return rel
    keys = sorted(rel.keys())
    stacked = np.stack([rel[k].astype(np.int64) for k in keys], axis=1)
    _, idx = np.unique(stacked, axis=0, return_index=True)
    return {k: rel[k][np.sort(idx)] for k in rel}


def _outer_union(rels: "list[Relation]") -> Relation:
    """UNION of possibly different-schema relations: the output schema is the
    union of the inputs' variables, missing columns padded with UNDEF."""
    allvars = sorted(set().union(*[set(r) for r in rels])) if rels else []
    parts: list[Relation] = []
    for r in rels:
        n = _nrows(r)
        parts.append({v: (r[v] if v in r else np.full(n, UNDEF, np.int32))
                      for v in allvars})
    return _concat(parts)


def filter_mask(expr: Expr, rel: Relation) -> np.ndarray:
    """Row mask of ``expr`` over ``rel`` — the one FILTER evaluator, shared by
    the engine, the oracle and the tests.  Two-valued semantics: a comparison
    whose side is unbound (a missing column or an UNDEF cell) is false, ``!``
    is plain negation, and ordering comparisons are over term ids."""
    n = _nrows(rel)

    def col(t) -> np.ndarray:
        if isinstance(t, Const):
            return np.full(n, t.tid, np.int64)
        c = rel.get(t.name)
        return c.astype(np.int64) if c is not None else np.full(n, UNDEF, np.int64)

    if isinstance(expr, Comparison):
        lv, rv = col(expr.lhs), col(expr.rhs)
        bound = (lv != UNDEF) & (rv != UNDEF)
        ops = {"=": np.equal, "!=": np.not_equal, "<": np.less,
               "<=": np.less_equal, ">": np.greater, ">=": np.greater_equal}
        return bound & ops[expr.op](lv, rv)
    if isinstance(expr, And):
        out = np.ones(n, bool)
        for p in expr.parts:
            out &= filter_mask(p, rel)
        return out
    if isinstance(expr, Or):
        out = np.zeros(n, bool)
        for p in expr.parts:
            out |= filter_mask(p, rel)
        return out
    assert isinstance(expr, Not)
    return ~filter_mask(expr.part, rel)


def join_indices(left: Relation,
                 right: Relation) -> "tuple[np.ndarray, np.ndarray]":
    """Row-index pairs ``(li, ri)`` of the inner join on the shared
    variables (cartesian when disjoint).  Emission order is canonical:
    ``li`` ascending, and within one ``li`` the ``ri`` ascending — the
    stable argsort keeps equal-key runs in original order — which is the
    order the operator pipeline reproduces by sorting accumulated pairs."""
    shared = sorted(set(left) & set(right))
    nl, nr = _nrows(left), _nrows(right)
    if not shared:  # cartesian
        li = np.repeat(np.arange(nl), nr)
        ri = np.tile(np.arange(nr), nl)
    else:
        lk = np.stack([left[v].astype(np.int64) for v in shared], axis=1)
        rk = np.stack([right[v].astype(np.int64) for v in shared], axis=1)
        # sort-merge on packed keys
        def pack(a: np.ndarray) -> np.ndarray:
            h = np.zeros(len(a), np.int64)
            for c in range(a.shape[1]):
                h = h * 1_000_003 + a[:, c]
            return h
        hl, hr = pack(lk), pack(rk)
        order_r = np.argsort(hr, kind="stable")
        hr_s = hr[order_r]
        lo = np.searchsorted(hr_s, hl, side="left")
        hi = np.searchsorted(hr_s, hl, side="right")
        cnt = hi - lo
        li = np.repeat(np.arange(nl), cnt)
        ri_pos = np.concatenate([np.arange(l, h) for l, h in zip(lo, hi)]) if cnt.sum() else np.zeros(0, np.int64)
        ri = order_r[ri_pos.astype(np.int64)]
        if shared and len(li):
            # guard against packed-hash collisions: verify equality
            ok = np.ones(len(li), bool)
            for v in shared:
                ok &= left[v][li] == right[v][ri]
            li, ri = li[ok], ri[ok]
    return li, ri


def join_rels(left: Relation, right: Relation) -> Relation:
    if not left:
        return right
    if not right:
        return left
    li, ri = join_indices(left, right)
    out: Relation = {}
    for v in left:
        out[v] = left[v][li]
    for v in right:
        if v not in out:
            out[v] = right[v][ri]
    return out


def left_join_rels(left: Relation, right: Relation) -> Relation:
    """OPTIONAL: the inner join plus every unmatched left row, right-only
    columns padded with UNDEF."""
    if not left:
        return right
    if not right:
        return left
    li, ri = join_indices(left, right)
    matched = np.zeros(_nrows(left), bool)
    matched[li] = True
    un = np.nonzero(~matched)[0]
    out: Relation = {}
    for v in left:
        out[v] = np.concatenate([left[v][li], left[v][un]])
    for v in right:
        if v not in out:
            out[v] = np.concatenate(
                [right[v][ri], np.full(len(un), UNDEF, right[v].dtype)])
    return out


@dataclass
class ExecutionMetrics:
    transferred_tuples: int = 0        # endpoint -> engine rows (NTT)
    requests: int = 0                  # subquery dispatches
    intermediate_rows: int = 0
    wall_ms: float = 0.0
    overflowed: bool = False


@dataclass(frozen=True)
class ExecutionResult:
    """What executing one ``PhysicalPlan`` produced: the result relation,
    the engine's runtime metrics (``ExecutionMetrics`` here,
    ``DistMetrics`` from the distributed engine), the plan it ran, and the
    statistics epoch that plan was emitted under — so serving/failover
    layers can attribute an answer without threading side channels.

    Deprecation shim: iterating unpacks as the legacy ``(rows, metrics)``
    tuple, so out-of-tree ``rows, m = engine.execute(plan)`` callers keep
    working (with a ``DeprecationWarning``) instead of breaking.  Prefer
    the named fields.

    ``card_log`` carries the pipeline's observed-vs-estimated cardinality
    samples (``repro.engine.pipeline.CardObservation``; empty on the legacy
    recursive path) — the signal ``repro.stats.feedback`` turns into
    triggered ``refresh_source`` calls.  ``fallback`` names the engine
    substitution, if any, that produced this result (e.g. the distributed
    engine degrading an algebra plan to ``LocalEngine``).
    """

    rows: Relation
    metrics: object
    plan: "PhysicalPlan | None" = None
    stats_epoch: int = 0
    card_log: tuple = ()
    fallback: "str | None" = None

    def __iter__(self):
        warnings.warn(
            "unpacking ExecutionResult as a (rows, metrics) tuple is "
            "deprecated; use result.rows / result.metrics",
            DeprecationWarning, stacklevel=2)
        return iter((self.rows, self.metrics))


class LocalEngine:
    """Host execution engine.

    ``execute`` lowers the plan onto the adaptive operator pipeline
    (``repro.engine.pipeline``) — bit-identical rows and NTT/request metrics
    to the original recursive evaluator, which survives as
    ``execute_recursive`` (``use_pipeline=False`` routes everything there)
    and remains the differential oracle of the pipeline tests.

    ``scan_policy`` is the pipeline's dispatch order (``"static"`` |
    ``"adaptive"`` | ``"random"``); ``clock`` an optional virtual clock for
    deterministic latency simulation.  Plain ``LocalEngine`` ignores
    injected faults (``honor_faults=False``); ``FailoverEngine`` flips it.
    """

    honor_faults = False

    def __init__(self, fed: Federation, use_pipeline: bool = True,
                 scan_policy: str = "static", clock=None):
        self.fed = fed
        self.use_pipeline = use_pipeline
        self.scan_policy = scan_policy
        self.clock = clock

    # -- pattern / star evaluation at one endpoint ---------------------------
    def _eval_pattern(self, src: Source, tp: TriplePattern,
                      bindings: Relation | None = None) -> Relation:
        s, p, o = tp.constants()
        table = src.table
        out_vars = [t.name for t in (tp.s, tp.p, tp.o) if isinstance(t, Var)]
        if bindings is None or not any(
            isinstance(t, Var) and t.name in bindings for t in (tp.s, tp.p, tp.o)
        ):
            rows = table.scan(s, p, o)
            rel: Relation = {}
            if isinstance(tp.s, Var):
                rel[tp.s.name] = table.s[rows]
            if isinstance(tp.p, Var):
                rel[tp.p.name] = table.p[rows]
            if isinstance(tp.o, Var):
                rel[tp.o.name] = table.o[rows]
            if bindings is not None:
                return self._join(bindings, rel)
            return rel
        # bound evaluation: loop distinct relevant binding rows (bind join)
        join_vars = [v for v in (tp.s, tp.p, tp.o)
                     if isinstance(v, Var) and v.name in bindings]
        jnames = [v.name for v in join_vars]
        stacked = np.stack([bindings[v].astype(np.int64) for v in jnames], axis=1)
        uniq = np.unique(stacked, axis=0)
        parts: list[Relation] = []
        for row in uniq:
            bind = dict(zip(jnames, row.tolist()))
            s2 = bind.get(tp.s.name, s) if isinstance(tp.s, Var) else s
            p2 = bind.get(tp.p.name, p) if isinstance(tp.p, Var) else p
            o2 = bind.get(tp.o.name, o) if isinstance(tp.o, Var) else o
            rows = table.scan(s2, p2, o2)
            rel = {}
            if isinstance(tp.s, Var):
                rel[tp.s.name] = table.s[rows] if tp.s.name not in bind else np.full(len(rows), bind[tp.s.name], np.int32)
            if isinstance(tp.p, Var):
                rel[tp.p.name] = table.p[rows] if tp.p.name not in bind else np.full(len(rows), bind[tp.p.name], np.int32)
            if isinstance(tp.o, Var):
                rel[tp.o.name] = table.o[rows] if tp.o.name not in bind else np.full(len(rows), bind[tp.o.name], np.int32)
            parts.append(rel)
        matches = _concat(parts) if parts else _empty(out_vars)
        return self._join(bindings, matches)

    # -- generic hash join (module-level helpers, shared with the pipeline) --
    def _join_indices(self, left: Relation,
                      right: Relation) -> "tuple[np.ndarray, np.ndarray]":
        return join_indices(left, right)

    def _join(self, left: Relation, right: Relation) -> Relation:
        return join_rels(left, right)

    def _left_join(self, left: Relation, right: Relation) -> Relation:
        return left_join_rels(left, right)

    def _eval_subquery(self, node: SubqueryNode, metrics: ExecutionMetrics,
                       bindings: Relation | None = None) -> Relation:
        """Evaluate the (merged) star subquery at each selected endpoint and
        union — intermediate joins happen remotely, only results ship."""
        full_vars: set[str] = set()
        for tp in node.patterns:
            full_vars |= set(tp.variables())
        if bindings:
            full_vars |= set(bindings)
        parts: list[Relation] = []
        for sid in node.sources:
            src = self.fed.sources[sid]
            rel: Relation | None = bindings
            for tp in node.patterns:
                rel = self._eval_pattern(src, tp, rel)
                if _nrows(rel) == 0 and rel:
                    break
            if rel is None or _nrows(rel) == 0:
                rel = _empty(sorted(full_vars))
            metrics.requests += 1
            metrics.transferred_tuples += _nrows(rel)
            parts.append(rel)
        out = _concat(parts)
        if not out:
            return _empty(sorted(full_vars))
        return out

    def _execute(self, node: PlanNode, metrics: ExecutionMetrics) -> Relation:
        if isinstance(node, SubqueryNode):
            return self._eval_subquery(node, metrics)
        if isinstance(node, LeftJoinPlanNode):
            left = self._execute(node.left, metrics)
            metrics.intermediate_rows += _nrows(left)
            right = self._execute(node.right, metrics)
            metrics.intermediate_rows += _nrows(right)
            return self._left_join(left, right)
        if isinstance(node, UnionPlanNode):
            parts = [self._execute(c, metrics) for c in node.children]
            for p in parts:
                metrics.intermediate_rows += _nrows(p)
            return _outer_union(parts)
        if isinstance(node, FilterPlanNode):
            rel = self._execute(node.child, metrics)
            metrics.intermediate_rows += _nrows(rel)
            m = filter_mask(node.expr, rel)
            return {v: c[m] for v, c in rel.items()}
        assert isinstance(node, JoinPlanNode)
        left = self._execute(node.left, metrics)
        metrics.intermediate_rows += _nrows(left)
        if node.strategy == "bind" and isinstance(node.right, SubqueryNode):
            right_bound = self._eval_subquery(node.right, metrics, bindings=left)
            metrics.intermediate_rows += _nrows(right_bound)
            return right_bound
        right = self._execute(node.right, metrics)
        metrics.intermediate_rows += _nrows(right)
        return self._join(left, right)

    def execute(self, plan: PhysicalPlan) -> ExecutionResult:
        if self.use_pipeline:
            from repro.engine.pipeline import compile_plan
            exec_ = compile_plan(plan, self.fed, honor_faults=self.honor_faults,
                                 policy=self.scan_policy, clock=self.clock)
            return exec_.run()
        return self.execute_recursive(plan)

    def execute_recursive(self, plan: PhysicalPlan) -> ExecutionResult:
        """The original monolithic recursive evaluator — the pipeline's
        differential oracle (bit-identical rows and metrics by contract)."""
        metrics = ExecutionMetrics()
        t0 = time.perf_counter()
        rel = self._execute(plan.root, metrics)
        # query completion (§3.4 step iv): projection + DISTINCT.  Algebra
        # queries fill never-bound projection variables with UNDEF (the
        # oracle does the same); the legacy flat-BGP path keeps its 0-fill.
        fill = 0 if plan.query.root is None else UNDEF
        proj = plan.query.effective_projection()
        rel = {v: rel.get(v, np.full(_nrows(rel), fill, np.int32)) for v in proj}
        if plan.query.distinct:
            rel = _dedup(rel)
        metrics.wall_ms = (time.perf_counter() - t0) * 1e3
        return ExecutionResult(rows=rel, metrics=metrics, plan=plan,
                               stats_epoch=plan.stats_epoch)


# --------------------------------------------------------------------------
# Gold-standard evaluator: the full group algebra over the union of sources
# --------------------------------------------------------------------------

def _naive_group(eng: LocalEngine, src: Source, node: GroupNode) -> Relation:
    """Recursive oracle evaluation of a (raw, un-normalized) group tree over
    one source.  Deliberately structured nothing like the planner: joins
    follow the syntactic order, so differential tests exercise normalization
    and join reordering, not just the operators."""
    if isinstance(node, Bgp):
        rel: Relation = {}
        for tp in node.patterns:
            rel = eng._eval_pattern(src, tp, rel if rel else None)
        return rel
    if isinstance(node, Join):
        rel = {}
        for c in node.children:
            rel = eng._join(rel, _naive_group(eng, src, c))
        return rel
    if isinstance(node, LeftJoin):
        return eng._left_join(_naive_group(eng, src, node.left),
                              _naive_group(eng, src, node.right))
    if isinstance(node, Union):
        return _outer_union([_naive_group(eng, src, m) for m in node.members])
    assert isinstance(node, Filter)
    rel = _naive_group(eng, src, node.child)
    m = filter_mask(node.expr, rel)
    return {v: c[m] for v, c in rel.items()}


def naive_evaluate(fed: Federation, query: BGPQuery) -> set[tuple[int, ...]]:
    from repro.rdf.dataset import TripleTable

    s = np.concatenate([src.table.s for src in fed.sources])
    p = np.concatenate([src.table.p for src in fed.sources])
    o = np.concatenate([src.table.o for src in fed.sources])
    table = TripleTable.from_triples(s, p, o)
    union = Source("union", table)
    eng = LocalEngine(Federation([union], fed.dictionary))
    if query.root is None:
        rel: Relation = {}
        for tp in query.patterns:
            nxt = eng._eval_pattern(union, tp, rel if rel else None)
            rel = nxt
            if _nrows(rel) == 0 and rel:
                break
        fill = 0
    else:
        rel = _naive_group(eng, union, query.algebra())
        fill = UNDEF
    proj = query.effective_projection()
    n = _nrows(rel)
    cols = [rel.get(v, np.full(n, fill, np.int32)) for v in proj]
    return set(zip(*[c.tolist() for c in cols])) if n else set()
