"""Host ("oracle") execution engine: exact, dynamically-shaped numpy
evaluation of physical plans over a federation, with the paper's runtime
metrics (NTT = tuples shipped endpoint->engine, requests, wall time).

This is the reference the distributed JAX engine is tested against, and the
executor behind the FedBench-style benchmarks (ET / NTT figures).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.planner import JoinPlanNode, PhysicalPlan, PlanNode, SubqueryNode
from repro.query.algebra import BGPQuery, Const, TriplePattern, Var
from repro.rdf.dataset import Federation, Source

Relation = dict[str, np.ndarray]  # same-length columns keyed by var name


def _empty(vars_: "list[str]") -> Relation:
    return {v: np.zeros(0, np.int32) for v in vars_}


def _nrows(rel: Relation) -> int:
    if not rel:
        return 0
    return len(next(iter(rel.values())))


def _concat(rels: "list[Relation]") -> Relation:
    """Union of same-schema relations. Keeps the column structure even when
    every input is empty — an empty-with-columns relation annihilates joins,
    whereas the no-columns relation ``{}`` is the join identity."""
    nonempty = [r for r in rels if _nrows(r)]
    if not nonempty:
        for r in rels:
            if r:
                return {k: v[:0] for k, v in r.items()}
        return {}
    keys = nonempty[0].keys()
    return {k: np.concatenate([r[k] for r in nonempty]) for k in keys}


def _dedup(rel: Relation) -> Relation:
    n = _nrows(rel)
    if n == 0:
        return rel
    keys = sorted(rel.keys())
    stacked = np.stack([rel[k].astype(np.int64) for k in keys], axis=1)
    _, idx = np.unique(stacked, axis=0, return_index=True)
    return {k: rel[k][np.sort(idx)] for k in rel}


@dataclass
class ExecutionMetrics:
    transferred_tuples: int = 0        # endpoint -> engine rows (NTT)
    requests: int = 0                  # subquery dispatches
    intermediate_rows: int = 0
    wall_ms: float = 0.0
    overflowed: bool = False


class LocalEngine:
    def __init__(self, fed: Federation):
        self.fed = fed

    # -- pattern / star evaluation at one endpoint ---------------------------
    def _eval_pattern(self, src: Source, tp: TriplePattern,
                      bindings: Relation | None = None) -> Relation:
        s, p, o = tp.constants()
        table = src.table
        out_vars = [t.name for t in (tp.s, tp.p, tp.o) if isinstance(t, Var)]
        if bindings is None or not any(
            isinstance(t, Var) and t.name in bindings for t in (tp.s, tp.p, tp.o)
        ):
            rows = table.scan(s, p, o)
            rel: Relation = {}
            if isinstance(tp.s, Var):
                rel[tp.s.name] = table.s[rows]
            if isinstance(tp.p, Var):
                rel[tp.p.name] = table.p[rows]
            if isinstance(tp.o, Var):
                rel[tp.o.name] = table.o[rows]
            if bindings is not None:
                return self._join(bindings, rel)
            return rel
        # bound evaluation: loop distinct relevant binding rows (bind join)
        join_vars = [v for v in (tp.s, tp.p, tp.o)
                     if isinstance(v, Var) and v.name in bindings]
        jnames = [v.name for v in join_vars]
        stacked = np.stack([bindings[v].astype(np.int64) for v in jnames], axis=1)
        uniq = np.unique(stacked, axis=0)
        parts: list[Relation] = []
        for row in uniq:
            bind = dict(zip(jnames, row.tolist()))
            s2 = bind.get(tp.s.name, s) if isinstance(tp.s, Var) else s
            p2 = bind.get(tp.p.name, p) if isinstance(tp.p, Var) else p
            o2 = bind.get(tp.o.name, o) if isinstance(tp.o, Var) else o
            rows = table.scan(s2, p2, o2)
            rel = {}
            if isinstance(tp.s, Var):
                rel[tp.s.name] = table.s[rows] if tp.s.name not in bind else np.full(len(rows), bind[tp.s.name], np.int32)
            if isinstance(tp.p, Var):
                rel[tp.p.name] = table.p[rows] if tp.p.name not in bind else np.full(len(rows), bind[tp.p.name], np.int32)
            if isinstance(tp.o, Var):
                rel[tp.o.name] = table.o[rows] if tp.o.name not in bind else np.full(len(rows), bind[tp.o.name], np.int32)
            parts.append(rel)
        matches = _concat(parts) if parts else _empty(out_vars)
        return self._join(bindings, matches)

    # -- generic hash join ----------------------------------------------------
    def _join(self, left: Relation, right: Relation) -> Relation:
        if not left:
            return right
        if not right:
            return left
        shared = sorted(set(left) & set(right))
        nl, nr = _nrows(left), _nrows(right)
        if not shared:  # cartesian
            li = np.repeat(np.arange(nl), nr)
            ri = np.tile(np.arange(nr), nl)
        else:
            lk = np.stack([left[v].astype(np.int64) for v in shared], axis=1)
            rk = np.stack([right[v].astype(np.int64) for v in shared], axis=1)
            # sort-merge on packed keys
            def pack(a: np.ndarray) -> np.ndarray:
                h = np.zeros(len(a), np.int64)
                for c in range(a.shape[1]):
                    h = h * 1_000_003 + a[:, c]
                return h
            hl, hr = pack(lk), pack(rk)
            order_r = np.argsort(hr, kind="stable")
            hr_s = hr[order_r]
            lo = np.searchsorted(hr_s, hl, side="left")
            hi = np.searchsorted(hr_s, hl, side="right")
            cnt = hi - lo
            li = np.repeat(np.arange(nl), cnt)
            ri_pos = np.concatenate([np.arange(l, h) for l, h in zip(lo, hi)]) if cnt.sum() else np.zeros(0, np.int64)
            ri = order_r[ri_pos.astype(np.int64)]
            if shared and len(li):
                # guard against packed-hash collisions: verify equality
                ok = np.ones(len(li), bool)
                for v in shared:
                    ok &= left[v][li] == right[v][ri]
                li, ri = li[ok], ri[ok]
        out: Relation = {}
        for v in left:
            out[v] = left[v][li]
        for v in right:
            if v not in out:
                out[v] = right[v][ri]
        return out

    def _eval_subquery(self, node: SubqueryNode, metrics: ExecutionMetrics,
                       bindings: Relation | None = None) -> Relation:
        """Evaluate the (merged) star subquery at each selected endpoint and
        union — intermediate joins happen remotely, only results ship."""
        full_vars: set[str] = set()
        for tp in node.patterns:
            full_vars |= set(tp.variables())
        if bindings:
            full_vars |= set(bindings)
        parts: list[Relation] = []
        for sid in node.sources:
            src = self.fed.sources[sid]
            rel: Relation | None = bindings
            for tp in node.patterns:
                rel = self._eval_pattern(src, tp, rel)
                if _nrows(rel) == 0 and rel:
                    break
            if rel is None or _nrows(rel) == 0:
                rel = _empty(sorted(full_vars))
            metrics.requests += 1
            metrics.transferred_tuples += _nrows(rel)
            parts.append(rel)
        out = _concat(parts)
        if not out:
            return _empty(sorted(full_vars))
        return out

    def _execute(self, node: PlanNode, metrics: ExecutionMetrics) -> Relation:
        if isinstance(node, SubqueryNode):
            return self._eval_subquery(node, metrics)
        assert isinstance(node, JoinPlanNode)
        left = self._execute(node.left, metrics)
        metrics.intermediate_rows += _nrows(left)
        if node.strategy == "bind" and isinstance(node.right, SubqueryNode):
            right_bound = self._eval_subquery(node.right, metrics, bindings=left)
            metrics.intermediate_rows += _nrows(right_bound)
            return right_bound
        right = self._execute(node.right, metrics)
        metrics.intermediate_rows += _nrows(right)
        return self._join(left, right)

    def execute(self, plan: PhysicalPlan) -> tuple[Relation, ExecutionMetrics]:
        metrics = ExecutionMetrics()
        t0 = time.perf_counter()
        rel = self._execute(plan.root, metrics)
        # query completion (§3.4 step iv): projection + DISTINCT
        proj = plan.query.effective_projection()
        rel = {v: rel.get(v, np.zeros(_nrows(rel), np.int32)) for v in proj}
        if plan.query.distinct:
            rel = _dedup(rel)
        metrics.wall_ms = (time.perf_counter() - t0) * 1e3
        return rel, metrics


# --------------------------------------------------------------------------
# Gold-standard evaluator: BGP over the union of all sources
# --------------------------------------------------------------------------

def naive_evaluate(fed: Federation, query: BGPQuery) -> set[tuple[int, ...]]:
    from repro.rdf.dataset import TripleTable

    s = np.concatenate([src.table.s for src in fed.sources])
    p = np.concatenate([src.table.p for src in fed.sources])
    o = np.concatenate([src.table.o for src in fed.sources])
    table = TripleTable.from_triples(s, p, o)
    union = Source("union", table)
    eng = LocalEngine(Federation([union], fed.dictionary))
    rel: Relation = {}
    for tp in query.patterns:
        nxt = eng._eval_pattern(union, tp, rel if rel else None)
        rel = nxt
        if _nrows(rel) == 0 and rel:
            break
    proj = query.effective_projection()
    n = _nrows(rel)
    cols = [rel.get(v, np.zeros(n, np.int32)) for v in proj]
    return set(zip(*[c.tolist() for c in cols])) if n else set()
