"""Adaptive operator-pipeline execution of physical plans.

``LocalEngine``'s original evaluator was one recursive ``_execute`` over the
plan tree: every subquery dispatched all of its sources in a fixed order, a
dead endpoint threw away the whole query, and nothing downstream learned how
wrong the optimizer's cardinalities were.  This module lowers a
``PhysicalPlan`` into an explicit graph of operators instead
(ADQUEX-style tuple routing, arXiv 1505.04880; ANAPSID-style symmetric-hash
joins):

* ``SubqueryOp`` + per-endpoint scan tasks, each routed through a
  ``SourceChannel`` that memoizes completed scans — a resumed or salvaged
  execution never re-ships tuples an endpoint already produced;
* ``SymHashJoinOp`` builds both sides incrementally: every arriving chunk is
  probed against the chunks already held for the other side, so match pairs
  exist long before either input is complete (the scheduler's scan order is
  free to change without changing the answer);
* a routing layer (``drop_source`` / ``_alternates``) that, when an endpoint
  dies mid-query, drops only that endpoint's scans — or redirects a star
  subquery to an alternate relevant source retained by the
  ``SourceSelection`` — and re-derives the dataflow from the salvaged parts.

Bit-identity contract: on a healthy federation ``PipelineExecution.run()``
returns exactly the rows (same order), NTT, request and intermediate-row
counts as ``LocalEngine.execute_recursive``.  The legacy join emits match
pairs sorted by ``(left_row, right_row)`` — its right indices come from a
stable argsort of the packed keys, so equal-key runs keep ascending original
order — and the symmetric-hash join reproduces that canonical order by
sorting its accumulated pairs at finalize, whatever order chunks arrived in.
See docs/execution.md.

Every scan and operator also records observed vs. estimated cardinality on
``ExecutionResult.card_log`` — the dirty-source signal consumed by
``repro.stats.feedback`` to trigger incremental ``refresh_source``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.planner import (
    FilterPlanNode,
    JoinPlanNode,
    LeftJoinPlanNode,
    PhysicalPlan,
    PlanNode,
    SubqueryNode,
    UnionPlanNode,
)
from repro.engine.local import (
    ExecutionMetrics,
    ExecutionResult,
    Relation,
    _concat,
    _dedup,
    _empty,
    _nrows,
    _outer_union,
    filter_mask,
    join_indices,
    join_rels,
)
from repro.query.algebra import TriplePattern, Var
from repro.rdf.dataset import Federation

UNDEF = int(np.int32(-1))


class VirtualClock:
    """Deterministic simulated clock for fault-injection tests and the
    adaptive benchmark: calling it reads the current virtual time,
    ``advance`` moves it forward (``SourceChannel`` charges each physical
    scan its endpoint's ``latency_s`` here; ``RetryPolicy(sleep=clock.
    advance)`` retries without wall-clock sleeps)."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += float(dt)


@dataclass(frozen=True)
class CardObservation:
    """One observed-vs-estimated cardinality sample.

    ``kind`` is ``"scan"`` (unbound single-star dispatch: the one form whose
    estimate and observation measure the same quantity, so the feedback hook
    scores only these by default), ``"scan_merged"`` / ``"scan_bound"`` for
    merged-exclusive-group and bind-join dispatches, or an operator kind
    (``"subquery"``/``"join"``/``"leftjoin"``/``"union"``/``"filter"``).
    ``source`` is the endpoint name for scan kinds, ``None`` for operators.
    """

    kind: str
    source: "str | None"
    star: "int | None"
    est: "float | None"
    obs: int


class SourceChannel:
    """The engine's connection to one endpoint.

    Owns fault injection (duck-typed against ``FlakySource``: ``check()`` at
    dispatch, ``note_tuples()`` per physical scan, ``latency_s`` for the
    simulated clock), the physical transfer counters the salvage tests and
    benchmark assert on, and a memo of completed scans keyed by the scan
    constants — the reason a salvaged or resumed execution never re-ships
    tuples this endpoint already produced.
    """

    def __init__(self, src, pos: int, honor_faults: bool, clock=None):
        self.src = src
        self.pos = pos
        self.honor_faults = honor_faults
        self.clock = clock
        self.dropped = False            # excluded mid-query by drop_source
        self.physical_scans = 0         # endpoint scans actually executed
        self.physical_tuples = 0        # tuples shipped endpoint -> engine
        self.cache_hits = 0             # scans answered from the memo
        self._scans: "dict[tuple, np.ndarray]" = {}

    @property
    def name(self) -> str:
        return self.src.name

    def latency_estimate(self) -> float:
        return float(getattr(self.src, "latency_s", 0.0) or 0.0)

    def connect(self) -> None:
        """Dispatch-time health check (raises ``EndpointDown`` on a dead or
        transiently failing ``FlakySource``)."""
        if self.honor_faults:
            check = getattr(self.src, "check", None)
            if check is not None:
                check()

    def scan(self, s, p, o) -> np.ndarray:
        key = (s, p, o)
        hit = self._scans.get(key)
        if hit is not None:
            self.cache_hits += 1
            return hit
        rows = self.src.table.scan(s, p, o)
        if self.honor_faults:
            note = getattr(self.src, "note_tuples", None)
            if note is not None:
                note(len(rows))         # may raise: mid-scan endpoint death
        lat = self.latency_estimate()
        if lat and self.clock is not None:
            adv = getattr(self.clock, "advance", None)
            if adv is not None:
                adv(lat)
        self.physical_scans += 1
        self.physical_tuples += len(rows)
        self._scans[key] = rows
        return rows


# --------------------------------------------------------------------------
# Operators
# --------------------------------------------------------------------------

class Op:
    """One pipeline operator.  Children push chunks via ``accept``; ``emit``
    finalizes (once per run) and returns the operator's full relation."""

    kind = "op"

    def __init__(self, exec_: "PipelineExecution", node: PlanNode,
                 children: "list[Op]"):
        self.exec = exec_
        self.node = node
        self.children = children
        self.parent: "Op | None" = None
        self.port = 0
        for i, c in enumerate(children):
            c.parent, c.port = self, i
        self.out: "Relation | None" = None

    def reset(self) -> None:
        self.out = None

    def accept(self, port: int, slot: int, rel: Relation) -> None:
        """Push one chunk of input ``port`` (default: buffering operators
        ignore chunks and pull full inputs at finalize)."""

    def finalize(self) -> Relation:
        raise NotImplementedError

    def emit(self) -> Relation:
        if self.out is None:
            self.out = self.finalize()
            est = getattr(self.node, "est_cardinality", None)
            self.exec._log(self.kind, None, None, est, _nrows(self.out))
        return self.out

    # chunked-output protocol (consumed by pair-accumulating parents)
    def chunk_sizes(self) -> "list[int]":
        return [_nrows(self.emit())]


class SubqueryOp(Op):
    """One (merged) star subquery: a scan task per live endpoint slot, the
    output the slot-ordered union of the shipped parts.  ``slots`` is the
    routing state — ``drop_source`` removes a dead endpoint's slot (and may
    append an alternate relevant source); ``shipped`` memoizes completed
    unbound dispatches across runs, so salvage re-derives the dataflow
    without re-executing them."""

    kind = "subquery"

    def __init__(self, exec_, node: SubqueryNode, bound: bool = False):
        super().__init__(exec_, node, [])
        self.bound = bound
        self.slots: "list[int]" = list(node.sources)
        ests = getattr(node, "est_source_cards", None) or []
        self.est_by_pos = dict(zip(node.sources, ests))
        self.shipped: "dict[int, Relation]" = {}   # unbound parts, cross-run
        self.parts: "dict[int, Relation]" = {}     # committed this run
        self.bindings: "Relation | None" = None    # set by BindJoinOp

    def reset(self) -> None:
        super().reset()
        self.parts = {}
        self.bindings = None

    def full_vars(self) -> "set[str]":
        out: set[str] = set()
        for tp in self.node.patterns:
            out |= set(tp.variables())
        if self.bindings:
            out |= set(self.bindings)
        return out

    def scan_kind(self) -> str:
        if self.bound:
            return "scan_bound"
        return "scan" if len(self.node.stars) == 1 else "scan_merged"

    def slot_index(self, pos: int) -> int:
        return self.slots.index(pos)

    def finalize(self) -> Relation:
        parts = [self.parts[p] for p in self.slots]
        out = _concat(parts)
        if not out:
            return _empty(sorted(self.full_vars()))
        return out

    def chunk_sizes(self) -> "list[int]":
        return [_nrows(self.parts[p]) for p in self.slots]


class SymHashJoinOp(Op):
    """Non-blocking symmetric-hash join: chunks from either input are probed
    against the chunks already held for the other input the moment they
    arrive, accumulating ``(left_chunk, left_row, right_chunk, right_row)``
    match quadruples.  Finalize assigns canonical row offsets (chunk order =
    the child's slot order) and sorts the pairs by global ``(li, ri)`` —
    exactly the legacy sort-merge emission order — so the answer is invariant
    to the scheduler's arrival order."""

    kind = "join"

    def __init__(self, exec_, node, children):
        super().__init__(exec_, node, children)
        self._chunks: "tuple[dict[int, Relation], dict[int, Relation]]" = ({}, {})
        self._pairs: "list[tuple[int, np.ndarray, int, np.ndarray]]" = []

    def reset(self) -> None:
        super().reset()
        self._chunks = ({}, {})
        self._pairs = []

    def accept(self, port: int, slot: int, rel: Relation) -> None:
        other = self._chunks[1 - port]
        found = 0
        for oslot, orel in other.items():
            if port == 0:
                li, ri = join_indices(rel, orel)
                quad = (slot, li, oslot, ri)
            else:
                li, ri = join_indices(orel, rel)
                quad = (oslot, li, slot, ri)
            if len(li):
                self._pairs.append(quad)
                found += len(li)
        self._chunks[port][slot] = rel
        if found:
            self.exec._note_progress(found)

    def _ingest_pending(self) -> None:
        """Pull the single output chunk of any child that does not stream
        (joins, filters, unions below this one push nothing during the scan
        phase)."""
        for port, child in enumerate(self.children):
            if not isinstance(child, SubqueryOp):
                if 0 not in self._chunks[port]:
                    self.accept(port, 0, child.emit())

    def _offsets(self, port: int) -> np.ndarray:
        sizes = self.children[port].chunk_sizes()
        return np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)

    def _canonical_pairs(self) -> "tuple[np.ndarray, np.ndarray]":
        if not self._pairs:
            z = np.zeros(0, np.int64)
            return z, z
        loff, roff = self._offsets(0), self._offsets(1)
        li = np.concatenate([loff[ls] + a for ls, a, _, _ in self._pairs])
        ri = np.concatenate([roff[rs] + b for _, _, rs, b in self._pairs])
        order = np.lexsort((ri, li))
        return li[order], ri[order]

    def finalize(self) -> Relation:
        lrel = self.children[0].emit()
        rrel = self.children[1].emit()
        self.exec.metrics.intermediate_rows += _nrows(lrel) + _nrows(rrel)
        if not lrel:            # legacy join identities ({} == no columns)
            return rrel
        if not rrel:
            return lrel
        self._ingest_pending()
        li, ri = self._canonical_pairs()
        out: Relation = {v: lrel[v][li] for v in lrel}
        for v in rrel:
            if v not in out:
                out[v] = rrel[v][ri]
        return out


class LeftJoinOp(SymHashJoinOp):
    """OPTIONAL on the pair-accumulating machinery: the canonical inner-join
    pairs plus every unmatched left row (ascending), right-only columns
    padded with UNDEF — the legacy ``_left_join`` emission order."""

    kind = "leftjoin"

    def finalize(self) -> Relation:
        lrel = self.children[0].emit()
        rrel = self.children[1].emit()
        self.exec.metrics.intermediate_rows += _nrows(lrel) + _nrows(rrel)
        if not lrel:
            return rrel
        if not rrel:
            return lrel
        self._ingest_pending()
        li, ri = self._canonical_pairs()
        matched = np.zeros(_nrows(lrel), bool)
        matched[li] = True
        un = np.nonzero(~matched)[0]
        out: Relation = {}
        for v in lrel:
            out[v] = np.concatenate([lrel[v][li], lrel[v][un]])
        for v in rrel:
            if v not in out:
                out[v] = np.concatenate(
                    [rrel[v][ri], np.full(len(un), UNDEF, rrel[v].dtype)])
        return out


class BindJoinOp(Op):
    """Bind join: the right star subquery is dispatched *bound* to the
    finalized left relation (one scan per distinct relevant binding row at
    each endpoint), and its union — each part already joined with the
    bindings endpoint-side — is the join output, as in the legacy
    ``_eval_subquery(node.right, bindings=left)``."""

    kind = "join"

    def finalize(self) -> Relation:
        left = self.children[0].emit()
        self.exec.metrics.intermediate_rows += _nrows(left)
        rop = self.children[1]
        rop.bindings = left
        self.exec._run_bound_tasks(rop)
        out = rop.emit()
        self.exec.metrics.intermediate_rows += _nrows(out)
        return out


class UnionOp(Op):
    kind = "union"

    def finalize(self) -> Relation:
        parts = [c.emit() for c in self.children]
        for p in parts:
            self.exec.metrics.intermediate_rows += _nrows(p)
        return _outer_union(parts)


class FilterOp(Op):
    kind = "filter"

    def finalize(self) -> Relation:
        rel = self.children[0].emit()
        self.exec.metrics.intermediate_rows += _nrows(rel)
        m = filter_mask(self.node.expr, rel)
        return {v: c[m] for v, c in rel.items()}


@dataclass
class ScanTask:
    op: SubqueryOp
    pos: int                    # endpoint position in the compile-time fed


# --------------------------------------------------------------------------
# The execution
# --------------------------------------------------------------------------

class PipelineExecution:
    """One plan lowered onto one federation, resumable and salvageable.

    ``run()`` is re-entrant: every call resets the operator states, replays
    the parts already shipped (channel memos make that free of endpoint
    traffic), then executes the remaining scan tasks in the routing policy's
    order.  Logical metrics (NTT / requests / intermediate rows — what the
    paper counts) are recomputed per run and match the legacy evaluator on
    the surviving plan; physical transfer lives on the ``SourceChannel``s
    and only ever grows by the genuinely new work.

    ``policy``: ``"static"`` dispatches scans in plan order (the legacy
    order); ``"adaptive"`` dispatches fast endpoints first (by
    ``latency_s``-informed estimate) so joins see chunks early and degraded
    endpoints cannot stall the pipeline head; ``"random"`` shuffles (the
    schedule-invariance tests).  The answer is policy-invariant by the
    canonical-pair contract.
    """

    def __init__(self, plan: PhysicalPlan, fed: Federation,
                 honor_faults: bool = False, policy: str = "static",
                 clock=None, rng=None):
        if policy not in ("static", "adaptive", "random"):
            raise ValueError(f"unknown scan policy {policy!r}")
        self.plan = plan
        self.fed = fed
        self.honor_faults = honor_faults
        self.policy = policy
        self.clock = clock
        self.rng = rng or np.random.default_rng(0)
        self.metrics = ExecutionMetrics()
        self.card_log: "list[CardObservation]" = []
        self.channels: "dict[int, SourceChannel]" = {}
        self.ops: "list[Op]" = []
        self.subquery_ops: "list[SubqueryOp]" = []
        self.root_op = self._build(plan.root)
        self.salvages = 0
        self.rerouted: "list[tuple[str, str]]" = []
        self.first_answer_t: "float | None" = None

    # -- graph construction --------------------------------------------------
    def _build(self, node: PlanNode) -> Op:
        if isinstance(node, SubqueryNode):
            op = SubqueryOp(self, node)
        elif isinstance(node, LeftJoinPlanNode):
            op = LeftJoinOp(self, node, [self._build(node.left),
                                         self._build(node.right)])
        elif isinstance(node, UnionPlanNode):
            op = UnionOp(self, node, [self._build(c) for c in node.children])
        elif isinstance(node, FilterPlanNode):
            op = FilterOp(self, node, [self._build(node.child)])
        else:
            if not isinstance(node, JoinPlanNode):
                raise TypeError(f"unknown plan node {type(node).__name__}")
            if node.strategy == "bind" and isinstance(node.right, SubqueryNode):
                right = SubqueryOp(self, node.right, bound=True)
                self.ops.append(right)
                self.subquery_ops.append(right)
                op = BindJoinOp(self, node, [self._build(node.left), right])
            else:
                op = SymHashJoinOp(self, node, [self._build(node.left),
                                                self._build(node.right)])
        self.ops.append(op)
        if isinstance(op, SubqueryOp):
            self.subquery_ops.append(op)
        return op

    def _channel(self, pos: int) -> SourceChannel:
        ch = self.channels.get(pos)
        if ch is None:
            ch = SourceChannel(self.fed.sources[pos], pos,
                               self.honor_faults, self.clock)
            self.channels[pos] = ch
        return ch

    # -- bookkeeping ---------------------------------------------------------
    def _log(self, kind, source, star, est, obs) -> None:
        self.card_log.append(CardObservation(kind=kind, source=source,
                                             star=star, est=est, obs=obs))

    def _note_progress(self, n_matches: int) -> None:
        if n_matches and self.first_answer_t is None:
            self.first_answer_t = (self.clock() if self.clock is not None
                                   else time.perf_counter())

    def _now(self) -> float:
        return self.clock() if self.clock is not None else time.perf_counter()

    # -- per-endpoint evaluation (mirrors LocalEngine._eval_pattern) ---------
    def _eval_pattern(self, chan: SourceChannel, tp: TriplePattern,
                      bindings: "Relation | None") -> Relation:
        s, p, o = tp.constants()
        table = chan.src.table
        out_vars = [t.name for t in (tp.s, tp.p, tp.o) if isinstance(t, Var)]
        if bindings is None or not any(
            isinstance(t, Var) and t.name in bindings for t in (tp.s, tp.p, tp.o)
        ):
            rows = chan.scan(s, p, o)
            rel: Relation = {}
            if isinstance(tp.s, Var):
                rel[tp.s.name] = table.s[rows]
            if isinstance(tp.p, Var):
                rel[tp.p.name] = table.p[rows]
            if isinstance(tp.o, Var):
                rel[tp.o.name] = table.o[rows]
            if bindings is not None:
                return join_rels(bindings, rel)
            return rel
        join_vars = [v for v in (tp.s, tp.p, tp.o)
                     if isinstance(v, Var) and v.name in bindings]
        jnames = [v.name for v in join_vars]
        stacked = np.stack([bindings[v].astype(np.int64) for v in jnames], axis=1)
        uniq = np.unique(stacked, axis=0)
        parts: list[Relation] = []
        for row in uniq:
            bind = dict(zip(jnames, row.tolist()))
            s2 = bind.get(tp.s.name, s) if isinstance(tp.s, Var) else s
            p2 = bind.get(tp.p.name, p) if isinstance(tp.p, Var) else p
            o2 = bind.get(tp.o.name, o) if isinstance(tp.o, Var) else o
            rows = chan.scan(s2, p2, o2)
            rel = {}
            if isinstance(tp.s, Var):
                rel[tp.s.name] = table.s[rows] if tp.s.name not in bind else np.full(len(rows), bind[tp.s.name], np.int32)
            if isinstance(tp.p, Var):
                rel[tp.p.name] = table.p[rows] if tp.p.name not in bind else np.full(len(rows), bind[tp.p.name], np.int32)
            if isinstance(tp.o, Var):
                rel[tp.o.name] = table.o[rows] if tp.o.name not in bind else np.full(len(rows), bind[tp.o.name], np.int32)
            parts.append(rel)
        matches = _concat(parts) if parts else _empty(out_vars)
        return join_rels(bindings, matches)

    def _ship(self, chan: SourceChannel, op: SubqueryOp) -> Relation:
        """One subquery dispatch at one endpoint: the legacy per-source chain
        with early break, through the channel's scan memo."""
        rel: "Relation | None" = op.bindings
        for tp in op.node.patterns:
            rel = self._eval_pattern(chan, tp, rel)
            if _nrows(rel) == 0 and rel:
                break
        if rel is None or _nrows(rel) == 0:
            rel = _empty(sorted(op.full_vars()))
        return rel

    def _commit(self, op: SubqueryOp, pos: int, part: Relation) -> None:
        op.parts[pos] = part
        self.metrics.requests += 1
        self.metrics.transferred_tuples += _nrows(part)
        star = op.node.stars[0] if len(op.node.stars) == 1 else None
        self._log(op.scan_kind(), self.channels[pos].name, star,
                  op.est_by_pos.get(pos), _nrows(part))
        if op.parent is not None:
            op.parent.accept(op.port, op.slot_index(pos), part)
        if op is self.root_op:
            self._note_progress(_nrows(part))

    def _order(self, tasks: "list[ScanTask]") -> "list[ScanTask]":
        if self.policy == "adaptive":
            return sorted(tasks,
                          key=lambda t: self._channel(t.pos).latency_estimate())
        if self.policy == "random":
            tasks = list(tasks)
            self.rng.shuffle(tasks)  # type: ignore[arg-type]
            return tasks
        return tasks

    def _run_bound_tasks(self, op: SubqueryOp) -> None:
        """Dispatch a bound subquery (the right side of a bind join) once its
        bindings are final.  Bound parts are never memoized across runs — the
        bindings may shrink after a salvage — but every underlying scan hits
        the channel memo, so a re-derivation ships nothing."""
        for task in self._order([ScanTask(op, p) for p in op.slots]):
            chan = self._channel(task.pos)
            chan.connect()
            self._commit(op, task.pos, self._ship(chan, op))

    def scan_order(self) -> "list[tuple[SubqueryOp, int]]":
        """The unbound scan schedule the next ``run()`` would use (testing /
        introspection)."""
        tasks = [ScanTask(op, pos) for op in self.subquery_ops
                 if not op.bound for pos in op.slots]
        return [(t.op, t.pos) for t in self._order(tasks)]

    # -- the run loop --------------------------------------------------------
    def run(self) -> ExecutionResult:
        t0 = time.perf_counter()
        self.metrics = ExecutionMetrics()
        self.card_log = []
        self.first_answer_t = None
        for op in self.ops:
            op.reset()
        replay: "list[ScanTask]" = []
        todo: "list[ScanTask]" = []
        for op in self.subquery_ops:
            if op.bound:
                continue
            for pos in op.slots:
                t = ScanTask(op, pos)
                (replay if pos in op.shipped else todo).append(t)
        # salvaged / resumed parts first: re-derive the dataflow for free
        for t in replay:
            self._channel(t.pos)
            self._commit(t.op, t.pos, t.op.shipped[t.pos])
        for t in self._order(todo):
            chan = self._channel(t.pos)
            chan.connect()
            part = self._ship(chan, t.op)
            t.op.shipped[t.pos] = part
            self._commit(t.op, t.pos, part)
        rel = self.root_op.emit()
        # query completion (§3.4 step iv), identical to the legacy evaluator
        fill = 0 if self.plan.query.root is None else UNDEF
        proj = self.plan.query.effective_projection()
        rel = {v: rel.get(v, np.full(_nrows(rel), fill, np.int32)) for v in proj}
        if self.plan.query.distinct:
            rel = _dedup(rel)
        self.metrics.wall_ms = (time.perf_counter() - t0) * 1e3
        return ExecutionResult(rows=rel, metrics=self.metrics, plan=self.plan,
                               stats_epoch=self.plan.stats_epoch,
                               card_log=tuple(self.card_log))

    # -- routing / salvage ---------------------------------------------------
    def _alternates(self, op: SubqueryOp) -> "list[int]":
        """Relevant sources the ``SourceSelection`` retains for this
        subquery's star(s) beyond the plan's dispatch list — the re-route
        candidates when one of its endpoints dies."""
        sel = self.plan.selection
        if sel is None or not op.node.stars:
            return []
        cands: "set[int] | None" = None
        for si in op.node.stars:
            if si >= len(sel.star_sources):
                return []
            s = set(sel.star_sources[si])
            cands = s if cands is None else (cands & s)
        return sorted(cands or ())

    def drop_source(self, name: str) -> "list[str]":
        """Salvage after an endpoint death: remove the dead endpoint's slots
        from every subquery, re-route to alternate relevant sources where the
        selection retains any, and keep every already-shipped part of the
        survivors — the next ``run()`` re-derives the answer without
        re-executing completed scans.  Returns the names of any endpoints
        newly routed in."""
        pos = next((p for p, ch in self.channels.items() if ch.name == name),
                   None)
        if pos is None:
            pos = next(i for i, s in enumerate(self.fed.sources)
                       if s.name == name)
        chan = self._channel(pos)
        chan.dropped = True
        routed: "list[str]" = []
        for op in self.subquery_ops:
            if pos not in op.slots:
                continue
            op.slots.remove(pos)
            op.shipped.pop(pos, None)
            for alt in self._alternates(op):
                if alt == pos or alt in op.slots:
                    continue
                if self._channel(alt).dropped:
                    continue
                if getattr(self.fed.sources[alt], "dead", False):
                    continue
                op.slots.append(alt)
                nm = self.fed.sources[alt].name
                routed.append(nm)
                self.rerouted.append((name, nm))
        self.salvages += 1
        return routed

    # -- physical-transfer introspection ------------------------------------
    @property
    def physical_scans(self) -> int:
        return sum(ch.physical_scans for ch in self.channels.values())

    @property
    def physical_tuples(self) -> int:
        return sum(ch.physical_tuples for ch in self.channels.values())


def compile_plan(plan: PhysicalPlan, fed: Federation,
                 honor_faults: bool = False, policy: str = "static",
                 clock=None, rng=None) -> PipelineExecution:
    """Lower ``plan`` into a resumable operator pipeline over ``fed``."""
    return PipelineExecution(plan, fed, honor_faults=honor_faults,
                             policy=policy, clock=clock, rng=rng)
