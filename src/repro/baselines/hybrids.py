"""Hybrid optimizers (paper §4.2, Fig. 9):

* ``OdysseyFedX`` — Odyssey's CS/CP source selection + star decomposition,
  FedX's variable-counting join ordering + bind joins.
* ``FedXOdyssey`` — FedX's ASK source selection, Odyssey's decomposition +
  DP join ordering over CS/CP cardinalities.
"""
from __future__ import annotations

import time

from repro.baselines.fedx import variable_counting_score
from repro.core.decomposition import decompose
from repro.core.federation import FederatedStats
from repro.core.join_order import dp_join_order, order_star_patterns
from repro.core.planner import (JoinPlanNode, OdysseyOptimizer, PhysicalPlan,
                                PlanNode, SubqueryNode, _vars_of)
from repro.core.source_selection import SourceSelection, select_sources
from repro.query.algebra import BGPQuery
from repro.rdf.dataset import Federation


class OdysseyFedX:
    """Odyssey source selection/decomposition + FedX ordering."""

    def __init__(self, stats: FederatedStats):
        self.stats = stats

    def optimize(self, query: BGPQuery) -> PhysicalPlan:
        t0 = time.perf_counter()
        graph = decompose(query)
        sel = select_sources(graph, self.stats)
        # units: stars; merge stars sharing one exclusive source
        groups: dict[int, list[int]] = {}
        multi: list[int] = []
        for s in graph.stars:
            srcs = sel.star_sources[s.idx]
            if len(srcs) == 1:
                groups.setdefault(srcs[0], []).append(s.idx)
            else:
                multi.append(s.idx)
        units: list[tuple[list[int], list[int]]] = []
        for src, stars in groups.items():
            units.append((stars, [src]))
        for si in multi:
            units.append(([si], sel.star_sources[si]))

        ordered: list[tuple[list[int], list[int]]] = []
        bound: set[str] = set()
        remaining = list(units)
        while remaining:
            def score(u):
                stars, srcs = u
                sc = min(min(variable_counting_score(tp, bound)
                             for tp in graph.stars[si].patterns) for si in stars)
                connected = any(graph.stars[si].variables() & bound
                                for si in stars) if bound else True
                return (not connected, sc, len(srcs) > 1)
            remaining.sort(key=score)
            u = remaining.pop(0)
            ordered.append(u)
            for si in u[0]:
                bound |= graph.stars[si].variables()

        def leaf(u):
            stars, srcs = u
            pats = []
            for si in sorted(stars):
                pats.extend(order_star_patterns(graph.stars[si], self.stats, sel,
                                                query.distinct))
            return SubqueryNode(stars=sorted(stars), patterns=pats, sources=list(srcs))

        root: PlanNode = leaf(ordered[0])
        for u in ordered[1:]:
            rhs = leaf(u)
            jvars = sorted(_vars_of(root) & _vars_of(rhs))
            root = JoinPlanNode(left=root, right=rhs, strategy="bind", join_vars=jvars)
        plan = PhysicalPlan(root=root, query=query, graph=graph, selection=sel)
        plan.fallback = any(s.has_var_pred for s in graph.stars)
        plan.optimization_ms = (time.perf_counter() - t0) * 1e3
        return plan


class FedXOdyssey(OdysseyOptimizer):
    """FedX ASK-based source selection + Odyssey decomposition/DP ordering."""

    def __init__(self, stats: FederatedStats, fed: Federation):
        super().__init__(stats)
        self.fed = fed

    def optimize(self, query: BGPQuery) -> PhysicalPlan:
        t0 = time.perf_counter()
        graph = decompose(query)
        # ASK selection per star: sources answering every pattern of the star
        star_sources: list[list[int]] = []
        star_cs: list[dict] = []
        import numpy as np
        for s in graph.stars:
            srcs = []
            for i, src in enumerate(self.fed.sources):
                if all(src.ask(*tp.constants()) for tp in s.patterns):
                    srcs.append(i)
            star_sources.append(srcs)
            star_cs.append({i: self.stats.cs[i].relevant_cs(s.bound_preds())
                            for i in srcs})
        sel = SourceSelection(star_sources=star_sources, star_cs=star_cs)
        tree = dp_join_order(graph, self.stats, sel, self.cost_model, query.distinct)
        root = self._emit(tree, graph, sel, query)
        plan = PhysicalPlan(root=root, query=query, graph=graph, selection=sel)
        plan.fallback = any(s.has_var_pred for s in graph.stars)
        plan.optimization_ms = (time.perf_counter() - t0) * 1e3
        return plan
