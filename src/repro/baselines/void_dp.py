"""DP-VOID baseline (paper §4): dynamic programming over *triple patterns*
with VOID-granularity statistics — uniformity + independence assumptions,
exactly the estimation errors CSs/CPs were designed to avoid. With
``use_ask=True`` this approximates SPLENDID/SemaGrow (VOID + ASK-refined
source selection)."""
from __future__ import annotations

import time
from itertools import combinations

from repro.core.cost import CostModel
from repro.core.decomposition import decompose
from repro.core.planner import JoinPlanNode, PhysicalPlan, PlanNode, SubqueryNode
from repro.query.algebra import BGPQuery, Const, TriplePattern, Var
from repro.rdf.dataset import Federation
from repro.stats.void import VoidStats, compute_void

from repro.baselines.fedx import _selection_from_patterns, _star_of


class VoidDPOptimizer:
    def __init__(self, fed: Federation, void: list[VoidStats] | None = None,
                 use_ask: bool = False, cost_model: CostModel | None = None):
        self.fed = fed
        self.void = void or [compute_void(s.table) for s in fed.sources]
        self.use_ask = use_ask
        self.cm = cost_model or CostModel()

    def _sources_for(self, tp: TriplePattern) -> list[int]:
        s, p, o = tp.constants()
        out = []
        for i, v in enumerate(self.void):
            if p is not None:
                if not v.has_pred(p):
                    continue
                if self.use_ask and not self.fed.sources[i].ask(s, p, o):
                    continue
                out.append(i)
            else:
                if self.use_ask and not self.fed.sources[i].ask(s, p, o):
                    continue
                out.append(i)
        return out

    def _card(self, tp: TriplePattern, srcs: list[int]) -> float:
        s, p, o = tp.constants()
        return sum(self.void[i].estimate_pattern(s, p, o) for i in srcs)

    def optimize(self, query: BGPQuery) -> PhysicalPlan:
        t0 = time.perf_counter()
        graph = decompose(query)
        pats = query.patterns
        n = len(pats)
        pat_sources = [self._sources_for(tp) for tp in pats]
        base_card = [max(self._card(tp, pat_sources[i]), 0.0) for i, tp in enumerate(pats)]

        # independence-assumption join selectivity: 1/max(distinct join keys)
        def pair_sel(i: int, j: int) -> float:
            shared = pats[i].variables() & pats[j].variables()
            if not shared:
                return 1.0
            sel = 1.0
            for _v in shared:
                d1 = max(1.0, base_card[i])
                d2 = max(1.0, base_card[j])
                sel *= 1.0 / max(1.0, min(d1, d2))
            return sel

        def subset_card(ss: frozenset[int]) -> float:
            card = 1.0
            for i in ss:
                card *= base_card[i]
            for i, j in combinations(sorted(ss), 2):
                card *= pair_sel(i, j)
            return card

        best: dict[frozenset[int], tuple[float, PlanNode, float]] = {}
        for i in range(n):
            ss = frozenset([i])
            node = SubqueryNode(stars=[_star_of(graph, i)], patterns=[pats[i]],
                                sources=pat_sources[i], est_cardinality=base_card[i])
            best[ss] = (self.cm.leaf_cost(base_card[i], pat_sources[i]), node, base_card[i])

        for size in range(2, n + 1):
            for combo in combinations(range(n), size):
                ss = frozenset(combo)
                cand = None
                for k in range(1, size):
                    for sub in combinations(combo, k):
                        a = frozenset(sub)
                        b = ss - a
                        if a not in best or b not in best:
                            continue
                        ca, na, karda = best[a]
                        cb, nb, kardb = best[b]
                        # require connectivity
                        va = set().union(*[pats[i].variables() for i in a])
                        vb = set().union(*[pats[i].variables() for i in b])
                        if not (va & vb) and size < n:
                            continue
                        card = subset_card(ss)
                        hash_cost = ca + cb + self.cm.hash_join_cost(card)
                        bind_ok = isinstance(nb, SubqueryNode)
                        bind_cost = (ca + self.cm.bind_join_cost(karda, card, nb.sources)
                                     if bind_ok else float("inf"))
                        strategy = "bind" if bind_cost < hash_cost else "hash"
                        cost = min(hash_cost, bind_cost)
                        if cand is None or cost < cand[0]:
                            jvars = sorted(va & vb)
                            cand = (cost, JoinPlanNode(left=na, right=nb, strategy=strategy,
                                                       join_vars=jvars, est_cardinality=card), card)
                if cand is not None and (ss not in best or cand[0] < best[ss][0]):
                    best[ss] = cand

        full = frozenset(range(n))
        root = best[full][1] if full in best else best[max(best, key=len)][1]
        sel = _selection_from_patterns(graph, query, pat_sources)
        plan = PhysicalPlan(root=root, query=query, graph=graph, selection=sel)
        plan.optimization_ms = (time.perf_counter() - t0) * 1e3
        return plan
