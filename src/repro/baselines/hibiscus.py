"""HiBISCuS-style baseline [14]: hypergraph source pruning via IRI-authority
intersections on join variables, on top of FedX-style ASK selection and
variable-counting ordering."""
from __future__ import annotations

import time

import numpy as np

from repro.baselines.fedx import FedXOptimizer, _selection_from_patterns
from repro.core.planner import PhysicalPlan
from repro.query.algebra import BGPQuery, Const, TriplePattern, Var
from repro.rdf.dataset import Federation


class HibiscusOptimizer(FedXOptimizer):
    def __init__(self, fed: Federation, warm: bool = False):
        super().__init__(fed, warm=warm)
        # per source, per predicate: subject/object authority sets
        auth = fed.dictionary.authority_array()
        self.subj_auth: list[dict[int, set[int]]] = []
        self.obj_auth: list[dict[int, set[int]]] = []
        for src in fed.sources:
            t = src.table
            sa: dict[int, set[int]] = {}
            oa: dict[int, set[int]] = {}
            for p in np.unique(t.p).tolist():
                rows = t.scan(None, int(p), None)
                sa[int(p)] = set(auth[t.s[rows]].tolist())
                oa[int(p)] = set(auth[t.o[rows]].tolist())
            self.subj_auth.append(sa)
            self.obj_auth.append(oa)

    def _prune_by_authorities(self, query: BGPQuery, pat_sources: list[list[int]]) -> list[list[int]]:
        """Drop a source for tp_i if, for some join variable, the authority
        sets of the joined positions cannot intersect with *any* surviving
        source of the partner pattern."""
        pats = query.patterns

        def auth_of(pi: int, src: int, pos: str) -> set[int]:
            tp = pats[pi]
            if not isinstance(tp.p, Const):
                return set().union(*self.subj_auth[src].values()) if pos == "s" else \
                    set().union(*self.obj_auth[src].values())
            table = self.subj_auth if pos == "s" else self.obj_auth
            return table[src].get(tp.p.tid, set())

        changed = True
        while changed:
            changed = False
            for i, tp_i in enumerate(pats):
                for j, tp_j in enumerate(pats):
                    if i == j:
                        continue
                    shared = tp_i.variables() & tp_j.variables()
                    for v in shared:
                        pos_i = "s" if (isinstance(tp_i.s, Var) and tp_i.s.name == v) else \
                            ("o" if (isinstance(tp_i.o, Var) and tp_i.o.name == v) else None)
                        pos_j = "s" if (isinstance(tp_j.s, Var) and tp_j.s.name == v) else \
                            ("o" if (isinstance(tp_j.o, Var) and tp_j.o.name == v) else None)
                        if pos_i is None or pos_j is None:
                            continue
                        partner_auth: set[int] = set()
                        for b in pat_sources[j]:
                            partner_auth |= auth_of(j, b, pos_j)
                        keep = [a for a in pat_sources[i]
                                if auth_of(i, a, pos_i) & partner_auth]
                        if len(keep) < len(pat_sources[i]):
                            pat_sources[i] = keep
                            changed = True
        return pat_sources

    def optimize(self, query: BGPQuery) -> PhysicalPlan:
        t0 = time.perf_counter()
        # one probe memo for the whole selection: the probes here are the
        # only real ASKs; super().optimize sees the pruned lists below
        memo: dict[tuple, list[int]] = {}
        pat_sources = [self._sources_for(tp, memo) for tp in query.patterns]
        pat_sources = self._prune_by_authorities(query, pat_sources)
        # reuse FedX ordering/grouping on the pruned sources
        orig = self._sources_for
        try:
            cache = {id(tp): srcs for tp, srcs in zip(query.patterns, pat_sources)}
            self._sources_for = lambda tp, memo=None: cache[id(tp)]  # type: ignore[assignment]
            plan = super().optimize(query)
        finally:
            self._sources_for = orig  # type: ignore[assignment]
        plan.optimization_ms = (time.perf_counter() - t0) * 1e3
        return plan
