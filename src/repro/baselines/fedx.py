"""FedX-style baseline [17]: ASK-based source selection, variable-counting
join ordering [18], exclusive groups, bind joins.

Emits the same ``PhysicalPlan`` structure as Odyssey so the engines and
metrics are shared. ``warm=True`` reuses the ASK cache (FedX-Warm).
"""
from __future__ import annotations

import time

from repro.core.decomposition import decompose
from repro.core.planner import JoinPlanNode, PhysicalPlan, PlanNode, SubqueryNode
from repro.core.source_selection import SourceSelection
from repro.query.algebra import BGPQuery, Const, TriplePattern, Var
from repro.rdf.dataset import Federation


def variable_counting_score(tp: TriplePattern, bound_vars: set[str]) -> float:
    """Heuristic selectivity [18]: constants/bound variables make a pattern
    selective; subjects more selective than objects, objects more than
    predicates."""
    score = 0.0
    s_free = isinstance(tp.s, Var) and tp.s.name not in bound_vars
    p_free = isinstance(tp.p, Var) and tp.p.name not in bound_vars
    o_free = isinstance(tp.o, Var) and tp.o.name not in bound_vars
    if s_free:
        score += 4.0
    if p_free:
        score += 1.0
    if o_free:
        score += 2.0
    return score


class FedXOptimizer:
    def __init__(self, fed: Federation, warm: bool = False):
        self.fed = fed
        self.warm = warm
        self._ask_cache: dict[tuple, list[int]] = {}   # warm: survives calls
        self.ask_count = 0                             # real ASK requests sent

    def _probe(self, key: tuple) -> list[int]:
        """One real ASK round: one request per endpoint, counted exactly."""
        s, p, o = key
        srcs = [i for i, src in enumerate(self.fed.sources) if src.ask(s, p, o)]
        self.ask_count += len(self.fed.sources)
        return srcs

    def _sources_for(self, tp: TriplePattern,
                     memo: dict[tuple, list[int]] | None = None) -> list[int]:
        """Relevant sources for one pattern.  ``memo`` is the per-selection
        probe memo (one ``optimize`` call == one source selection), so
        patterns sharing an ASK signature cost a single probe round per
        selection; warm mode keeps the memo across calls (FedX-Warm) while
        cold mode re-probes per selection, FedX's documented cold behavior.
        Returns a fresh list so callers can prune/mutate their copy without
        corrupting the memo."""
        key = tp.constants()
        if self.warm:
            memo = self._ask_cache
        elif memo is None:
            memo = {}
        srcs = memo.get(key)
        if srcs is None:
            srcs = self._probe(key)
            memo[key] = srcs
        return list(srcs)

    def optimize(self, query: BGPQuery) -> PhysicalPlan:
        t0 = time.perf_counter()
        graph = decompose(query)
        memo: dict[tuple, list[int]] = {}
        pat_sources = [self._sources_for(tp, memo) for tp in query.patterns]

        # exclusive groups: patterns with the same singleton source
        groups: dict[int, list[int]] = {}
        singles: list[int] = []
        for i, srcs in enumerate(pat_sources):
            if len(srcs) == 1:
                groups.setdefault(srcs[0], []).append(i)
            else:
                singles.append(i)
        units: list[tuple[list[int], list[int]]] = []  # (pattern idxs, sources)
        for src, idxs in groups.items():
            units.append((idxs, [src]))
        for i in singles:
            units.append(([i], pat_sources[i]))

        # variable-counting greedy order over units (exclusive groups first on
        # ties, FedX's documented behavior)
        ordered: list[tuple[list[int], list[int]]] = []
        bound: set[str] = set()
        remaining = list(units)
        while remaining:
            def unit_score(u: tuple[list[int], list[int]]) -> tuple:
                idxs, srcs = u
                sc = min(variable_counting_score(query.patterns[i], bound) for i in idxs)
                connected = any(
                    query.patterns[i].variables() & bound for i in idxs
                ) if bound else True
                return (not connected, sc, len(srcs) > 1, -len(idxs))
            remaining.sort(key=unit_score)
            u = remaining.pop(0)
            ordered.append(u)
            for i in u[0]:
                bound |= query.patterns[i].variables()

        # left-deep bind-join plan
        def leaf(u: tuple[list[int], list[int]]) -> SubqueryNode:
            idxs, srcs = u
            pats = [query.patterns[i] for i in idxs]
            star_ids = sorted({_star_of(graph, i) for i in idxs})
            return SubqueryNode(stars=star_ids, patterns=pats, sources=list(srcs))

        root: PlanNode = leaf(ordered[0])
        for u in ordered[1:]:
            rhs = leaf(u)
            jvars = sorted(_vars(root) & set(
                v for i in u[0] for v in query.patterns[i].variables()))
            root = JoinPlanNode(left=root, right=rhs, strategy="bind", join_vars=jvars)

        sel = _selection_from_patterns(graph, query, pat_sources)
        plan = PhysicalPlan(root=root, query=query, graph=graph, selection=sel)
        plan.optimization_ms = (time.perf_counter() - t0) * 1e3
        return plan


def _star_of(graph, pat_idx: int) -> int:
    tp = graph.query.patterns[pat_idx]
    for s in graph.stars:
        if tp in s.patterns:
            return s.idx
    return 0


def _vars(node: PlanNode) -> set[str]:
    if isinstance(node, SubqueryNode):
        out: set[str] = set()
        for tp in node.patterns:
            out |= set(tp.variables())
        return out
    assert isinstance(node, JoinPlanNode)
    return _vars(node.left) | _vars(node.right)


def _selection_from_patterns(graph, query: BGPQuery, pat_sources: list[list[int]]) -> SourceSelection:
    """Adapt per-pattern source lists into the shared SourceSelection shape
    (star sources = union over its patterns) with exact per-pattern NSS."""
    star_sources = []
    for s in graph.stars:
        srcs: set[int] = set()
        for tp in s.patterns:
            srcs |= set(pat_sources[query.patterns.index(tp)])
        star_sources.append(sorted(srcs))
    sel = SourceSelection(star_sources=star_sources, star_cs=[{} for _ in graph.stars])
    total = sum(len(s) for s in pat_sources)
    sel.pattern_source_count = lambda g, _t=total: _t  # type: ignore[assignment]
    return sel
