from repro.baselines.fedx import FedXOptimizer
from repro.baselines.void_dp import VoidDPOptimizer
from repro.baselines.hibiscus import HibiscusOptimizer

__all__ = ["FedXOptimizer", "VoidDPOptimizer", "HibiscusOptimizer"]
