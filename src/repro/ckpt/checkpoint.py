"""Fault-tolerant checkpointing (no orbax dependency).

Design for 1000+ nodes:
  * leaf-wise ``.npy`` shards under ``step_xxxx.tmp/`` then a single atomic
    ``rename`` — a preempted writer never corrupts the latest checkpoint;
  * a manifest with per-leaf CRC32s, verified on restore;
  * keep-last-k GC;
  * **elastic restore**: checkpoints store the *global* arrays (gathered per
    leaf); restoring onto a different mesh re-shards via device_put with the
    new topology's shardings, so scaling the data axis up/down between runs
    is a no-op for correctness.

Per-host sharded writes (each host persisting only its addressable shards)
drop in by swapping ``_gather``/``device_put`` for per-shard IO keyed by
(shard index, host); single-process CPU containers exercise the same paths.
"""
from __future__ import annotations

import json
import os
import shutil
import time
import zlib
from dataclasses import dataclass

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(str(getattr(k, "key", k)) for k in path), leaf)
            for path, leaf in leaves], jax.tree.structure(tree)


@dataclass
class CheckpointManager:
    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None) -> str:
        named, _ = _flatten(tree)
        tmp = os.path.join(self.directory, f"step_{step:08d}.tmp")
        final = os.path.join(self.directory, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "leaves": {},
                    "extra": extra or {}}
        for i, (name, leaf) in enumerate(named):
            arr = np.asarray(jax.device_get(leaf))
            fn = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][name] = {
                "file": fn,
                "crc": zlib.crc32(arr.tobytes()),
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final)  # atomic publish
        self._gc()
        return final

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, like_tree, shardings=None) -> tuple[object, dict]:
        """Restore into the structure of ``like_tree``; if ``shardings`` is
        given (possibly for a different mesh), re-shard each leaf (elastic)."""
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        named, treedef = _flatten(like_tree)
        sh_leaves = None
        if shardings is not None:
            sh_named, _ = _flatten(shardings)
            sh_leaves = dict(sh_named)
        out = []
        for name, like in named:
            meta = manifest["leaves"][name]
            arr = np.load(os.path.join(d, meta["file"]))
            if zlib.crc32(arr.tobytes()) != meta["crc"]:
                raise IOError(f"checkpoint corruption in {name}")
            if sh_leaves is not None:
                out.append(jax.device_put(arr, sh_leaves[name]))
            else:
                out.append(jax.numpy.asarray(arr))
        tree = jax.tree.unflatten(treedef, out)
        return tree, manifest["extra"]

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
