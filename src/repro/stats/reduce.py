"""CS reduction (paper §3.3): bound the number of CSs to ``max_cs``.

Keep the CSs shared by the most entities; merge each dropped CS into its
*smallest kept superset* (combining counts and occurrences). Merging into a
superset is conservative for relevance detection: a query with P ⊆ dropped
also satisfies P ⊆ superset, so source selection keeps its no-false-negative
guarantee (property-tested). CSs with no kept superset are retained — dropping
them could lose completeness, which the paper never allows.
"""
from __future__ import annotations

import numpy as np

from repro.core.characteristic_sets import CSStats


def reduce_cs(cs: CSStats, max_cs: int) -> CSStats:
    if cs.n_cs <= max_cs:
        return cs
    order = np.argsort(-cs.cs_count, kind="stable")
    keep_set = set(order[:max_cs].tolist())
    drop = [c for c in order[max_cs:].tolist()]

    pred_sets = [frozenset(cs.preds_of(c).tolist()) for c in range(cs.n_cs)]
    # map dropped -> smallest kept superset (or keep if none)
    merged_into: dict[int, int] = {}
    for c in drop:
        best = -1
        best_size = None
        for k in keep_set:
            if pred_sets[c] <= pred_sets[k]:
                sz = len(pred_sets[k])
                if best_size is None or sz < best_size:
                    best, best_size = k, sz
        if best >= 0:
            merged_into[c] = best
        else:
            keep_set.add(c)  # cannot merge without losing completeness

    keep = sorted(keep_set)
    remap = {c: i for i, c in enumerate(keep)}

    n_new = len(keep)
    cs_count = np.zeros(n_new, np.int64)
    occ_maps: list[dict[int, int]] = [dict() for _ in range(n_new)]
    for c in range(cs.n_cs):
        tgt = remap[merged_into.get(c, c)]
        cs_count[tgt] += cs.cs_count[c]
        preds = cs.preds_of(c)
        occs = cs.occ_of(c)
        m = occ_maps[tgt]
        for p, oc in zip(preds.tolist(), occs.tolist()):
            m[p] = m.get(p, 0) + oc

    indptr = np.zeros(n_new + 1, np.int64)
    pred_chunks: list[np.ndarray] = []
    occ_chunks: list[np.ndarray] = []
    for i, m in enumerate(occ_maps):
        ps = np.array(sorted(m), np.int32)
        pred_chunks.append(ps)
        occ_chunks.append(np.array([m[int(p)] for p in ps], np.int64))
        indptr[i + 1] = indptr[i] + len(ps)

    old2new = np.empty(cs.n_cs, np.int32)
    for c in range(cs.n_cs):
        old2new[c] = remap[merged_into.get(c, c)]
    return CSStats(
        cs_count=cs_count,
        indptr=indptr,
        pred_ids=np.concatenate(pred_chunks) if pred_chunks else np.zeros(0, np.int32),
        pred_occ=np.concatenate(occ_chunks) if occ_chunks else np.zeros(0, np.int64),
        ent_ids=cs.ent_ids,
        ent_cs=old2new[cs.ent_cs],
    )
