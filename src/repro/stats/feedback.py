"""Cardinality feedback: observed execution closes the statistics loop.

Odyssey's statistics are computed once per source and drift as endpoints
ingest data.  The operator pipeline (``repro.engine.pipeline``) records, for
every unbound single-star dispatch, the estimate the planner priced the
endpoint at (``SubqueryNode.est_source_cards``) next to the row count the
endpoint actually returned.  ``CardinalityFeedback`` aggregates those samples
per source and, when a source's mean log-scale q-error
(``repro.core.cost.estimation_error``) crosses a threshold, marks it dirty;
``apply_pending()`` then re-derives exactly that source's CS/CP state via the
versioned lifecycle (``FederatedStats.refresh_source``), bumping the epoch so
the plan cache lazily evicts exactly the plans priced under the stale
statistics.

Threading contract (matches ``repro.serve.query.QuerySession``):

* ``observe_result`` is thread-safe — the executor thread calls it per
  finished query.
* ``apply_pending`` must run on the *planner* thread (the only thread that
  touches the optimizer/statistics), typically at the top of each planning
  batch.  It mutates ``FederatedStats`` in place; concurrent planning against
  a half-refreshed store would be a race.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np

from repro.core.cost import estimation_error


@dataclass
class SourceDrift:
    """Accumulated evidence that one source's statistics have drifted."""

    name: str
    errors: list = field(default_factory=list)

    @property
    def n(self) -> int:
        return len(self.errors)

    @property
    def mean_error(self) -> float:
        return float(np.mean(self.errors)) if self.errors else 0.0


class CardinalityFeedback:
    """Observed-vs-estimated cardinality aggregator driving ``refresh_source``.

    ``threshold_x`` is expressed as a *factor*: the default 4.0 marks a
    source dirty once its scans are off by 4x on (geometric) average.
    ``min_observations`` guards against refreshing on a single noisy scan.
    """

    def __init__(self, stats, fed, threshold_x: float = 4.0,
                 min_observations: int = 3):
        if threshold_x <= 1.0:
            raise ValueError(f"threshold_x must be > 1 (got {threshold_x})")
        self.stats = stats
        self.fed = fed
        self.threshold = float(np.log2(threshold_x))
        self.min_observations = int(min_observations)
        self._lock = threading.Lock()
        self._drift: dict[str, SourceDrift] = {}
        # lifecycle bookkeeping the tests / ServeStats surface
        self.n_observations = 0
        self.refreshes: list[str] = []          # source names, in apply order

    # -- executor side -------------------------------------------------------

    def observe_result(self, result) -> None:
        """Fold one ``ExecutionResult``'s ``card_log`` into the per-source
        drift state.  Only ``kind == "scan"`` samples count: unbound
        single-star dispatches are the one form whose estimate and
        observation measure the same quantity (merged groups split estimates
        evenly; bind-join observations depend on the left side's bindings).
        Thread-safe."""
        log = getattr(result, "card_log", ()) or ()
        with self._lock:
            for ob in log:
                if ob.kind != "scan" or ob.source is None or ob.est is None:
                    continue
                drift = self._drift.setdefault(ob.source, SourceDrift(ob.source))
                drift.errors.append(estimation_error(ob.est, ob.obs))
                self.n_observations += 1

    # -- shared --------------------------------------------------------------

    def dirty_sources(self) -> list[str]:
        """Source names whose mean error crosses the threshold with enough
        observations behind it.  Thread-safe; does not mutate anything."""
        with self._lock:
            return sorted(
                d.name for d in self._drift.values()
                if d.n >= self.min_observations and d.mean_error >= self.threshold)

    def mean_error(self, name: str) -> float:
        with self._lock:
            d = self._drift.get(name)
            return d.mean_error if d is not None else 0.0

    # -- planner side --------------------------------------------------------

    def apply_pending(self) -> list[str]:
        """Refresh every dirty source from its current table and clear its
        accumulated errors.  Must run on the planner thread — it mutates the
        shared ``FederatedStats`` (one epoch bump per refreshed source, so
        the plan cache retires exactly the stale entries).  Returns the
        refreshed source names."""
        dirty = self.dirty_sources()
        applied: list[str] = []
        for name in dirty:
            try:
                src = self.fed.by_name(name)
            except (KeyError, StopIteration):
                continue                      # excluded mid-flight; drop it
            self.stats.refresh_source(src.sid, src.table)
            applied.append(name)
        if applied:
            with self._lock:
                for name in applied:
                    self._drift.pop(name, None)
                self.refreshes.extend(applied)
        return applied
