from repro.stats.void import VoidStats, compute_void
from repro.stats.reduce import reduce_cs

__all__ = ["VoidStats", "compute_void", "reduce_cs"]
