from repro.stats.feedback import CardinalityFeedback, SourceDrift
from repro.stats.reduce import reduce_cs
from repro.stats.void import VoidStats, compute_void

__all__ = ["CardinalityFeedback", "SourceDrift", "VoidStats", "compute_void",
           "reduce_cs"]
