"""VOID-level statistics [2] — the granularity the DP-VOID / SPLENDID
baselines use: dataset totals plus per-predicate triple/subject/object counts.
Coarser than CSs, hence the estimation errors the paper attributes to the
uniformity + independence assumptions.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.rdf.dataset import TripleTable


@dataclass
class VoidStats:
    n_triples: int
    n_subjects: int
    n_objects: int
    preds: np.ndarray          # sorted predicate ids
    pred_triples: np.ndarray   # per predicate
    pred_subjects: np.ndarray
    pred_objects: np.ndarray

    def has_pred(self, p: int) -> bool:
        i = np.searchsorted(self.preds, p)
        return i < len(self.preds) and self.preds[i] == p

    def triples_with_pred(self, p: int) -> int:
        i = np.searchsorted(self.preds, p)
        if i < len(self.preds) and self.preds[i] == p:
            return int(self.pred_triples[i])
        return 0

    def pred_stat(self, p: int) -> tuple[int, int, int]:
        i = np.searchsorted(self.preds, p)
        if i < len(self.preds) and self.preds[i] == p:
            return int(self.pred_triples[i]), int(self.pred_subjects[i]), int(self.pred_objects[i])
        return 0, 0, 0

    def estimate_pattern(self, s: int | None, p: int | None, o: int | None) -> float:
        """Classic VOID selectivity with uniformity assumptions."""
        if p is None:
            base = float(self.n_triples)
            if s is not None:
                base /= max(1, self.n_subjects)
            if o is not None:
                base /= max(1, self.n_objects)
            return base
        t, ns, no = self.pred_stat(p)
        if t == 0:
            return 0.0
        est = float(t)
        if s is not None:
            est /= max(1, ns)
        if o is not None:
            est /= max(1, no)
        return est

    def nbytes(self) -> int:
        return int(self.preds.nbytes + self.pred_triples.nbytes
                   + self.pred_subjects.nbytes + self.pred_objects.nbytes + 24)


def compute_void(table: TripleTable) -> VoidStats:
    preds, inv = np.unique(table.p, return_inverse=True)
    pred_triples = np.bincount(inv, minlength=len(preds))
    pred_subjects = np.zeros(len(preds), np.int64)
    pred_objects = np.zeros(len(preds), np.int64)
    for i in range(len(preds)):
        m = inv == i
        pred_subjects[i] = len(np.unique(table.s[m]))
        pred_objects[i] = len(np.unique(table.o[m]))
    return VoidStats(
        n_triples=table.n_triples,
        n_subjects=len(table.subjects()),
        n_objects=len(table.objects()),
        preds=preds,
        pred_triples=pred_triples.astype(np.int64),
        pred_subjects=pred_subjects,
        pred_objects=pred_objects,
    )
