"""Flash attention (online softmax) Pallas TPU kernel.

The TPU-native tiling: grid = (batch·kv_heads·q_groups, S_q/BQ, S_k/BK) with
the KV axis innermost so the (BQ, BK) score tile lives entirely in VMEM and
the running (max, denom, output) state is carried in VMEM scratch across KV
steps. Q/K tiles are MXU-aligned (BQ, BK multiples of 128; head_dim padded to
128 by the wrapper). Causal/local masking happens on the fly from program
ids — no (S, S) mask tensor exists anywhere.

``repro.models.attention_chunked`` is the identical math as a jnp double scan
(used by the 512-device dry-run); this kernel is what a real TPU deployment
runs per shard after the GSPMD partitioner has split heads/batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, window: int, block_q: int, block_k: int, n_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (BQ, hd)
    k = k_ref[0]                       # (BK, hd)
    v = v_ref[0]                       # (BK, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.zeros((block_q, block_k), jnp.float32)
    if causal:
        mask = jnp.where(kpos > qpos, NEG_INF, mask)
    if window:
        mask = jnp.where(qpos - kpos >= window, NEG_INF, mask)
    s = s + mask

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (BH, S, hd) with per-q-head k/v already broadcast: k, v: (BH, S, hd).
    Scaling (hd^-0.5) is the caller's job. Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    assert S % block_q == 0 and S % block_k == 0
    n_q = S // block_q
    n_k = S // block_k
    grid = (BH, n_q, n_k)
    kern = functools.partial(_kernel, causal=causal, window=window,
                             block_q=block_q, block_k=block_k, n_k=n_k)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
