"""On-device join-order DP: the resident fused sweep + the per-tile kernel.

``repro.core.join_order._dp_sweep`` prices, per popcount layer, every
(connected subset, connected partition) candidate pair and keeps the first
strict minimum per subset.  Two device entry points live here:

``dp_sweep_resident``
    The whole sweep as **one compiled device program**: the host enumerates
    the layer schedule once per graph topology (connected subsets, flat
    candidate-pair index tiles — see ``join_order._dp_schedule``) and ships
    only those int32 index tiles plus the seed state; a ``lax.scan`` over
    the layers then fuses candidate pricing (``CostModel.
    join_candidates_params_jnp``), the segmented first-strict-minimum
    reduction and the best-plan state scatter into one XLA program, with the
    full DP state (cost / cardinality / source counts / weights / bindable
    flags / winner strategy + split) resident on device for the whole
    sweep.  The member axis is batched straight through every gather and
    scatter.  Nothing crosses host<->device between layers — the old
    per-layer ``_pad3``/``_pad2`` round-trips were exactly the inversion
    that made ``dp_backend='jax'`` lose to numpy.  On CPU this is the
    *compiled (non-interpret)* jax path: XLA:CPU compiles the scan program
    (compiled Pallas is TPU/GPU-only), and it beats the numpy sweep at
    n >= 12 / B >= 8.

``dp_layer``
    The original per-tile Pallas kernel (grid over ``(member, column tile,
    row tile)``), kept for the tiled fallback path — layer tiles too large
    for a resident schedule under the memory budget — and as the TPU
    mapping of the layer step.  ``interpret=True`` is the CPU default like
    every kernel in this package.

Both entries price in float64 (callers run under
``jax.experimental.enable_x64``) and reproduce the numpy sweep's
enumeration order and first-strict-minimum tie-breaking bit for bit; the
cost-model parameters are **traced** ``(4,)`` inputs, so one compiled
program serves every ``CostModel`` — a parameter sweep (``kernel_bench``,
a user tuning weights) never retraces or thrashes the program cache.
"""
from __future__ import annotations

import functools
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.experimental import pallas as pl

BLOCK_R = 128
BLOCK_C = 128
_BIG_ROW = np.int32(2**31 - 1)     # "no valid pair in this column"

_STRAT_EXCL, _STRAT_HASH, _STRAT_BIND = 2, 3, 4   # mirror join_order's codes


def _kernel(params_ref, cost_a_ref, cost_b_ref, card_a_ref, n_src_b_ref,
            src_w_b_ref, bind_ref, valid_ref, card_s_ref,
            best_c_ref, best_r_ref, best_b_ref, *, block_r):
    from repro.core.cost import CostModel

    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        best_c_ref[...] = jnp.full(best_c_ref.shape, jnp.inf, best_c_ref.dtype)
        best_r_ref[...] = jnp.full(best_r_ref.shape, _BIG_ROW, jnp.int32)
        best_b_ref[...] = jnp.zeros(best_b_ref.shape, jnp.int32)

    valid = valid_ref[...] != 0                       # (block_r, bc)
    bindable = bind_ref[0] != 0
    card_s = card_s_ref[...]                          # (1, bc) per-subset
    pair_c, is_bind = CostModel.join_candidates_params_jnp(
        params_ref[...], cost_a_ref[0], cost_b_ref[0], card_s,
        card_a_ref[0], n_src_b_ref[0], src_w_b_ref[0], bindable)
    pair_c = jnp.where(valid, pair_c, jnp.inf)

    tile_min = jnp.min(pair_c, axis=0, keepdims=True)           # (1, bc)
    rows = (jax.lax.broadcasted_iota(jnp.int32, pair_c.shape, 0)
            + r * block_r)
    is_min = valid & (pair_c == tile_min)
    first = jnp.min(jnp.where(is_min, rows, _BIG_ROW), axis=0,
                    keepdims=True)
    bind_at = jnp.max(jnp.where(is_min & (rows == first),
                                is_bind.astype(jnp.int32), 0),
                      axis=0, keepdims=True)

    # strictly-less running update: an equal minimum in a later row tile
    # never displaces the earlier (lower-row) one
    upd = tile_min < best_c_ref[...]
    best_c_ref[...] = jnp.where(upd, tile_min, best_c_ref[...])
    best_r_ref[...] = jnp.where(upd, first, best_r_ref[...])
    best_b_ref[...] = jnp.where(upd, bind_at, best_b_ref[...])


def _bucket(n: int, block: int) -> int:
    """Padded extent for ``n``: the next power of two (>= 8) below ``block``,
    a multiple of ``block`` above it.  Buckets the kernel's trace shapes so
    layers/queries of nearby sizes share one compiled program instead of
    retracing per exact tile shape (padding is inert: ``valid`` is 0 there)."""
    if n >= block:
        return n + (-n) % block
    p = 8
    while p < n:
        p *= 2
    return p


def _pad3(x, rp, cp, dtype):
    if x.shape[1] == rp and x.shape[2] == cp:
        # extents already match the bucketed trace shape: no alloc+copy, just
        # a dtype view (astype(copy=False) is free when the dtype matches)
        return np.asarray(x).astype(dtype, copy=False)
    out = np.zeros((x.shape[0], rp, cp), dtype)
    out[:, :x.shape[1], :x.shape[2]] = x
    return out


def _pad2(x, cp, dtype):
    if x.shape[1] == cp:
        return np.asarray(x).astype(dtype, copy=False)
    out = np.zeros((x.shape[0], cp), dtype)
    out[:, :x.shape[1]] = x
    return out


class _ProgramCache:
    """Tiny LRU of compiled device programs with observable counters.

    The old ``lru_cache(maxsize=64)`` keyed the per-tile program on
    ``(params, interpret)`` — but the *trace* does not depend on the
    cost-model values at all once they are passed as a traced ``(4,)``
    array, so a cost-model parameter sweep was silently compiling (and at
    >64 sets, evicting) one program per parameter tuple.  Programs are now
    keyed on what the trace actually depends on (the kernel variant +
    ``interpret`` — jax's own jit cache handles shape specialization under
    each entry), and ``evictions`` / ``hits`` / ``misses`` make any future
    keying regression observable."""

    def __init__(self, max_entries: int = 16):
        self.max_entries = max_entries
        self._entries: OrderedDict[tuple, object] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._entries)

    def get(self, key: tuple, build):
        fn = self._entries.get(key)
        if fn is not None:
            self.hits += 1
            self._entries.move_to_end(key)
            return fn
        self.misses += 1
        fn = build()
        self._entries[key] = fn
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1
        return fn

    def clear(self) -> None:
        self._entries.clear()
        self.hits = 0
        self.misses = 0
        self.evictions = 0


PROGRAM_CACHE = _ProgramCache()


def _build_layer_program(interpret: bool):
    @jax.jit
    def call(params, cost_a, cost_b, card_a, n_src_b, src_w_b, bindable,
             valid, card_s):
        B, R_p, C_p = cost_a.shape          # pre-padded to bucketed extents
        br, bc = min(BLOCK_R, R_p), min(BLOCK_C, C_p)
        grid = (B, C_p // bc, R_p // br)
        pair_spec = pl.BlockSpec((1, br, bc), lambda b, c, r: (b, r, c))
        col_spec = pl.BlockSpec((1, bc), lambda b, c, r: (b, c))
        return pl.pallas_call(
            functools.partial(_kernel, block_r=br),
            grid=grid,
            in_specs=[pl.BlockSpec((4,), lambda b, c, r: (0,))]
            + [pair_spec] * 6
            + [pl.BlockSpec((br, bc), lambda b, c, r: (r, c)), col_spec],
            out_specs=[col_spec, col_spec, col_spec],
            # repro: ignore[RPR005] -- trace-time dtype only: this jitted body
            # executes under the enable_x64 context its callers (dp_layer /
            # kernel_bench) hold by documented contract
            out_shape=[jax.ShapeDtypeStruct((B, C_p), jnp.float64),
                       jax.ShapeDtypeStruct((B, C_p), jnp.int32),
                       jax.ShapeDtypeStruct((B, C_p), jnp.int32)],
            interpret=interpret,
        )(params, cost_a, cost_b, card_a, n_src_b, src_w_b, bindable, valid,
          card_s)

    return call


def dp_layer_program(params: tuple, interpret: bool = True):
    """The jitted device-level entry: expects pre-padded arrays whose row /
    column extents are block multiples (see ``_bucket``), ``float64`` pair
    state and ``int8`` masks, and returns the raw padded outputs.  ``params``
    is passed on every call as a traced ``(4,)`` array, so the returned
    program is shared across cost models.  Run it under
    ``jax.experimental.enable_x64``; benchmarks time this directly so the
    Pallas side is a jitted call on device arrays exactly like the jitted
    oracle — not the host wrapper with its padding logic."""
    fn = PROGRAM_CACHE.get(("layer", bool(interpret)),
                           lambda: _build_layer_program(bool(interpret)))
    # repro: ignore[RPR005] -- the docstring contract requires callers to run
    # the returned program under enable_x64; building the params array f64
    # here would silently truncate to f32 only if that contract is broken
    p = jnp.asarray([float(v) for v in params], jnp.float64)
    return functools.partial(fn, p)


def dp_layer(cost_a, cost_b, card_a, n_src_b, src_w_b, bindable, valid,
             card_s, params: tuple, interpret: bool = True):
    """Price one dense layer tile and reduce it per column.

    Inputs are the per-pair gathers described in ``ref.dp_layer_ref`` (same
    shapes, same semantics); ``params`` is the cost model's
    ``(intermediate_weight, transfer_weight, request_cost, bind_batch)``.
    Returns numpy ``(best_cost (B, C) float64, first_row (B, C) int32,
    is_bind (B, C) bool)`` with the numpy sweep's exact tie-breaking.

    Row/column extents are padded host-side to bucketed trace shapes
    (powers of two below a block, block multiples above) so nearby tile
    sizes share one compiled program; when the extents already match their
    buckets the inputs are passed through without the padding alloc+copy.
    The ``enable_x64`` context is only entered when x64 is not already on —
    hot loops (the tiled sweep fallback) enable it once around the whole
    sweep instead of paying the context switch per layer tile."""
    B, R, C = np.shape(cost_a)
    R_p, C_p = _bucket(R, BLOCK_R), _bucket(C, BLOCK_C)
    f64 = np.float64

    def run():
        call = dp_layer_program(params, interpret)
        if valid.shape == (R_p, C_p):
            valid_p = np.asarray(valid, np.int8)
        else:
            valid_p = np.zeros((R_p, C_p), np.int8)
            valid_p[:R, :C] = valid
        best, row, bind = call(
            _pad3(cost_a, R_p, C_p, f64), _pad3(cost_b, R_p, C_p, f64),
            _pad3(card_a, R_p, C_p, f64), _pad3(n_src_b, R_p, C_p, f64),
            _pad3(src_w_b, R_p, C_p, f64), _pad3(bindable, R_p, C_p, np.int8),
            valid_p, _pad2(card_s, C_p, f64))
        return (np.asarray(best)[:, :C], np.asarray(row)[:, :C],
                np.asarray(bind)[:, :C].astype(bool))

    if jax.config.jax_enable_x64:
        return run()
    with enable_x64():
        return run()


# --------------------------------------------------------------------------
# Resident fused sweep: the whole DP as one scanned device program
# --------------------------------------------------------------------------

def _build_sweep_program():
    @jax.jit
    def sweep(params, pair_a, pair_b, pair_seg, layer_cols,
              card, excl_cost, excl_w, cost0, n_src0, src_w0):
        """One ``lax.scan`` over the padded layer schedule.

        ``pair_a``/``pair_b``/``pair_seg`` are ``(L, P)`` int32: per layer,
        the flat candidate pairs in the reference order (column-major over
        the layer's connected subsets, relative submasks ascending within a
        column); ``pair_seg`` is the pair's column position within the layer
        (sentinel ``C`` marks padding).  ``layer_cols`` is ``(L, C)`` int32:
        the layer's connected-subset masks (sentinel ``size`` marks
        padding).  ``card``/``excl_cost``/``excl_w`` are the host-
        precomputed per-(member, mask) subset cardinalities and exclusive-
        group leaf seeds (``excl_cost = inf`` where no exclusive leaf
        exists); ``cost0``/``n_src0``/``src_w0`` seed the singleton leaves
        (a mask is bind-join-able exactly when its source count is > 0, so
        there is no separate bindable plane).  Everything stays on device
        for the whole scan; the return is the final ``(cost, strat,
        split)`` state."""
        B = cost0.shape[0]
        size = cost0.shape[1]
        C = layer_cols.shape[1]
        P = pair_a.shape[1]
        INF = jnp.inf
        BIG = jnp.int32(2**31 - 1)
        pos = jnp.arange(P, dtype=jnp.int32)

        from repro.core.cost import CostModel

        def step(carry, layer):
            cost, n_src, src_w, strat, split = carry
            a, b, seg, cols = layer
            pad_pair = seg >= C                     # (P,)
            pad_col = cols >= size                  # (C,)
            a_g = jnp.where(pad_pair, 0, a)
            b_g = jnp.where(pad_pair, 0, b)
            cols_g = jnp.where(pad_col, 0, cols)

            # fused candidate pricing over the flat pair tile (member axis
            # batched straight through the gathers)
            ca = jnp.take(cost, a_g, axis=1)
            cb = jnp.take(cost, b_g, axis=1)
            card_a = jnp.take(card, a_g, axis=1)
            ns_b = jnp.take(n_src, b_g, axis=1)
            sw_b = jnp.take(src_w, b_g, axis=1)
            card_out = jnp.take(card, jnp.where(pad_pair, 0, a ^ b), axis=1)
            pair_c, is_bind = CostModel.join_candidates_params_jnp(
                params, ca, cb, card_out, card_a, ns_b, sw_b, ns_b > 0)
            pair_c = jnp.where(pad_pair[None, :], INF, pair_c)

            # segmented first-strict-minimum per column: scatter-min the
            # costs, then scatter-min the flat positions attaining them
            # (positions ascend in the reference enumeration order, so the
            # winner is the numpy sweep's first strict minimum)
            seg_min = jnp.full((B, C), INF).at[:, seg].min(
                pair_c, mode="drop")
            min_of_pair = jnp.take(seg_min, jnp.minimum(seg, C - 1), axis=1)
            elig = (pair_c == min_of_pair) & jnp.isfinite(pair_c)
            first = jnp.full((B, C), BIG).at[:, seg].min(
                jnp.where(elig, pos[None, :], BIG), mode="drop")
            fp = jnp.minimum(first, P - 1)
            split_a = jnp.take(a, fp)                       # (B, C)
            bind_at = jnp.take_along_axis(is_bind, fp, axis=1)

            # exclusive-group leaf seed: candidate index 0 in the reference
            # order — pair candidates must beat it strictly
            ec = jnp.where(pad_col[None, :], INF,
                           jnp.take(excl_cost, cols_g, axis=1))
            ew = jnp.take(excl_w, cols_g, axis=1)
            pair_win = seg_min < ec
            has_excl = jnp.isfinite(ec)
            is_excl = has_excl & ~pair_win

            # unconditional state scatter: each subset lives in exactly one
            # layer, so the current value at any scattered column is still
            # its seed — and where *no* candidate won (``pair_win`` and
            # ``has_excl`` both false) every "new" value below reproduces
            # that seed exactly (cost inf, counts 0, weight 1, strat 0).
            # Skipping the read-modify-write keeps the step at one scatter
            # per plane; padded columns (sentinel ``size``) drop out.
            cost = cost.at[:, cols].set(
                jnp.where(pair_win, seg_min, ec), mode="drop")
            n_src = n_src.at[:, cols].set(
                jnp.where(is_excl, 1.0, 0.0), mode="drop")
            src_w = src_w.at[:, cols].set(
                jnp.where(is_excl, ew, 1.0), mode="drop")
            strat = strat.at[:, cols].set(
                jnp.where(pair_win,
                          jnp.where(bind_at, _STRAT_BIND, _STRAT_HASH),
                          jnp.where(has_excl, _STRAT_EXCL, 0)
                          ).astype(jnp.int32), mode="drop")
            split = split.at[:, cols].set(
                jnp.where(pair_win, split_a, 0).astype(jnp.int32),
                mode="drop")
            return (cost, n_src, src_w, strat, split), None

        strat0 = jnp.zeros((B, size), jnp.int32)
        split0 = jnp.zeros((B, size), jnp.int32)
        (cost, _, _, strat, split), _ = jax.lax.scan(
            step, (cost0, n_src0, src_w0, strat0, split0),
            (pair_a, pair_b, pair_seg, layer_cols))
        return cost, strat, split

    return sweep


def dp_sweep_resident(params: tuple, pair_a, pair_b, pair_seg, layer_cols,
                      card, excl_cost, excl_w, cost0, n_src0, src_w0):
    """Run the whole member-batched DP sweep as one compiled device program.

    Host-side contract: the schedule arrays are int32 with the sentinels
    described in the program docstring (pad ``P``/``C`` extents to shared
    buckets so nearby topologies reuse one compile — jax's jit cache keys
    on shapes under the single ``PROGRAM_CACHE`` entry); the numeric seeds
    are float64 and ``n_src0`` doubles as the bindable plane (> 0).
    Returns numpy ``(cost (B, size) float64, strat (B, size) int32, split
    (B, size) int32)`` — strategy codes match ``join_order``'s
    ``_STRAT_*`` constants, ``split`` is the winning submask A, strat 0
    means the device never wrote the mask.  This is the single
    host<->device round trip of the sweep: index tiles + seeds up, the
    final plan state down."""
    fn = PROGRAM_CACHE.get(("sweep",), _build_sweep_program)

    def run():
        # param array built under x64 so the traced values stay float64
        p = jnp.asarray([float(v) for v in params], jnp.float64)
        cost, strat, split = fn(p, pair_a, pair_b, pair_seg, layer_cols,
                                card, excl_cost, excl_w, cost0, n_src0,
                                src_w0)
        return (np.asarray(cost), np.asarray(strat), np.asarray(split))

    if jax.config.jax_enable_x64:
        return run()
    with enable_x64():
        return run()
