"""Member-batched DP layer sweep — the join-order DP's hot loop on-device.

``repro.core.join_order._dp_sweep`` prices, per popcount layer, every
(connected subset, connected partition) candidate pair and keeps the first
strict minimum per subset.  The batched sweep's layer math is pure array ops
over a member-stacked state, so this kernel maps it onto a Pallas grid over
``(member, column tile, row tile)`` — exactly the (member, tile) grid the
roadmap sketches; the row axis is the innermost grid dimension so each
``(member, column-tile)`` output block accumulates a running
first-strict-minimum across its row tiles.

Layout: the host gathers the per-pair DP state into dense ``(B, R, C)``
blocks (member, relative-submask row, connected-subset column) with a
member-independent ``(R, C)`` validity mask (rows ascend in the reference
enumeration order: popcount ascending, combination-lex).  Each grid step
prices one ``(BLOCK_R, BLOCK_C)`` tile of one member through the
broadcasting ``CostModel.*_jnp`` forms, masks invalid pairs to ``+inf``,
reduces rows to (min cost, first row attaining it, bind flag at that row)
and folds the result into the output block under a strictly-less update —
row tiles ascend, so "first tile to reach the running minimum, first row
within the tile" reproduces the numpy path's first-strict-minimum
tie-breaking bit-exactly.

All pricing runs in float64 (the wrapper enters
``jax.experimental.enable_x64``), matching the numpy DP bit for bit;
``interpret=True`` is the CPU/CI default like every kernel in this package.
A TPU deployment would flip to float32 blocks and pay a documented ULP
tolerance — the differential contract here is exactness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64
from jax.experimental import pallas as pl

BLOCK_R = 128
BLOCK_C = 128
_BIG_ROW = np.int32(2**31 - 1)     # "no valid pair in this column"


def _kernel(cost_a_ref, cost_b_ref, card_a_ref, n_src_b_ref, src_w_b_ref,
            bind_ref, valid_ref, card_s_ref,
            best_c_ref, best_r_ref, best_b_ref, *, cm, block_r):
    r = pl.program_id(2)

    @pl.when(r == 0)
    def _init():
        best_c_ref[...] = jnp.full(best_c_ref.shape, jnp.inf, best_c_ref.dtype)
        best_r_ref[...] = jnp.full(best_r_ref.shape, _BIG_ROW, jnp.int32)
        best_b_ref[...] = jnp.zeros(best_b_ref.shape, jnp.int32)

    valid = valid_ref[...] != 0                       # (block_r, bc)
    bindable = bind_ref[0] != 0
    card_s = card_s_ref[...]                          # (1, bc) per-subset
    pair_c, is_bind = cm.join_candidates_jnp(
        cost_a_ref[0], cost_b_ref[0], card_s, cm.hash_join_cost_jnp(card_s),
        card_a_ref[0], n_src_b_ref[0], src_w_b_ref[0], bindable)
    pair_c = jnp.where(valid, pair_c, jnp.inf)

    tile_min = jnp.min(pair_c, axis=0, keepdims=True)           # (1, bc)
    rows = (jax.lax.broadcasted_iota(jnp.int32, pair_c.shape, 0)
            + r * block_r)
    is_min = valid & (pair_c == tile_min)
    first = jnp.min(jnp.where(is_min, rows, _BIG_ROW), axis=0,
                    keepdims=True)
    bind_at = jnp.max(jnp.where(is_min & (rows == first),
                                is_bind.astype(jnp.int32), 0),
                      axis=0, keepdims=True)

    # strictly-less running update: an equal minimum in a later row tile
    # never displaces the earlier (lower-row) one
    upd = tile_min < best_c_ref[...]
    best_c_ref[...] = jnp.where(upd, tile_min, best_c_ref[...])
    best_r_ref[...] = jnp.where(upd, first, best_r_ref[...])
    best_b_ref[...] = jnp.where(upd, bind_at, best_b_ref[...])


def _bucket(n: int, block: int) -> int:
    """Padded extent for ``n``: the next power of two (>= 8) below ``block``,
    a multiple of ``block`` above it.  Buckets the kernel's trace shapes so
    layers/queries of nearby sizes share one compiled program instead of
    retracing per exact tile shape (padding is inert: ``valid`` is 0 there)."""
    if n >= block:
        return n + (-n) % block
    p = 8
    while p < n:
        p *= 2
    return p


def _pad3(x, rp, cp, dtype):
    out = np.zeros((x.shape[0], rp, cp), dtype)
    out[:, :x.shape[1], :x.shape[2]] = x
    return out


def _pad2(x, cp, dtype):
    out = np.zeros((x.shape[0], cp), dtype)
    out[:, :x.shape[1]] = x
    return out


@functools.lru_cache(maxsize=64)
def _jitted(params: tuple, interpret: bool):
    from repro.core.cost import CostModel

    iw, tw, rc, bb = params
    cm = CostModel(intermediate_weight=iw, transfer_weight=tw,
                   request_cost=rc, bind_batch=bb)

    @jax.jit
    def call(cost_a, cost_b, card_a, n_src_b, src_w_b, bindable, valid,
             card_s):
        B, R_p, C_p = cost_a.shape          # pre-padded to bucketed extents
        br, bc = min(BLOCK_R, R_p), min(BLOCK_C, C_p)
        grid = (B, C_p // bc, R_p // br)
        pair_spec = pl.BlockSpec((1, br, bc), lambda b, c, r: (b, r, c))
        col_spec = pl.BlockSpec((1, bc), lambda b, c, r: (b, c))
        return pl.pallas_call(
            functools.partial(_kernel, cm=cm, block_r=br),
            grid=grid,
            in_specs=[pair_spec] * 6
            + [pl.BlockSpec((br, bc), lambda b, c, r: (r, c)), col_spec],
            out_specs=[col_spec, col_spec, col_spec],
            out_shape=[jax.ShapeDtypeStruct((B, C_p), jnp.float64),
                       jax.ShapeDtypeStruct((B, C_p), jnp.int32),
                       jax.ShapeDtypeStruct((B, C_p), jnp.int32)],
            interpret=interpret,
        )(cost_a, cost_b, card_a, n_src_b, src_w_b, bindable, valid, card_s)

    return call


def dp_layer_program(params: tuple, interpret: bool = True):
    """The jitted device-level entry: expects pre-padded arrays whose row /
    column extents are block multiples (see ``_bucket``), ``float64`` pair
    state and ``int8`` masks, and returns the raw padded outputs.  This is
    what ``dp_layer`` calls after host-side padding; run it under
    ``jax.experimental.enable_x64``.  Benchmarks time this directly so the
    Pallas side is a jitted call on device arrays exactly like the jitted
    oracle — not the host wrapper with its per-call padding copies."""
    return _jitted(tuple(float(p) for p in params), bool(interpret))


def dp_layer(cost_a, cost_b, card_a, n_src_b, src_w_b, bindable, valid,
             card_s, params: tuple, interpret: bool = True):
    """Price one dense layer tile and reduce it per column.

    Inputs are the per-pair gathers described in ``ref.dp_layer_ref`` (same
    shapes, same semantics); ``params`` is the cost model's
    ``(intermediate_weight, transfer_weight, request_cost, bind_batch)``.
    Returns numpy ``(best_cost (B, C) float64, first_row (B, C) int32,
    is_bind (B, C) bool)`` with the numpy sweep's exact tie-breaking.

    Row/column extents are padded host-side to bucketed trace shapes
    (powers of two below a block, block multiples above) so nearby tile
    sizes share one compiled program; padding carries ``valid = 0`` and is
    invisible in the outputs."""
    B, R, C = np.shape(cost_a)
    R_p, C_p = _bucket(R, BLOCK_R), _bucket(C, BLOCK_C)
    f64 = np.float64
    with enable_x64():
        call = dp_layer_program(params, interpret)
        valid_p = np.zeros((R_p, C_p), np.int8)
        valid_p[:R, :C] = valid
        best, row, bind = call(
            _pad3(cost_a, R_p, C_p, f64), _pad3(cost_b, R_p, C_p, f64),
            _pad3(card_a, R_p, C_p, f64), _pad3(n_src_b, R_p, C_p, f64),
            _pad3(src_w_b, R_p, C_p, f64), _pad3(bindable, R_p, C_p, np.int8),
            valid_p, _pad2(card_s, C_p, f64))
        return (np.asarray(best)[:, :C], np.asarray(row)[:, :C],
                np.asarray(bind)[:, :C].astype(bool))
