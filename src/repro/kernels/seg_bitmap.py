"""Per-entity predicate bitmaps via one-hot MXU matmul.

CS computation needs, per subject segment, the OR of its predicates' bucket
bits. A CUDA port would use atomics or a segmented scan; the TPU-native
formulation is a *blocked matmul*: with S segment one-hots (BN × BS) and
predicate-bucket one-hots (BN × NBUCKETS),

    bitmap[BS, NBUCKETS] += seg_onehotᵀ @ bucket_onehot

runs on the MXU (128-aligned on both output dims) and the >0 threshold
recovers the OR. Row padding uses segment id -1, which one-hot-encodes to
zero rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256       # rows (s,p) per step
BLOCK_S = 128       # segments per output tile
NBUCKETS = 128      # predicate hash buckets (one MXU lane tile)


def _kernel(seg_ref, bkt_ref, out_ref):
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]                                   # (BLOCK_N, 1) int32
    bkt = bkt_ref[...]                                   # (BLOCK_N, 1) int32
    s0 = pl.program_id(0) * BLOCK_S
    seg_iota = jax.lax.broadcasted_iota(jnp.int32, (BLOCK_N, BLOCK_S), 1) + s0
    seg_oh = (seg == seg_iota).astype(jnp.float32)       # (BLOCK_N, BLOCK_S)
    bkt_iota = jax.lax.broadcasted_iota(jnp.int32, (BLOCK_N, NBUCKETS), 1)
    bkt_oh = (bkt == bkt_iota).astype(jnp.float32)       # (BLOCK_N, NBUCKETS)
    out_ref[...] += jax.lax.dot_general(
        seg_oh, bkt_oh, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def seg_bitmap(seg: jax.Array, bucket: jax.Array, n_seg: int,
               interpret: bool = True) -> jax.Array:
    """seg: (N,) sorted int32 segment ids (pad -1); bucket: (N,) int32 in
    [0, NBUCKETS). Returns (n_seg, NBUCKETS) float32 *counts* per (segment,
    bucket); callers binarize for the OR semantics."""
    n = seg.shape[0]
    assert n % BLOCK_N == 0 and n_seg % BLOCK_S == 0
    grid = (n_seg // BLOCK_S, n // BLOCK_N)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, 1), lambda s, n: (n, 0)),
            pl.BlockSpec((BLOCK_N, 1), lambda s, n: (n, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_S, NBUCKETS), lambda s, n: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((n_seg, NBUCKETS), jnp.float32),
        interpret=interpret,
    )(seg.reshape(-1, 1), bucket.reshape(-1, 1))
