"""Batched bitset AND + popcount between entity-summary signatures.

Candidate federated-CP generation intersects every object-signature row of
one source with every subject-signature row of another (per authority). The
kernel computes the full (nA, nB) popcount matrix tile-by-tile; bit counting
uses the SWAR popcount on int32 words (logical shifts via lax) — pure VPU
work, W-axis innermost so each tile reuses its signature block from VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_A = 128
BLOCK_B = 128
BLOCK_W = 8        # signature words per step


def _popcount32(v: jax.Array) -> jax.Array:
    s = jax.lax.shift_right_logical
    v = v - (s(v, 1) & 0x55555555)
    v = (v & 0x33333333) + (s(v, 2) & 0x33333333)
    v = (v + s(v, 4)) & 0x0F0F0F0F
    return s(v * 0x01010101, 24)


def _kernel(a_ref, b_ref, out_ref):
    w = pl.program_id(2)

    @pl.when(w == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]                       # (BLOCK_A, BLOCK_W) int32 words
    b = b_ref[...]                       # (BLOCK_B, BLOCK_W)
    acc = jnp.zeros((BLOCK_A, BLOCK_B), jnp.int32)
    for k in range(BLOCK_W):             # unrolled: VREG-resident columns
        acc += _popcount32(a[:, k:k + 1] & b[:, k:k + 1].T)
    out_ref[...] += acc


def summary_probe(a_sig: jax.Array, b_sig: jax.Array, interpret: bool = True) -> jax.Array:
    """a_sig: (nA, W) int32 words; b_sig: (nB, W). Returns (nA, nB) int32
    popcount of the pairwise AND (0 ⇔ definitely-no-overlap)."""
    na, w = a_sig.shape
    nb, w2 = b_sig.shape
    assert w == w2 and na % BLOCK_A == 0 and nb % BLOCK_B == 0 and w % BLOCK_W == 0
    grid = (na // BLOCK_A, nb // BLOCK_B, w // BLOCK_W)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_A, BLOCK_W), lambda i, j, w: (i, w)),
            pl.BlockSpec((BLOCK_B, BLOCK_W), lambda i, j, w: (j, w)),
        ],
        out_specs=pl.BlockSpec((BLOCK_A, BLOCK_B), lambda i, j, w: (i, j)),
        out_shape=jax.ShapeDtypeStruct((na, nb), jnp.int32),
        interpret=interpret,
    )(a_sig, b_sig)
