"""Pallas TPU kernels for the statistics/engine hot spots.

Each kernel has a pure-jnp oracle in ``ref.py`` and a jit'd public wrapper in
``ops.py``. All kernels are validated in ``interpret=True`` mode on CPU and
written with MXU/VPU-aligned block shapes for TPU as the target:

  * ``sorted_intersect`` -- multiplicity-weighted intersection count of sorted
    id lists (Algorithm 1's inner loop);
  * ``seg_bitmap``      -- per-entity predicate bitmaps as a one-hot MXU
    matmul (CS computation's segmented OR, re-thought for the MXU);
  * ``join_count``      -- per-probe-row match counts against a sorted build
    side (bounded-buffer join sizing in the distributed engine);
  * ``summary_probe``   -- batched bitset AND + popcount between entity
    summaries (candidate federated-CP pruning);
  * ``dp_layer``        -- the join-order DP's per-layer candidate pricing +
    first-strict-minimum reduction, gridded over (member, column tile, row
    tile); float64, bit-identical to the numpy sweep (``dp_backend='jax'``).
"""
