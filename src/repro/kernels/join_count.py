"""Per-probe-row match counts against a sorted build side.

The distributed engine's bounded-buffer joins need, for every probe key, the
number of matching build rows (to size output offsets before materializing).
Same tiled all-pairs-equality pattern as ``sorted_intersect`` but reducing
over the build axis only, producing an (N_probe,) count vector.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_P = 256
BLOCK_B = 256


def _kernel(p_ref, b_ref, bw_ref, out_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = p_ref[...]              # (BLOCK_P, 1)
    b = b_ref[...]              # (1, BLOCK_B)
    bw = bw_ref[...]            # (1, BLOCK_B)
    eq = p == b                 # (BLOCK_P, BLOCK_B)
    out_ref[...] += jnp.sum(jnp.where(eq, bw, 0), axis=1, keepdims=True).astype(jnp.int32)


def join_count(probe: jax.Array, build: jax.Array, build_w: jax.Array,
               interpret: bool = True) -> jax.Array:
    """probe: (NP,) int32; build: (NB,) sorted int32 (pad with weight 0).
    Returns (NP,) int32 match multiplicities."""
    np_, nb = probe.shape[0], build.shape[0]
    assert np_ % BLOCK_P == 0 and nb % BLOCK_B == 0
    grid = (np_ // BLOCK_P, nb // BLOCK_B)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_P, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, BLOCK_B), lambda i, j: (0, j)),
            pl.BlockSpec((1, BLOCK_B), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_P, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.int32),
        interpret=interpret,
    )(probe.reshape(-1, 1), build.reshape(1, -1), build_w.reshape(1, -1))
    return out[:, 0]
