"""Pure-jnp oracles for every kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sorted_intersect_weighted_ref(a, aw, b, bw) -> jax.Array:
    eq = a[:, None] == b[None, :]
    return jnp.sum(jnp.where(eq, aw[:, None] * bw[None, :], 0), dtype=jnp.int32)


def seg_bitmap_ref(seg, bucket, n_seg, n_buckets=128) -> jax.Array:
    """(n_seg, n_buckets) float32 counts of (segment, bucket) pairs."""
    valid = seg >= 0
    seg_oh = (seg[:, None] == jnp.arange(n_seg)[None, :]) & valid[:, None]
    bkt_oh = bucket[:, None] == jnp.arange(n_buckets)[None, :]
    return (seg_oh.astype(jnp.float32).T @ bkt_oh.astype(jnp.float32))


def join_count_ref(probe, build, build_w) -> jax.Array:
    eq = probe[:, None] == build[None, :]
    return jnp.sum(jnp.where(eq, build_w[None, :], 0), axis=1).astype(jnp.int32)


def popcount32_ref(v) -> jax.Array:
    s = jax.lax.shift_right_logical
    v = v - (s(v, 1) & 0x55555555)
    v = (v & 0x33333333) + (s(v, 2) & 0x33333333)
    v = (v + s(v, 4)) & 0x0F0F0F0F
    return s(v * 0x01010101, 24)


def summary_probe_ref(a_sig, b_sig) -> jax.Array:
    return popcount32_ref(a_sig[:, None, :] & b_sig[None, :, :]).sum(-1).astype(jnp.int32)


def ssm_scan_ref(dt, bt, ct, x, a) -> jax.Array:
    """Selective-scan oracle via associative scan (models/mamba.py math)."""
    dA = jnp.exp(dt[..., None] * a)                          # (B,S,D,N)
    dBx = (dt * x)[..., None] * bt[:, :, None, :]

    def combine(l, r):
        (a1, b1), (a2, b2) = l, r
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    return jnp.einsum("bsdn,bsn->bsd", h, ct)
