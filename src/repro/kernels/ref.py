"""Pure-jnp oracles for every kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sorted_intersect_weighted_ref(a, aw, b, bw) -> jax.Array:
    eq = a[:, None] == b[None, :]
    return jnp.sum(jnp.where(eq, aw[:, None] * bw[None, :], 0), dtype=jnp.int32)


def seg_bitmap_ref(seg, bucket, n_seg, n_buckets=128) -> jax.Array:
    """(n_seg, n_buckets) float32 counts of (segment, bucket) pairs."""
    valid = seg >= 0
    seg_oh = (seg[:, None] == jnp.arange(n_seg)[None, :]) & valid[:, None]
    bkt_oh = bucket[:, None] == jnp.arange(n_buckets)[None, :]
    return (seg_oh.astype(jnp.float32).T @ bkt_oh.astype(jnp.float32))


def join_count_ref(probe, build, build_w) -> jax.Array:
    eq = probe[:, None] == build[None, :]
    return jnp.sum(jnp.where(eq, build_w[None, :], 0), axis=1).astype(jnp.int32)


def popcount32_ref(v) -> jax.Array:
    s = jax.lax.shift_right_logical
    v = v - (s(v, 1) & 0x55555555)
    v = (v & 0x33333333) + (s(v, 2) & 0x33333333)
    v = (v + s(v, 4)) & 0x0F0F0F0F
    return s(v * 0x01010101, 24)


def summary_probe_ref(a_sig, b_sig) -> jax.Array:
    return popcount32_ref(a_sig[:, None, :] & b_sig[None, :, :]).sum(-1).astype(jnp.int32)


def dp_layer_ref(cost_a, cost_b, card_a, n_src_b, src_w_b, bindable, valid,
                 card_s, params) -> "tuple[jax.Array, jax.Array, jax.Array]":
    """Oracle for ``kernels/dp_layer.py``: dense candidate pricing + the
    per-column first-strict-minimum reduction of the join-order DP's layer
    sweep.

    ``cost_a``/``cost_b``/``card_a``/``n_src_b``/``src_w_b`` are ``(B, R, C)``
    float64 per-pair gathers (member, relative submask row, connected-subset
    column), ``bindable`` is ``(B, R, C)`` bool, ``valid`` is the
    member-independent ``(R, C)`` connectivity mask, ``card_s`` is the
    ``(B, C)`` per-subset cardinality (the hash-join cost is derived from it
    in place, as the kernel does), and ``params = (intermediate_weight,
    transfer_weight, request_cost, bind_batch)``.  Returns per
    ``(member, column)``: the minimum candidate cost (``inf`` when no pair
    is valid), the first row attaining it (rows ascend in the reference
    enumeration order, so first == the numpy DP's first-strict-minimum
    tie-breaking) and whether that candidate is a bind join.  Runs in
    float64 — call under ``jax.experimental.enable_x64``."""
    iw, tw, rc, bb = params
    hash_s = iw * card_s
    hc = (cost_a + cost_b) + hash_s[:, None, :]
    n_req = jnp.maximum(1.0, card_a / bb) * n_src_b
    bc = cost_a + ((rc * n_req + tw * card_s[:, None, :] * src_w_b)
                   + iw * card_s[:, None, :])
    is_bind = bindable & (bc < hc)
    pair = jnp.where(valid[None, :, :], jnp.where(is_bind, bc, hc), jnp.inf)
    best = jnp.min(pair, axis=1)
    rows = jnp.arange(pair.shape[1], dtype=jnp.int32)[None, :, None]
    is_min = valid[None, :, :] & (pair == best[:, None, :])
    first = jnp.min(jnp.where(is_min, rows, jnp.int32(2**31 - 1)), axis=1)
    bind_at = jnp.any(is_min & (rows == first[:, None, :]) & is_bind, axis=1)
    return best, first, bind_at


def dp_sweep_ref(params, pair_a, pair_b, pair_seg, layer_cols, card,
                 excl_cost, excl_w, cost0, n_src0, src_w0):
    """Oracle for ``kernels/dp_layer.dp_sweep_resident``: the whole scanned
    sweep re-evaluated candidate by candidate in scalar form (python loops
    over layers, columns and flat pairs — deliberately nothing shared with
    the scatter/gather program it checks).  Inputs are the program's exactly:
    the ``(L, P)``/``(L, C)`` sentinel-padded schedule, the ``(B, size)``
    cardinality plane, the exclusive-leaf seeds and the singleton seeds.
    Returns ``(cost, strat, split)`` with the program's strategy codes
    (0 never-written, 2 exclusive leaf, 3 hash, 4 bind).  Float64 numpy
    throughout, with the scalar operation order of ``CostModel`` —
    candidates priced in flat-position order, first strict minimum wins,
    the exclusive leaf is candidate 0."""
    import numpy as np

    iw, tw, rc, bb = [float(v) for v in params]
    cost = np.array(cost0, dtype=np.float64)
    n_src = np.array(n_src0, dtype=np.float64)
    src_w = np.array(src_w0, dtype=np.float64)
    B, size = cost.shape
    strat = np.zeros((B, size), np.int32)
    split = np.zeros((B, size), np.int32)
    C = layer_cols.shape[1]
    for li in range(pair_a.shape[0]):
        for ci in range(C):
            S = int(layer_cols[li, ci])
            if S >= size:                      # padded column
                continue
            flat = np.nonzero(pair_seg[li] == ci)[0]
            for b in range(B):
                best, b_split, b_bind = np.inf, 0, False
                for p in flat:
                    am, bm = int(pair_a[li, p]), int(pair_b[li, p])
                    hc = (cost[b, am] + cost[b, bm]) + iw * card[b, S]
                    n_req = max(1.0, card[b, am] / bb) * n_src[b, bm]
                    bc = cost[b, am] + ((rc * n_req
                                         + tw * card[b, S] * src_w[b, bm])
                                        + iw * card[b, S])
                    is_bind = bool(n_src[b, bm] > 0) and bc < hc
                    c = bc if is_bind else hc
                    if c < best:
                        best, b_split, b_bind = c, am, is_bind
                ec = excl_cost[b, S]
                if best < ec:
                    cost[b, S] = best
                    strat[b, S] = 4 if b_bind else 3
                    split[b, S] = b_split
                    n_src[b, S] = 0.0
                    src_w[b, S] = 1.0
                elif np.isfinite(ec):
                    cost[b, S] = ec
                    strat[b, S] = 2
                    n_src[b, S] = 1.0
                    src_w[b, S] = excl_w[b, S]
    return cost, strat, split


def ssm_scan_ref(dt, bt, ct, x, a) -> jax.Array:
    """Selective-scan oracle via associative scan (models/mamba.py math)."""
    dA = jnp.exp(dt[..., None] * a)                          # (B,S,D,N)
    dBx = (dt * x)[..., None] * bt[:, :, None, :]

    def combine(l, r):
        (a1, b1), (a2, b2) = l, r
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    return jnp.einsum("bsdn,bsn->bsd", h, ct)
