"""Chunked selective-scan (Mamba-1) Pallas TPU kernel.

The jnp associative scan materializes (B, S, d_inner, N) state through HBM —
the §Perf falcon-mamba diagnosis. The TPU-native structure mirrors the
chunked jnp path (`models/mamba.py`) but keeps the chunk state in VMEM:

  grid = (B, d_inner/BD, S/CHUNK) with the sequence axis innermost; the
  carry state h (BD, N) lives in VMEM scratch across sequence steps; within
  a chunk the recurrence runs as an unrolled first-order scan over CHUNK
  steps on the VPU (d_inner is the vectorized lane axis, N unrolled).

Inputs are the per-timestep scan parameters (already activated):
  dt (B, S, D), Bt (B, S, N), Ct (B, S, N), x (B, S, D), A (D, N)
Output: y (B, S, D) with y_t = C_t · h_t, h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 64
BLOCK_D = 256


def _kernel(dt_ref, bt_ref, ct_ref, x_ref, a_ref, y_ref, h_ref, *,
            n_state: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    dt = dt_ref[0]            # (chunk, BD)
    x = x_ref[0]              # (chunk, BD)
    a = a_ref[...]            # (BD, n_state)
    bt = bt_ref[0]            # (chunk, n_state)
    ct = ct_ref[0]            # (chunk, n_state)

    dtx = dt * x              # (chunk, BD)
    y = jnp.zeros((chunk, dt.shape[1]), jnp.float32)
    h = h_ref[...]            # (BD, n_state) carry
    for t in range(chunk):    # first-order recurrence, VPU-vectorized over BD
        dA = jnp.exp(dt[t][:, None] * a)                 # (BD, N)
        h = h * dA + dtx[t][:, None] * bt[t][None, :]    # (BD, N)
        y = y.at[t].set(jnp.sum(h * ct[t][None, :], axis=1))
    h_ref[...] = h
    y_ref[0] = y.astype(y_ref.dtype)


def ssm_scan(dt: jax.Array, bt: jax.Array, ct: jax.Array, x: jax.Array,
             a: jax.Array, *, chunk: int = CHUNK, block_d: int = BLOCK_D,
             interpret: bool = True) -> jax.Array:
    """dt, x: (B, S, D) f32; bt, ct: (B, S, N) f32; a: (D, N) f32 (negative).
    Returns y: (B, S, D) f32. S % chunk == 0, D % block_d == 0."""
    B, S, D = x.shape
    N = bt.shape[-1]
    assert S % chunk == 0 and D % block_d == 0
    grid = (B, D // block_d, S // chunk)
    kern = functools.partial(_kernel, n_state=N, chunk=chunk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(dt, bt, ct, x, a)
